from .client import make_local_update, prox_penalty
from .aggregation import aggregate, aggregate_async, staleness_weights
from .round import (
    ServerState,
    init_server_state,
    make_select_fn,
    make_cohort_round,
    make_async_cohort_round,
    make_silo_steps,
)
from .server import FLServer, build_volatility
