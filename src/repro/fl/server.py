"""FL server: the deadline-based round loop (paper §III) around the jitted
round step, plus evaluation, pow-d candidate loss reporting, history capture
and checkpointing.

The loop realises the paper's five stages: (1) client selection + model
distribution (``select`` + data gather), (2) local training, (3) model
transmission, (4) force stop — stages 2-4 collapse into the success-mask
semantics of the jitted round (volatile clients' deltas are masked out, which
*is* the deadline drop) — and (5) aggregation.

With ``staleness_rounds=S > 0`` the loop runs *async* rounds instead: stage 4
no longer discards late-but-alive clients — their deltas (still relative to
the global model they were handed) are held in a pending buffer and added to
the global model when they arrive, decayed by ``staleness_alpha**lag``
(``aggregate_async``).  The selector still sees deadline-based feedback, so
the selection trajectory at S=0 is exactly the synchronous one.

Volatility can be specified three ways (``build_volatility``): a builtin name
(``bernoulli | markov | deadline``), a ``repro.scenarios`` name (diurnal,
regional_outage, flash_crowd, ...), or any ``(init_state, sample)`` model
object passed straight through — so the accuracy tables run under structured
regimes too.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.volatility import make_volatility, paper_success_rates

from .round import ServerState, init_server_state, make_async_cohort_round, make_cohort_round

__all__ = ["FLServer", "build_volatility"]


def build_volatility(fl_cfg: FLConfig, K: int, volatility=None):
    """Resolve the run's volatility spec to ``(vol, rho)``.

    ``volatility`` (or, when omitted, ``fl_cfg.volatility``) may be:

    * a builtin generator name — ``bernoulli | markov | deadline`` — built
      over the paper's class rates (the historical string path);
    * a ``repro.scenarios`` scenario name (e.g. ``diurnal``,
      ``regional_outage``), instantiated at ``(K, fl_cfg.rounds,
      fl_cfg.seed)`` with its own marginal-rate hint;
    * any ``(init_state, sample)`` model object, passed through unchanged
      (``rho`` from its ``rho`` / ``marginal_rate()`` if present, else the
      paper classes).
    """
    spec = fl_cfg.volatility if volatility is None else volatility
    if not isinstance(spec, str):
        vol = spec
        rho = getattr(vol, "rho", None)
        if rho is None and hasattr(vol, "marginal_rate"):
            rho = vol.marginal_rate()
        if rho is None:
            rho = paper_success_rates(K, fl_cfg.success_rates)
        return vol, jnp.asarray(rho, jnp.float32)
    if spec in ("bernoulli", "markov", "deadline"):
        rho = jnp.asarray(paper_success_rates(K, fl_cfg.success_rates))
        vol = make_volatility(
            spec,
            rho,
            stickiness=fl_cfg.markov_stickiness,
            seed=fl_cfg.seed,
            epochs_choices=fl_cfg.local_epochs,
        )
        return vol, rho
    from repro.scenarios import make_scenario  # deferred: scenarios imports the engine

    try:
        vol, rho = make_scenario(spec, K, fl_cfg.rounds, seed=fl_cfg.seed)
    except KeyError as e:
        raise ValueError(
            f"unknown volatility {spec!r}: not a builtin (bernoulli | markov | deadline) "
            f"and not a repro.scenarios name ({e})"
        ) from None
    return vol, jnp.asarray(rho, jnp.float32)


class FLServer:
    """Runs paper-scale FL (CNN / small-LM workloads, cohort mapping).

    ``volatility`` overrides ``fl_cfg.volatility`` with a scenario name or a
    model object (see ``build_volatility``).
    """

    def __init__(self, model, fl_cfg: FLConfig, store, eval_fn=None, spmd_axes=None, volatility=None):
        from repro.engine.round_program import RoundProgram  # deferred: the engine imports fl.round

        self.model = model
        self.cfg = fl_cfg
        self.store = store
        # ONE knob-resolution path: volatility spec, staleness wrapping and
        # quota schedule all come from the engine's RoundProgram, so the
        # training loop and the serving drivers cannot drift apart
        # (pinned in tests/test_round_program.py).
        self.program = RoundProgram.from_config(fl_cfg, volatility=volatility)
        self.quota = self.program.quota_fn
        self.vol, self.rho = self.program.base_vol, self.program.rho
        self.staleness = 0 if self.program.staleness is None else int(self.program.staleness)
        self.lag_model = self.program.lag_model
        select = self.program.select_fn()
        if self.staleness > 0:
            _, round_fn = make_async_cohort_round(
                model, fl_cfg, self.quota, self.lag_model, self.rho, spmd_axes, select=select
            )
        else:
            _, round_fn = make_cohort_round(
                model, fl_cfg, self.quota, self.vol, self.rho, spmd_axes, select=select
            )
        self._select = jax.jit(select)
        self._round = jax.jit(round_fn)
        self._apply_delta = jax.jit(
            lambda params, delta: jax.tree.map(
                lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype), params, delta
            )
        )
        self._eval_fn = eval_fn
        rng = np.random.default_rng(fl_cfg.seed)
        self.epochs = rng.choice(fl_cfg.local_epochs, fl_cfg.K).astype(np.int32)
        # static per-round step budget so the jitted round compiles once
        spe = max(1, int(max(store.sizes())) // fl_cfg.batch_size)
        self.n_steps = int(max(fl_cfg.local_epochs)) * spe
        self._cand_loss = jax.jit(
            lambda params, batch: jax.vmap(lambda b: model.loss(params, b)[0])(batch)
        )

    def init_state(self, rng) -> ServerState:
        params, _ = self.model.init(rng)
        vol_state = self.lag_model.init_state() if self.lag_model is not None else self.vol.init_state()
        return init_server_state(params, self.cfg.K, vol_state)

    def _report_candidate_losses(self, state: ServerState, rng):
        """pow-d stage: d uniform candidates report loss on the global model."""
        d = self.cfg.pow_d
        cand = np.asarray(jax.random.permutation(rng, self.cfg.K))[:d]
        xb, yb, _ = self.store.round_batches(cand, np.ones(self.cfg.K, np.int32), self.cfg.batch_size)
        batch = {"x": jnp.asarray(xb[:, 0]), "y": jnp.asarray(yb[:, 0])}
        losses = self._cand_loss(state.params, batch)
        cache = np.asarray(state.loss_cache)
        cache[cand] = np.asarray(losses)
        return state._replace(loss_cache=jnp.asarray(cache))

    def run(self, state: ServerState, rounds: Optional[int] = None, eval_every: int = 10, log_every: int = 50):
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        history: Dict[str, List] = {"round": [], "acc": [], "loss": [], "cep": [], "succ_ratio": []}
        key = jax.random.PRNGKey(cfg.seed + 1)
        total_q = float(self.store.sizes().sum())
        pending: Dict[int, List] = {}  # arrival round -> [late delta trees]
        n_late_total = 0.0
        for t in range(rounds):
            # async: stale updates scheduled for this round land first
            for delta in pending.pop(t, []):
                state = state._replace(params=self._apply_delta(state.params, delta))
            key, k_sel, k_round, k_cand = jax.random.split(key, 4)
            if cfg.scheme == "pow_d":
                state = self._report_candidate_losses(state, k_cand)
            idx, p, capped, sigma = self._select(state, k_sel)
            idx_np = np.asarray(idx)
            xb, yb, mask = self.store.round_batches(idx_np, self.epochs, cfg.batch_size, self.n_steps)
            batches = {"x": jnp.asarray(xb), "y": jnp.asarray(yb)}
            round_args = (
                state,
                idx,
                p,
                capped,
                sigma,
                batches,
                jnp.asarray(mask),
                jnp.asarray(self.store.sizes()[idx_np]),
                jnp.asarray(total_q, jnp.float32),
                jnp.asarray(self.epochs[idx_np], jnp.float32),
                k_round,
            )
            if self.staleness > 0:
                state, metrics, late_deltas = self._round(*round_args)
                n_late_total += float(metrics["n_late"])
                for s in range(self.staleness):
                    pending.setdefault(t + s + 1, []).append(
                        jax.tree.map(lambda a, s=s: a[s], late_deltas)
                    )
            else:
                state, metrics = self._round(*round_args)
            if self._eval_fn is not None and ((t + 1) % eval_every == 0 or t == rounds - 1):
                acc, loss = self._eval_fn(state.params)
                history["round"].append(t + 1)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["cep"].append(float(state.cep))
                history["succ_ratio"].append(float(state.cep) / ((t + 1) * cfg.k))
        if self.staleness > 0:
            history["n_late"] = n_late_total
        return state, history
