"""FL server: the deadline-based round loop (paper §III) around the jitted
round step, plus evaluation, pow-d candidate loss reporting, history capture
and checkpointing.

The loop realises the paper's five stages: (1) client selection + model
distribution (``select`` + data gather), (2) local training, (3) model
transmission, (4) force stop — stages 2-4 collapse into the success-mask
semantics of the jitted round (volatile clients' deltas are masked out, which
*is* the deadline drop) — and (5) aggregation.
"""
from __future__ import annotations

import time
from dataclasses import asdict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.selection import make_quota_schedule
from repro.core.volatility import make_volatility, paper_success_rates

from .round import ServerState, init_server_state, make_cohort_round

__all__ = ["FLServer", "build_volatility"]


def build_volatility(fl_cfg: FLConfig, K: int):
    rho = jnp.asarray(paper_success_rates(K, fl_cfg.success_rates))
    vol = make_volatility(
        fl_cfg.volatility,
        rho,
        stickiness=fl_cfg.markov_stickiness,
        seed=fl_cfg.seed,
        epochs_choices=fl_cfg.local_epochs,
    )
    return vol, rho


class FLServer:
    """Runs paper-scale FL (CNN / small-LM workloads, cohort mapping)."""

    def __init__(self, model, fl_cfg: FLConfig, store, eval_fn=None, spmd_axes=None):
        self.model = model
        self.cfg = fl_cfg
        self.store = store
        self.quota = make_quota_schedule(fl_cfg.quota, fl_cfg.k, fl_cfg.K, fl_cfg.rounds, fl_cfg.quota_frac)
        self.vol, self.rho = build_volatility(fl_cfg, fl_cfg.K)
        select, round_fn = make_cohort_round(model, fl_cfg, self.quota, self.vol, self.rho, spmd_axes)
        self._select = jax.jit(select)
        self._round = jax.jit(round_fn)
        self._eval_fn = eval_fn
        rng = np.random.default_rng(fl_cfg.seed)
        self.epochs = rng.choice(fl_cfg.local_epochs, fl_cfg.K).astype(np.int32)
        # static per-round step budget so the jitted round compiles once
        spe = max(1, int(max(store.sizes())) // fl_cfg.batch_size)
        self.n_steps = int(max(fl_cfg.local_epochs)) * spe
        self._cand_loss = jax.jit(
            lambda params, batch: jax.vmap(lambda b: model.loss(params, b)[0])(batch)
        )

    def init_state(self, rng) -> ServerState:
        params, _ = self.model.init(rng)
        return init_server_state(params, self.cfg.K, self.vol.init_state())

    def _report_candidate_losses(self, state: ServerState, rng):
        """pow-d stage: d uniform candidates report loss on the global model."""
        d = self.cfg.pow_d
        cand = np.asarray(jax.random.permutation(rng, self.cfg.K))[:d]
        xb, yb, _ = self.store.round_batches(cand, np.ones(self.cfg.K, np.int32), self.cfg.batch_size)
        batch = {"x": jnp.asarray(xb[:, 0]), "y": jnp.asarray(yb[:, 0])}
        losses = self._cand_loss(state.params, batch)
        cache = np.asarray(state.loss_cache)
        cache[cand] = np.asarray(losses)
        return state._replace(loss_cache=jnp.asarray(cache))

    def run(self, state: ServerState, rounds: Optional[int] = None, eval_every: int = 10, log_every: int = 50):
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        history: Dict[str, List] = {"round": [], "acc": [], "loss": [], "cep": [], "succ_ratio": []}
        key = jax.random.PRNGKey(cfg.seed + 1)
        total_q = float(self.store.sizes().sum())
        for t in range(rounds):
            key, k_sel, k_round, k_cand = jax.random.split(key, 4)
            if cfg.scheme == "pow_d":
                state = self._report_candidate_losses(state, k_cand)
            idx, p, capped, sigma = self._select(state, k_sel)
            idx_np = np.asarray(idx)
            xb, yb, mask = self.store.round_batches(idx_np, self.epochs, cfg.batch_size, self.n_steps)
            batches = {"x": jnp.asarray(xb), "y": jnp.asarray(yb)}
            state, metrics = self._round(
                state,
                idx,
                p,
                capped,
                sigma,
                batches,
                jnp.asarray(mask),
                jnp.asarray(self.store.sizes()[idx_np]),
                jnp.asarray(total_q, jnp.float32),
                jnp.asarray(self.epochs[idx_np], jnp.float32),
                k_round,
            )
            if self._eval_fn is not None and ((t + 1) % eval_every == 0 or t == rounds - 1):
                acc, loss = self._eval_fn(state.params)
                history["round"].append(t + 1)
                history["acc"].append(float(acc))
                history["loss"].append(float(loss))
                history["cep"].append(float(state.cep))
                history["succ_ratio"].append(float(state.cep) / ((t + 1) * cfg.k))
        return state, history
