"""Local update operators o1 (paper P1): FedAvg SGD and FedProx.

``make_local_update`` builds a pure function

    local_train(global_params, batches, step_mask, rng) -> (local_params, stats)

that runs ``n_steps`` of SGD over pre-gathered mini-batches
(``batches[name]: (n_steps, B, ...)``), skipping masked steps (heterogeneous
epoch counts — paper §VI-A).  FedProx adds the proximal term
``gamma/2 * ||theta - theta_global||^2`` to every step's loss (Li et al.).

The function is vmapped across the cohort by ``repro.fl.round`` — on a mesh,
with ``spmd_axis_name`` so each mesh data-slice trains its own client.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

__all__ = ["make_local_update", "prox_penalty"]


def prox_penalty(params, global_params) -> jax.Array:
    sq = jax.tree.map(lambda a, b: jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32))), params, global_params)
    return jax.tree.reduce(jnp.add, sq)


def make_local_update(model, opt, update_kind: str = "fedavg", prox_coef: float = 0.5) -> Callable:
    def loss_fn(params, batch, global_params, rng):
        loss, metrics = model.loss(params, batch, rng)
        if update_kind == "fedprox":
            loss = loss + 0.5 * prox_coef * prox_penalty(params, global_params)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local_train(global_params, batches: Dict[str, jax.Array], step_mask: jax.Array, rng: jax.Array):
        opt_state = opt.init(global_params)

        def step(carry, inp):
            params, opt_state, i = carry
            batch, m = inp
            (loss, _), grads = grad_fn(params, batch, global_params, jax.random.fold_in(rng, i))
            new_params, new_opt = opt.update(params, grads, opt_state, i)
            # masked step: heterogeneous local epochs — skipped steps are no-ops
            keep = m.astype(jnp.float32)
            params = jax.tree.map(lambda n, o: (keep * n.astype(jnp.float32) + (1 - keep) * o.astype(jnp.float32)).astype(o.dtype), new_params, params)
            opt_state = jax.tree.map(lambda n, o: (keep * n.astype(jnp.float32) + (1 - keep) * o.astype(jnp.float32)).astype(o.dtype), new_opt, opt_state)
            return (params, opt_state, i + 1), loss * keep

        (params, _, _), losses = jax.lax.scan(
            step, (global_params, opt_state, jnp.zeros((), jnp.int32)), (batches, step_mask)
        )
        n_eff = jnp.maximum(jnp.sum(step_mask), 1.0)
        return params, {"local_loss": jnp.sum(losses) / n_eff}

    return local_train
