"""Jitted FL round steps.

Two builders per DESIGN.md §3:

* ``make_cohort_round`` — the full paper round in one jitted program:
  ProbAlloc -> stochastic selection -> vmapped local training of the cohort
  (one mesh data-slice per client when ``spmd_axes`` is given) -> volatile
  success bits -> masked deadline aggregation -> E3CS weight update.
  The selection math runs over all K (replicated scalars) so the technique is
  part of the compiled program.

* ``make_silo_steps`` — for huge architectures: one client trains at a time
  on the entire mesh (FSDP+TP); returns (local_step, agg_step) jitted pieces
  the server loop time-multiplexes across the cohort.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.selection import E3CSState, e3cs_init, e3cs_probs, e3cs_update, fedcs_select, random_select, sample_selection, selection_mask, ucb_init, ucb_select, ucb_update
from repro.obs.trace import stage
from repro.optim import sgd

from .aggregation import aggregate, aggregate_async
from .client import make_local_update

__all__ = [
    "ServerState",
    "init_server_state",
    "make_select_fn",
    "make_cohort_round",
    "make_async_cohort_round",
    "make_silo_steps",
]


class ServerState(NamedTuple):
    params: object
    e3cs: E3CSState
    ucb: object
    loss_cache: jax.Array  # (K,) pow-d loss estimates
    vol_state: jax.Array
    t: jax.Array
    sel_counts: jax.Array  # (K,)
    cep: jax.Array  # scalar
    succ_hist: jax.Array  # scalar successes observed (for metrics)


def init_server_state(params, K: int, vol_state) -> ServerState:
    return ServerState(
        params=params,
        e3cs=e3cs_init(K),
        ucb=ucb_init(K),
        loss_cache=jnp.full((K,), 1e9, jnp.float32),  # unexplored => very lossy
        vol_state=vol_state,
        t=jnp.zeros((), jnp.int32),
        sel_counts=jnp.zeros((K,), jnp.float32),
        cep=jnp.zeros((), jnp.float32),
        succ_hist=jnp.zeros((), jnp.float32),
    )


def make_select_fn(fl_cfg, quota_fn, rho=None):
    """Returns jitted select(state, rng) -> (idx, p, capped, sigma)."""
    K, k = fl_cfg.K, fl_cfg.k

    allocator = getattr(fl_cfg, "allocator", "sort")
    if allocator not in ("sort", "bisect"):
        raise ValueError(f"unknown allocator {allocator!r} (want 'sort' or 'bisect')")

    def select(state: ServerState, rng: jax.Array):
        sigma = quota_fn(state.t)
        if fl_cfg.scheme == "e3cs":
            with stage("round.allocate"):
                if allocator == "bisect":
                    # sort-free fixed point (the shardable engine allocator);
                    # lazy import — repro.engine depends on this module
                    from repro.engine.sharded import masked_prob_alloc

                    w = jnp.exp(state.e3cs.logw - jnp.max(state.e3cs.logw))
                    p, capped = masked_prob_alloc(w, k, sigma)
                else:
                    p, capped = e3cs_probs(state.e3cs, k, sigma)
            with stage("round.sample"):
                idx = sample_selection(rng, p, k, fl_cfg.sampler)
        elif fl_cfg.scheme == "random":
            idx = random_select(rng, K, k)
            p = jnp.full((K,), k / K)
            capped = jnp.zeros((K,), bool)
        elif fl_cfg.scheme == "fedcs":
            idx = fedcs_select(jnp.asarray(rho), k, rng)
            p = selection_mask(idx, K)
            capped = jnp.zeros((K,), bool)
        elif fl_cfg.scheme == "ucb":
            idx = ucb_select(state.ucb, k)
            p = selection_mask(idx, K)
            capped = jnp.zeros((K,), bool)
        elif fl_cfg.scheme == "pow_d":
            from repro.core.selection import pow_d_select

            idx = pow_d_select(rng, state.loss_cache, k, fl_cfg.pow_d)
            p = selection_mask(idx, K)
            capped = jnp.zeros((K,), bool)
        else:
            raise ValueError(fl_cfg.scheme)
        return idx, p, capped, sigma

    return select


def _selector_update(state: ServerState, fl_cfg, idx, p, capped, mask, x_full, sigma, local_losses):
    new_e3cs = state.e3cs
    new_ucb = state.ucb
    if fl_cfg.scheme == "e3cs":
        new_e3cs = e3cs_update(state.e3cs, p, capped, mask, x_full, fl_cfg.k, sigma, fl_cfg.eta)
    elif fl_cfg.scheme == "ucb":
        new_ucb = ucb_update(state.ucb, idx, x_full)
    # participating successful clients refresh the pow-d loss cache
    loss_cache = state.loss_cache
    upd = jnp.zeros_like(loss_cache).at[idx].set(local_losses)
    got = jnp.zeros_like(loss_cache).at[idx].set(x_full[idx])
    loss_cache = jnp.where(got > 0, upd, loss_cache)
    return new_e3cs, new_ucb, loss_cache


def make_cohort_round(
    model,
    fl_cfg,
    quota_fn,
    volatility,
    rho=None,
    spmd_axes=None,
    aggregation: Optional[str] = None,
    donate: bool = True,
    select=None,
):
    """Full jitted round. Returns ``round_fn(state, idx, p, capped, sigma,
    batches, step_mask, data_sizes, epochs, rng) -> (state, metrics)`` plus
    the ``select`` fn (host calls select first to gather the cohort's data).
    ``select`` overrides the allocate+select stage — ``FLServer`` passes
    ``RoundProgram.select_fn()`` so the training loop shares the engine's
    knob resolution; the default builds the identical fn from the raw config.
    """
    opt = sgd(fl_cfg.lr, fl_cfg.momentum)
    local = make_local_update(model, opt, fl_cfg.local_update, fl_cfg.prox_coef)
    vlocal = jax.vmap(local, in_axes=(None, 0, 0, 0), spmd_axis_name=spmd_axes)
    agg_scheme = aggregation or fl_cfg.aggregation
    select = select if select is not None else make_select_fn(fl_cfg, quota_fn, rho)

    def round_fn(state: ServerState, idx, p, capped, sigma, batches, step_mask, data_sizes, total_data, epochs, rng):
        K = fl_cfg.K
        r_vol, r_local = jax.random.split(jax.random.fold_in(rng, 1))
        x_full, vol_state = volatility.sample(r_vol, state.vol_state)  # (K,)
        mask = selection_mask(idx, K)
        success = x_full[idx]

        cohort_params, stats = vlocal(state.params, batches, step_mask, jax.random.split(r_local, fl_cfg.k))
        new_params = aggregate(
            state.params,
            cohort_params,
            success,
            data_sizes,
            total_data,
            K,
            agg_scheme,
            epochs=epochs,
            sel_probs=p[idx],
        )
        new_e3cs, new_ucb, loss_cache = _selector_update(
            state, fl_cfg, idx, p, capped, mask, x_full, sigma, stats["local_loss"]
        )
        n_succ = jnp.sum(success)
        metrics = {
            "cep": state.cep + n_succ,
            "n_success": n_succ,
            "mean_local_loss": jnp.mean(stats["local_loss"]),
            "sigma": sigma,
        }
        new_state = ServerState(
            params=new_params,
            e3cs=new_e3cs,
            ucb=new_ucb,
            loss_cache=loss_cache,
            vol_state=vol_state,
            t=state.t + 1,
            sel_counts=state.sel_counts + mask,
            cep=state.cep + n_succ,
            succ_hist=state.succ_hist + n_succ,
        )
        return new_state, metrics

    return select, round_fn


def make_async_cohort_round(
    model,
    fl_cfg,
    quota_fn,
    lag_model,
    rho=None,
    spmd_axes=None,
    aggregation: Optional[str] = None,
    select=None,
):
    """Staleness-aware variant of ``make_cohort_round``.

    ``lag_model`` draws per-client completion lags (``repro.core.volatility``
    lag protocol: int32, 0 = on time, l>=1 = late, negative = dead).  The
    jitted ``round_fn`` aggregates on-time deltas immediately and returns the
    decayed late contributions as a third output — a pytree with a leading
    ``(S,)`` axis, slice ``s`` due ``s+1`` rounds later — which the host loop
    schedules and applies when they arrive (``FLServer.run``).  The selector
    keeps the paper's deadline-based feedback: it observes the on-time bits
    ``1{lag == 0}``, matching the async scan engine's semantics.
    """
    S = int(fl_cfg.staleness_rounds)
    alpha = float(fl_cfg.staleness_alpha)
    opt = sgd(fl_cfg.lr, fl_cfg.momentum)
    local = make_local_update(model, opt, fl_cfg.local_update, fl_cfg.prox_coef)
    vlocal = jax.vmap(local, in_axes=(None, 0, 0, 0), spmd_axis_name=spmd_axes)
    agg_scheme = aggregation or fl_cfg.aggregation
    select = select if select is not None else make_select_fn(fl_cfg, quota_fn, rho)

    def round_fn(state: ServerState, idx, p, capped, sigma, batches, step_mask, data_sizes, total_data, epochs, rng):
        K = fl_cfg.K
        r_vol, r_local = jax.random.split(jax.random.fold_in(rng, 1))
        lag_full, vol_state = lag_model.sample(r_vol, state.vol_state)  # (K,) int32
        x_full = (lag_full == 0).astype(jnp.float32)  # deadline-based feedback
        mask = selection_mask(idx, K)
        success = x_full[idx]
        lag_sel = lag_full[idx]

        cohort_params, stats = vlocal(state.params, batches, step_mask, jax.random.split(r_local, fl_cfg.k))
        new_params, late_deltas = aggregate_async(
            state.params,
            cohort_params,
            lag_sel,
            data_sizes,
            total_data,
            K,
            agg_scheme,
            alpha=alpha,
            staleness=S,
            epochs=epochs,
            sel_probs=p[idx],
        )
        new_e3cs, new_ucb, loss_cache = _selector_update(
            state, fl_cfg, idx, p, capped, mask, x_full, sigma, stats["local_loss"]
        )
        n_succ = jnp.sum(success)
        n_late = jnp.sum(((lag_sel >= 1) & (lag_sel <= S)).astype(jnp.float32))
        metrics = {
            "cep": state.cep + n_succ,
            "n_success": n_succ,
            "n_late": n_late,
            "mean_local_loss": jnp.mean(stats["local_loss"]),
            "sigma": sigma,
        }
        new_state = ServerState(
            params=new_params,
            e3cs=new_e3cs,
            ucb=new_ucb,
            loss_cache=loss_cache,
            vol_state=vol_state,
            t=state.t + 1,
            sel_counts=state.sel_counts + mask,
            cep=state.cep + n_succ,
            succ_hist=state.succ_hist + n_succ,
        )
        return new_state, metrics, late_deltas

    return select, round_fn


def make_silo_steps(model, fl_cfg):
    """Huge-arch path: one client at a time on the full mesh.

    ``local_step(params, opt_state, batch, step) -> (params, opt_state, loss)``
    ``agg_accum(acc, local, global, w) -> acc``   (delta accumulation)
    ``agg_apply(global, acc) -> new_global``
    """
    opt = sgd(fl_cfg.lr, fl_cfg.momentum)

    def local_step(params, opt_state, batch, step, rng):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch, rng)
        params, opt_state = opt.update(params, grads, opt_state, step)
        return params, opt_state, loss

    def agg_accum(acc, local_params, global_params, w):
        return jax.tree.map(
            lambda a, l, g: a + w * (l.astype(jnp.float32) - g.astype(jnp.float32)), acc, local_params, global_params
        )

    def agg_apply(global_params, acc):
        return jax.tree.map(lambda g, a: (g.astype(jnp.float32) + a).astype(g.dtype), global_params, acc)

    return local_step, opt.init, agg_accum, agg_apply
