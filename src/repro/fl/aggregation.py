"""Aggregation operators o2 under the deadline mechanism (paper P1, Alg. 1 l.9-10).

The paper's volatility constraint substitutes the *global* model for every
client that failed or was not selected:

    theta_{t+1} = sum_i w_i * [mask_i * theta_i + (1-mask_i) * theta_t]
               = theta_t + sum_i w_i * mask_i * (theta_i - theta_t)

so all schemes are implemented in delta form over the cohort only (the K-k
unselected clients contribute zero delta by construction):

* ``mean``           — w_i = 1/K (Alg. 1's plain average).
* ``fedavg``         — w_i = q_i / q (data-size weighted, paper P1).
* ``epoch_weighted`` — w_i ∝ (q_i/q) / E_i (Ruan et al. [11]: fewer-epoch
  clients get up-weighted so they are not overwhelmed).
* ``unbiased``       — w_i = q_i / (q * p_i): inverse-propensity estimator
  (Chen et al. [19]); beyond-paper option that removes selection bias in
  expectation — experiments quantify its variance cost.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["aggregate"]


def aggregate(
    global_params,
    cohort_params,
    success: jax.Array,  # (k,) {0,1}
    data_sizes: jax.Array,  # (k,) q_i of the selected clients
    total_data: jax.Array,  # scalar q
    K: int,
    scheme: str = "fedavg",
    epochs: jax.Array = None,  # (k,) E_i (epoch_weighted)
    sel_probs: jax.Array = None,  # (k,) p_{i,t} (unbiased)
):
    """cohort_params: pytree with leading cohort axis (k, ...)."""
    k = success.shape[0]
    if scheme == "mean":
        w = jnp.full((k,), 1.0 / K)
    elif scheme == "fedavg":
        w = data_sizes / jnp.maximum(total_data, 1e-9)
    elif scheme == "epoch_weighted":
        base = data_sizes / jnp.maximum(total_data, 1e-9)
        inv = 1.0 / jnp.maximum(epochs.astype(jnp.float32), 1.0)
        # renormalise so the cohort's total weight is preserved
        w = base.sum() * (base * inv) / jnp.maximum((base * inv).sum(), 1e-9)
    elif scheme == "unbiased":
        w = data_sizes / jnp.maximum(total_data, 1e-9) / jnp.clip(sel_probs, 1e-3, 1.0)
    else:
        raise ValueError(scheme)
    w = w * success  # failed clients contribute the global model (zero delta)

    def upd(g, c):
        delta = c.astype(jnp.float32) - g.astype(jnp.float32)[None]
        contrib = jnp.tensordot(w, delta, axes=(0, 0))
        return (g.astype(jnp.float32) + contrib).astype(g.dtype)

    return jax.tree.map(upd, global_params, cohort_params)
