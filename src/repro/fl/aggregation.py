"""Aggregation operators o2 under the deadline mechanism (paper P1, Alg. 1 l.9-10).

The paper's volatility constraint substitutes the *global* model for every
client that failed or was not selected:

    theta_{t+1} = sum_i w_i * [mask_i * theta_i + (1-mask_i) * theta_t]
               = theta_t + sum_i w_i * mask_i * (theta_i - theta_t)

so all schemes are implemented in delta form over the cohort only (the K-k
unselected clients contribute zero delta by construction):

* ``mean``           — w_i = 1/K (Alg. 1's plain average).
* ``fedavg``         — w_i = q_i / q (data-size weighted, paper P1).
* ``epoch_weighted`` — w_i ∝ (q_i/q) / E_i (Ruan et al. [11]: fewer-epoch
  clients get up-weighted so they are not overwhelmed).
* ``unbiased``       — w_i = q_i / (q * p_i): inverse-propensity estimator
  (Chen et al. [19]); beyond-paper option that removes selection bias in
  expectation — experiments quantify its variance cost.

``aggregate_async`` is the staleness-aware generalisation: instead of a
binary success mask it takes per-client completion *lags* (``0`` = on time,
``l >= 1`` = l rounds late, negative = dead), applies the on-time deltas
immediately and returns the late-but-alive deltas as ``staleness`` deferred
contributions, already scaled by ``alpha**lag`` — the standard decay-weighted
async aggregation.  ``staleness=0`` with ``lag = 0/−1`` reproduces
``aggregate`` exactly (the paper's drop semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["aggregate", "aggregate_async", "staleness_weights"]


def _scheme_weights(
    scheme: str,
    data_sizes: jax.Array,
    total_data: jax.Array,
    K: int,
    k: int,
    epochs: jax.Array = None,
    sel_probs: jax.Array = None,
) -> jax.Array:
    """The (k,) base cohort weights w_i of each aggregation scheme."""
    if scheme == "mean":
        return jnp.full((k,), 1.0 / K)
    if scheme == "fedavg":
        return data_sizes / jnp.maximum(total_data, 1e-9)
    if scheme == "epoch_weighted":
        base = data_sizes / jnp.maximum(total_data, 1e-9)
        inv = 1.0 / jnp.maximum(epochs.astype(jnp.float32), 1.0)
        # renormalise so the cohort's total weight is preserved
        return base.sum() * (base * inv) / jnp.maximum((base * inv).sum(), 1e-9)
    if scheme == "unbiased":
        return data_sizes / jnp.maximum(total_data, 1e-9) / jnp.clip(sel_probs, 1e-3, 1.0)
    raise ValueError(scheme)


def staleness_weights(lag: jax.Array, alpha: float, staleness: int) -> jax.Array:
    """Decay credit ``alpha**lag`` for ``0 <= lag <= staleness``, else 0."""
    lagf = jnp.maximum(lag.astype(jnp.float32), 0.0)
    ok = (lag >= 0) & (lag <= staleness)
    return jnp.where(ok, jnp.asarray(alpha, jnp.float32) ** lagf, 0.0)


def aggregate(
    global_params,
    cohort_params,
    success: jax.Array,  # (k,) {0,1}
    data_sizes: jax.Array,  # (k,) q_i of the selected clients
    total_data: jax.Array,  # scalar q
    K: int,
    scheme: str = "fedavg",
    epochs: jax.Array = None,  # (k,) E_i (epoch_weighted)
    sel_probs: jax.Array = None,  # (k,) p_{i,t} (unbiased)
):
    """cohort_params: pytree with leading cohort axis (k, ...)."""
    k = success.shape[0]
    w = _scheme_weights(scheme, data_sizes, total_data, K, k, epochs, sel_probs)
    w = w * success  # failed clients contribute the global model (zero delta)

    def upd(g, c):
        delta = c.astype(jnp.float32) - g.astype(jnp.float32)[None]
        contrib = jnp.tensordot(w, delta, axes=(0, 0))
        return (g.astype(jnp.float32) + contrib).astype(g.dtype)

    return jax.tree.map(upd, global_params, cohort_params)


def aggregate_async(
    global_params,
    cohort_params,
    lag: jax.Array,  # (k,) int32 completion lags (0 on time, >=1 late, <0 dead)
    data_sizes: jax.Array,  # (k,) q_i of the selected clients
    total_data: jax.Array,  # scalar q
    K: int,
    scheme: str = "fedavg",
    *,
    alpha: float = 0.5,
    staleness: int = 0,
    epochs: jax.Array = None,
    sel_probs: jax.Array = None,
):
    """Staleness-aware aggregation: returns ``(new_params, late_deltas)``.

    On-time clients (``lag == 0``) are aggregated into ``new_params`` now with
    their full scheme weight, exactly like ``aggregate``.  A late-but-alive
    client (``1 <= lag <= staleness``) contributes ``alpha**lag * w_i *
    (theta_i - theta_t)`` — its delta is still relative to the global model it
    was handed at selection time — returned in ``late_deltas``: a pytree whose
    leaves carry a leading ``(staleness,)`` axis, slice ``s`` being the summed
    contribution that lands ``s+1`` rounds from now.  The server adds slice
    ``s`` to the global model at round ``t+s+1`` (see ``FLServer``).  Clients
    with ``lag`` negative or beyond ``staleness`` are dropped (the paper's
    deadline semantics).
    """
    k = lag.shape[0]
    w = _scheme_weights(scheme, data_sizes, total_data, K, k, epochs, sel_probs)
    s_idx = jnp.arange(staleness + 1, dtype=lag.dtype)
    arrive = (lag[None, :] == s_idx[:, None]).astype(jnp.float32)  # (S+1, k) one-hot by lag
    decay = jnp.asarray(alpha, jnp.float32) ** s_idx.astype(jnp.float32)
    A = arrive * decay[:, None] * w[None, :]  # (S+1, k) credit matrix

    def contribs(g, c):
        delta = c.astype(jnp.float32) - g.astype(jnp.float32)[None]
        return jnp.tensordot(A, delta, axes=(1, 0))  # (S+1, ...)

    parts = jax.tree.map(contribs, global_params, cohort_params)
    new_params = jax.tree.map(
        lambda g, part: (g.astype(jnp.float32) + part[0]).astype(g.dtype), global_params, parts
    )
    late_deltas = jax.tree.map(lambda part: part[1:], parts)
    return new_params, late_deltas
