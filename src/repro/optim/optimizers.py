"""Optimizers from scratch (no optax): SGD(+momentum) and AdamW.

Functional API mirroring the rest of the framework:

    opt = sgd(lr=1e-2, momentum=0.9)
    state = opt.init(params)
    params, state = opt.update(params, grads, state, step)

``lr`` may be a float or a schedule ``step -> float``.  All state lives in
the same dtype as the parameters unless ``fp32_state=True`` (recommended for
bf16 training; the FL paper's SGD runs fp32 anyway).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw"]

Schedule = Union[float, Callable]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def sgd(lr: Schedule = 1e-2, momentum: float = 0.0, nesterov: bool = False, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(params, grads, state, step=0):
        lr_t = _lr_at(lr, step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: (p - lr_t * g.astype(jnp.float32)).astype(p.dtype), params, grads)
            return new_params, ()
        new_state = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state, grads)
        eff = (
            jax.tree.map(lambda m, g: g.astype(m.dtype) + momentum * m, new_state, grads)
            if nesterov
            else new_state
        )
        new_params = jax.tree.map(lambda p, m: (p - lr_t * m.astype(jnp.float32)).astype(p.dtype), params, eff)
        return new_params, new_state

    return Optimizer(init, update)


class AdamWState(NamedTuple):
    mu: object
    nu: object


def adamw(
    lr: Schedule = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    fp32_state: bool = True,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32 if fp32_state else p.dtype)
        return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))

    def update(params, grads, state, step=0):
        lr_t = _lr_at(lr, step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)), state.nu, grads)

        def upd(p, m, v):
            mh = m / c1
            vh = v / c2
            step_ = lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(mh.dtype))
            return (p.astype(jnp.float32) - step_).astype(p.dtype)

        return jax.tree.map(upd, params, mu, nu), AdamWState(mu, nu)

    return Optimizer(init, update)
