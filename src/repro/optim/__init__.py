from .optimizers import Optimizer, sgd, adamw
from .schedules import constant, cosine_decay, warmup_cosine

__all__ = ["Optimizer", "sgd", "adamw", "constant", "cosine_decay", "warmup_cosine"]
