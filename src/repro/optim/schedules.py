"""Learning-rate schedules (pure functions of the step index)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine_decay", "warmup_cosine"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        x = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * x))), jnp.float32)

    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cd = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)

    def f(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.asarray(w, jnp.float32) * cd(jnp.maximum(step - warmup, 0))

    return f
