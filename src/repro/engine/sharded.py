"""Million-client ProbAlloc: the Eq. 24 alpha-search without a global sort.

``repro.core.selection.prob_alloc`` vectorises the paper's case analysis via a
full ``O(K log K)`` sort plus cumulative sums — fine at K=100, hostile at
K=10^6 (a global sort is the one primitive that does not shard).  This module
solves the same fixed point by **fixed-iteration bisection** on the monotone
scalar function

    g(alpha) = alpha / sum_j min(w_j, (1 - sigma) * alpha)         (Eq. 24)

g is non-decreasing in alpha (numerator linear, denominator concave and
saturating), and the capped allocation is exact when ``g(alpha) = 1/(k - K
sigma)``.  Each bisection step only needs ``sum_j min(w_j, cap)`` — an
embarrassingly shardable masked reduction that we evaluate tile-by-tile
(two-level summation, which is also what a cross-device ``psum`` of per-shard
partials computes), so the whole search is O(n_iters * K) flops, O(K) memory
traffic, and never materialises an ordering of the weights.

``n_iters=48`` halvings shrink the bracket below float32 resolution, so the
result matches the sort-based solver (and the paper's literal case
enumeration, ``prob_alloc_reference``) to ~1e-6 in p.

All entry points take an optional ``active`` mask and traced ``k`` /
``sigma`` scalars, which is what lets the multi-job engine vmap one compiled
allocator over heterogeneous (K, k, sigma) jobs via padding.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["prob_alloc_sharded", "masked_prob_alloc"]

_EPS = 1e-30


def _tiled_sum(x: jax.Array, tile: int) -> jax.Array:
    """Two-level (per-tile, then cross-tile) sum: shard-shaped and more
    accurate than a flat fp32 reduction at K ~ 10^6."""
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return jnp.sum(jnp.sum(x.reshape(-1, tile), axis=1))


def masked_prob_alloc(
    w: jax.Array,
    k: jax.Array,
    sigma: jax.Array,
    active: jax.Array | None = None,
    n_iters: int = 48,
    tile: int = 8192,
):
    """Sort-free ProbAlloc (paper Algorithm 2) over an optionally-masked
    population.

    Args:
      w: ``(K_pad,)`` non-negative weights (entries with ``active == 0`` are
         ignored and receive ``p = 0``).
      k: cohort size — python int or traced scalar.
      sigma: fairness floor in ``[0, k/K_active]`` — python float or traced.
      active: ``(K_pad,)`` 0/1 validity mask (default: all active).
      n_iters: bisection iterations (static).
      tile: reduction tile width (static).

    Returns:
      ``(p, capped)``: allocation with ``sum(p) = k``, ``sigma <= p_i <= 1``
      on active arms and ``p_i = 0`` off them; ``capped`` is the overflow set.
    """
    w = jnp.asarray(w)
    dt = w.dtype
    if active is None:
        active = jnp.ones(w.shape, dt)
    else:
        active = jnp.asarray(active, dt)
    w = w * active
    k = jnp.asarray(k, dt)
    sigma = jnp.asarray(sigma, dt)
    K_act = _tiled_sum(active, tile)
    residual = k - K_act * sigma  # >= 0 by the feasibility constraint
    one_ms = 1.0 - sigma

    w_sum = _tiled_sum(w, tile)
    w_max = jnp.max(jnp.where(active > 0, w, -jnp.inf))
    # overflow iff the plain (uncapped) allocation exceeds 1 somewhere
    overflow = sigma + residual * w_max / jnp.maximum(w_sum, _EPS) > 1.0 + 1e-9

    def capped_branch(_):
        # bracket: g(0+) = 1/(K_act*(1-sigma)) <= 1/residual (since k <= K)
        # and g(w_sum/residual) >= 1/residual, so the root is in (0, hi].
        hi0 = w_sum / jnp.maximum(residual, _EPS)

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            s = _tiled_sum(jnp.minimum(w, one_ms * mid), tile)
            go_up = mid * residual < s  # g(mid) < 1/residual -> alpha too small
            return jnp.where(go_up, mid, lo), jnp.where(go_up, hi, mid)

        lo, hi = jax.lax.fori_loop(0, n_iters, body, (jnp.zeros((), dt), hi0))
        alpha = 0.5 * (lo + hi)
        cap = one_ms * alpha
        w_c = jnp.minimum(w, cap)
        p = sigma + residual * w_c / jnp.maximum(_tiled_sum(w_c, tile), _EPS)
        return p, p >= 1.0 - 1e-6

    def plain_branch(_):
        p = sigma + residual * w / jnp.maximum(w_sum, _EPS)
        return p, jnp.zeros(w.shape, bool)

    p, capped = jax.lax.cond(overflow, capped_branch, plain_branch, None)
    p = jnp.clip(p, sigma, 1.0) * active
    return p, capped & (active > 0)


@partial(jax.jit, static_argnames=("k", "n_iters", "tile"))
def prob_alloc_sharded(w: jax.Array, k: int, sigma, n_iters: int = 48, tile: int = 8192):
    """Drop-in for ``repro.core.selection.prob_alloc`` at fleet scale:
    identical (p, capped) contract, no global sort, O(n_iters * K) work."""
    return masked_prob_alloc(w, k, sigma, active=None, n_iters=n_iters, tile=tile)
