"""Million-client ProbAlloc and K-sharded selection rounds.

``repro.core.selection.prob_alloc`` vectorises the paper's case analysis via a
full ``O(K log K)`` sort plus cumulative sums — fine at K=100, hostile at
K=10^6 (a global sort is the one primitive that does not shard).  This module
solves the same fixed point by **fixed-iteration bisection** on the monotone
scalar function

    g(alpha) = alpha / sum_j min(w_j, (1 - sigma) * alpha)         (Eq. 24)

g is non-decreasing in alpha (numerator linear, denominator concave and
saturating), and the capped allocation is exact when ``g(alpha) = 1/(k - K
sigma)``.  Each bisection step only needs ``sum_j min(w_j, cap)`` — an
embarrassingly shardable masked reduction that we evaluate tile-by-tile
(two-level summation), so the whole search is O(n_iters * K) flops, O(K)
memory traffic, and never materialises an ordering of the weights.

``n_iters=48`` halvings shrink the bracket below float32 resolution, so the
result matches the sort-based solver (and the paper's literal case
enumeration, ``prob_alloc_reference``) to ~1e-6 in p.  Weights keep their
dtype end to end: float64 inputs (x64 mode) solve in float64 with a
dtype-scaled epsilon instead of silently downcasting.

Three levels of parallelism, all the same reduction:

* **tiles** — ``masked_prob_alloc`` sums per-tile partials (more accurate
  than a flat fp32 reduction at K ~ 10^6, and the shape a ``psum`` needs);
* **bracket blocks** — with ``block=b > 1`` each pass evaluates the capped
  sum at the ``2**b - 1`` dyadic candidate caps of the next ``b`` halvings in
  ONE sweep of the weights (``repro.kernels.bisect_tiles``: the slab stays in
  VMEM across the block), collapsing 48 sweeps to ``ceil(48/b)``;
* **devices** — with ``axis_name`` set, every reduction finishes with one
  scalar (or, in block mode, one ``(2**b - 1,)``-vector) ``psum`` per step;
  nothing else crosses shards.  ``prob_alloc_shmap`` stands this up on a real
  device mesh via ``shard_map``, and ``build_sharded_scan_runner`` threads the
  fully sharded round — allocator, distributed Plackett-Luce top-k, per-shard
  volatility draw (``jax.random.fold_in(key, shard_index)``, bit-reproducible
  for a fixed shard count) and E3CS update — through a whole compiled
  ``lax.scan`` horizon.

All entry points take an optional ``active`` mask and traced ``k`` /
``sigma`` scalars, which is what lets the multi-job engine vmap one compiled
allocator over heterogeneous (K, k, sigma) jobs via padding.
"""
from __future__ import annotations


from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at the top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # pinned 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

from repro.core.selection.sampling import local_topk_candidates, merge_topk_candidates, perturbed_scores
from repro.kernels.bisect_tiles import bisect_block_sums

__all__ = [
    "prob_alloc_sharded",
    "masked_prob_alloc",
    "masked_prob_alloc_scalars",
    "prob_alloc_shmap",
    "distributed_topk",
    "plackett_luce_shmap",
    "build_sharded_scan_runner",
    "sharded_selection_sim",
]

def _shard_topk_merge(scores_loc: jax.Array, k: int, axis_name: str):
    """The one distributed top-k step every sharded selection shares: this
    shard's ``lax.top_k(k)`` candidates (global indices via the shard
    offset), an all-gather of the ``(D, k)`` pairs, and the exact merge
    (``repro.core.selection.sampling.merge_topk_candidates``).  Returns the
    replicated ``(k,)`` global top-k indices."""
    Ks = scores_loc.shape[0]
    v, gi = local_topk_candidates(scores_loc, k, jax.lax.axis_index(axis_name) * Ks)
    cv = jax.lax.all_gather(v, axis_name, tiled=True)
    ci = jax.lax.all_gather(gi, axis_name, tiled=True)
    return merge_topk_candidates(cv, ci, k)


def _shmap(f, mesh, in_specs, out_specs):
    """`shard_map` with replication checking off: the bisection's `fori_loop`
    carry trips the static replication checker (jax#21296); the specs here are
    explicit so the check adds nothing."""
    try:
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    except TypeError:  # newer jax renamed the kwarg
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)


def _axis_size(mesh, axis_name: str) -> int:
    return mesh.shape[axis_name]


def _tiny(dt) -> jnp.ndarray:
    """Dtype-scaled division guard (float32: ~1e-38, float64: ~1e-308) —
    a flat 1e-30 floor is wider than float64's usable range and was the one
    constant that broke x64-mode allocations."""
    return jnp.asarray(jnp.finfo(dt).tiny, dt)


def _tiled_sum(x: jax.Array, tile: int) -> jax.Array:
    """Two-level (per-tile, then cross-tile) sum: shard-shaped and more
    accurate than a flat fp32 reduction at K ~ 10^6."""
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return jnp.sum(jnp.sum(x.reshape(-1, tile), axis=1))


def _reduce_sum(x: jax.Array, tile: int, axis_name: Optional[str]) -> jax.Array:
    s = _tiled_sum(x, tile)
    return jax.lax.psum(s, axis_name) if axis_name else s


def masked_prob_alloc(
    w: jax.Array,
    k: jax.Array,
    sigma: jax.Array,
    active: jax.Array | None = None,
    n_iters: int = 48,
    tile: int = 8192,
    axis_name: Optional[str] = None,
    block: int = 1,
):
    """Sort-free ProbAlloc (paper Algorithm 2) over an optionally-masked
    population.

    Args:
      w: ``(K_pad,)`` non-negative weights (entries with ``active == 0`` are
         ignored and receive ``p = 0``).  Any float dtype; the search runs and
         returns in ``w.dtype``.
      k: cohort size — python int or traced scalar.
      sigma: fairness floor in ``[0, k/K_active]`` — python float or traced.
      active: ``(K_pad,)`` 0/1 validity mask (default: all active).
      n_iters: total bisection halvings (static).
      tile: reduction tile width (static).
      axis_name: when set, ``w``/``active`` are this device's shard of a
         K-sharded population and every reduction finishes with a ``psum``
         over the named mesh axis — ``k``/``sigma`` stay global, and the
         returned ``(p, capped)`` are the local shard.  One scalar ``psum``
         per bisection step; nothing else crosses shards.
      block: halvings resolved per weight sweep (static).  ``block=1`` is
         plain bisection; ``block=b`` probes the ``2**b - 1`` dyadic interior
         caps of the bracket in one fused pass (``repro.kernels.bisect_tiles``)
         and binary-searches the precomputed sums — same final bracket up to
         float roundoff in the midpoint arithmetic, ``ceil(n_iters/b)`` sweeps
         (and cross-device syncs) instead of ``n_iters``.

    Returns:
      ``(p, capped)``: allocation with ``sum(p) = k``, ``sigma <= p_i <= 1``
      on active arms and ``p_i = 0`` off them; ``capped`` is the overflow set.
    """
    w, active, k, sigma = _alloc_prelude(w, k, sigma, active)
    residual, cap, denom, use_cap = _alloc_scalars(
        w, k, sigma, active, n_iters=n_iters, tile=tile, axis_name=axis_name, block=block
    )
    # the unified elementwise epilogue: with cap=+inf / denom=max(w_sum,eps)
    # in the plain branch, min(w, cap) == w bitwise, so one expression
    # reproduces both branches of the historical lax.cond exactly.
    p = sigma + residual * jnp.minimum(w, cap) / denom
    capped = (p >= 1.0 - 1e-6) & use_cap
    p = jnp.clip(p, sigma, 1.0) * active
    return p, capped & (active > 0)


def _alloc_prelude(w, k, sigma, active):
    """Shared input normalisation: cast to the weight dtype and fold the
    activity mask into the weights (exactly once)."""
    w = jnp.asarray(w)
    dt = w.dtype
    if active is None:
        active = jnp.ones(w.shape, dt)
    else:
        active = jnp.asarray(active, dt)
    return w * active, active, jnp.asarray(k, dt), jnp.asarray(sigma, dt)


def _alloc_scalars(w, k, sigma, active, *, n_iters, tile, axis_name, block):
    """The scalar half of ``masked_prob_alloc``: bracket the cap by
    bisection and return ``(residual, cap, denom, use_cap)`` such that

        p_raw  = sigma + residual * min(w, cap) / denom
        capped = (p_raw >= 1 - 1e-6) & use_cap
        p      = clip(p_raw, sigma, 1) * active

    reproduces the full allocation bitwise.  ``w`` must already be masked
    (``_alloc_prelude``).  This is the piece the fused round kernel hoists
    out: everything downstream of these four scalars is elementwise."""
    dt = w.dtype
    eps = _tiny(dt)
    K_act = _reduce_sum(active, tile, axis_name)
    residual = k - K_act * sigma  # >= 0 by the feasibility constraint
    one_ms = 1.0 - sigma

    w_sum = _reduce_sum(w, tile, axis_name)
    w_max = jnp.max(jnp.where(active > 0, w, -jnp.inf))
    if axis_name:
        w_max = jax.lax.pmax(w_max, axis_name)
    # overflow iff the plain (uncapped) allocation exceeds 1 somewhere
    overflow = sigma + residual * w_max / jnp.maximum(w_sum, eps) > 1.0 + 1e-9

    def capped_branch(_):
        # bracket: g(0+) = 1/(K_act*(1-sigma)) <= 1/residual (since k <= K)
        # and g(w_sum/residual) >= 1/residual, so the root is in (0, hi].
        hi0 = w_sum / jnp.maximum(residual, eps)

        if block == 1:

            def body(_, lohi):
                lo, hi = lohi
                mid = 0.5 * (lo + hi)
                s = _reduce_sum(jnp.minimum(w, one_ms * mid), tile, axis_name)
                go_up = mid * residual < s  # g(mid) < 1/residual -> alpha too small
                return jnp.where(go_up, mid, lo), jnp.where(go_up, hi, mid)

            n_pass = n_iters
        else:
            npts = (1 << block) - 1
            frac = jnp.arange(1, npts + 1, dtype=dt) / (npts + 1)

            def body(_, lohi):
                lo, hi = lohi
                mids = lo + (hi - lo) * frac  # the block's dyadic candidates
                s = bisect_block_sums(w, one_ms * mids, tile=tile).astype(dt)
                if axis_name:
                    s = jax.lax.psum(s, axis_name)
                n_up = jnp.sum((mids * residual < s).astype(jnp.int32))
                grid = jnp.concatenate([lo[None], mids, hi[None]])
                return grid[n_up], grid[n_up + 1]

            n_pass = -(-n_iters // block)

        lo, hi = jax.lax.fori_loop(0, n_pass, body, (jnp.zeros((), dt), hi0))
        alpha = 0.5 * (lo + hi)
        cap = one_ms * alpha
        denom = jnp.maximum(_reduce_sum(jnp.minimum(w, cap), tile, axis_name), eps)
        return residual, cap, denom, jnp.ones((), bool)

    def plain_branch(_):
        return residual, jnp.asarray(jnp.inf, dt), jnp.maximum(w_sum, eps), jnp.zeros((), bool)

    return jax.lax.cond(overflow, capped_branch, plain_branch, None)


def masked_prob_alloc_scalars(
    w: jax.Array,
    k: jax.Array,
    sigma: jax.Array,
    active: jax.Array | None = None,
    n_iters: int = 48,
    tile: int = 8192,
    axis_name: Optional[str] = None,
    block: int = 1,
):
    """``masked_prob_alloc`` minus its elementwise epilogue: run the same
    bisection (identical scalars, identical cross-shard reductions) and
    return ``(residual, cap, denom, use_cap)``.  The fused round kernel
    (``repro.kernels.round_fused``) consumes these to rebuild ``p`` /
    ``capped`` inside one VMEM-resident pass, bit-identical to the staged
    allocator."""
    w, active, k, sigma = _alloc_prelude(w, k, sigma, active)
    return _alloc_scalars(w, k, sigma, active, n_iters=n_iters, tile=tile, axis_name=axis_name, block=block)


@partial(jax.jit, static_argnames=("k", "n_iters", "tile", "block"))
def prob_alloc_sharded(w: jax.Array, k: int, sigma, n_iters: int = 48, tile: int = 8192, block: int = 1):
    """Drop-in for ``repro.core.selection.prob_alloc`` at fleet scale:
    identical (p, capped) contract, no global sort, O(n_iters * K) work."""
    return masked_prob_alloc(w, k, sigma, active=None, n_iters=n_iters, tile=tile, block=block)


def _pad0(a: jax.Array, n: int) -> jax.Array:
    """Pad axis 0 with zeros up to length ``n``."""
    if a.shape[0] == n:
        return a
    return jnp.pad(a, [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1))


def prob_alloc_shmap(
    w: jax.Array,
    k,
    sigma,
    mesh,
    active: jax.Array | None = None,
    axis_name: str = "shards",
    n_iters: int = 48,
    tile: int = 8192,
    block: int = 1,
):
    """``masked_prob_alloc`` data-parallel over a K-sharded device mesh.

    The weights are padded to a multiple of the mesh axis size, sharded via
    ``shard_map``, and each device evaluates its slab's capped partial sum —
    per bisection step, one scalar ``psum`` combines them and everything else
    is shard-local (the compiled program contains no gather, no sort, and
    exactly one all-reduce inside the refinement loop; asserted on the HLO in
    ``tests/test_sharded.py``).  Ragged populations are handled by the pad
    mask.  Returns global ``(p, capped)`` of the original length.
    """
    K = w.shape[0]
    D = _axis_size(mesh, axis_name)
    K_pad = D * (-(-K // D))
    if active is None:
        active = jnp.ones((K,), w.dtype)
    wp = _pad0(jnp.asarray(w), K_pad)
    ap = _pad0(jnp.asarray(active, wp.dtype), K_pad)
    body = partial(masked_prob_alloc, n_iters=n_iters, tile=tile, axis_name=axis_name, block=block)
    f = _shmap(
        body,
        mesh,
        in_specs=(P(axis_name), P(), P(), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
    )
    p, capped = f(wp, jnp.asarray(k, wp.dtype), jnp.asarray(sigma, wp.dtype), ap)
    return p[:K], capped[:K]


# ---------------------------------------------------------------------------
# Distributed Plackett-Luce top-k
# ---------------------------------------------------------------------------


def distributed_topk(scores: jax.Array, k: int, mesh, axis_name: str = "shards") -> jax.Array:
    """Global top-k indices of ``scores`` without a global sort or gather of
    the full vector: per-shard ``lax.top_k(k)``, an all-gather of the
    ``(D, k)`` candidate (value, index) pairs, and one final k-way merge —
    O(K/D) work per device plus O(D·k) replicated, versus O(K log K) for a
    global sort.

    Exactly equal to ``lax.top_k(scores, k)[1]`` — including tie order — by
    the containment argument in
    ``repro.core.selection.sampling.merge_topk_candidates``.
    """
    K = scores.shape[0]
    D = _axis_size(mesh, axis_name)
    K_pad = D * (-(-K // D))
    if k > K_pad // D:
        raise ValueError(f"k={k} exceeds the shard width {K_pad // D} (= ceil(K/D)); need k <= K/D")
    neg_inf = jnp.asarray(-jnp.inf, scores.dtype)
    sp = jnp.concatenate([jnp.asarray(scores), jnp.full((K_pad - K,), neg_inf)]) if K_pad != K else scores
    body = partial(_shard_topk_merge, k=k, axis_name=axis_name)
    return _shmap(body, mesh, in_specs=P(axis_name), out_specs=P())(sp)


def plackett_luce_shmap(rng: jax.Array, p: jax.Array, k: int, mesh, axis_name: str = "shards") -> jax.Array:
    """K-sharded Plackett-Luce draw: each shard perturbs its slab of
    ``log p`` with Gumbel noise from its own fold_in stream
    (``fold_in(rng, shard_index)``; bit-reproducible for a fixed shard count)
    and the cohort is the distributed top-k of the perturbed scores.

    Same distribution as ``plackett_luce_sample`` (iid Gumbel perturbations
    followed by an exact global top-k); not the same bits for D > 1, since the
    per-shard streams differ from one (K,) draw.
    """
    K = p.shape[0]
    D = _axis_size(mesh, axis_name)
    K_pad = D * (-(-K // D))
    Ks = K_pad // D
    if k > Ks:
        raise ValueError(f"k={k} exceeds the shard width {Ks} (= ceil(K/D)); need k <= K/D")
    pp = _pad0(jnp.asarray(p), K_pad)

    def body(p_loc):
        d = jax.lax.axis_index(axis_name)
        key = jax.random.fold_in(rng, d) if D > 1 else rng
        pos = d * Ks + jnp.arange(Ks, dtype=jnp.int32)
        scores = jnp.where(pos < K, perturbed_scores(key, p_loc), -jnp.inf)
        return _shard_topk_merge(scores, k, axis_name)

    return _shmap(body, mesh, in_specs=P(axis_name), out_specs=P())(pp)


# ---------------------------------------------------------------------------
# The K-sharded selection round, compiled over a whole scan horizon
# ---------------------------------------------------------------------------


def build_sharded_scan_runner(
    fl,
    vol,
    rho,
    mesh,
    override: str = "none",
    outputs: str = "full",
    axis_name: str = "shards",
    n_iters: int = 48,
    tile: int = 8192,
    block: int = 1,
    staleness: Optional[int] = None,
    alpha: float = 0.5,
    feedback: str = "deadline",
    carry_key: bool = False,
    scan_length: Optional[int] = None,
    taps: bool = False,
    fused: bool = False,
):
    """Compile the whole T-round horizon with the K axis sharded over a mesh.

    The mesh placement of the ONE round body in
    ``repro.engine.round_program`` (same round semantics, same per-round
    ``split(key, 3)`` PRNG discipline as the dense engine) with every
    per-client array — E3CS log-weights, allocation, volatility parameters and
    state, selection counts, loss cache, the per-round trace rows and (async)
    the ``(S, K/D)`` staleness rings — living as shards on a ``shard_map``
    mesh.  Per round the only cross-shard traffic is: one scalar ``psum`` per
    bisection step (the allocator), one ``(D·k,)`` candidate all-gather (the
    distributed Plackett-Luce top-k), one ``pmax`` pair for weight
    re-centering, and — in lean mode — one scalar ``psum`` per round metric.

    PRNG: the carried key is replicated and split exactly like the unsharded
    engine; shard-local draws (Gumbel perturbations, volatility bits) use
    ``fold_in(round_key, shard_index)`` so runs are bit-reproducible for a
    fixed shard count.  On a 1-device mesh the fold_in is skipped, which makes
    the sharded engine **bit-identical** to
    ``build_scan_runner(fl(allocator="bisect"), ...)`` for every scheme
    (pinned in ``tests/test_sharded.py``).  Caveat: with
    ``override="packed"`` the contract additionally needs ``K % 8 == 0`` —
    byte sharding pads the population to whole bytes, and a padded draw shape
    changes every threefry stream, so non-aligned K is distributionally
    equivalent but not bit-equal.

    Schemes: ``e3cs`` is fully sharded (the hot path).  ``random`` / ``fedcs``
    / ``ucb`` / ``pow_d`` keep their small selector state replicated and run
    the selection itself replicated (gathering the (K,) vector they score, for
    ucb/pow_d) — correctness-grade at scale, bit-identical at D=1.

    ``override="packed"`` shards the ``(T, ceil(K/8))`` uint8 trace rows along
    the byte axis, so replay memory divides by D as well (``"packed_lags"``
    does the same for 2-bit async lag traces at 4 clients/byte); ``"dense"``
    shards the trace columns; ``"none"`` draws from ``vol`` with per-shard
    parameters (any — possibly nested — dataclass model whose per-client
    arrays are K-indexed: the builtins, or ``CompletionLag`` over one).

    With ``staleness=S`` the runner compiles the *async* round body: ``vol``
    is a lag model and the ``(S, K/D)``-sharded pending-credit ring rides in
    the scan carry — the "sharded async rounds" composition.  Returns
    ``(run, state0)`` with the ``build_scan_runner`` signatures; K-arrays in
    ``state0`` and the outputs are padded to ``K_pad`` (a multiple of D·8
    for packed, D·4 for packed_lags); slice ``[:K]``.

    ``taps=True`` appends the ``repro.obs.ROUND_TAPS`` telemetry payload
    (``{"series", "counters"}``, psum-reduced so replicated across shards)
    as the runner's trailing output — same schema as the dense engine.
    """
    from repro.engine.round_program import RoundProgram  # deferred: round_program imports this module

    program = RoundProgram(
        fl=fl, vol=vol, rho=rho, override=override, staleness=staleness, alpha=alpha,
        feedback=feedback, mesh=mesh, axis_name=axis_name, n_iters=n_iters, tile=tile,
        block=block, fused=fused,
    )
    return program.build_runner(outputs=outputs, carry_key=carry_key, scan_length=scan_length, taps=taps)


def sharded_selection_sim(
    scheme: str,
    mesh,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    packed_override: Optional[np.ndarray] = None,
    outputs: str = "full",
    block: int = 1,
    vol=None,
    rho=None,
    taps: bool = False,
    fused: bool = False,
):
    """Sharded counterpart of ``engine.scan_sim.scan_selection_sim``: same
    keyword surface plus a ``mesh``, same output dict (K-wide arrays sliced
    back to the true population; ``taps=True`` adds the ``"taps"`` entry)."""
    from repro.configs.base import FLConfig
    from repro.core.volatility import make_volatility, paper_success_rates

    if xs_override is not None and packed_override is not None:
        raise ValueError("pass at most one of xs_override / packed_override")
    override = "dense" if xs_override is not None else ("packed" if packed_override is not None else "none")
    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, allocator="bisect")
    if rho is None:
        rho = getattr(vol, "rho", None)
    if rho is None:
        rho = paper_success_rates(K)
    if vol is None:
        vol = make_volatility(volatility, jnp.asarray(rho), stickiness=stickiness, seed=seed)
    run, state = build_sharded_scan_runner(
        fl, vol, rho, mesh, override=override, outputs=outputs, block=block, taps=taps, fused=fused
    )
    key = jax.random.PRNGKey(seed)
    if override == "dense":
        xs_in = jnp.asarray(xs_override, jnp.float32)
    elif override == "packed":
        xs_in = jnp.asarray(packed_override, jnp.uint8)
    else:
        xs_in = jnp.zeros((T, 0), jnp.float32)

    def _taps_out(rest):
        payload = rest[-1]
        return {
            "series": {n: np.asarray(v) for n, v in payload["series"].items()},
            "counters": {n: float(v) for n, v in payload["counters"].items()},
        }

    if outputs == "lean":
        state, successes, sigmas, *rest = run(state, key, xs_in)
        out = {
            "successes": np.asarray(successes),
            "sigmas": np.asarray(sigmas),
            "counts": np.asarray(state.sel_counts)[:K],
        }
        if taps:
            out["taps"] = _taps_out(rest)
        return out
    state, masks, xs, ps, sigmas, *rest = run(state, key, xs_in)
    masks = np.asarray(masks)[:, :K]
    out = {
        "masks": masks,
        "xs": np.asarray(xs)[:, :K],
        "ps": np.asarray(ps)[:, :K],
        "sigmas": np.asarray(sigmas),
        "counts": masks.sum(0),
    }
    if taps:
        out["taps"] = _taps_out(rest)
    return out
