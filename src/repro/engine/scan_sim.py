"""Scan-compiled selection simulator: the whole T-round horizon in ONE
compiled program.

The legacy ``repro.core.sim`` loop dispatches ~10 host->device ops per round
(selector update, volatility transition, metric reads), which dominates
wall-clock at paper scale (K=100, T=2500) and makes million-client sweeps
infeasible.  Here the per-round step — ProbAlloc, stochastic selection,
volatility transition, selector update and metrics — is the body of a single
``jax.lax.scan``, so the entire simulation compiles once and runs with zero
per-round Python overhead.

The step replicates the legacy loop's PRNG discipline exactly (carry the key,
``split(key, 3)`` per round), so outputs are bit-identical to
``selection_sim_loop`` for every scheme; ``tests/test_engine.py`` pins this.

Volatility inside the scan comes in three flavours, picked by ``override``:

* ``"none"``   — a *stateful* model object (any ``(init_state, sample)``
  implementer: the built-ins, or ``repro.scenarios`` diurnal / regional /
  flash-crowd / replay models).  Its state rides in ``ServerState.vol_state``
  (an arbitrary pytree), so Markov chains and latent regional factors compile
  into the whole-horizon program.
* ``"dense"``  — a recorded ``(T, K)`` float32 trace streamed through the
  scan's xs input.
* ``"packed"`` — the same trace bit-packed to ``(T, ceil(K/8))`` uint8 (32x
  smaller; K=1e6, T=2500 fits in ~312 MB) and expanded row-by-row inside the
  scan body by ``repro.kernels.unpack_bits`` — selections are bit-identical
  to the dense path (``tests/test_scenarios.py``).

Async rounds (``staleness=S``): per-round outcomes generalise from binary
success/fail to a *completion lag* drawn by a lag model
(``repro.core.volatility.CompletionLag`` / ``BinaryLag`` — same
``(init_state, sample)`` protocol, int32 lags).  A bounded ring of ``S``
pending rounds rides in the scan carry: a client selected at round t that
completes ``l`` rounds late (``1 <= l <= S``) is credited at round ``t+l``
with decay weight ``alpha**l`` instead of being dropped; lag beyond ``S`` (or
``DEAD_LAG``) is dropped exactly like today.  The selector keeps the paper's
deadline-based feedback (it observes the on-time bits ``1{lag==0}`` — the
server cannot wait for stragglers before choosing the next cohort), so with
``S=0`` — or with a ``BinaryLag`` at any S — selections, counts and E3CS
weights are **bit-identical** to the synchronous path (``tests/test_async.py``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.selection import e3cs_update, make_quota_schedule, selection_mask, ucb_update
from repro.core.volatility import make_volatility, paper_success_rates
from repro.fl.round import init_server_state, make_select_fn
from repro.kernels.unpack_bits import unpack_bits

__all__ = [
    "make_sim_step",
    "build_scan_runner",
    "scan_selection_sim",
    "async_selection_sim",
    "staleness_ring_step",
]


def staleness_ring_step(pending, mask, lag, S: int, alpha: float):
    """One update of the bounded staleness ring; returns ``(arriving,
    new_pending)``.

    ``pending`` is ``(..., S, K)`` — slot s holds the decayed credit arriving
    s rounds from now; ``mask`` / ``lag`` are ``(..., K)`` (any leading batch
    axes, e.g. the multi-job J axis).  Pops slot 0 (this round's arrivals),
    shifts, and pushes the newly selected late completions (``1 <= lag <= S``)
    with credit ``alpha**lag`` into their arrival slots.  The single source of
    the ring semantics for both the scan engine and the compiled service loop.
    """
    if S == 0:
        return jnp.zeros_like(mask), pending
    decay = jnp.asarray([alpha ** (s + 1) for s in range(S)], jnp.float32)
    lag_rows = jnp.arange(1, S + 1, dtype=jnp.int32)
    sched = mask[..., None, :] * (lag[..., None, :] == lag_rows[:, None]) * decay[:, None]
    arriving = pending[..., 0, :]
    shifted = jnp.concatenate(
        [pending[..., 1:, :], jnp.zeros_like(pending[..., :1, :])], axis=-2
    )
    return arriving, shifted + sched

_OVERRIDE_MODES = ("none", "dense", "packed")


def make_sim_step(
    fl: FLConfig,
    quota_fn,
    vol,
    rho,
    use_override=False,
    override: Optional[str] = None,
    lean: bool = False,
    staleness: Optional[int] = None,
    alpha: float = 0.5,
):
    """Build the per-round scan body ``step((state, key), x_over) -> ...``.

    Mirrors the legacy loop body op-for-op so results stay bit-identical.
    ``override`` picks the success-bit source (see module docstring);
    ``use_override`` is the legacy bool spelling of ``"dense"``.  With
    ``lean=True`` the step emits only per-round scalars (successes, sigma)
    instead of the (K,)-wide mask/x/p rows — the state math is unchanged, so
    cumulative counts stay bit-identical while scan outputs drop from
    O(T*K) to O(T), which is what makes the full T=2500 horizon feasible at
    K=1e6 (full outputs would be ~10 GB per (T, K) float32 array).

    With ``staleness=S`` (an int, 0 allowed) the step becomes the *async*
    round body: ``vol`` must be a lag model (int32 lags, see
    ``repro.core.volatility.CompletionLag``), the carry gains a ``(S, K)``
    pending-credit ring, and the step returns
    ``((state, key, pending), out)`` where ``out`` is ``(on_time, stale,
    sigma)`` per round when lean or ``(mask, lag, p, sigma, arriving)`` when
    full.  ``state.cep`` accumulates the staleness-aware effective
    participation (on-time + decayed late credit) and ``state.succ_hist`` the
    on-time part, so lean runs keep both without O(T*K) outputs.
    """
    mode = override if override is not None else ("dense" if use_override else "none")
    if mode not in _OVERRIDE_MODES:
        raise ValueError(f"unknown override mode {mode!r} (want one of {_OVERRIDE_MODES})")
    select = make_select_fn(fl, quota_fn, rho)
    K, k, scheme = fl.K, fl.k, fl.scheme

    if staleness is not None:
        if mode != "none":
            raise ValueError("async rounds (staleness != None) need a stateful lag model, not a trace override")
        return _make_async_sim_step(fl, select, vol, int(staleness), alpha, lean)

    def step(carry, x_over):
        state, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        if mode == "dense":
            x, vs = x_over, state.vol_state
        elif mode == "packed":
            x, vs = unpack_bits(x_over, K), state.vol_state
        else:
            x, vs = vol.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        e3cs = state.e3cs
        if scheme == "e3cs":
            e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)  # pow-d loss proxy
        ucb = state.ucb
        if scheme == "ucb":
            ucb = ucb_update(state.ucb, idx, x)
        state = state._replace(
            e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
            sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
        )
        out = (jnp.vdot(mask, x), sigma) if lean else (mask, x, p, sigma)
        return (state, key), out

    return step


def _make_async_sim_step(fl: FLConfig, select, lag_model, S: int, alpha: float, lean: bool):
    """The async round body (see ``make_sim_step``).  Same PRNG discipline as
    the sync step — ``split(key, 3)`` per round, ``k2`` to the lag model — so
    a ``BinaryLag`` (which forwards ``k2`` verbatim to its base model)
    reproduces the synchronous masks/weights bit-for-bit at any S."""
    K, k, scheme = fl.K, fl.k, fl.scheme

    def step(carry, _):
        state, key, pending = carry
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        lag, vs = lag_model.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        x = (lag == 0).astype(jnp.float32)  # deadline-based selector feedback
        e3cs = state.e3cs
        if scheme == "e3cs":
            e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)  # pow-d loss proxy
        ucb = state.ucb
        if scheme == "ucb":
            ucb = ucb_update(state.ucb, idx, x)
        arriving, pending = staleness_ring_step(pending, mask, lag, S, alpha)
        on_time = jnp.vdot(mask, x)
        stale = jnp.sum(arriving)
        state = state._replace(
            e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
            sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
            cep=state.cep + on_time + stale, succ_hist=state.succ_hist + on_time,
        )
        out = (on_time, stale, sigma) if lean else (mask, lag, p, sigma, arriving)
        return (state, key, pending), out

    return step


def build_scan_runner(
    fl: FLConfig,
    vol,
    rho,
    override: str = "none",
    outputs: str = "full",
    staleness: Optional[int] = None,
    alpha: float = 0.5,
    mesh=None,
    carry_key: bool = False,
    scan_length: Optional[int] = None,
):
    """Compile a whole-horizon runner for an arbitrary volatility model.

    Returns ``(run, state0)``, jitted over ``fl.rounds`` rounds:

    * ``outputs="full"`` — ``run(state, key, xs_in) -> (state, masks, xs, ps,
      sigmas)`` with (T, K)-wide per-round outputs (what
      ``scan_selection_sim`` post-processes).
    * ``outputs="lean"`` — ``run(state, key, xs_in) -> (state, successes,
      sigmas)`` with only (T,) per-round scalars; cumulative selection counts
      live in ``state.sel_counts`` and are bit-identical to the full path.
      Use this at K=1e6-scale horizons where a single (T, K) float32 output
      would dwarf the packed input trace.

    ``vol`` is any ``(init_state, sample)`` implementer — its (pytree) state
    is carried through the scan, so stateful scenario models compile into the
    program.  ``xs_in`` is ``(T, 0)`` for ``override="none"``, the float32
    trace for ``"dense"``, or the uint8 bit-packed trace for ``"packed"``.

    With ``staleness=S`` (int >= 0) the runner compiles the *async* round
    body instead: ``vol`` must be a lag model, a ``(S, K)`` pending-credit
    ring (initialised to zero inside the program) rides in the scan carry,
    and the signatures become

    * full — ``run(state, key, xs_in) -> (state, masks, lags, ps, sigmas,
      arrived)`` where ``arrived[t]`` is the (K,) decayed late credit landing
      at round t;
    * lean — ``run(state, key, xs_in) -> (state, on_time, stale, sigmas)``
      with only (T,) scalars; the staleness-aware CEP accumulates in
      ``state.cep`` (``state.succ_hist`` keeps the on-time part).

    ``S=0`` reproduces today's synchronous drop semantics exactly (late work
    is never credited), and the program stays free of any (S, K) buffer.

    With ``mesh`` set, the whole round body — allocator, Plackett-Luce draw,
    volatility and E3CS update — executes data-parallel over the K-sharded
    device mesh instead (``repro.engine.sharded.build_sharded_scan_runner``;
    packed trace rows shard along K too).  ``carry_key`` / ``scan_length``
    support chunked horizons: the runner scans ``scan_length`` rounds
    (default ``fl.rounds`` — the quota schedule always spans ``fl.rounds``)
    and, when ``carry_key`` is set, returns the carried PRNG key after the
    final state so a disk-streamed replay (``repro.scenarios.replay``) can
    resume the next chunk bit-identically.

    Unlike ``scan_selection_sim`` this builder is not memoised: hold on to the
    returned ``run`` to amortise compilation across repeat calls (the
    scenario harness and benchmarks do).
    """
    if mesh is not None:
        if staleness is not None or carry_key or scan_length is not None:
            raise ValueError("mesh-sharded runners do not support staleness / carry_key / scan_length yet")
        from repro.engine.sharded import build_sharded_scan_runner

        return build_sharded_scan_runner(fl, vol, rho, mesh, override=override, outputs=outputs)
    if outputs not in ("full", "lean"):
        raise ValueError(f"unknown outputs mode {outputs!r} (want 'full' or 'lean')")
    lean = outputs == "lean"
    rho = jnp.asarray(rho, jnp.float32)
    quota_fn = make_quota_schedule(fl.quota, fl.k, fl.K, fl.rounds, fl.quota_frac)
    step = make_sim_step(fl, quota_fn, vol, rho, override=override, lean=lean, staleness=staleness, alpha=alpha)
    state0 = init_server_state({}, fl.K, vol.init_state())
    T = fl.rounds if scan_length is None else int(scan_length)

    if staleness is not None:
        S = int(staleness)
        if carry_key:
            raise ValueError("carry_key is only supported for synchronous runners")

        @jax.jit
        def run_async(state, key, xs_in):
            pending = jnp.zeros((S, fl.K), jnp.float32)
            (state, _, _), out = jax.lax.scan(step, (state, key, pending), None, length=T)
            if lean:
                on_time, stale, sigmas = out
                return state, on_time, stale, sigmas
            masks, lags, ps, sigmas, arrived = out
            return state, masks, lags, ps, sigmas, arrived

        return run_async, state0

    @jax.jit
    def run(state, key, xs_in):
        (state, key), out = jax.lax.scan(step, (state, key), xs_in, length=T)
        head = (state, key) if carry_key else (state,)
        if lean:
            successes, sigmas = out
            return (*head, successes, sigmas)
        masks, xs, ps, sigmas = out
        return (*head, masks, xs, ps, sigmas)

    return run, state0


@functools.lru_cache(maxsize=64)
def _compiled_runner(scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override, allocator):
    """Cache the jitted whole-horizon runner per static configuration, so
    repeat calls (sweeps, benchmarks) pay compilation once."""
    fl = FLConfig(
        K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler,
        allocator=allocator,
    )
    rho = jnp.asarray(paper_success_rates(K))
    vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
    return build_scan_runner(fl, vol, rho, override=override)


def scan_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    packed_override: Optional[np.ndarray] = None,
    vol=None,
    rho=None,
    allocator: str = "sort",
) -> Dict[str, np.ndarray]:
    """Drop-in replacement for the legacy ``selection_sim`` loop.

    ``vol`` (an ``(init_state, sample)`` object) takes precedence over the
    ``volatility`` name; ``packed_override`` streams a ``(T, ceil(K/8))``
    uint8 bit-packed trace through the scan, unpacked on the fly.
    ``allocator="bisect"`` swaps E3CS's sorted ProbAlloc for the sort-free
    bisection (identical to ~1e-6 in p; the sharded engine's reference).
    """
    if xs_override is not None and packed_override is not None:
        raise ValueError("pass at most one of xs_override / packed_override")
    override = "dense" if xs_override is not None else ("packed" if packed_override is not None else "none")
    if vol is not None or rho is not None:
        fl = FLConfig(
            K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler,
            allocator=allocator,
        )
        if rho is None:
            rho = getattr(vol, "rho", None)
        if rho is None:
            rho = paper_success_rates(K)
        if vol is None:
            vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
        run, state = build_scan_runner(fl, vol, rho, override=override)
    else:
        run, state = _compiled_runner(
            scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override, allocator
        )
    key = jax.random.PRNGKey(seed)
    if override == "dense":
        xs_in = jnp.asarray(xs_override, jnp.float32)
    elif override == "packed":
        xs_in = jnp.asarray(packed_override, jnp.uint8)
    else:
        xs_in = jnp.zeros((T, 0), jnp.float32)
    _, masks, xs, ps, sigmas = run(state, key, xs_in)
    masks = np.asarray(masks)
    return {
        "masks": masks,
        "xs": np.asarray(xs),
        "ps": np.asarray(ps),
        "sigmas": np.asarray(sigmas),
        "counts": masks.sum(0),
    }


def async_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    staleness: int = 2,
    alpha: float = 0.5,
    p_late: float = 0.7,
    lag_decay: float = 0.5,
    lag_model=None,
    rho=None,
    outputs: str = "full",
) -> Dict[str, np.ndarray]:
    """Whole-horizon *async* numerical experiment: completion-lag outcomes,
    bounded staleness buffer of ``staleness`` rounds, late credit
    ``alpha**lag``.

    ``lag_model`` is any ``(init_state, sample)`` lag implementer (e.g.
    ``CompletionLag`` over a scenario generator); by default the named
    ``volatility`` model is wrapped in ``CompletionLag(p_late, lag_decay,
    max_lag=max(staleness, 1))``.  Returns per-round ``on_time`` / ``stale``
    credit, the staleness-aware ``cep`` (= on_time + stale, accumulated in
    the carried state so it is exact in lean mode too), and — in full mode —
    the (T, K) masks and lags.
    """
    from repro.core.volatility import CompletionLag  # local: avoid cycles at import time

    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
    if lag_model is None:
        if rho is None:
            rho = paper_success_rates(K)
        base = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
        lag_model = CompletionLag(base, p_late=p_late, lag_decay=lag_decay, max_lag=max(int(staleness), 1))
    if rho is None:
        rho = getattr(lag_model, "rho", None)
    if rho is None:
        rho = paper_success_rates(K)
    run, state = build_scan_runner(fl, lag_model, rho, outputs=outputs, staleness=int(staleness), alpha=alpha)
    key = jax.random.PRNGKey(seed)
    xs_in = jnp.zeros((T, 0), jnp.float32)
    if outputs == "lean":
        state, on_time, stale, sigmas = run(state, key, xs_in)
        out = {}
    else:
        state, masks, lags, ps, sigmas, arrived = run(state, key, xs_in)
        masks = np.asarray(masks)
        arrived = np.asarray(arrived)
        on_time = (masks * (np.asarray(lags) == 0)).sum(1)
        stale = arrived.sum(1)
        out = {"masks": masks, "lags": np.asarray(lags), "ps": np.asarray(ps), "arrived": arrived,
               "counts": masks.sum(0)}
    out.update({
        "on_time": np.asarray(on_time),
        "stale": np.asarray(stale),
        "sigmas": np.asarray(sigmas),
        "cep": float(state.cep),
        "on_time_total": float(state.succ_hist),
        "sel_counts": np.asarray(state.sel_counts),
    })
    return out
