"""Scan-compiled selection simulator: the whole T-round horizon in ONE
compiled program.

The legacy ``repro.core.sim`` loop dispatches ~10 host->device ops per round
(selector update, volatility transition, metric reads), which dominates
wall-clock at paper scale (K=100, T=2500) and makes million-client sweeps
infeasible.  Here the per-round step — ProbAlloc, stochastic selection,
volatility transition, selector update and metrics — is the body of a single
``jax.lax.scan``, so the entire simulation compiles once and runs with zero
per-round Python overhead.

The step replicates the legacy loop's PRNG discipline exactly (carry the key,
``split(key, 3)`` per round), so outputs are bit-identical to
``selection_sim_loop`` for every scheme; ``tests/test_engine.py`` pins this.

Volatility inside the scan comes in three flavours, picked by ``override``:

* ``"none"``   — a *stateful* model object (any ``(init_state, sample)``
  implementer: the built-ins, or ``repro.scenarios`` diurnal / regional /
  flash-crowd / replay models).  Its state rides in ``ServerState.vol_state``
  (an arbitrary pytree), so Markov chains and latent regional factors compile
  into the whole-horizon program.
* ``"dense"``  — a recorded ``(T, K)`` float32 trace streamed through the
  scan's xs input.
* ``"packed"`` — the same trace bit-packed to ``(T, ceil(K/8))`` uint8 (32x
  smaller; K=1e6, T=2500 fits in ~312 MB) and expanded row-by-row inside the
  scan body by ``repro.kernels.unpack_bits`` — selections are bit-identical
  to the dense path (``tests/test_scenarios.py``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.selection import e3cs_update, make_quota_schedule, selection_mask, ucb_update
from repro.core.volatility import make_volatility, paper_success_rates
from repro.fl.round import init_server_state, make_select_fn
from repro.kernels.unpack_bits import unpack_bits

__all__ = ["make_sim_step", "build_scan_runner", "scan_selection_sim"]

_OVERRIDE_MODES = ("none", "dense", "packed")


def make_sim_step(
    fl: FLConfig, quota_fn, vol, rho, use_override=False, override: Optional[str] = None, lean: bool = False
):
    """Build the per-round scan body ``step((state, key), x_over) -> ...``.

    Mirrors the legacy loop body op-for-op so results stay bit-identical.
    ``override`` picks the success-bit source (see module docstring);
    ``use_override`` is the legacy bool spelling of ``"dense"``.  With
    ``lean=True`` the step emits only per-round scalars (successes, sigma)
    instead of the (K,)-wide mask/x/p rows — the state math is unchanged, so
    cumulative counts stay bit-identical while scan outputs drop from
    O(T*K) to O(T), which is what makes the full T=2500 horizon feasible at
    K=1e6 (full outputs would be ~10 GB per (T, K) float32 array).
    """
    mode = override if override is not None else ("dense" if use_override else "none")
    if mode not in _OVERRIDE_MODES:
        raise ValueError(f"unknown override mode {mode!r} (want one of {_OVERRIDE_MODES})")
    select = make_select_fn(fl, quota_fn, rho)
    K, k, scheme = fl.K, fl.k, fl.scheme

    def step(carry, x_over):
        state, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        if mode == "dense":
            x, vs = x_over, state.vol_state
        elif mode == "packed":
            x, vs = unpack_bits(x_over, K), state.vol_state
        else:
            x, vs = vol.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        e3cs = state.e3cs
        if scheme == "e3cs":
            e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)  # pow-d loss proxy
        ucb = state.ucb
        if scheme == "ucb":
            ucb = ucb_update(state.ucb, idx, x)
        state = state._replace(
            e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
            sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
        )
        out = (jnp.vdot(mask, x), sigma) if lean else (mask, x, p, sigma)
        return (state, key), out

    return step


def build_scan_runner(fl: FLConfig, vol, rho, override: str = "none", outputs: str = "full"):
    """Compile a whole-horizon runner for an arbitrary volatility model.

    Returns ``(run, state0)``, jitted over ``fl.rounds`` rounds:

    * ``outputs="full"`` — ``run(state, key, xs_in) -> (state, masks, xs, ps,
      sigmas)`` with (T, K)-wide per-round outputs (what
      ``scan_selection_sim`` post-processes).
    * ``outputs="lean"`` — ``run(state, key, xs_in) -> (state, successes,
      sigmas)`` with only (T,) per-round scalars; cumulative selection counts
      live in ``state.sel_counts`` and are bit-identical to the full path.
      Use this at K=1e6-scale horizons where a single (T, K) float32 output
      would dwarf the packed input trace.

    ``vol`` is any ``(init_state, sample)`` implementer — its (pytree) state
    is carried through the scan, so stateful scenario models compile into the
    program.  ``xs_in`` is ``(T, 0)`` for ``override="none"``, the float32
    trace for ``"dense"``, or the uint8 bit-packed trace for ``"packed"``.

    Unlike ``scan_selection_sim`` this builder is not memoised: hold on to the
    returned ``run`` to amortise compilation across repeat calls (the
    scenario harness and benchmarks do).
    """
    if outputs not in ("full", "lean"):
        raise ValueError(f"unknown outputs mode {outputs!r} (want 'full' or 'lean')")
    lean = outputs == "lean"
    rho = jnp.asarray(rho, jnp.float32)
    quota_fn = make_quota_schedule(fl.quota, fl.k, fl.K, fl.rounds, fl.quota_frac)
    step = make_sim_step(fl, quota_fn, vol, rho, override=override, lean=lean)
    state0 = init_server_state({}, fl.K, vol.init_state())
    T = fl.rounds

    @jax.jit
    def run(state, key, xs_in):
        (state, _), out = jax.lax.scan(step, (state, key), xs_in, length=T)
        if lean:
            successes, sigmas = out
            return state, successes, sigmas
        masks, xs, ps, sigmas = out
        return state, masks, xs, ps, sigmas

    return run, state0


@functools.lru_cache(maxsize=64)
def _compiled_runner(scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override):
    """Cache the jitted whole-horizon runner per static configuration, so
    repeat calls (sweeps, benchmarks) pay compilation once."""
    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
    rho = jnp.asarray(paper_success_rates(K))
    vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
    return build_scan_runner(fl, vol, rho, override=override)


def scan_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    packed_override: Optional[np.ndarray] = None,
    vol=None,
    rho=None,
) -> Dict[str, np.ndarray]:
    """Drop-in replacement for the legacy ``selection_sim`` loop.

    ``vol`` (an ``(init_state, sample)`` object) takes precedence over the
    ``volatility`` name; ``packed_override`` streams a ``(T, ceil(K/8))``
    uint8 bit-packed trace through the scan, unpacked on the fly.
    """
    if xs_override is not None and packed_override is not None:
        raise ValueError("pass at most one of xs_override / packed_override")
    override = "dense" if xs_override is not None else ("packed" if packed_override is not None else "none")
    if vol is not None or rho is not None:
        fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
        if rho is None:
            rho = getattr(vol, "rho", None)
        if rho is None:
            rho = paper_success_rates(K)
        if vol is None:
            vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
        run, state = build_scan_runner(fl, vol, rho, override=override)
    else:
        run, state = _compiled_runner(
            scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override
        )
    key = jax.random.PRNGKey(seed)
    if override == "dense":
        xs_in = jnp.asarray(xs_override, jnp.float32)
    elif override == "packed":
        xs_in = jnp.asarray(packed_override, jnp.uint8)
    else:
        xs_in = jnp.zeros((T, 0), jnp.float32)
    _, masks, xs, ps, sigmas = run(state, key, xs_in)
    masks = np.asarray(masks)
    return {
        "masks": masks,
        "xs": np.asarray(xs),
        "ps": np.asarray(ps),
        "sigmas": np.asarray(sigmas),
        "counts": masks.sum(0),
    }
