"""Scan-compiled selection simulator: the whole T-round horizon in ONE
compiled program.

The legacy ``repro.core.sim`` loop dispatches ~10 host->device ops per round
(selector update, volatility transition, metric reads), which dominates
wall-clock at paper scale (K=100, T=2500) and makes million-client sweeps
infeasible.  Here the per-round step — ProbAlloc, stochastic selection,
volatility transition, selector update and metrics — is the body of a single
``jax.lax.scan``, so the entire simulation compiles once and runs with zero
per-round Python overhead.

The step replicates the legacy loop's PRNG discipline exactly (carry the key,
``split(key, 3)`` per round), so outputs are bit-identical to
``selection_sim_loop`` for every scheme; ``tests/test_engine.py`` pins this.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.selection import e3cs_update, make_quota_schedule, selection_mask, ucb_update
from repro.core.volatility import BernoulliVolatility, MarkovVolatility, paper_success_rates
from repro.fl.round import init_server_state, make_select_fn

__all__ = ["make_sim_step", "scan_selection_sim"]


def make_sim_step(fl: FLConfig, quota_fn, vol, rho, use_override: bool = False):
    """Build the per-round scan body ``step((state, key), x_over) -> ...``.

    Mirrors the legacy loop body op-for-op so results stay bit-identical.
    """
    select = make_select_fn(fl, quota_fn, rho)
    K, k, scheme = fl.K, fl.k, fl.scheme

    def step(carry, x_over):
        state, key = carry
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        if use_override:
            x, vs = x_over, state.vol_state
        else:
            x, vs = vol.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        e3cs = state.e3cs
        if scheme == "e3cs":
            e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)  # pow-d loss proxy
        ucb = state.ucb
        if scheme == "ucb":
            ucb = ucb_update(state.ucb, idx, x)
        state = state._replace(
            e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
            sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
        )
        return (state, key), (mask, x, p, sigma)

    return step


@functools.lru_cache(maxsize=64)
def _compiled_runner(scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, use_override):
    """Cache the jitted whole-horizon runner per static configuration, so
    repeat calls (sweeps, benchmarks) pay compilation once."""
    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
    rho = jnp.asarray(paper_success_rates(K))
    vol = MarkovVolatility(rho, stickiness) if volatility == "markov" else BernoulliVolatility(rho)
    quota_fn = make_quota_schedule(quota, k, K, T, frac)
    step = make_sim_step(fl, quota_fn, vol, rho, use_override)
    state = init_server_state({}, K, vol.init_state())

    @jax.jit
    def run(state, key, xs_in):
        (state, _), (masks, xs, ps, sigmas) = jax.lax.scan(step, (state, key), xs_in, length=T)
        return state, masks, xs, ps, sigmas

    return run, state


def scan_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Drop-in replacement for the legacy ``selection_sim`` loop."""
    use_override = xs_override is not None
    run, state = _compiled_runner(
        scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, use_override
    )
    key = jax.random.PRNGKey(seed)
    xs_in = jnp.asarray(xs_override, jnp.float32) if use_override else jnp.zeros((T, 0), jnp.float32)
    _, masks, xs, ps, sigmas = run(state, key, xs_in)
    masks = np.asarray(masks)
    return {
        "masks": masks,
        "xs": np.asarray(xs),
        "ps": np.asarray(ps),
        "sigmas": np.asarray(sigmas),
        "counts": masks.sum(0),
    }
