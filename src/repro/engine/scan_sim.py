"""Scan-compiled selection simulator: the whole T-round horizon in ONE
compiled program.

The legacy ``repro.core.sim`` loop dispatches ~10 host->device ops per round
(selector update, volatility transition, metric reads), which dominates
wall-clock at paper scale (K=100, T=2500) and makes million-client sweeps
infeasible.  Here the per-round step — ProbAlloc, stochastic selection,
volatility transition, selector update and metrics — is the body of a single
``jax.lax.scan``, so the entire simulation compiles once and runs with zero
per-round Python overhead.

Since PR 5 the round body itself lives in ``repro.engine.round_program`` —
the single ``RoundProgram`` every engine entry point (this module, the
K-sharded runner, the legacy host-stepped loop, the FL training server and
the serving drivers) composes its pipeline from.  This module keeps the
historical convenience surface:

* ``build_scan_runner(fl, vol, rho, ...)`` — compile a whole-horizon runner
  (sync or async, dense or mesh-sharded, generated or replayed outcomes);
  a thin constructor over ``RoundProgram.build_runner`` with the same
  output contracts it always had.
* ``scan_selection_sim`` / ``async_selection_sim`` — the numerical
  experiments (drop-in for the legacy ``selection_sim`` loop).
* ``make_sim_step`` — the bare scan body, for callers that scan it
  themselves.

The step replicates the legacy loop's PRNG discipline exactly (carry the
key, ``split(key, 3)`` per round), so outputs are bit-identical to the
pre-refactor engines for every scheme; ``tests/test_round_program.py`` pins
this against committed goldens.

Volatility inside the scan comes in four flavours, picked by ``override``:
``"none"`` (a stateful ``(init_state, sample)`` model whose pytree state
rides in the carry), ``"dense"`` (a recorded ``(T, K)`` trace streamed
through the scan xs), ``"packed"`` (1-bit rows expanded in-scan by
``repro.kernels.unpack_bits``) and — async only — ``"packed_lags"`` (2-bit
completion-lag rows expanded by ``unpack_crumbs``).

Async rounds (``staleness=S``): outcomes generalise from binary
success/fail to a *completion lag* (``repro.core.volatility.CompletionLag``
/ ``BinaryLag``); a bounded ring of ``S`` pending rounds rides in the scan
carry crediting late arrivals ``alpha**lag``.  The selector keeps the
paper's deadline-based feedback by default; ``feedback="late_credit"``
additionally buffers the selection-round allocation so E3CS rewards
late-but-alive clients (see ``round_program``).  With ``S=0`` — or a
``BinaryLag`` at any S — selections, counts and E3CS weights are
**bit-identical** to the synchronous path (``tests/test_async.py``).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.volatility import make_volatility, paper_success_rates
from repro.engine.round_program import RoundProgram, staleness_ring_step

__all__ = [
    "make_sim_step",
    "build_scan_runner",
    "scan_selection_sim",
    "async_selection_sim",
    "staleness_ring_step",
]


def make_sim_step(
    fl: FLConfig,
    quota_fn,
    vol,
    rho,
    use_override=False,
    override: Optional[str] = None,
    lean: bool = False,
    staleness: Optional[int] = None,
    alpha: float = 0.5,
    feedback: str = "deadline",
):
    """Build the per-round scan body ``step(carry, x_over) -> ...`` (the
    dense ``RoundProgram`` body; see that module for the carry/output
    shapes).  ``use_override`` is the legacy bool spelling of ``"dense"``;
    ``quota_fn`` overrides the schedule the program would derive from
    ``fl``.  With ``lean=True`` the step emits only per-round scalars
    instead of (K,)-wide rows — state math unchanged, so cumulative counts
    stay bit-identical while scan outputs drop from O(T*K) to O(T)."""
    mode = override if override is not None else ("dense" if use_override else "none")
    program = RoundProgram(
        fl=fl, vol=vol, rho=rho, override=mode, staleness=staleness, alpha=alpha,
        feedback=feedback, quota_fn=quota_fn,
    )
    step, _ = program.build_step(lean=lean)
    return step


def build_scan_runner(
    fl: FLConfig,
    vol,
    rho,
    override: str = "none",
    outputs: str = "full",
    staleness: Optional[int] = None,
    alpha: float = 0.5,
    mesh=None,
    carry_key: bool = False,
    scan_length: Optional[int] = None,
    feedback: str = "deadline",
    block: int = 1,
    taps: bool = False,
    sketch=None,
    fused: bool = False,
):
    """Compile a whole-horizon runner for an arbitrary volatility model.

    Returns ``(run, state0)``, jitted over ``fl.rounds`` rounds (or
    ``scan_length``), with the ``RoundProgram.build_runner`` signatures:

    * sync  full — ``run(state, key, xs_in) -> (state, masks, xs, ps, sigmas)``
    * sync  lean — ``... -> (state, successes, sigmas)``
    * async full — ``... -> (state, masks, lags, ps, sigmas, arrived)``
    * async lean — ``... -> (state, on_time, stale, sigmas)``

    ``vol`` is any ``(init_state, sample)`` implementer (success bits when
    synchronous, completion lags when ``staleness=S``); its pytree state is
    carried through the scan.  ``xs_in`` is ``(T, 0)`` for
    ``override="none"``, the float32 (or int32 lag) trace for ``"dense"``,
    or the packed uint8 trace for ``"packed"`` / ``"packed_lags"``.

    ``mesh`` shards the whole round body over the K axis
    (``repro.engine.sharded`` collectives; packed trace rows shard along K
    too).  ``carry_key`` / ``scan_length`` support chunked horizons: the
    runner returns the carried PRNG key (and async rings) so a disk-streamed
    replay (``repro.scenarios.replay``) can resume the next chunk
    bit-identically — in every placement.

    ``taps=True`` enables the in-scan telemetry stage: the runner's output
    tuple gains one trailing ``{"series": {gauge: (T,)}, "counters":
    {counter: scalar}}`` payload in the ``repro.obs.ROUND_TAPS`` schema —
    identical across placements, bit-identical outputs otherwise.  With
    ``carry_key=True`` the counters ride the carry instead, so chunked
    horizons window identically to one-shot ones.

    ``sketch=SketchSpec(...)`` (requires ``taps=True``, one-shot only —
    incompatible with ``carry_key``) additionally runs the client-axis
    sketch stage inside the scan: the taps payload gains a ``"sketches"``
    key of fixed-size mergeable region/count/lag histograms
    (``repro.obs.sketches``; shard streams merge via ``merge_sketches``,
    ``fairness_series`` turns them into Jain/Gini/top-share).

    ``fused=True`` (E3CS + plackett_luce only) swaps the staged
    allocate-epilogue/perturb/top-k and observe/update/credit stages for the
    one-pass fused kernels in ``repro.kernels.round_fused`` — bit-identical
    to the staged pipeline (pinned against the same goldens), default off.

    Unlike ``scan_selection_sim`` this builder is not memoised: hold on to
    the returned ``run`` to amortise compilation across repeat calls (the
    scenario harness and benchmarks do).
    """
    program = RoundProgram(
        fl=fl, vol=vol, rho=rho, override=override, staleness=staleness, alpha=alpha,
        feedback=feedback, mesh=mesh, block=block, fused=fused,
    )
    return program.build_runner(
        outputs=outputs, carry_key=carry_key, scan_length=scan_length, taps=taps, sketch=sketch
    )


@functools.lru_cache(maxsize=64)
def _compiled_runner(scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override, allocator,
                     taps=False, fused=False):
    """Cache the jitted whole-horizon runner per static configuration, so
    repeat calls (sweeps, benchmarks) pay compilation once."""
    fl = FLConfig(
        K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler,
        allocator=allocator,
    )
    rho = jnp.asarray(paper_success_rates(K))
    vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
    return build_scan_runner(fl, vol, rho, override=override, taps=taps, fused=fused)


def scan_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    packed_override: Optional[np.ndarray] = None,
    vol=None,
    rho=None,
    allocator: str = "sort",
    taps: bool = False,
    fused: bool = False,
) -> Dict[str, np.ndarray]:
    """Drop-in replacement for the legacy ``selection_sim`` loop.

    ``vol`` (an ``(init_state, sample)`` object) takes precedence over the
    ``volatility`` name; ``packed_override`` streams a ``(T, ceil(K/8))``
    uint8 bit-packed trace through the scan, unpacked on the fly.
    ``allocator="bisect"`` swaps E3CS's sorted ProbAlloc for the sort-free
    bisection (identical to ~1e-6 in p; the sharded engine's reference).
    ``taps=True`` adds a ``"taps"`` entry — per-round ``ROUND_TAPS`` gauge
    series plus final counters — without perturbing any other output.
    """
    if xs_override is not None and packed_override is not None:
        raise ValueError("pass at most one of xs_override / packed_override")
    override = "dense" if xs_override is not None else ("packed" if packed_override is not None else "none")
    if vol is not None or rho is not None:
        fl = FLConfig(
            K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler,
            allocator=allocator,
        )
        if rho is None:
            rho = getattr(vol, "rho", None)
        if rho is None:
            rho = paper_success_rates(K)
        if vol is None:
            vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
        run, state = build_scan_runner(fl, vol, rho, override=override, taps=taps, fused=fused)
    else:
        run, state = _compiled_runner(
            scheme, K, k, T, quota, frac, eta, sampler, volatility, stickiness, seed, override, allocator, taps,
            fused,
        )
    key = jax.random.PRNGKey(seed)
    if override == "dense":
        xs_in = jnp.asarray(xs_override, jnp.float32)
    elif override == "packed":
        xs_in = jnp.asarray(packed_override, jnp.uint8)
    else:
        xs_in = jnp.zeros((T, 0), jnp.float32)
    _, masks, xs, ps, sigmas, *rest = run(state, key, xs_in)
    masks = np.asarray(masks)
    out = {
        "masks": masks,
        "xs": np.asarray(xs),
        "ps": np.asarray(ps),
        "sigmas": np.asarray(sigmas),
        "counts": masks.sum(0),
    }
    if taps:
        out["taps"] = _taps_to_numpy(rest[-1])
    return out


def _taps_to_numpy(payload) -> dict:
    """Host-side view of a runner's trailing taps payload."""
    out = {
        "series": {n: np.asarray(v) for n, v in payload["series"].items()},
        "counters": {n: float(v) for n, v in payload["counters"].items()},
    }
    if "sketches" in payload:
        out["sketches"] = {n: np.asarray(v) for n, v in payload["sketches"].items()}
    return out


def async_selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    staleness: int = 2,
    alpha: float = 0.5,
    p_late: float = 0.7,
    lag_decay: float = 0.5,
    lag_model=None,
    rho=None,
    outputs: str = "full",
    feedback: str = "deadline",
    packed_lag_override: Optional[np.ndarray] = None,
    taps: bool = False,
    fused: bool = False,
) -> Dict[str, np.ndarray]:
    """Whole-horizon *async* numerical experiment: completion-lag outcomes,
    bounded staleness buffer of ``staleness`` rounds, late credit
    ``alpha**lag``.

    ``lag_model`` is any ``(init_state, sample)`` lag implementer (e.g.
    ``CompletionLag`` over a scenario generator); by default the named
    ``volatility`` model is wrapped in ``CompletionLag(p_late, lag_decay,
    max_lag=max(staleness, 1))``.  ``packed_lag_override`` instead streams a
    recorded 2-bit lag trace through the scan (``repro.scenarios.replay``
    crumb format), bit-identical to replaying it via ``ReplayLag``.
    ``feedback="late_credit"`` switches E3CS to the buffered late-arrival
    feedback policy (see ``round_program``).  Returns per-round ``on_time``
    / ``stale`` credit, the staleness-aware ``cep`` (= on_time + stale,
    accumulated in the carried state so it is exact in lean mode too), and —
    in full mode — the (T, K) masks and lags.
    """
    from repro.core.volatility import CompletionLag  # local: avoid cycles at import time

    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
    override = "none" if packed_lag_override is None else "packed_lags"
    if lag_model is None:
        if rho is None:
            rho = paper_success_rates(K)
        base = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
        lag_model = CompletionLag(base, p_late=p_late, lag_decay=lag_decay, max_lag=max(int(staleness), 1))
    if rho is None:
        rho = getattr(lag_model, "rho", None)
    if rho is None:
        rho = paper_success_rates(K)
    run, state = build_scan_runner(
        fl, lag_model, rho, override=override, outputs=outputs, staleness=int(staleness), alpha=alpha,
        feedback=feedback, taps=taps, fused=fused,
    )
    key = jax.random.PRNGKey(seed)
    if override == "packed_lags":
        xs_in = jnp.asarray(packed_lag_override, jnp.uint8)
    else:
        xs_in = jnp.zeros((T, 0), jnp.float32)
    tap_payload = None
    if outputs == "lean":
        state, on_time, stale, sigmas, *rest = run(state, key, xs_in)
        out = {}
    else:
        state, masks, lags, ps, sigmas, arrived, *rest = run(state, key, xs_in)
        masks = np.asarray(masks)
        arrived = np.asarray(arrived)
        on_time = (masks * (np.asarray(lags) == 0)).sum(1)
        stale = arrived.sum(1)
        out = {"masks": masks, "lags": np.asarray(lags), "ps": np.asarray(ps), "arrived": arrived,
               "counts": masks.sum(0)}
    if taps:
        tap_payload = _taps_to_numpy(rest[-1])
    out.update({
        "on_time": np.asarray(on_time),
        "stale": np.asarray(stale),
        "sigmas": np.asarray(sigmas),
        "cep": float(state.cep),
        "on_time_total": float(state.succ_hist),
        "sel_counts": np.asarray(state.sel_counts),
        "final_logw": np.asarray(state.e3cs.logw),
    })
    if tap_payload is not None:
        out["taps"] = tap_payload
    return out
