"""Fleet-scale selection engine.

Sub-modules:
  * ``round_program`` — the ONE composable round body (allocate -> select ->
    observe -> credit -> update), parameterized by placement, staleness and
    feedback policy; every other entry point composes it
  * ``scan_sim``  — whole-horizon ``lax.scan`` simulator (one compiled program)
  * ``sharded``   — sort-free, tiled ProbAlloc + the K-sharded mesh placement
  * ``multi_job`` — batched multi-tenant engine (vmap over J concurrent jobs)

See ``README.md`` in this directory for the stage diagram and scaling model.
"""
from .round_program import RoundProgram, lag_credit_schedule, ring_pop_push, staleness_ring_step
from .scan_sim import async_selection_sim, build_scan_runner, make_sim_step, scan_selection_sim
from .sharded import (
    build_sharded_scan_runner,
    distributed_topk,
    masked_prob_alloc,
    plackett_luce_shmap,
    prob_alloc_sharded,
    prob_alloc_shmap,
    sharded_selection_sim,
)
from .multi_job import (
    MultiJobConfig,
    MultiJobState,
    make_multi_job,
    multi_job_init,
    pack_jobs,
    pad_slots,
    slot_admit,
    slot_retire,
)

__all__ = [
    "RoundProgram",
    "lag_credit_schedule",
    "ring_pop_push",
    "staleness_ring_step",
    "async_selection_sim",
    "build_scan_runner",
    "make_sim_step",
    "scan_selection_sim",
    "build_sharded_scan_runner",
    "distributed_topk",
    "masked_prob_alloc",
    "plackett_luce_shmap",
    "prob_alloc_sharded",
    "prob_alloc_shmap",
    "sharded_selection_sim",
    "MultiJobConfig",
    "MultiJobState",
    "make_multi_job",
    "multi_job_init",
    "pack_jobs",
    "pad_slots",
    "slot_admit",
    "slot_retire",
]
