"""Fleet-scale selection engine.

Sub-modules:
  * ``scan_sim``  — whole-horizon ``lax.scan`` simulator (one compiled program)
  * ``sharded``   — sort-free, tiled ProbAlloc for million-client populations
  * ``multi_job`` — batched multi-tenant engine (vmap over J concurrent jobs)

See ``README.md`` in this directory for the API and scaling model.
"""
from .scan_sim import async_selection_sim, build_scan_runner, make_sim_step, scan_selection_sim
from .sharded import (
    build_sharded_scan_runner,
    distributed_topk,
    masked_prob_alloc,
    plackett_luce_shmap,
    prob_alloc_sharded,
    prob_alloc_shmap,
    sharded_selection_sim,
)
from .multi_job import (
    MultiJobConfig,
    MultiJobState,
    make_multi_job,
    multi_job_init,
    pack_jobs,
)

__all__ = [
    "async_selection_sim",
    "build_scan_runner",
    "make_sim_step",
    "scan_selection_sim",
    "build_sharded_scan_runner",
    "distributed_topk",
    "masked_prob_alloc",
    "plackett_luce_shmap",
    "prob_alloc_sharded",
    "prob_alloc_shmap",
    "sharded_selection_sim",
    "MultiJobConfig",
    "MultiJobState",
    "make_multi_job",
    "multi_job_init",
    "pack_jobs",
]
