"""One RoundProgram: the paper's round pipeline, assembled from stages.

The paper's round is one fixed pipeline —

    allocate (ProbAlloc) -> select (Plackett-Luce) -> observe (volatile
    outcomes) -> credit (staleness ring) -> update (E3CS / selector state)

— yet the repo grew four independent copies of it: the legacy ``core/sim``
loop, ``engine/scan_sim``'s scan bodies, ``engine/sharded``'s shard_map
horizon, and the ``fl/server`` training loop.  Every follow-on (sharded
async rounds, selector credit for late arrivals, real-transport serving)
was blocked on re-implementing it a fifth time.  Client-selection surveys
(Fu et al. 2022; Németh et al. 2022) frame selection policy, participation
model and system scale as *orthogonal axes*; this module makes the
architecture agree:

* **placement** — ``mesh=None`` runs the round dense on one device;
  ``mesh=<1-D device mesh>`` runs the same stages data-parallel over the
  K-sharded mesh (``prob_alloc`` -> ``masked_prob_alloc(axis_name=...)``
  with one scalar ``psum`` per bisection step, Plackett-Luce -> per-shard
  top-k + exact ``(D, k)`` candidate merge, per-shard PRNG via
  ``fold_in(key, shard_index)``).  A 1-device mesh is bit-identical to the
  dense engine (the fold_in is skipped).
* **staleness** — ``staleness=None`` is the synchronous deadline-drop
  round; ``staleness=S`` generalises outcomes to completion lags and rides
  a bounded ``(S, K)`` pending-credit ring in the scan carry, crediting a
  client that completes ``l <= S`` rounds late with ``alpha**l``.  ``S=0``
  reproduces the sync drop semantics exactly.  Under a mesh the ring is
  sharded ``(S, K/D)`` — sharded async rounds are a *composition*, not a
  fifth implementation.
* **observe source** — ``override`` picks where outcomes come from:
  ``"none"`` (a stateful ``(init_state, sample)`` model carried through the
  scan), ``"dense"`` (a ``(T, K)`` trace streamed through the scan xs:
  float32 success bits, or int32 lags when async), ``"packed"`` (1-bit
  success rows, 8 clients/byte, expanded in-scan by ``unpack_bits``), or
  ``"packed_lags"`` (2-bit lag rows, 4 clients/byte, expanded by
  ``unpack_crumbs`` — the async twin of ``"packed"``).  Under a mesh the
  packed rows shard along the byte axis, so replay memory divides by D.
* **feedback policy** — ``"deadline"`` keeps the paper's selector
  feedback: E3CS observes the on-time bits ``1{lag == 0}`` only.
  ``"late_credit"`` additionally buffers the *selection-round* allocation
  next to the credit ring: when a late-but-alive client's update lands at
  ``t + l``, the estimator receives the decayed reward ``alpha**l`` at the
  buffered importance weight ``1/p_t`` (same Eq. 16/17 math, same
  proof-regime clamp), so persistence is rewarded instead of ignored.
  ``repro.scenarios.harness`` scores the two policies side by side.

``RoundProgram.build_runner`` compiles any combination over a whole
``lax.scan`` horizon with the ``build_scan_runner`` output contracts;
``RoundProgram.from_config`` is the single resolution path from an
``FLConfig`` to a program (the training server and the serving drivers both
construct through it, so staleness / allocator / volatility knobs cannot
drift between entry points).

Bit-identity contract (pinned in ``tests/test_round_program.py`` against
goldens captured from the pre-refactor engines): (S=None, D=1) matches the
old ``scan_sim`` sync engine for all five schemes and every observe source;
(S=2, D=1) matches the old async engine; mesh=1 matches the dense
``allocator="bisect"`` engine.  The PRNG discipline is the one every
engine shared: carry the key, ``split(key, 3)`` per round, ``k1`` to
selection, ``k2`` to the outcome draw.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import FLConfig
from repro.core.selection import (
    E3CSState,
    e3cs_init,
    e3cs_probs,
    e3cs_update,
    fedcs_select,
    make_quota_schedule,
    pow_d_select,
    random_select,
    selection_mask,
    ucb_init,
    ucb_select,
    ucb_update,
)
from repro.core.selection.sampling import merge_topk_candidates, perturbed_scores
from repro.core.volatility import DEAD_LAG
from repro.engine.sharded import (
    _axis_size,
    _pad0,
    _shard_topk_merge,
    _shmap,
    masked_prob_alloc,
    masked_prob_alloc_scalars,
)
from repro.fl.round import ServerState, init_server_state, make_select_fn
from repro.kernels.round_fused import fused_alloc_select, fused_perturb_select, fused_round_tail
from repro.kernels.unpack_bits import unpack_bits, unpack_crumbs
from repro.obs.sketches import SKETCH_FIELDS, SketchSpec, lag_bins, region_ids, sketch_carry0, sketch_step
from repro.obs.taps import ROUND_TAPS
from repro.obs.trace import stage

__all__ = [
    "RoundProgram",
    "ring_pop_push",
    "lag_credit_schedule",
    "staleness_ring_step",
    "OBSERVE_MODES",
    "FEEDBACK_MODES",
]

OBSERVE_MODES = ("none", "dense", "packed", "packed_lags")
FEEDBACK_MODES = ("deadline", "late_credit")
_LAG_DEAD_CODE = 3  # 2-bit crumb sentinel (see repro.scenarios.replay)


# ---------------------------------------------------------------------------
# The staleness ring — single source for every engine and the serving loop
# ---------------------------------------------------------------------------


def ring_pop_push(pending, sched):
    """One generic bounded-ring update: pop slot 0 (due now), shift, add the
    newly scheduled rows.

    ``pending`` is ``(..., S, K)`` — slot s holds the value arriving s rounds
    from now; ``sched`` is the ``(..., S, K)`` rows to schedule (slot s lands
    ``s + 1`` rounds from now).  Returns ``(arriving, new_pending)``.  Both
    the late-*credit* ring (CEP accounting, aggregation weights) and the
    late-*feedback* ring (buffered E3CS updates) are instances of this.
    """
    arriving = pending[..., 0, :]
    shifted = jnp.concatenate(
        [pending[..., 1:, :], jnp.zeros_like(pending[..., :1, :])], axis=-2
    )
    return arriving, shifted + sched


def lag_credit_schedule(mask, lag, S: int, alpha: float):
    """Decayed-credit rows for this round's selections: row s is
    ``mask * 1{lag == s+1} * alpha**(s+1)`` — the ``(..., S, K)`` schedule a
    lag draw pushes into a ring.  ``mask`` / ``lag`` are ``(..., K)`` (any
    leading batch axes, e.g. the multi-job J axis)."""
    decay = jnp.asarray([alpha ** (s + 1) for s in range(S)], jnp.float32)
    lag_rows = jnp.arange(1, S + 1, dtype=jnp.int32)
    return mask[..., None, :] * (lag[..., None, :] == lag_rows[:, None]) * decay[:, None]


def staleness_ring_step(pending, mask, lag, S: int, alpha: float):
    """One update of the bounded staleness-credit ring; returns
    ``(arriving, new_pending)``.  ``S=0`` is the synchronous no-ring case
    (nothing arrives, pending unchanged)."""
    if S == 0:
        return jnp.zeros_like(mask), pending
    return ring_pop_push(pending, lag_credit_schedule(mask, lag, S, alpha))


# ---------------------------------------------------------------------------
# Placement contexts: what differs between dense and K-sharded execution
# ---------------------------------------------------------------------------


class _LocalCtx:
    """Dense single-placement stage context (the D=1 reference)."""

    def __init__(self, program: "RoundProgram"):
        fl = program.fl
        self.K_loc = fl.K
        self.active = None
        self.e3cs_kwargs = {}
        K, k = fl.K, fl.k

        if program.fused:
            # fused allocate-epilogue + perturb + top-k: the Gumbel field is
            # drawn with the staged sampler's exact call so the staged and
            # fused engines consume identical noise (bit-identity contract)
            allocator = getattr(fl, "allocator", "sort")
            quota_fn = program.quota_fn

            def select(state, rng):
                sigma = quota_fn(state.t)
                g = jax.random.gumbel(rng, (K,), jnp.float32)
                if allocator == "bisect":
                    with stage("round.allocate"):
                        w = jnp.exp(state.e3cs.logw - jnp.max(state.e3cs.logw))
                        scalars = masked_prob_alloc_scalars(w, k, sigma)
                    with stage("round.sample"):
                        p, capped, _, idx = fused_alloc_select(
                            w, g, k, sigma=sigma, scalars=scalars
                        )
                else:
                    with stage("round.allocate"):
                        p, capped = e3cs_probs(state.e3cs, k, sigma)
                    with stage("round.sample"):
                        _, idx = fused_perturb_select(p, g, k)
                return idx, p, capped, sigma, selection_mask(idx, K)

        else:
            base = make_select_fn(fl, program.quota_fn, program.rho)

            def select(state, rng):
                idx, p, capped, sigma = base(state, rng)
                return idx, p, capped, sigma, selection_mask(idx, K)

        self.select = select
        self.observe = _make_observe(program, K_loc=K, fold=lambda key: key)

    @staticmethod
    def psum(v):
        return v

    @staticmethod
    def pmax(v):
        return v

    @staticmethod
    def gather(x):
        return x


class _ShardCtx:
    """Per-shard stage context, built *inside* the ``shard_map`` body (it
    closes over the traced shard index)."""

    def __init__(self, program: "RoundProgram", vol_loc, rho_full, active_loc, Ks: int, D: int):
        fl = program.fl
        axis_name = program.axis_name
        d = jax.lax.axis_index(axis_name)
        K, k, scheme = fl.K, fl.k, fl.scheme
        self.K_loc = Ks
        self.active = active_loc
        self.e3cs_kwargs = dict(K=K, axis_name=axis_name, active=active_loc)
        quota_fn = program.quota_fn

        def select(state, k1):
            sigma = quota_fn(state.t)
            capped = jnp.zeros((Ks,), bool)
            if scheme == "e3cs":
                logw = state.e3cs.logw
                gmax = jax.lax.pmax(
                    jnp.max(jnp.where(active_loc > 0, logw, -jnp.inf)), axis_name
                )
                w = jnp.exp(logw - gmax) * active_loc
                if program.fused:
                    # one VMEM pass: allocation epilogue + perturb + local
                    # top-k; only the bisection scalars and the (D, k)
                    # candidate merge cross shards
                    k_sel = jax.random.fold_in(k1, d) if D > 1 else k1
                    g = jax.random.gumbel(k_sel, (Ks,), jnp.float32)
                    with stage("round.allocate"):
                        scalars = masked_prob_alloc_scalars(
                            w, k, sigma, active=active_loc, n_iters=program.n_iters,
                            tile=program.tile, axis_name=axis_name, block=program.block,
                        )
                    with stage("round.sample"):
                        p, capped, vals, loc = fused_alloc_select(
                            w, g, k, sigma=sigma, scalars=scalars, active=active_loc
                        )
                        gi = loc + jnp.asarray(d * Ks, jnp.int32)
                        cv = jax.lax.all_gather(vals, axis_name, tiled=True)
                        ci = jax.lax.all_gather(gi, axis_name, tiled=True)
                        idx = merge_topk_candidates(cv, ci, k)
                else:
                    with stage("round.allocate"):
                        p, capped = masked_prob_alloc(
                            w, k, sigma, active=active_loc, n_iters=program.n_iters,
                            tile=program.tile, axis_name=axis_name, block=program.block,
                        )
                    k_sel = jax.random.fold_in(k1, d) if D > 1 else k1
                    scores = jnp.where(active_loc > 0, perturbed_scores(k_sel, p), -jnp.inf)
                    idx = _shard_topk_merge(scores, k, axis_name)
            elif scheme == "random":
                idx = random_select(k1, K, k)
            elif scheme == "fedcs":
                idx = fedcs_select(rho_full, k, k1)
            elif scheme == "ucb":
                idx = ucb_select(state.ucb, k)
            elif scheme == "pow_d":
                loss_full = jax.lax.all_gather(state.loss_cache, axis_name, tiled=True)[:K]
                idx = pow_d_select(k1, loss_full, k, fl.pow_d)
            else:
                raise ValueError(fl.scheme)
            loc = idx - d * Ks
            valid = (loc >= 0) & (loc < Ks)
            mask = jnp.zeros((Ks,), jnp.float32).at[jnp.clip(loc, 0, Ks - 1)].max(
                valid.astype(jnp.float32)
            )
            if scheme == "random":
                p = jnp.full((Ks,), k / K)
            elif scheme != "e3cs":
                p = mask
            return idx, p, capped, sigma, mask

        self.select = select
        fold = (lambda key: jax.random.fold_in(key, d)) if D > 1 else (lambda key: key)
        self.observe = _make_observe(program, K_loc=Ks, fold=fold, vol=vol_loc)
        self.psum = lambda v: jax.lax.psum(v, axis_name)
        self.pmax = lambda v: jax.lax.pmax(v, axis_name)
        self.gather = lambda x: jax.lax.all_gather(x, axis_name, tiled=True)[:K]


def _make_observe(program: "RoundProgram", K_loc: int, fold, vol=None):
    """The observe stage: success bits (sync) or completion lags (async)
    from the program's configured source.  ``k2`` follows the shared PRNG
    discipline even when the source is a trace (the split still happens, the
    key is simply unused) so replayed runs stay bit-identical to generated
    ones given identical outcomes."""
    mode = program.override
    vol = program.vol if vol is None else vol
    is_async = program.staleness is not None

    if mode == "none":

        def observe(x_over, k2, vs):
            return vol.sample(fold(k2), vs)

    elif mode == "dense":
        cast = (lambda x: jnp.asarray(x, jnp.int32)) if is_async else (lambda x: x)

        def observe(x_over, k2, vs):
            return cast(x_over), vs

    elif mode == "packed":

        def observe(x_over, k2, vs):
            return unpack_bits(x_over, K_loc), vs

    else:  # packed_lags

        def observe(x_over, k2, vs):
            codes = unpack_crumbs(x_over, K_loc)
            return jnp.where(codes == _LAG_DEAD_CODE, DEAD_LAG, codes), vs

    return observe


# ---------------------------------------------------------------------------
# The one round body
# ---------------------------------------------------------------------------


def _make_step(program: "RoundProgram", ctx, lean: bool, taps: bool = False,
               sketch: Optional[SketchSpec] = None, region=None):
    """Assemble the scan body from the program's stages and a placement
    context.  This is the single copy of the round pipeline; every engine
    entry point scans (or host-steps) exactly this function.

    Sync carry is ``(state, key)``; async carry is ``(state, key, rings)``
    where ``rings`` is ``(credit,)`` or ``(credit, feedback)`` — see
    ``RoundProgram.init_rings``.  With ``taps=True`` the carry additionally
    threads the ``ROUND_TAPS`` counter pytree as a trailing element and each
    round emits its gauge row as a trailing scan output.  Taps observe
    values the round already computes (psum-reduced under a mesh, so every
    placement emits the identical replicated scalars) and never touch the
    PRNG stream or the state math — taps-on runs are bit-identical to the
    goldens (pinned in ``tests/test_obs.py``).

    With ``sketch=<SketchSpec>`` (requires taps) the carry further threads
    the per-shard sketch accumulators and each round emits a trailing
    *local* sketch row — zeros except every ``sketch.window``-th round,
    gated on the global ``state.t`` (``repro.obs.sketches``).  The runner
    merges shards with one post-scan psum and windows the stream; like
    taps, sketches never touch the round's math or PRNG stream.  ``region``
    is the (K_loc,) int32 region-id slab (defaults to the spec's global
    layout — the sharded runner passes the shard slice).
    """
    fl = program.fl
    k, scheme, eta, K_glob = fl.k, fl.scheme, fl.eta, fl.K
    sync = program.staleness is None
    S = 0 if sync else int(program.staleness)
    alpha = program.alpha
    late_fb = (not sync) and program.feedback == "late_credit" and scheme == "e3cs" and S > 0
    fused = program.fused
    if fused:
        # static per-slot credit schedule + in-kernel observe decode kind
        decay = tuple(alpha ** (s + 1) for s in range(S))
        if program.override == "packed":
            kind = "bits"
        elif program.override == "packed_lags":
            kind = "crumbs"
        else:
            kind = "x" if sync else "lag"
    if sketch is not None:
        L = lag_bins(program.staleness)
        if region is None:
            region = jnp.asarray(region_ids(sketch, ctx.K_loc))

    def tap_row(mask, x, sigma, capped, arriving=None):
        stale = jnp.zeros((), jnp.float32) if arriving is None else ctx.psum(jnp.sum(arriving))
        return {
            "selected": ctx.psum(jnp.sum(mask)),
            "on_time": ctx.psum(jnp.vdot(mask, x)),
            "stale": stale,
            "sigma": jnp.asarray(sigma, jnp.float32),
            "capped_frac": ctx.psum(jnp.sum(capped.astype(jnp.float32))) / K_glob,
        }

    def step(carry, x_over):
        tapc = skc = None
        if sync:
            if sketch is not None:
                (state, key, tapc, skc) = carry
            elif taps:
                (state, key, tapc) = carry
            else:
                (state, key) = carry
        else:
            if sketch is not None:
                (state, key, rings, tapc, skc) = carry
            elif taps:
                (state, key, rings, tapc) = carry
            else:
                (state, key, rings) = carry
        key, k1, k2 = jax.random.split(key, 3)
        # allocate + select
        with stage("round.select"):
            idx, p, capped, sigma, mask = ctx.select(state, k1)
        if fused:
            # observe-decode + Eq. 16/17 elementwise + credit rings in ONE
            # fused pass (repro.kernels.round_fused); only the recenter —
            # which needs a cross-tile / cross-shard max — stays out here
            with stage("round.observe"):
                if kind in ("bits", "crumbs"):
                    obs, vs = x_over, state.vol_state  # raw bytes decode in-kernel
                else:
                    obs, vs = ctx.observe(x_over, k2, state.vol_state)
            with stage("round.update"):
                residual = jnp.asarray(k, p.dtype) - K_glob * sigma
                tail = fused_round_tail(
                    obs, mask, p, capped, state.e3cs.logw, state.loss_cache,
                    rings[0] if (not sync and S > 0) else None,
                    rings[1] if late_fb else None,
                    kind=kind, residual=residual, eta=eta, K_glob=K_glob,
                    decay=decay, active=ctx.active,
                )
                x = tail["x"]
                logw = tail["logw_pre"] - ctx.pmax(tail["m"])
                if ctx.active is not None:
                    logw = logw * ctx.active
                e3cs = E3CSState(logw=logw, t=state.e3cs.t + 1)
                loss_cache = tail["loss_cache"]
                ucb = state.ucb
            if not sync:
                lag = tail["lag"]
                with stage("round.credit"):
                    if S == 0:
                        arriving, new_rings = jnp.zeros_like(mask), (rings[0],)
                    else:
                        arriving, new_rings = tail["arriving"], (tail["credit"],)
                    if late_fb:
                        logw = e3cs.logw + tail["arr_fb"]
                        m = jnp.max(logw) if ctx.active is None else jnp.max(
                            jnp.where(ctx.active > 0, logw, -jnp.inf)
                        )
                        logw = logw - ctx.pmax(m)
                        if ctx.active is not None:
                            logw = logw * ctx.active
                        e3cs = e3cs._replace(logw=logw)
                        new_rings = new_rings + (tail["fb"],)
        else:
            # observe
            with stage("round.observe"):
                obs, vs = ctx.observe(x_over, k2, state.vol_state)
            if sync:
                x = obs
            else:
                lag = obs
                x = (lag == 0).astype(jnp.float32)  # deadline-based selector feedback
            # update (selector state; Eq. 16/17 lives in e3cs_update)
            with stage("round.update"):
                e3cs = state.e3cs
                if scheme == "e3cs":
                    e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, eta, **ctx.e3cs_kwargs)
                loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)  # pow-d loss proxy
                ucb = state.ucb
                if scheme == "ucb":
                    ucb = ucb_update(state.ucb, idx, ctx.gather(x))
        if sync:
            state = state._replace(
                e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
                sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
            )
            out = (ctx.psum(jnp.vdot(mask, x)), sigma) if lean else (mask, x, p, sigma)
            if taps:
                row = tap_row(mask, x, sigma, capped)
                new_tapc = ROUND_TAPS.accumulate(tapc, row)
                if sketch is not None:
                    skc2, sk_row = sketch_step(
                        sketch, skc, mask, x, None, p, state.sel_counts, state.t,
                        region, ctx.active, L,
                    )
                    return (state, key, new_tapc, skc2), out + (row, sk_row)
                return (state, key, new_tapc), out + (row,)
            return (state, key), out
        # credit: pop this round's arrivals, push the new late completions
        # (the fused path already did this inside the tail kernel)
        if not fused:
            with stage("round.credit"):
                if S == 0:
                    arriving, pending = jnp.zeros_like(mask), rings[0]
                else:
                    sched = lag_credit_schedule(mask, lag, S, alpha)
                    arriving, pending = ring_pop_push(rings[0], sched)
                new_rings = (pending,)
                if late_fb:
                    # buffer the selection-round importance weight next to the credit
                    # ring: the arriving slot is a ready-to-apply log-weight step
                    # (same residual/clamp as e3cs_update, decayed reward alpha**lag;
                    # the schedule rows are shared with the credit ring above)
                    xhat_rows = sched / jnp.maximum(p, 1e-12)
                    residual = jnp.asarray(k, p.dtype) - K_glob * sigma
                    rows = jnp.minimum(residual * eta * xhat_rows / K_glob, 1.0)
                    frozen = capped if ctx.active is None else capped | (ctx.active == 0)
                    rows = jnp.where(frozen, 0.0, rows)
                    arriving_fb, fb = ring_pop_push(rings[1], rows)
                    logw = e3cs.logw + arriving_fb
                    m = jnp.max(logw) if ctx.active is None else jnp.max(
                        jnp.where(ctx.active > 0, logw, -jnp.inf)
                    )
                    logw = logw - ctx.pmax(m)
                    if ctx.active is not None:
                        logw = logw * ctx.active
                    e3cs = e3cs._replace(logw=logw)
                    new_rings = (pending, fb)
        on_time = ctx.psum(jnp.vdot(mask, x))
        stale = ctx.psum(jnp.sum(arriving))
        state = state._replace(
            e3cs=e3cs, ucb=ucb, vol_state=vs, t=state.t + 1,
            sel_counts=state.sel_counts + mask, loss_cache=loss_cache,
            cep=state.cep + on_time + stale, succ_hist=state.succ_hist + on_time,
        )
        out = (on_time, stale, sigma) if lean else (mask, lag, p, sigma, arriving)
        if taps:
            row = tap_row(mask, x, sigma, capped, arriving)
            new_tapc = ROUND_TAPS.accumulate(tapc, row)
            if sketch is not None:
                skc2, sk_row = sketch_step(
                    sketch, skc, mask, x, lag, p, state.sel_counts, state.t,
                    region, ctx.active, L,
                )
                return (state, key, new_rings, new_tapc, skc2), out + (row, sk_row)
            return (state, key, new_rings, new_tapc), out + (row,)
        return (state, key, new_rings), out

    return step


# ---------------------------------------------------------------------------
# Sharded volatility-model plumbing (nested dataclasses, e.g. CompletionLag)
# ---------------------------------------------------------------------------


def _collect_k_fields(vol, K: int, prefix: str = "") -> dict:
    """Dotted names of the model's per-client ``(K, ...)`` array fields,
    recursing into nested dataclass fields (``CompletionLag.base.rho``)."""
    if not dataclasses.is_dataclass(vol):
        raise TypeError(
            f"sharded rounds need a dataclass volatility model with (K,)-indexed "
            f"array fields (bernoulli / markov / deadline, or a lag wrapper over "
            f"one), got {type(vol).__name__}; replay traces through "
            f"override='packed' / 'packed_lags' instead"
        )
    out = {}
    for f in dataclasses.fields(vol):
        v = getattr(vol, f.name)
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            out.update(_collect_k_fields(v, K, prefix + f.name + "."))
        elif hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1 and v.shape[0] == K:
            out[prefix + f.name] = jnp.asarray(v)
    return out


def _rebuild_vol(vol, arrs: dict):
    """Replace the (possibly nested) fields named by ``_collect_k_fields``
    with their per-shard slabs."""
    if not arrs:
        return vol
    groups: dict = {}
    for name, a in arrs.items():
        head, _, rest = name.partition(".")
        if rest:
            groups.setdefault(head, {})[rest] = a
        else:
            groups[head] = a
    kw = {
        head: _rebuild_vol(getattr(vol, head), v) if isinstance(v, dict) else v
        for head, v in groups.items()
    }
    return dataclasses.replace(vol, **kw)


# ---------------------------------------------------------------------------
# RoundProgram
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundProgram:
    """A composed round pipeline; see the module docstring for the axes.

    ``vol`` is the observe model: a success-bit ``(init_state, sample)``
    implementer when synchronous, a *lag* model when ``staleness`` is set
    (its pytree state rides in the scan carry either way).  For trace
    overrides it only seeds ``vol_state`` (bits come from the trace).
    ``base_vol`` optionally records the underlying success-bit model a lag
    model wraps (``from_config`` fills it) — the training server evaluates
    against it.
    """

    fl: FLConfig
    vol: object
    rho: object
    override: str = "none"
    staleness: Optional[int] = None
    alpha: float = 0.5
    feedback: str = "deadline"
    mesh: Optional[object] = None
    axis_name: str = "shards"
    n_iters: int = 48
    tile: int = 8192
    block: int = 1
    fused: bool = False
    base_vol: object = None
    quota_fn: object = None  # override; default derives the schedule from fl

    def __post_init__(self):
        if self.fused:
            if self.fl.scheme != "e3cs":
                raise ValueError(
                    "fused=True fuses the E3CS allocate/perturb/update stages; "
                    f"scheme {self.fl.scheme!r} has nothing to fuse"
                )
            if self.fl.sampler != "plackett_luce":
                raise ValueError(
                    "fused=True implements the plackett_luce (Gumbel top-k) sampler only"
                )
        if self.override not in OBSERVE_MODES:
            raise ValueError(f"unknown override mode {self.override!r} (want one of {OBSERVE_MODES})")
        if self.feedback not in FEEDBACK_MODES:
            raise ValueError(f"unknown feedback policy {self.feedback!r} (want one of {FEEDBACK_MODES})")
        if self.staleness is None and self.override == "packed_lags":
            raise ValueError("override='packed_lags' replays completion lags; it needs staleness=S (async rounds)")
        if self.staleness is not None and self.override == "packed":
            raise ValueError("async rounds replay 2-bit lag traces: use override='packed_lags', not 'packed'")
        if self.feedback == "late_credit" and self.staleness is None:
            raise ValueError(
                "feedback='late_credit' buffers selection-round allocations in the staleness "
                "ring; it needs staleness=S (S=0 degenerates to deadline feedback)"
            )
        self.rho = jnp.asarray(self.rho, jnp.float32) if self.rho is not None else None
        # materialise the quota schedule OUTSIDE any jit trace: the sharded
        # runner builds its stage context inside the shard_map body, and a
        # schedule first constructed under a trace would cache tracer-backed
        # constants on the program (leaking into later compilations)
        if self.quota_fn is None:
            fl = self.fl
            self.quota_fn = make_quota_schedule(fl.quota, fl.k, fl.K, fl.rounds, fl.quota_frac)

    # -- single knob-resolution path -------------------------------------

    @classmethod
    def from_config(
        cls,
        fl_cfg: FLConfig,
        volatility=None,
        mesh=None,
        feedback: str = "deadline",
        override: str = "none",
        **engine_opts,
    ) -> "RoundProgram":
        """Resolve an ``FLConfig`` (plus an optional volatility override and
        mesh) into a program — the ONE place staleness / allocator /
        volatility knobs are interpreted.  ``repro.fl.FLServer`` and the
        ``repro.launch.select_serve`` drivers both construct through here,
        with a regression test pinning that they cannot drift.

        * volatility: ``fl_cfg.volatility`` (or the ``volatility`` argument)
          resolved by ``repro.fl.server.build_volatility`` — builtin name,
          scenario name, or model object.
        * staleness: ``fl_cfg.staleness_rounds > 0`` wraps the model in
          ``CompletionLag(late_prob, lag_decay, max_lag=S)`` and selects the
          async round body; 0 is the synchronous program.
        * allocator/mesh: a mesh forces the sort-free ``"bisect"`` allocator
          (the sharded round has no sorted path), so the D=1 dense reference
          of a sharded program is ``allocator="bisect"`` by construction.
        """
        from repro.core.volatility import CompletionLag
        from repro.fl.server import build_volatility  # deferred: fl.server imports this module

        vol, rho = build_volatility(fl_cfg, fl_cfg.K, volatility=volatility)
        if mesh is not None and fl_cfg.allocator != "bisect":
            fl_cfg = dataclasses.replace(fl_cfg, allocator="bisect")
        S = int(fl_cfg.staleness_rounds)
        base_vol = vol
        staleness: Optional[int] = None
        if S > 0:
            staleness = S
            vol = CompletionLag(
                vol, p_late=fl_cfg.late_prob, lag_decay=fl_cfg.lag_decay, max_lag=S
            )
        return cls(
            fl=fl_cfg, vol=vol, rho=rho, override=override, staleness=staleness,
            alpha=float(fl_cfg.staleness_alpha), feedback=feedback, mesh=mesh,
            base_vol=base_vol, **engine_opts,
        )

    # -- derived pieces ---------------------------------------------------

    @property
    def lag_model(self):
        """The lag model driving async rounds (None when synchronous)."""
        return self.vol if self.staleness is not None else None

    def select_fn(self):
        """The dense per-round ``select(state, rng) -> (idx, p, capped,
        sigma)`` — the allocate+select stages for host-driven loops (the FL
        training server gathers cohort data between select and train)."""
        return make_select_fn(self.fl, self.quota_fn, self.rho)

    def init_rings(self, K_loc: Optional[int] = None):
        """Zeroed async carry rings: ``(credit,)``, plus the buffered
        feedback ring under ``feedback='late_credit'``.  The per-client
        width defaults to what the program's placement needs — ``fl.K``
        dense, the shard-padded ``K_pad`` under a mesh — so the rings drop
        straight into a ``carry_key`` runner; ``K_loc`` overrides it."""
        S = 0 if self.staleness is None else int(self.staleness)
        if K_loc is None:
            K = self.fl.K if self.mesh is None else self._sharded_geometry()[0]
        else:
            K = int(K_loc)
        rings = (jnp.zeros((S, K), jnp.float32),)
        if self.feedback == "late_credit" and self.fl.scheme == "e3cs" and S > 0:
            rings = rings + (jnp.zeros((S, K), jnp.float32),)
        return rings

    def build_step(self, lean: bool = False, taps: bool = False):
        """The dense scan body ``step(carry, x_over)`` plus its initial
        state — what ``core.sim.selection_sim_loop`` host-steps per round and
        ``build_runner`` scans over the horizon.  With ``taps=True`` the
        carry gains a trailing ``ROUND_TAPS.init_counters()`` pytree and the
        per-round output a trailing gauge row (see ``_make_step``); taps off
        leaves the carry contract exactly as before."""
        if self.mesh is not None:
            raise ValueError("build_step is the dense body; sharded programs compile via build_runner")
        step = _make_step(self, _LocalCtx(self), lean, taps)
        state0 = init_server_state({}, self.fl.K, self.vol.init_state())
        return step, state0

    # -- compiled whole-horizon runners ----------------------------------

    def build_runner(
        self,
        outputs: str = "full",
        carry_key: bool = False,
        scan_length: Optional[int] = None,
        taps: bool = False,
        sketch: Optional[SketchSpec] = None,
    ):
        """Compile the program over a whole horizon; returns ``(run, state0)``.

        Output contracts (the historical ``build_scan_runner`` ones):

        * sync  full — ``run(state, key, xs_in) -> (state, masks, xs, ps, sigmas)``
        * sync  lean — ``... -> (state, successes, sigmas)``
        * async full — ``... -> (state, masks, lags, ps, sigmas, arrived)``
        * async lean — ``... -> (state, on_time, stale, sigmas)``

        ``carry_key=True`` threads the PRNG key (and, async, the rings)
        through the signature so chunked/streamed horizons resume
        bit-identically: sync becomes ``run(state, key, xs_in) -> (state,
        key, *outs)``; async becomes ``run(state, key, rings, xs_in) ->
        (state, key, rings, *outs)`` (seed rings with ``init_rings``).
        ``scan_length`` scans that many rounds instead of ``fl.rounds`` (the
        quota schedule always spans ``fl.rounds``).

        Under a mesh, per-client state, trace rows and outputs are padded to
        ``K_pad`` (a multiple of D, of 8·D for ``"packed"``, of 4·D for
        ``"packed_lags"``); slice ``[:K]``.

        ``taps=True`` appends one trailing payload to every contract above:
        ``{"series": {gauge: (T,)}, "counters": {counter: scalar}}`` — the
        ``ROUND_TAPS`` schema, identical for every placement.  With
        ``carry_key=True`` the taps counters thread through the streamed
        carry instead: seed them with ``ROUND_TAPS.init_counters()`` and the
        signature becomes sync ``run(state, key, tapc, xs_in) -> (state,
        key, tapc, *outs, series)`` / async ``run(state, key, rings, tapc,
        xs_in) -> (state, key, rings, tapc, *outs, series)``, where
        ``series`` is the per-chunk ``{gauge: (T,)}`` row dict — concatenate
        chunks host-side (``repro.scenarios.replay.replay_packed_stream``
        does); chunked and one-shot streams are bit-identical.

        ``sketch=<SketchSpec>`` (requires ``taps=True``, one-shot only)
        additionally runs the client-axis sketch stage
        (``repro.obs.sketches``): the taps payload gains a ``"sketches"``
        key mapping ``SKETCH_FIELDS`` to ``(T // window, ...)`` streams —
        psum-merged under a mesh, so every placement emits the identical
        stream, and bit-identical to sketches-off runs on every other
        output.
        """
        if outputs not in ("full", "lean"):
            raise ValueError(f"unknown outputs mode {outputs!r} (want 'full' or 'lean')")
        if sketch is not None and not taps:
            raise ValueError("sketch streams ride the taps stage; pass taps=True")
        if sketch is not None and carry_key:
            raise ValueError(
                "sketch streams are one-shot (the windowed emission is sliced in-jit); "
                "chunked carry_key horizons stream taps counters instead"
            )
        lean = outputs == "lean"
        T = self.fl.rounds if scan_length is None else int(scan_length)
        if self.mesh is None:
            return self._build_local_runner(lean, carry_key, T, taps, sketch)
        return self._build_sharded_runner(lean, carry_key, T, taps, sketch)

    def _build_local_runner(self, lean: bool, carry_key: bool, T: int, taps: bool,
                            sketch: Optional[SketchSpec] = None):
        step = _make_step(self, _LocalCtx(self), lean, taps, sketch)
        state0 = init_server_state({}, self.fl.K, self.vol.init_state())
        sync = self.staleness is None
        tap0 = ROUND_TAPS.init_counters() if taps else None
        if sketch is not None:
            W = sketch.window
            sk0 = sketch_carry0(self.fl.K, lag_bins(self.staleness))

        if sync:
            if sketch is not None:

                @jax.jit
                def run_sketch(state, key, xs_in):
                    (state, key, tapc, _), out = jax.lax.scan(
                        step, (state, key, tap0, sk0), xs_in, length=T
                    )
                    *outs, row, sk = out
                    stream = jax.tree.map(lambda a: a[W - 1 :: W], sk)
                    return (state, *outs, {"series": row, "counters": tapc, "sketches": stream})

                return run_sketch, state0

            if taps and carry_key:

                @jax.jit
                def run_stream(state, key, tapc, xs_in):
                    (state, key, tapc), out = jax.lax.scan(step, (state, key, tapc), xs_in, length=T)
                    *outs, row = out
                    return (state, key, tapc, *outs, row)

                return run_stream, state0

            if taps:

                @jax.jit
                def run_taps(state, key, xs_in):
                    (state, key, tapc), out = jax.lax.scan(step, (state, key, tap0), xs_in, length=T)
                    *outs, row = out
                    return (state, *outs, {"series": row, "counters": tapc})

                return run_taps, state0

            @jax.jit
            def run(state, key, xs_in):
                (state, key), out = jax.lax.scan(step, (state, key), xs_in, length=T)
                head = (state, key) if carry_key else (state,)
                return (*head, *out)

            return run, state0

        init_rings = self.init_rings

        if sketch is not None:

            @jax.jit
            def run_async(state, key, xs_in):
                (state, key, _, tapc, _), out = jax.lax.scan(
                    step, (state, key, init_rings(), tap0, sk0), xs_in, length=T
                )
                *outs, row, sk = out
                stream = jax.tree.map(lambda a: a[W - 1 :: W], sk)
                return (state, *outs, {"series": row, "counters": tapc, "sketches": stream})

        elif taps and carry_key:

            @jax.jit
            def run_async(state, key, rings, tapc, xs_in):
                (state, key, rings, tapc), out = jax.lax.scan(
                    step, (state, key, rings, tapc), xs_in, length=T
                )
                *outs, row = out
                return (state, key, rings, tapc, *outs, row)

        elif carry_key:

            @jax.jit
            def run_async(state, key, rings, xs_in):
                (state, key, rings), out = jax.lax.scan(step, (state, key, rings), xs_in, length=T)
                return (state, key, rings, *out)

        elif taps:

            @jax.jit
            def run_async(state, key, xs_in):
                (state, key, _, tapc), out = jax.lax.scan(
                    step, (state, key, init_rings(), tap0), xs_in, length=T
                )
                *outs, row = out
                return (state, *outs, {"series": row, "counters": tapc})

        else:

            @jax.jit
            def run_async(state, key, xs_in):
                (state, key, _), out = jax.lax.scan(step, (state, key, init_rings()), xs_in, length=T)
                return (state, *out)

        return run_async, state0

    def _sharded_geometry(self):
        """(K_pad, Ks, width, D): padded population, per-shard width, xs row
        width, mesh size — the byte-packed modes pad K to whole shard bytes."""
        fl, D = self.fl, _axis_size(self.mesh, self.axis_name)
        K = fl.K
        if self.override in ("packed", "packed_lags"):
            cpb = 8 if self.override == "packed" else 4  # clients per byte
            B_loc = -(-((K + cpb - 1) // cpb) // D)
            return cpb * B_loc * D, cpb * B_loc, B_loc * D, D
        K_pad = D * (-(-K // D))
        width = K_pad if self.override == "dense" else D
        return K_pad, K_pad // D, width, D

    def _build_sharded_runner(self, lean: bool, carry_key: bool, T: int, taps: bool,
                              sketch: Optional[SketchSpec] = None):
        fl, axis_name = self.fl, self.axis_name
        K, k, scheme = fl.K, fl.k, fl.scheme
        sync = self.staleness is None
        S = 0 if sync else int(self.staleness)
        if scheme == "e3cs" and fl.sampler != "plackett_luce":
            raise ValueError("the sharded engine only implements the plackett_luce sampler")
        K_pad, Ks, width, D = self._sharded_geometry()
        if scheme == "e3cs" and k > Ks:
            raise ValueError(f"k={k} exceeds the shard width {Ks}; need k <= K_pad/D for per-shard top-k")
        active = (jnp.arange(K_pad) < K).astype(jnp.float32)
        vol_arrays = (
            {n: _pad0(a, K_pad) for n, a in _collect_k_fields(self.vol, K).items()}
            if self.override == "none"
            else {}
        )
        vs0 = jax.tree.map(
            lambda a: _pad0(a, K_pad) if getattr(a, "ndim", 0) >= 1 and a.shape[0] == K else a,
            self.vol.init_state(),
        )
        vs_spec = jax.tree.map(
            lambda a: P(axis_name) if getattr(a, "ndim", 0) >= 1 and a.shape[0] == K_pad else P(), vs0
        )
        rho_rep = self.rho if scheme == "fedcs" else jnp.zeros((1,), jnp.float32)

        state0 = ServerState(
            params={},
            e3cs=e3cs_init(K_pad),
            ucb=ucb_init(K),  # replicated (small selector state)
            loss_cache=jnp.full((K_pad,), 1e9, jnp.float32),
            vol_state=vs0,
            t=jnp.zeros((), jnp.int32),
            sel_counts=jnp.zeros((K_pad,), jnp.float32),
            cep=jnp.zeros((), jnp.float32),
            succ_hist=jnp.zeros((), jnp.float32),
        )
        state_spec = ServerState(
            params={},
            e3cs=E3CSState(logw=P(axis_name), t=P()),
            ucb=jax.tree.map(lambda _: P(), state0.ucb),
            loss_cache=P(axis_name),
            vol_state=vs_spec,
            t=P(),
            sel_counts=P(axis_name),
            cep=P(),
            succ_hist=P(),
        )
        rings0 = self.init_rings() if not sync else ()  # sized (S, K_pad) via the mesh geometry
        rings_spec = tuple(P(None, axis_name) for _ in rings0)
        # tap rows/counters are psum-reduced inside the body -> replicated P()
        tap0 = ROUND_TAPS.init_counters() if taps else {}
        tap_spec = {n: P() for n in tap0}
        row_spec = {n: P() for n in ROUND_TAPS.gauge_names()}
        # the sketch stream is psum-merged after the scan -> replicated P();
        # the per-shard sketch carry never crosses the shard_map boundary
        if sketch is not None:
            W = sketch.window
            L = lag_bins(self.staleness)
            region_pad = jnp.asarray(_pad0(jnp.asarray(region_ids(sketch, K)), K_pad), jnp.int32)
            sk_spec = {n: P() for n in SKETCH_FIELDS}
        else:
            region_pad = jnp.zeros((K_pad,), jnp.int32)
        program = self

        def horizon(state, key, rings, tapc, xs, vol_arr, rho_full, active_loc, region_loc):
            vol_loc = _rebuild_vol(program.vol, vol_arr)
            ctx = _ShardCtx(program, vol_loc, rho_full, active_loc, Ks, D)
            step = _make_step(program, ctx, lean, taps, sketch,
                              region_loc if sketch is not None else None)
            tail = (tapc,) if taps else ()
            if sketch is not None:
                tail = tail + (sketch_carry0(Ks, L),)
            carry0 = ((state, key) if sync else (state, key, rings)) + tail
            carry, out = jax.lax.scan(step, carry0, xs, length=T)
            new_rings = () if sync else carry[2]
            new_tapc = (carry[2] if sync else carry[3]) if taps else {}
            if sketch is not None:
                *rest, sk = out
                sk = jax.tree.map(lambda a: jax.lax.psum(a, axis_name), sk)
                sk = jax.tree.map(lambda a: a[W - 1 :: W], sk)
                out = tuple(rest) + (sk,)
            return (carry[0], carry[1], new_rings, new_tapc) + out

        if sync:
            out_specs = (P(), P()) if lean else (P(None, axis_name),) * 3 + (P(),)
        else:
            out_specs = (P(), P(), P()) if lean else (
                P(None, axis_name), P(None, axis_name), P(None, axis_name), P(), P(None, axis_name)
            )
        if taps:
            out_specs = out_specs + (row_spec,)
        if sketch is not None:
            out_specs = out_specs + (sk_spec,)
        shm = _shmap(
            horizon,
            self.mesh,
            in_specs=(
                state_spec, P(), rings_spec, tap_spec, P(None, axis_name),
                {n: P(axis_name) for n in vol_arrays}, P(), P(axis_name), P(axis_name),
            ),
            out_specs=(state_spec, P(), rings_spec, tap_spec) + out_specs,
        )
        pad_dtype = {"dense": jnp.int32 if not sync else jnp.float32}.get(self.override, jnp.uint8)

        def _pad_xs(xs_in):
            if self.override == "none":
                return jnp.zeros((T, D), jnp.float32)  # ignored; keeps one scan signature
            xs = jnp.asarray(xs_in, pad_dtype)
            return jnp.pad(xs, ((0, 0), (0, width - xs.shape[1])))

        def _finish(state, tapc, out):
            if not taps:
                return (state, *out)
            if sketch is not None:
                *outs, row, sk = out
                return (state, *outs, {"series": row, "counters": tapc, "sketches": sk})
            *outs, row = out
            return (state, *outs, {"series": row, "counters": tapc})

        if carry_key and sync and taps:

            @jax.jit
            def run(state, key, tapc, xs_in):
                state, key, _, tapc, *out = shm(
                    state, key, (), tapc, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                *outs, row = out
                return (state, key, tapc, *outs, row)

        elif carry_key and sync:

            @jax.jit
            def run(state, key, xs_in):
                state, key, _, _, *out = shm(
                    state, key, (), tap0, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                return (state, key, *out)

        elif carry_key and taps:

            @jax.jit
            def run(state, key, rings, tapc, xs_in):
                state, key, rings, tapc, *out = shm(
                    state, key, rings, tapc, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                *outs, row = out
                return (state, key, rings, tapc, *outs, row)

        elif carry_key:

            @jax.jit
            def run(state, key, rings, xs_in):
                state, key, rings, _, *out = shm(
                    state, key, rings, tap0, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                return (state, key, rings, *out)

        elif sync:

            @jax.jit
            def run(state, key, xs_in):
                state, _, _, tapc, *out = shm(
                    state, key, (), tap0, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                return _finish(state, tapc, out)

        else:

            @jax.jit
            def run(state, key, xs_in):
                state, _, _, tapc, *out = shm(
                    state, key, rings0, tap0, _pad_xs(xs_in), vol_arrays, rho_rep, active, region_pad
                )
                return _finish(state, tapc, out)

        return run, state0
