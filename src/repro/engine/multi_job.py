"""Batched multi-tenant selection engine: J concurrent FL jobs per dispatch.

A selection service does not run one federated population — it runs many
(different products, regions, cohort sizes) and each one only needs a few
hundred microseconds of device time per round.  Dispatching them one by one
wastes the machine on launch overhead.  This module vmaps one E3CS
selection/update step over a ``(J, K_max)``-packed state so a *single* device
program serves every job in the batch per tick.

Heterogeneity (K_j, k_j, sigma_j, eta_j) is handled with padding masks:

  * populations are padded to ``K_max``; ``active`` masks dead slots out of
    the allocator, the sampler and the weight update,
  * cohorts are padded to ``k_max``; selection indices beyond ``k_j`` are
    returned as ``-1`` and contribute nothing to the update.

``job_step`` on a padded row is the *definition* of the single-job engine, so
the batched path is bit-identical to running J independent engines with the
same per-job PRNG keys (pinned by ``tests/test_engine.py``).

The allocator is the sort-free bisection of ``repro.engine.sharded`` — k and
sigma stay traced, which is what makes one compiled program cover jobs of
different shapes.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .sharded import masked_prob_alloc

__all__ = [
    "MultiJobConfig",
    "MultiJobState",
    "pack_jobs",
    "multi_job_init",
    "make_multi_job",
    "slot_admit",
    "slot_retire",
    "pad_slots",
]

_EPS = 1e-20


class MultiJobConfig(NamedTuple):
    """Per-job parameters, packed to ``(J,)`` / ``(J, K_max)`` arrays."""

    k: jax.Array  # (J,) int32 cohort sizes, <= k_max
    sigma: jax.Array  # (J,) float32 absolute fairness floors
    eta: jax.Array  # (J,) float32 learning rates
    active: jax.Array  # (J, K_max) {0,1} client-validity masks


class MultiJobState(NamedTuple):
    """Evolving per-job selector state, packed along the ``J`` axis."""

    logw: jax.Array  # (J, K_max) E3CS log-weights
    t: jax.Array  # (J,) int32 round counters


def pack_jobs(
    Ks: Sequence[int],
    ks: Sequence[int],
    sigma_fracs: Sequence[float],
    etas: Sequence[float],
    K_max: int | None = None,
) -> Tuple[MultiJobConfig, int]:
    """Pad J heterogeneous jobs into one batch; returns (config, k_max)."""
    Ks, ks = list(Ks), list(ks)
    K_max = K_max or max(Ks)
    k_max = max(ks)
    J = len(Ks)
    active = np.zeros((J, K_max), np.float32)
    for j, Kj in enumerate(Ks):
        active[j, :Kj] = 1.0
    sigma = np.asarray([f * kj / Kj for f, kj, Kj in zip(sigma_fracs, ks, Ks)], np.float32)
    cfg = MultiJobConfig(
        k=jnp.asarray(ks, jnp.int32),
        sigma=jnp.asarray(sigma),
        eta=jnp.asarray(etas, jnp.float32),
        active=jnp.asarray(active),
    )
    return cfg, k_max


def multi_job_init(cfg: MultiJobConfig) -> MultiJobState:
    """Fresh state for a packed batch: uniform weights, round counters at 0."""
    J, K_max = cfg.active.shape
    return MultiJobState(logw=jnp.zeros((J, K_max), jnp.float32), t=jnp.zeros((J,), jnp.int32))


def slot_admit(
    cfg: MultiJobConfig, slot: int, K: int, k: int, sigma_frac: float, eta: float
) -> MultiJobConfig:
    """Claim one slot of a packed batch for a new tenant job.

    Pure row edits on the ``(J,)`` / ``(J, K_max)`` config arrays: the first
    ``K`` entries of the slot's ``active`` mask go live, the rest stay dead
    padding, and ``(k, sigma, eta)`` take the job's values.  Because the
    vmapped ``job_step`` reads every per-job parameter from these arrays (k
    and sigma stay traced), admitting a job changes *data*, never shapes —
    the compiled engine step is reused as-is, no recompilation on join.
    ``sigma_frac`` is the fairness floor as a fraction of the uniform rate
    ``k/K`` (the convention ``pack_jobs`` uses).
    """
    K_max = cfg.active.shape[1]
    if not (0 < K <= K_max):
        raise ValueError(f"job population K={K} must be in (0, {K_max}]")
    if not (0 < k <= K):
        raise ValueError(f"cohort size k={k} must be in (0, K={K}]")
    row = (jnp.arange(K_max) < K).astype(jnp.float32)
    return cfg._replace(
        k=cfg.k.at[slot].set(k),
        sigma=cfg.sigma.at[slot].set(sigma_frac * k / K),
        eta=cfg.eta.at[slot].set(eta),
        active=cfg.active.at[slot].set(row),
    )


def slot_retire(cfg: MultiJobConfig, slot: int) -> MultiJobConfig:
    """Release a slot: its ``active`` row goes fully dead (the allocator,
    sampler and update all mask on it), ready for the next ``slot_admit``."""
    return cfg._replace(active=cfg.active.at[slot].set(0.0))


def pad_slots(cfg: MultiJobConfig, state: MultiJobState, new_J: int):
    """Grow a packed batch to ``new_J`` slots (returns ``(cfg, state)``).

    The new slots are dead padding (``active == 0``, ``k = 1`` so the traced
    cohort math stays well-defined); live rows are copied unchanged, so a
    job's selection stream is bit-identical before and after the growth.
    Growing changes the ``J`` axis shape — the caller pays one engine
    recompilation per *distinct* ``new_J``, which is why the serving batcher
    grows along a fixed bucket ladder (``repro.serve.engines``) instead of
    one slot at a time.
    """
    J, K_max = cfg.active.shape
    if new_J < J:
        raise ValueError(f"cannot shrink a batch in place: {J} -> {new_J} slots")
    if new_J == J:
        return cfg, state
    pad = new_J - J

    def grow(a, fill=0):
        widths = ((0, pad),) + ((0, 0),) * (a.ndim - 1)
        return jnp.pad(a, widths, constant_values=fill)

    cfg = MultiJobConfig(
        k=grow(cfg.k, 1), sigma=grow(cfg.sigma), eta=grow(cfg.eta), active=grow(cfg.active)
    )
    state = MultiJobState(logw=grow(state.logw), t=grow(state.t))
    return cfg, state


def make_multi_job(k_max: int, n_iters: int = 48, tile: int = 8192):
    """Build the engine step functions for a padded cohort size ``k_max``.

    Returns ``(job_step, batched_step)``:

      * ``job_step(cfg_row, logw, t, key, x)`` — one job, padded arrays;
        the reference single-job engine.
      * ``batched_step(cfg, state, keys, xs)`` — jitted vmap of ``job_step``
        over the J axis; one device dispatch serves the whole fleet tick.

    Outputs per job: ``idx`` (k_max,) int32 selection, ``-1`` beyond k_j;
    ``mask`` (K_max,) 0/1; ``p`` (K_max,) the allocation used for the draw.
    """

    def job_step(cfg_row: MultiJobConfig, logw, t, key, x):
        active = cfg_row.active
        kf = cfg_row.k.astype(jnp.float32)
        sigma, eta = cfg_row.sigma, cfg_row.eta
        K_act = jnp.sum(active)

        # ProbAlloc over the live slots (Algorithm 2, sort-free)
        neg_inf = jnp.asarray(-jnp.inf, logw.dtype)
        w = jnp.exp(logw - jnp.max(jnp.where(active > 0, logw, neg_inf)))
        p, capped = masked_prob_alloc(w, kf, sigma, active=active, n_iters=n_iters, tile=tile)

        # Plackett-Luce draw: Gumbel top-k over the padded row; slots beyond
        # k_j are reported as -1 and dropped from the mask.
        g = jax.random.gumbel(key, p.shape, p.dtype)
        scores = jnp.where(active > 0, jnp.log(jnp.maximum(p, _EPS)) + g, -jnp.inf)
        _, idx = jax.lax.top_k(scores, k_max)
        idx = idx.astype(jnp.int32)
        valid = jnp.arange(k_max, dtype=jnp.int32) < cfg_row.k
        mask = jnp.zeros(p.shape, p.dtype).at[idx].max(valid.astype(p.dtype))
        idx = jnp.where(valid, idx, -1)

        # E3CS exponential-weight update (Eqs. 16-17) with traced (k, sigma)
        xhat = mask * x / jnp.maximum(p, 1e-12)
        residual = kf - K_act * sigma
        step = jnp.minimum(residual * eta * xhat / jnp.maximum(K_act, 1.0), 1.0)
        new_logw = logw + jnp.where(capped | (active == 0), 0.0, step)
        new_logw = new_logw - jnp.max(jnp.where(active > 0, new_logw, neg_inf))
        new_logw = new_logw * active  # keep dead slots pinned at 0
        return new_logw, t + 1, {"idx": idx, "mask": mask, "p": p, "capped": capped}

    def _batched(cfg: MultiJobConfig, state: MultiJobState, keys, xs):
        logw, t, out = jax.vmap(job_step)(cfg, state.logw, state.t, keys, xs)
        return MultiJobState(logw=logw, t=t), out

    return job_step, jax.jit(_batched)
