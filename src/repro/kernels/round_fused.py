"""Fused round kernels: the E3CS round's per-client work in one VMEM pass.

The staged ``RoundProgram`` pipeline makes 4-5 full passes over the (K,)
client axis per round — allocation epilogue, Gumbel perturbation, top-k,
trace unpack, weight update, credit-ring shift — each a separate kernel (or
XLA op) that round-trips the vectors through HBM.  At K ~ 1e7 the round is
launch- and bandwidth-bound, not compute-bound.  This module collapses the
round into two tiled Pallas kernels, each reading every input tile exactly
once:

* **select** (``fused_alloc_select`` / ``fused_perturb_select``) — rebuild
  the allocation ``p`` from the four scalars of
  ``engine.sharded.masked_prob_alloc_scalars`` (bisection stays outside: it
  is a scalar fixed-point, not a vector pass), add the pre-drawn Gumbel
  noise, and stream the running top-k in VMEM scratch
  (``gumbel_topk.streaming_topk_body``).  The Gumbel vector is drawn
  *outside* with the staged engine's exact ``jax.random.gumbel`` call so
  selections stay bit-reproducible.

* **tail** (``fused_round_tail``) — per tile: unpack the packed 1-bit /
  2-bit trace row (or pass dense outcomes through), derive the on-time bits,
  apply Eq. 16/17's clamped importance-weighted log-weight step with the
  overflow/activity freeze, emit the per-tile re-centering max, refresh the
  pow-d loss cache, and pop/shift/push both staleness rings (late credit +
  late feedback) — everything downstream of the outcome row except the
  global re-centering, which needs a cross-tile (and cross-shard) max and is
  finished by the caller from the (n_tiles,) partial maxes.

Bit-identity contract: with dispatch on the jnp references (``ref.py``) the
fused engine path is staged-op-for-staged-op identical by construction; in
interpret mode the Pallas kernels are pinned bit-identical to the committed
round goldens across {sync, async} x {D=1, D=8} x {dense, 1-bit, 2-bit}
(``tests/test_round_fused.py``).  Dispatch honours ``REPRO_INTERPRET``
(see ``dispatch.py``); ``tile=None`` consults the autotune cache.

Known (measure-zero) divergence: exactly tied perturbed scores may resolve
in a different order than ``lax.top_k``, and a shard with fewer than ``k``
active clients pads its candidate list with ``(NEG_INF, 0)`` instead of
``(-inf, <index>)`` — with continuous Gumbel scores and ``K_active >= k``
per shard (the supported regime) neither is reachable.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.volatility import DEAD_LAG

from .autotune import best_config
from .dispatch import kernel_route
from .gumbel_topk import NEG_INF, streaming_topk_body
from .ref import fused_alloc_select_ref, fused_perturb_select_ref, round_tail_ref

__all__ = [
    "fused_alloc_select",
    "fused_perturb_select",
    "fused_round_tail",
    "fused_select_kernel_call",
    "round_tail_kernel_call",
]

_LAG_DEAD_CODE = 3  # 2-bit crumb sentinel (mirrors engine.round_program)


# ---------------------------------------------------------------------------
# Select: allocation epilogue + perturb + streaming top-k
# ---------------------------------------------------------------------------


def _select_kernel(scal_ref, *refs, k, tile, n_tiles, K, has_active, from_w):
    refs = list(refs)
    w = refs.pop(0)[...]  # weights (from_w) or staged probabilities (from_p)
    g = refs.pop(0)[...]
    act = refs.pop(0)[...] if has_active else None
    if from_w:
        p_ref = refs.pop(0)
        c_ref = refs.pop(0)
    val_ref, idx_ref, best_v, best_i = refs

    ti = pl.program_id(0)
    if from_w:
        sigma, residual, cap, denom = scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3]
        use_cap = scal_ref[4] > 0
        p = sigma + residual * jnp.minimum(w, cap) / denom
        cp = (p >= 1.0 - 1e-6) & use_cap
        p = jnp.clip(p, sigma, 1.0)
        if act is not None:
            p = p * act
            cp = cp & (act > 0)
        p_ref[...] = p
        c_ref[...] = cp.astype(jnp.float32)
    else:
        p = w
    s = jnp.log(jnp.maximum(p, 1e-20)) + g
    pos = ti * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    valid = pos < K
    if act is not None:
        valid = valid & (act > 0)
    s = jnp.where(valid, s, NEG_INF)
    streaming_topk_body(s, val_ref, idx_ref, best_v, best_i, k=k, tile=tile, n_tiles=n_tiles)


def fused_select_kernel_call(
    w: jax.Array,
    g: jax.Array,
    k: int,
    *,
    scalars: Optional[Tuple] = None,
    sigma=None,
    active: Optional[jax.Array] = None,
    tile: int = 8192,
    interpret: bool = False,
):
    """One-pass select.  With ``scalars`` (from_w mode) ``w`` is the masked
    weight vector and the kernel rebuilds ``(p, capped)`` before perturbing;
    without, ``w`` *is* the staged ``p`` and only perturb+top-k run.
    Returns ``(p, capped_f32, vals, idx)`` or ``(vals, idx)``."""
    from_w = scalars is not None
    K = w.shape[0]
    tile = min(tile, max(K, 8))
    K_p = math.ceil(K / tile) * tile
    pad = K_p - K
    if pad:
        w = jnp.pad(w, (0, pad))
        g = jnp.pad(g, (0, pad))
        if active is not None:
            active = jnp.pad(active, (0, pad))
    n_tiles = K_p // tile
    has_active = active is not None

    if from_w:
        residual, cap, denom, use_cap = scalars
        scal = jnp.stack([
            jnp.asarray(sigma, jnp.float32),
            jnp.asarray(residual, jnp.float32),
            jnp.asarray(cap, jnp.float32),
            jnp.asarray(denom, jnp.float32),
            use_cap.astype(jnp.float32),
        ])
    else:
        scal = jnp.zeros((1,), jnp.float32)  # unused; keeps one kernel signature

    vec = pl.BlockSpec((tile,), lambda t: (t,))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM), vec, vec]
    args = [scal, w, g]
    if has_active:
        in_specs.append(vec)
        args.append(active)
    out_specs = []
    out_shape = []
    if from_w:
        out_specs += [vec, vec]
        out_shape += [
            jax.ShapeDtypeStruct((K_p,), jnp.float32),
            jax.ShapeDtypeStruct((K_p,), jnp.float32),
        ]
    out_specs += [pl.BlockSpec((k,), lambda t: (0,)), pl.BlockSpec((k,), lambda t: (0,))]
    out_shape += [
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.int32),
    ]
    kernel = functools.partial(
        _select_kernel, k=k, tile=tile, n_tiles=n_tiles, K=K, has_active=has_active, from_w=from_w
    )
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((k,), jnp.float32), pltpu.VMEM((k,), jnp.int32)],
        interpret=interpret,
    )(*args)
    if from_w:
        p, c, vals, idx = out
        return p[:K], c[:K], vals, idx
    vals, idx = out
    return vals, idx


def fused_alloc_select(
    w: jax.Array,
    g: jax.Array,
    k: int,
    *,
    sigma,
    scalars: Tuple,
    active: Optional[jax.Array] = None,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Dispatching from_w select: ``(p, capped, vals, idx)``, ``idx`` local
    (the sharded caller adds its shard offset, exactly like
    ``local_topk_candidates``)."""
    use_kernel, interp = _route(interpret)
    if not use_kernel:
        return fused_alloc_select_ref(w, g, k, sigma=sigma, scalars=scalars, active=active)
    tile = tile or best_config("round_fused", w.shape[0])["tile"]
    p, c, vals, idx = fused_select_kernel_call(
        w, g, k, scalars=scalars, sigma=sigma, active=active, tile=tile, interpret=interp
    )
    return p, c > 0, vals, idx


def fused_perturb_select(
    p: jax.Array,
    g: jax.Array,
    k: int,
    *,
    active: Optional[jax.Array] = None,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Dispatching from_p select (sorted-allocator path): ``(vals, idx)``."""
    use_kernel, interp = _route(interpret)
    if not use_kernel:
        return fused_perturb_select_ref(p, g, k, active=active)
    tile = tile or best_config("round_fused", p.shape[0])["tile"]
    return fused_select_kernel_call(p, g, k, active=active, tile=tile, interpret=interp)


def _route(interpret: Optional[bool]):
    """Per-call dispatch: explicit ``interpret`` forces the kernel; otherwise
    ``REPRO_INTERPRET`` / backend decide (jnp reference is the CPU default —
    the interpreter would dominate a scanned horizon)."""
    if interpret is not None:
        return True, interpret
    return kernel_route(cpu_kernel_default=False)


# ---------------------------------------------------------------------------
# Tail: unpack + update + credit rings
# ---------------------------------------------------------------------------


def _make_tail_kernel(*, kind, S, late_fb, has_active, eta, K_glob, K, tile, decay):
    is_async = kind in ("crumbs", "lag")

    def kernel(res_ref, *refs):
        refs = list(refs)
        obs = refs.pop(0)[...]
        mask = refs.pop(0)[...]
        p = refs.pop(0)[...]
        cp = refs.pop(0)[...] > 0
        logw = refs.pop(0)[...]
        loss = refs.pop(0)[...]
        act = refs.pop(0)[...] if has_active else None
        credit = refs.pop(0) if S > 0 else None
        fbr = refs.pop(0) if late_fb else None
        x_ref = refs.pop(0)
        lag_ref = refs.pop(0) if is_async else None
        logw_ref = refs.pop(0)
        tmax_ref = refs.pop(0)
        loss_ref = refs.pop(0)
        if S > 0:
            arr_ref = refs.pop(0)
            cr_out = refs.pop(0)
        if late_fb:
            afb_ref = refs.pop(0)
            fb_out = refs.pop(0)

        ti = pl.program_id(0)
        # -- decode the outcome row (same integer ops as unpack_bits/_crumbs)
        lag = None
        if kind == "bits":
            b = obs.astype(jnp.int32)  # (tile//8,)
            shifts = jax.lax.broadcasted_iota(jnp.int32, (tile // 8, 8), 1)
            x = (jnp.right_shift(b[:, None], shifts) & 1).reshape(tile).astype(jnp.float32)
        elif kind == "crumbs":
            b = obs.astype(jnp.int32)  # (tile//4,)
            shifts = jax.lax.broadcasted_iota(jnp.int32, (tile // 4, 4), 1) * 2
            codes = (jnp.right_shift(b[:, None], shifts) & 3).reshape(tile)
            lag = jnp.where(codes == _LAG_DEAD_CODE, DEAD_LAG, codes)
        elif kind == "x":
            x = obs
        else:  # "lag"
            lag = obs
        if lag is not None:
            x = (lag == 0).astype(jnp.float32)  # deadline-based selector feedback
            lag_ref[...] = lag
        x_ref[...] = x

        # -- Eq. 16/17 elementwise (staged op order; recenter is the caller's)
        residual = res_ref[0]
        xhat = mask * x / jnp.maximum(p, 1e-12)
        step = residual * eta * xhat / K_glob
        step = jnp.minimum(step, 1.0)
        frozen = cp if act is None else cp | (act == 0)
        logw_pre = logw + jnp.where(frozen, 0.0, step)
        logw_ref[...] = logw_pre
        pos = ti * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
        valid = pos < K
        if act is not None:
            valid = valid & (act > 0)
        tmax_ref[0] = jnp.max(jnp.where(valid, logw_pre, -jnp.inf))
        loss_ref[...] = jnp.where(mask > 0, 1.0 - x, loss)

        # -- staleness rings: pop slot 0, shift, push this round's schedule
        if S > 0:
            sched = [mask * (lag == s + 1) * decay[s] for s in range(S)]
            arr_ref[...] = credit[0, :]
            for s in range(S):
                nxt = credit[s + 1, :] if s + 1 < S else jnp.zeros((tile,), jnp.float32)
                cr_out[s, :] = nxt + sched[s]
            if late_fb:
                afb_ref[...] = fbr[0, :]
                for s in range(S):
                    row = jnp.minimum(residual * eta * (sched[s] / jnp.maximum(p, 1e-12)) / K_glob, 1.0)
                    row = jnp.where(frozen, 0.0, row)
                    nxt = fbr[s + 1, :] if s + 1 < S else jnp.zeros((tile,), jnp.float32)
                    fb_out[s, :] = nxt + row

    return kernel


def round_tail_kernel_call(
    obs,
    mask,
    p,
    capped,
    logw,
    loss_cache,
    credit=None,
    fb=None,
    *,
    kind: str,
    residual,
    eta: float,
    K_glob: int,
    decay=(),
    active=None,
    tile: int = 8192,
    interpret: bool = False,
):
    """Tiled tail pass; see ``ref.round_tail_ref`` for the exact contract.
    Returns the same dict (``m`` reduced from the per-tile maxes)."""
    K = mask.shape[0]
    is_async = kind in ("crumbs", "lag")
    S = len(decay) if credit is not None else 0
    late_fb = fb is not None
    tile = min(tile, max(K, 8))
    tile = max(8, tile - tile % 8)  # packed rows decode 8 (bits) / 4 (crumbs) per byte
    K_p = math.ceil(K / tile) * tile
    pad = K_p - K
    has_active = active is not None

    vec = pl.BlockSpec((tile,), lambda t: (t,))
    if kind == "bits":
        obs = jnp.pad(obs, (0, K_p // 8 - obs.shape[0]))
        obs_spec = pl.BlockSpec((tile // 8,), lambda t: (t,))
    elif kind == "crumbs":
        obs = jnp.pad(obs, (0, K_p // 4 - obs.shape[0]))
        obs_spec = pl.BlockSpec((tile // 4,), lambda t: (t,))
    else:
        if pad:
            obs = jnp.pad(obs, (0, pad))
        obs_spec = vec
    if pad:
        mask = jnp.pad(mask, (0, pad))
        p = jnp.pad(p, (0, pad), constant_values=1.0)
        capped = jnp.pad(capped.astype(jnp.float32), (0, pad))
        logw = jnp.pad(logw, (0, pad))
        loss_cache = jnp.pad(loss_cache, (0, pad))
        if has_active:
            active = jnp.pad(active, (0, pad))
        if credit is not None:
            credit = jnp.pad(credit, ((0, 0), (0, pad)))
        if fb is not None:
            fb = jnp.pad(fb, ((0, 0), (0, pad)))
    n_tiles = K_p // tile

    ring = pl.BlockSpec((max(S, 1), tile), lambda t: (0, t))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM), obs_spec, vec, vec, vec, vec, vec]
    args = [
        jnp.reshape(residual, (1,)).astype(jnp.float32),
        obs, mask, p, capped.astype(jnp.float32), logw, loss_cache,
    ]
    if has_active:
        in_specs.append(vec)
        args.append(active)
    if S > 0:
        in_specs.append(ring)
        args.append(credit)
        if late_fb:
            in_specs.append(ring)
            args.append(fb)

    out_specs = [vec]
    out_shape = [jax.ShapeDtypeStruct((K_p,), jnp.float32)]  # x
    if is_async:
        out_specs.append(vec)
        out_shape.append(jax.ShapeDtypeStruct((K_p,), jnp.int32))  # lag
    out_specs += [vec, pl.BlockSpec((1,), lambda t: (t,)), vec]
    out_shape += [
        jax.ShapeDtypeStruct((K_p,), jnp.float32),  # logw_pre
        jax.ShapeDtypeStruct((n_tiles,), jnp.float32),  # per-tile masked max
        jax.ShapeDtypeStruct((K_p,), jnp.float32),  # loss_cache
    ]
    if S > 0:
        out_specs += [vec, ring]
        out_shape += [
            jax.ShapeDtypeStruct((K_p,), jnp.float32),  # arriving credit
            jax.ShapeDtypeStruct((S, K_p), jnp.float32),  # shifted credit ring
        ]
    if late_fb:
        out_specs += [vec, ring]
        out_shape += [
            jax.ShapeDtypeStruct((K_p,), jnp.float32),  # arriving feedback
            jax.ShapeDtypeStruct((S, K_p), jnp.float32),  # shifted feedback ring
        ]

    kernel = _make_tail_kernel(
        kind=kind, S=S, late_fb=late_fb, has_active=has_active, eta=eta,
        K_glob=K_glob, K=K, tile=tile, decay=tuple(decay),
    )
    res = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)

    res = list(res)
    out = {"x": res.pop(0)[:K]}
    if is_async:
        out["lag"] = res.pop(0)[:K]
    out["logw_pre"] = res.pop(0)[:K]
    out["m"] = jnp.max(res.pop(0))  # max of per-tile maxes == global max, exactly
    out["loss_cache"] = res.pop(0)[:K]
    if S > 0:
        out["arriving"] = res.pop(0)[:K]
        out["credit"] = res.pop(0)[:, :K]
    if late_fb:
        out["arr_fb"] = res.pop(0)[:K]
        out["fb"] = res.pop(0)[:, :K]
    return out


def fused_round_tail(
    obs,
    mask,
    p,
    capped,
    logw,
    loss_cache,
    credit=None,
    fb=None,
    *,
    kind: str,
    residual,
    eta: float,
    K_glob: int,
    decay=(),
    active=None,
    tile: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Dispatching tail pass (kernel vs ``ref.round_tail_ref``)."""
    use_kernel, interp = _route(interpret)
    if not use_kernel:
        return round_tail_ref(
            obs, mask, p, capped, logw, loss_cache, credit, fb,
            kind=kind, residual=residual, eta=eta, K_glob=K_glob, decay=decay, active=active,
        )
    tile = tile or best_config("round_fused", mask.shape[0])["tile"]
    return round_tail_kernel_call(
        obs, mask, p, capped, logw, loss_cache, credit, fb,
        kind=kind, residual=residual, eta=eta, K_glob=K_glob, decay=decay,
        active=active, tile=tile, interpret=interp,
    )
