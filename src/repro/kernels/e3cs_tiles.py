"""E3CS hot-path Pallas kernels: fused Gumbel-perturb + top-k, and the tiled
exponential-weight update.

At fleet scale (K ~ 10^6 clients) the selection step is bandwidth-bound: every
extra pass over the (K,) probability/weight vectors costs a full HBM
round-trip.  The two kernels here each make exactly one pass:

* ``fused_gumbel_topk_kernel_call`` — fuses the Plackett-Luce perturbation
  ``score_i = log p_i + Gumbel(u_i)`` (with ``Gumbel(u) = -log(-log u)``) into
  the streaming top-k merge of ``gumbel_topk.py``, so perturbed scores are
  never materialised in HBM.  Uniform variates are generated outside the
  kernel with the host PRNG (keeps the draw bit-reproducible across backends)
  and consumed tile-by-tile.

* ``e3cs_update_kernel_call`` — fuses Eq. (16)'s importance-weighted
  estimator, the proof-regime clamp (step <= 1), the overflow-set freeze
  (Eq. 17) and the log-weight add into one elementwise pass.  The global
  re-centering max is returned per-tile so the caller can finish the shift
  with a tiny (n_tiles,) reduction instead of re-reading all of ``logw``.

Layout follows the house idiom of ``gumbel_topk.py``: 1-D grid over weight
tiles, running top-k state in VMEM scratch, trailing-tile finalisation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .gumbel_topk import NEG_INF, streaming_topk_body

__all__ = ["fused_gumbel_topk_kernel_call", "e3cs_update_kernel_call"]

_EPS = 1e-20


def _fused_kernel(p_ref, u_ref, val_ref, idx_ref, best_v, best_i, *, k, tile, n_tiles, K):
    ti = pl.program_id(0)
    p = p_ref[...].astype(jnp.float32)  # (tile,)
    u = u_ref[...].astype(jnp.float32)
    # Gumbel perturbation fused in-register: log p - log(-log u)
    g = -jnp.log(-jnp.log(jnp.clip(u, _EPS, 1.0 - 1e-7)))
    s = jnp.log(jnp.maximum(p, _EPS)) + g
    pos = ti * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    s = jnp.where((pos < K) & (p > 0.0), s, NEG_INF)
    streaming_topk_body(s, val_ref, idx_ref, best_v, best_i, k=k, tile=tile, n_tiles=n_tiles)


def fused_gumbel_topk_kernel_call(p: jax.Array, u: jax.Array, k: int, tile: int = 8192, interpret: bool = False):
    """One-pass Plackett-Luce draw: perturb ``p`` with ``Gumbel(u)`` and keep
    the running top-k, without writing scores back to HBM.

    Args:
      p: (K,) selection probabilities.
      u: (K,) iid Uniform(0,1) variates.
      k: cohort size (static).

    Returns (values, indices): top-k perturbed scores, descending.
    """
    K = p.shape[0]
    tile = min(tile, max(K, 8))
    K_p = math.ceil(K / tile) * tile
    if K_p != K:
        p = jnp.pad(p, (0, K_p - K))
        u = jnp.pad(u, (0, K_p - K), constant_values=0.5)
    n_tiles = K_p // tile
    kernel = functools.partial(_fused_kernel, k=k, tile=tile, n_tiles=n_tiles, K=K)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda t: (0,)),
            pl.BlockSpec((k,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((k,), jnp.float32), pltpu.VMEM((k,), jnp.int32)],
        interpret=interpret,
    )(p, u)
    return vals, idx


def _update_kernel(logw_ref, p_ref, mask_ref, x_ref, frozen_ref, scale_ref, out_ref, tmax_ref, *, tile, K):
    ti = pl.program_id(0)
    logw = logw_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    mask = mask_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    frozen = frozen_ref[...].astype(jnp.float32)
    scale = scale_ref[0]

    xhat = mask * x / jnp.maximum(p, 1e-12)  # Eq. (16)
    step = jnp.minimum(scale * xhat, 1.0)  # Eq. (17) exponent, proof clamp
    new = logw + jnp.where(frozen > 0, 0.0, step)
    out_ref[...] = new

    pos = ti * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    tmax_ref[0] = jnp.max(jnp.where(pos < K, new, NEG_INF))


def e3cs_update_kernel_call(
    logw: jax.Array,
    p: jax.Array,
    sel_mask: jax.Array,
    x: jax.Array,
    frozen: jax.Array,
    scale: jax.Array,
    tile: int = 8192,
    interpret: bool = False,
):
    """Fused E3CS weight update (Eqs. 16-17) over (K,) vectors.

    ``scale`` is the scalar exponent coefficient ``(k - K sigma) * eta / K``.
    Returns ``(new_logw, tile_max)``; the caller re-centers with
    ``new_logw - tile_max.max()`` (ProbAlloc is shift-invariant).
    """
    K = logw.shape[0]
    tile = min(tile, max(K, 8))
    K_p = math.ceil(K / tile) * tile
    if K_p != K:
        pad = K_p - K
        logw = jnp.pad(logw, (0, pad))
        p = jnp.pad(p, (0, pad), constant_values=1.0)
        sel_mask = jnp.pad(sel_mask, (0, pad))
        x = jnp.pad(x, (0, pad))
        frozen = jnp.pad(frozen.astype(jnp.float32), (0, pad))
    n_tiles = K_p // tile
    kernel = functools.partial(_update_kernel, tile=tile, K=K)
    new_logw, tmax = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K_p,), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.float32),
        ],
        scratch_shapes=[],
        interpret=interpret,
    )(logw, p, sel_mask, x, frozen.astype(jnp.float32), jnp.reshape(scale, (1,)).astype(jnp.float32))
    return new_logw[:K], tmax
