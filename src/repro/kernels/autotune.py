"""Tile autotuning: sweep kernel launch configs once, cache winners on disk.

The Pallas entry points in this package historically hardcoded
``tile=8192``.  That is a fine default for the CI box but leaves real
bandwidth on the table at K=1e7 (too many grid steps) and can exceed VMEM
for wide dtypes on small cores.  This module sweeps a small candidate grid
per ``(kernel, K-bucket, dtype, backend)`` with
``benchmarks.common.time_fn(blocking=True)`` and persists the winners to a
JSON cache under ``results/autotune/`` (``REPRO_AUTOTUNE_DIR`` overrides —
see ``repro.obs.paths``).  ``ops.py`` dispatch consults the cache whenever
a caller leaves ``tile=None``; callers that pass an explicit tile (the
engine's ``RoundProgram``, whose reduction grouping is part of its golden
contract) are never affected.

Cache format (one flat JSON object, sorted keys)::

    {
      "bisect_tiles|K1048576|float32|cpu": {"tile": 16384, "block": 4},
      "gumbel_topk|K1048576|float32|cpu":  {"tile": 8192},
      ...
    }

K is bucketed to the next power of two (min 1024) so one sweep covers a
band of problem sizes.  A corrupt or unreadable cache degrades to the
hardcoded defaults with a warning — it never crashes a run.  Cold lookups
(no cache entry) are recorded and surfaced by ``benchmarks/kernels.py`` so
``scripts/check_bench.py`` can annotate timings taken with untuned
defaults.  The sweep itself is deterministic given fixed timings: candidate
order is fixed and ties break toward the earlier candidate.
"""
from __future__ import annotations

import json
import os
import time
import warnings
from typing import Any, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.paths import autotune_path

__all__ = [
    "DEFAULTS", "CANDIDATES", "cache_key", "load_cache", "save_cache",
    "best_config", "sweep", "autotune", "cold_keys", "reset_cold",
]

DEFAULTS: Dict[str, Dict[str, int]] = {
    "gumbel_topk": {"tile": 8192},
    "e3cs_tiles": {"tile": 8192},
    "bisect_tiles": {"tile": 8192, "block": 4},
    "round_fused": {"tile": 8192},
}

# Candidate grids.  "tile" is the 1-D grid block; "block" is the bisection
# probe count exponent (2**block - 1 probe points per sweep); "unroll" is
# reserved for kernels that expose it (none currently do — kept so cache
# entries stay forward-compatible).
CANDIDATES: Dict[str, Dict[str, List[int]]] = {
    "gumbel_topk": {"tile": [2048, 4096, 8192, 16384, 32768]},
    "e3cs_tiles": {"tile": [2048, 4096, 8192, 16384, 32768]},
    "bisect_tiles": {"tile": [2048, 4096, 8192, 16384, 32768], "block": [2, 4, 6]},
    "round_fused": {"tile": [2048, 4096, 8192, 16384, 32768]},
}

_cache_memo: Tuple[Optional[str], Optional[float], Optional[dict]] = (None, None, None)
_cold: set = set()


def _bucket(K: int) -> int:
    """Power-of-two bucket (min 1024) so one sweep covers a size band."""
    return 1 << max(10, int(K - 1).bit_length())


def cache_key(kernel: str, K: int, dtype: str = "float32", backend: Optional[str] = None) -> str:
    backend = backend or jax.default_backend()
    return f"{kernel}|K{_bucket(K)}|{dtype}|{backend}"


def load_cache(path: Optional[str] = None) -> Dict[str, Dict[str, int]]:
    """Read the JSON cache; corrupt/missing degrades to ``{}`` (warn once
    per offending file content, never raise)."""
    path = path or autotune_path()
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            cache = json.load(f)
        if not isinstance(cache, dict) or not all(isinstance(v, dict) for v in cache.values()):
            raise ValueError("autotune cache is not a {key: config} object")
    except (ValueError, OSError) as e:
        warnings.warn(f"ignoring corrupt autotune cache {path}: {e}", stacklevel=2)
        return {}
    return cache


def save_cache(cache: Dict[str, Dict[str, int]], path: Optional[str] = None) -> str:
    path = path or autotune_path()
    with open(path, "w") as f:
        json.dump({k: cache[k] for k in sorted(cache)}, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def _cached(path: str) -> dict:
    """mtime-memoised cache read, so per-call lookups stay cheap while
    external writes (another process refreshing the cache) are picked up."""
    global _cache_memo
    try:
        mtime = os.stat(path).st_mtime
    except OSError:
        mtime = None
    memo_path, memo_mtime, memo_val = _cache_memo
    if memo_path == path and memo_mtime == mtime and memo_val is not None:
        return memo_val
    val = load_cache(path)
    _cache_memo = (path, mtime, val)
    return val


def best_config(kernel: str, K: int, dtype: str = "float32", backend: Optional[str] = None) -> Dict[str, int]:
    """Tuned launch config for ``kernel`` at size ``K`` — cache hit merged
    over the hardcoded defaults; a miss returns the defaults and is
    recorded as a cold lookup (see ``cold_keys``)."""
    base = dict(DEFAULTS.get(kernel) or {"tile": 8192})
    key = cache_key(kernel, K, dtype, backend)
    hit = _cached(autotune_path()).get(key)
    if hit is None:
        _cold.add(key)
        return base
    base.update({k: int(v) for k, v in hit.items() if isinstance(v, (int, float))})
    return base


def cold_keys() -> List[str]:
    """Cache keys that were looked up but had no tuned entry, since the
    last ``reset_cold()`` — a cold cache means timings reflect defaults."""
    return sorted(_cold)


def reset_cold() -> None:
    _cold.clear()


# ---------------------------------------------------------------------------
# Sweep harness
# ---------------------------------------------------------------------------

def _time_fn_fallback(fn, *args, iters: int = 3, warmup: int = 1, blocking: bool = True):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / max(iters, 1) * 1e6


def _timer():
    try:
        from benchmarks.common import time_fn
        return time_fn
    except ImportError:
        return _time_fn_fallback


def _bench_builder(kernel: str, K: int, seed: int = 0):
    """A closure ``build(config) -> fn`` timing the dispatch path actually
    used in production (the ops-level wrappers) under ``config``."""
    rng = np.random.default_rng(seed)
    if kernel == "gumbel_topk":
        from repro.kernels import ops
        p = jnp.asarray(np.abs(rng.normal(size=K)) + 1e-3, jnp.float32)
        key = jax.random.PRNGKey(seed)
        kk = max(8, min(K // 16, 1024))

        def build(cfg):
            return lambda: ops.gumbel_topk_sample(key, p, kk, tile=cfg["tile"])
        return build
    if kernel == "e3cs_tiles":
        from repro.kernels import ops
        logw = jnp.asarray(rng.normal(size=K), jnp.float32)
        p = jnp.asarray(rng.uniform(0.05, 1.0, size=K), jnp.float32)
        mask = jnp.asarray(rng.binomial(1, 0.2, size=K), jnp.float32)
        x = jnp.asarray(rng.binomial(1, 0.6, size=K), jnp.float32)
        frozen = jnp.zeros((K,), jnp.float32)

        def build(cfg):
            return lambda: ops.e3cs_update_tiled(logw, p, mask, x, frozen, 0.1, tile=cfg["tile"])
        return build
    if kernel == "bisect_tiles":
        from repro.kernels.bisect_tiles import bisect_block_sums
        w = jnp.asarray(rng.uniform(0.0, 1.0, size=K), jnp.float32)

        def build(cfg):
            n_caps = (1 << cfg.get("block", 4)) - 1
            caps = jnp.linspace(0.01, 1.0, n_caps, dtype=jnp.float32)
            return lambda: bisect_block_sums(w, caps, tile=cfg["tile"])
        return build
    if kernel == "round_fused":
        from repro.engine.sharded import masked_prob_alloc_scalars
        from repro.kernels.round_fused import fused_alloc_select
        w = jnp.asarray(rng.uniform(0.0, 1.0, size=K), jnp.float32)
        kk = max(8, min(K // 16, 1024))
        sigma = jnp.float32(0.2 * kk / K)
        scalars = jax.jit(lambda w_, s_: masked_prob_alloc_scalars(w_, kk, s_))(w, sigma)
        g = jax.random.gumbel(jax.random.PRNGKey(seed), (K,), jnp.float32)

        def build(cfg):
            return lambda: fused_alloc_select(w, g, kk, sigma=sigma, scalars=scalars, tile=cfg["tile"])
        return build
    raise ValueError(f"unknown kernel {kernel!r}")


def _configs(kernel: str, candidates: Optional[Dict[str, List[int]]] = None) -> List[Dict[str, int]]:
    grid = candidates or CANDIDATES[kernel]
    axes = sorted(grid)
    configs: List[Dict[str, int]] = [{}]
    for ax in axes:
        configs = [dict(c, **{ax: v}) for c in configs for v in grid[ax]]
    return configs


def sweep(
    kernel: str,
    K: int,
    *,
    dtype: str = "float32",
    backend: Optional[str] = None,
    candidates: Optional[Dict[str, List[int]]] = None,
    timer=None,
    iters: int = 3,
    warmup: int = 1,
    seed: int = 0,
) -> Tuple[Dict[str, int], Dict[str, float]]:
    """Time every candidate config for ``kernel`` at size ``K``; return
    ``(best_config, {json_config: us_per_call})``.  ``timer`` is injectable
    for deterministic tests; the default is ``benchmarks.common.time_fn``
    with ``blocking=True``."""
    timer = timer or _timer()
    build = _bench_builder(kernel, K, seed=seed)
    table: Dict[str, float] = {}
    best_cfg: Optional[Dict[str, int]] = None
    best_us = float("inf")
    for cfg in _configs(kernel, candidates):
        fn = build(cfg)
        us = float(timer(fn, iters=iters, warmup=warmup, blocking=True))
        table[json.dumps(cfg, sort_keys=True)] = us
        if us < best_us:  # strict: ties keep the earlier candidate
            best_us, best_cfg = us, dict(cfg)
    assert best_cfg is not None
    return best_cfg, table


def autotune(
    kernels: Optional[Iterable[str]] = None,
    K_list: Iterable[int] = (10_000,),
    *,
    path: Optional[str] = None,
    save: bool = True,
    timer=None,
    iters: int = 3,
    warmup: int = 1,
) -> Dict[str, Any]:
    """Run the sweep for every (kernel, K) pair and merge winners into the
    on-disk cache.  Returns ``{"cache": ..., "tables": ...}``."""
    kernels = list(kernels) if kernels is not None else sorted(CANDIDATES)
    path = path or autotune_path()
    cache = load_cache(path)
    tables: Dict[str, Dict[str, float]] = {}
    for kern in kernels:
        for K in K_list:
            best, table = sweep(kern, int(K), timer=timer, iters=iters, warmup=warmup)
            key = cache_key(kern, int(K))
            cache[key] = best
            tables[key] = table
    if save:
        save_cache(cache, path)
        global _cache_memo
        _cache_memo = (None, None, None)
    return {"cache": cache, "tables": tables, "path": path}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description="regenerate the autotune cache")
    ap.add_argument("--K", type=int, nargs="+", default=[10_000, 100_000, 1_000_000])
    ap.add_argument("--kernels", nargs="+", default=None)
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    out = autotune(args.kernels, args.K, iters=args.iters)
    print(f"wrote {out['path']}")
    for key, tab in out["tables"].items():
        win = json.dumps(out["cache"][key], sort_keys=True)
        print(f"  {key}: {win}")
