"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid ``(batch, heads, n_chunks)`` — chunks innermost; the inter-chunk
recurrent state ``(N, P)`` lives in VMEM scratch and persists across the
chunk dimension (sequential TPU grid).  Per chunk the kernel does the SSD
block decomposition entirely in VMEM:

    intra:  Y  = ((C B^T) ∘ L ∘ dt_j) X          (Q,Q)x(Q,P) MXU matmuls
    inter:  Y += (C exp(cum)) S_prev             (Q,N)x(N,P)
    state:  S  = exp(total) S_prev + (dt exp(total-cum) B)^T X

Chunk length Q and state width N are 128 (MXU-aligned); the head dim P rides
whole (64).  B/C are shared across the heads of a group — the BlockSpec index
map reads group ``h // rep``, mirroring the GQA trick in flash attention.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel_call"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr, *, Q, n_chunks, seq_len):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar (negative)
    Bm = b_ref[0, :, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, :, 0].astype(jnp.float32)  # (Q, N)

    # zero-out padded tail positions (dt=0 makes them inert)
    pos = ci * Q + jax.lax.broadcasted_iota(jnp.int32, (Q, 1), 0)[:, 0]
    dt = jnp.where(pos < seq_len, dt, 0.0)

    dA = dt * A  # (Q,)
    cum = jnp.cumsum(dA)  # (Q,)
    total = cum[-1]

    # ---- intra-chunk ----
    li = cum[:, None] - cum[None, :]  # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(li), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    att = scores * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())))  # (Q, P)

    # ---- inter-chunk: contribution of the carried state ----
    s_prev = state_scr[...]  # (N, P)
    y = y + jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], s_prev, (((1,), (0,)), ((), ())))

    # ---- state update ----
    w = dt * jnp.exp(total - cum)  # (Q,)
    s_new = s_prev * jnp.exp(total) + jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())))
    state_scr[...] = s_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        st_ref[0, 0] = s_new.astype(st_ref.dtype)


def ssd_scan_kernel_call(x, dt, A, B, C, chunk: int = 128, interpret: bool = False):
    """x: (b,S,H,P); dt: (b,S,H); A: (H,); B/C: (b,S,G,N).

    Returns (y (b,S,H,P), final_state (b,H,N,P)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, max(S, 8))
    S_p = math.ceil(S / Q) * Q
    if S_p != S:
        pad = S_p - S
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = S_p // Q

    kernel = functools.partial(_kernel, Q=Q, n_chunks=n_chunks, seq_len=S)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, Q, 1, N), lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S_p, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, N, P), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :S], st
