"""Bit-unpack as a Pallas kernel — streaming packed availability traces.

Recorded volatility traces store one success bit per client per round
(``repro.scenarios.replay``): a ``(T, ceil(K/8))`` uint8 array, 8 clients per
byte, little-endian bit order (bit ``j`` of byte ``b`` is client ``8*b + j``,
matching ``np.packbits(..., bitorder="little")``).  At replay time the scan
simulator needs the round's ``(K,)`` float32 bit-vector; materialising the
whole ``(T, K)`` float32 trace would be 32x the packed footprint (10 GB at
K=1e6, T=2500 vs ~312 MB packed), so each row is expanded on the fly.

This kernel does the expansion tile-by-tile: the grid walks byte tiles, each
program reads ``tile_b`` bytes from VMEM, shifts out the 8 bit-planes on the
VPU and writes the ``8 * tile_b`` float32 lane block.  The op is purely
bandwidth-bound (1 byte in, 32 bytes out) and fuses under the scan body so the
unpacked row never round-trips through HBM on a real backend.

``unpack_bits_ref`` is the jnp reference (also the CPU fast path — the
interpreter would dominate a T-round scan); ``tests/test_scenarios.py`` pins
kernel == reference in interpret mode, ragged shapes included.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "unpack_bits_ref",
    "unpack_bits_kernel_call",
    "unpack_bits",
    "unpack_crumbs_ref",
    "unpack_crumbs_kernel_call",
    "unpack_crumbs",
]


def unpack_bits_ref(packed: jax.Array, K: int) -> jax.Array:
    """Little-endian bit expansion: ``(..., B)`` uint8 -> ``(..., K)`` float32."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)
    flat = bits.reshape(*packed.shape[:-1], packed.shape[-1] * 8)
    return flat[..., :K].astype(jnp.float32)


def _kernel(p_ref, x_ref, *, tile_b):
    b = p_ref[...].astype(jnp.int32)  # (tile_b,)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (tile_b, 8), 1)
    bits = jnp.right_shift(b[:, None], shifts) & 1
    x_ref[...] = bits.reshape(tile_b * 8).astype(jnp.float32)


def unpack_bits_kernel_call(packed: jax.Array, K: int, tile_b: int = 1024, interpret: bool = False):
    """packed: (B,) uint8 with ``B >= ceil(K/8)``. Returns (K,) float32."""
    B = packed.shape[0]
    tile_b = min(tile_b, max(B, 1))
    B_p = math.ceil(B / tile_b) * tile_b
    if B_p != B:
        packed = jnp.pad(packed, (0, B_p - B))
    n_tiles = B_p // tile_b
    kernel = functools.partial(_kernel, tile_b=tile_b)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_b,), lambda t: (t,))],
        out_specs=pl.BlockSpec((tile_b * 8,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((B_p * 8,), jnp.float32),
        interpret=interpret,
    )(packed)
    return out[:K]


def unpack_bits(packed: jax.Array, K: int, tile_b: int = 1024) -> jax.Array:
    """Dispatching row unpack: Pallas kernel on accelerators, jnp reference
    on CPU (where the interpreter would be the bottleneck); routed per call
    by ``REPRO_INTERPRET`` (``repro.kernels.dispatch``)."""
    from .dispatch import kernel_route  # deferred: dispatch is dependency-free

    use_kernel, interpret = kernel_route(cpu_kernel_default=False)
    if not use_kernel:
        return unpack_bits_ref(packed, K)
    return unpack_bits_kernel_call(packed, K, tile_b=tile_b, interpret=interpret)


def unpack_crumbs_ref(packed: jax.Array, K: int) -> jax.Array:
    """Little-endian 2-bit ("crumb") expansion: ``(..., B)`` uint8 ->
    ``(..., K)`` int32 codes in {0, 1, 2, 3}, 4 clients per byte.

    The async engine's lag traces (``repro.scenarios.replay``) store one crumb
    per client per round: codes 0..2 are completion lags, code 3 is the dead
    sentinel (decoded to ``DEAD_LAG`` by the caller).
    """
    shifts = jnp.arange(4, dtype=jnp.uint8) * jnp.uint8(2)
    crumbs = (packed[..., None] >> shifts) & jnp.uint8(3)
    flat = crumbs.reshape(*packed.shape[:-1], packed.shape[-1] * 4)
    return flat[..., :K].astype(jnp.int32)


def _crumb_kernel(p_ref, x_ref, *, tile_b):
    b = p_ref[...].astype(jnp.int32)  # (tile_b,)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (tile_b, 4), 1) * 2
    crumbs = jnp.right_shift(b[:, None], shifts) & 3
    x_ref[...] = crumbs.reshape(tile_b * 4)


def unpack_crumbs_kernel_call(packed: jax.Array, K: int, tile_b: int = 1024, interpret: bool = False):
    """packed: (B,) uint8 with ``B >= ceil(K/4)``. Returns (K,) int32 codes."""
    B = packed.shape[0]
    tile_b = min(tile_b, max(B, 1))
    B_p = math.ceil(B / tile_b) * tile_b
    if B_p != B:
        packed = jnp.pad(packed, (0, B_p - B))
    n_tiles = B_p // tile_b
    kernel = functools.partial(_crumb_kernel, tile_b=tile_b)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_b,), lambda t: (t,))],
        out_specs=pl.BlockSpec((tile_b * 4,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((B_p * 4,), jnp.int32),
        interpret=interpret,
    )(packed)
    return out[:K]


def unpack_crumbs(packed: jax.Array, K: int, tile_b: int = 1024) -> jax.Array:
    """Dispatching crumb unpack (see ``unpack_bits`` for the idiom)."""
    from .dispatch import kernel_route  # deferred: dispatch is dependency-free

    use_kernel, interpret = kernel_route(cpu_kernel_default=False)
    if not use_kernel:
        return unpack_crumbs_ref(packed, K)
    return unpack_crumbs_kernel_call(packed, K, tile_b=tile_b, interpret=interpret)
