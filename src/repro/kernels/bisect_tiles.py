"""Fused bisection reduction: every remaining capped sum of a bracket-
refinement block in ONE pass over the weights.

ProbAlloc's sort-free alpha-search (``repro.engine.sharded``) evaluates
``s(cap) = sum_j min(w_j, cap)`` once per bisection step — 48 full sweeps of
the (K,) weight vector, each a separate HBM round-trip at fleet scale.  But a
bracket-refinement *block* of ``b`` halvings only ever probes caps on the
``2**b - 1`` equally spaced interior points of the current bracket (the dyadic
grid the ``b`` sequential midpoints land on), and ``s`` is monotone in
``cap``, so evaluating all of them at once and binary-searching the
*precomputed* sums resolves the whole block: 48 sweeps collapse to
``ceil(48/b)``.

This kernel computes that batched reduction: the grid walks weight tiles, each
program loads one ``(tile,)`` slab of ``w`` into VMEM **once** and accumulates
``min(w, cap)`` partial sums for every candidate cap against it — the weights
stay resident across the block's iterations instead of being re-streamed from
HBM per step.  Output is the ``(n_caps,)`` vector of capped sums.  Under the
K-sharded engine this is the *per-shard local reduction*: each device runs it
on its slab and one `psum` of the ``(n_caps,)`` partials per block replaces
one scalar `psum` per step.

Requirements: ``caps >= 0`` and padding entries of ``w`` equal to 0, so pad
slots contribute ``min(0, cap) = 0`` and no masking is needed.

``bisect_block_sums_ref`` is the jnp reference (and the CPU fast path — the
interpreter would dominate a scan horizon); ``tests/test_sharded.py`` pins
kernel == reference in interpret mode, ragged shapes included.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bisect_block_sums_ref", "bisect_block_sums_kernel_call", "bisect_block_sums"]


def bisect_block_sums_ref(w: jax.Array, caps: jax.Array, tile: int = 8192) -> jax.Array:
    """``(n_caps,)`` capped sums ``s_b = sum_j min(w_j, caps_b)``.

    Two-level (per-tile, then cross-tile) summation — the same reduction shape
    as ``repro.engine.sharded._tiled_sum``, batched over the cap axis so the
    weights are read once for the whole block.
    """
    n = w.shape[0]
    tile = min(tile, max(n, 1))
    pad = (-n) % tile
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
    wt = w.reshape(-1, tile)
    caps = caps.astype(w.dtype)
    return jnp.sum(jnp.sum(jnp.minimum(wt[:, :, None], caps[None, None, :]), axis=1), axis=0)


def _kernel(w_ref, caps_ref, out_ref, *, n_caps):
    # accumulates across grid programs into one shared output block — safe
    # only where the grid executes sequentially (TPU; the interpreter); the
    # dispatcher below never routes parallel-grid backends here
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[...].astype(jnp.float32)  # (tile,) — loaded once for all caps
    caps = caps_ref[...].astype(jnp.float32)  # (n_caps,)
    out_ref[...] += jnp.sum(jnp.minimum(w[:, None], caps[None, :]), axis=0)


def bisect_block_sums_kernel_call(w: jax.Array, caps: jax.Array, tile: int = 8192, interpret: bool = False):
    """``w``: (K,) non-negative weights; ``caps``: (n_caps,) non-negative
    caps.  Returns the (n_caps,) float32 capped-sum vector."""
    K = w.shape[0]
    n_caps = caps.shape[0]
    tile = min(tile, max(K, 8))
    K_p = math.ceil(K / tile) * tile
    if K_p != K:
        w = jnp.pad(w, (0, K_p - K))  # zero pads: min(0, cap) = 0 contributes nothing
    n_tiles = K_p // tile
    kernel = functools.partial(_kernel, n_caps=n_caps)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda t: (t,)),
            pl.BlockSpec((n_caps,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((n_caps,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_caps,), jnp.float32),
        interpret=interpret,
    )(w, caps)


def bisect_block_sums(w: jax.Array, caps: jax.Array, tile: int = None) -> jax.Array:
    """Dispatching block reduction: Pallas kernel on TPU, jnp reference
    elsewhere; routed per call by ``REPRO_INTERPRET``
    (``repro.kernels.dispatch``).

    The reference path covers three cases the kernel cannot: CPU (the
    interpreter would be the bottleneck), float64 inputs (the kernel
    accumulates in float32 and would silently truncate x64-mode allocations
    — enforced here even under a forced kernel route), and parallel-grid
    backends like GPU (the kernel's cross-program output accumulation needs
    a sequential grid — interpret mode, which the route forces off-TPU, is
    sequential).  ``tile=None`` consults the autotune cache
    (``repro.kernels.autotune``); the engine's allocator always passes its
    own tile, so its reduction grouping never shifts under tuning.
    """
    from .dispatch import kernel_route  # deferred: dispatch is dependency-free

    if tile is None:
        from .autotune import best_config

        tile = int(best_config("bisect_tiles", w.shape[0])["tile"])
    if w.dtype != jnp.float32:
        return bisect_block_sums_ref(w, caps, tile=tile)
    use_kernel, interpret = kernel_route(cpu_kernel_default=False)
    if not use_kernel:
        return bisect_block_sums_ref(w, caps, tile=tile)
    return bisect_block_sums_kernel_call(w, caps, tile=tile, interpret=interpret).astype(w.dtype)
