"""Flash attention (causal / sliding-window, GQA) as a Pallas TPU kernel.

TPU adaptation of the classic algorithm: the grid is
``(batch*q_heads, n_q_blocks, n_kv_blocks)`` with the KV dimension innermost;
VMEM scratch carries the running max / normaliser / accumulator across KV
blocks (TPU grids execute sequentially per core, so scratch persists).
Block shapes are MXU-aligned (multiples of 128 on the sequence dims; the head
dim rides along whole).  GQA is expressed in the BlockSpec index maps — query
head ``h`` reads KV head ``h // group``, so no KV duplication is materialised
in HBM.

Causal structure is exploited two ways: KV blocks that are fully masked are
skipped via ``pl.when`` (no MXU work issued), and the diagonal block applies
the triangular mask element-wise.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, bq, bk, n_kv, causal, window, seq_k
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = kj * bk
    # block-level skip when the whole KV block is masked out
    relevant = k_lo < seq_k
    if causal:
        relevant = jnp.logical_and(relevant, k_lo <= q_lo + bq - 1)
    if window > 0:
        relevant = jnp.logical_and(relevant, k_lo + bk - 1 > q_lo - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, :, :] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention_kernel_call(
    q: jax.Array,  # (BH, Sq, hd)  batch*q_heads flattened
    k: jax.Array,  # (BKV, Sk, hd) batch*kv_heads flattened
    v: jax.Array,
    group: int,  # q heads per kv head
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    Sq_p = math.ceil(Sq / bq) * bq
    Sk_p = math.ceil(Sk / bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0)))
    n_q, n_kv = Sq_p // bq, Sk_p // bk

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), bq=bq, bk=bk, n_kv=n_kv, causal=causal, window=window, seq_k=Sk
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
