"""Kernel-vs-reference routing, resolved at call time.

Every Pallas entry point in this package routes through :func:`kernel_route`
so one documented environment variable controls dispatch everywhere:

``REPRO_INTERPRET``
    * unset / ``"auto"`` — per-backend default: compiled Pallas kernels on
      TPU; on CPU either the Pallas interpreter or the jnp reference,
      whichever the call site declares as its CPU default
      (``cpu_kernel_default``).
    * ``"1"`` — force the Pallas kernel path everywhere, in interpret mode
      off-TPU.  This is the bit-identity validation mode: the fused round
      path is pinned against the committed goldens under this setting.
    * ``"0"`` — force the jnp reference path everywhere (no Pallas at all);
      the escape hatch when a kernel misbehaves on some backend.

The variable is read *per call* by the thin, non-jitted wrappers (interpret
mode is then passed into the inner jit as a static argument), so flipping it
mid-process takes effect on the next call — ``tests/test_kernels.py`` pins
this.  Runners compiled by ``RoundProgram.build_runner`` bake the route in
at trace time, like every other static configuration they close over.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

__all__ = ["interpret_mode", "kernel_route"]

_ENV = "REPRO_INTERPRET"


def interpret_mode() -> Optional[bool]:
    """Tri-state read of ``REPRO_INTERPRET``: True / False / None (auto)."""
    raw = os.environ.get(_ENV, "").strip().lower()
    if raw in ("", "auto"):
        return None
    if raw in ("1", "true", "yes", "on"):
        return True
    if raw in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"{_ENV}={raw!r}: expected 1/0/auto")


def kernel_route(cpu_kernel_default: bool = True, tpu_kernel: bool = True) -> Tuple[bool, bool]:
    """Resolve ``(use_kernel, interpret)`` for one kernel call.

    ``cpu_kernel_default`` is the auto-mode CPU behaviour: True runs the
    kernel through the Pallas interpreter (cheap ops where the interpreter
    is fine), False uses the jnp reference (hot paths where the interpreter
    is too slow).  ``tpu_kernel=False`` opts a site out of the compiled
    kernel even on TPU (e.g. unsupported dtype); ``REPRO_INTERPRET=1``
    still forces the kernel, in interpret mode.
    """
    mode = interpret_mode()
    if mode is False:
        return False, False
    backend = jax.default_backend()
    if mode is True:
        return True, backend != "tpu" or not tpu_kernel
    if backend == "tpu":
        return tpu_kernel, False
    return cpu_kernel_default, True
