from . import ops, ref
from .ops import flash_attention, ssd_scan, gumbel_topk_sample
