from . import ops, ref
from .ops import flash_attention, ssd_scan, gumbel_topk_sample
from .unpack_bits import unpack_bits, unpack_bits_kernel_call, unpack_bits_ref
