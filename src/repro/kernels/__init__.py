from . import autotune, dispatch, ops, ref
from .dispatch import interpret_mode, kernel_route
from .ops import e3cs_update_tiled, fused_gumbel_topk_sample, gumbel_topk_sample
from .round_fused import fused_alloc_select, fused_perturb_select, fused_round_tail
from .unpack_bits import unpack_bits, unpack_bits_kernel_call, unpack_bits_ref
