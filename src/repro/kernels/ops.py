"""Jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python via the Pallas interpreter, which is how
correctness is validated against ``ref.py``.  On a real TPU backend
``interpret`` flips off automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .e3cs_tiles import e3cs_update_kernel_call, fused_gumbel_topk_kernel_call
from .flash_attention import flash_attention_kernel_call
from .gumbel_topk import gumbel_topk_kernel_call
from .ssd_scan import ssd_scan_kernel_call

__all__ = ["flash_attention", "ssd_scan", "gumbel_topk_sample", "fused_gumbel_topk_sample", "e3cs_update_tiled"]


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0, block_q: int = 128, block_k: int = 128):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd). Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    group = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    o = flash_attention_kernel_call(
        qf, kf, vf, group, causal=causal, window=window, block_q=block_q, block_k=block_k, interpret=_interpret()
    )
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, chunk: int = 128):
    """Chunked SSD scan; see repro.models.ssm for argument shapes."""
    return ssd_scan_kernel_call(x, dt, A, B, C, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k", "tile"))
def gumbel_topk_sample(rng, p, k: int, tile: int = 8192):
    """Plackett-Luce k-subset sample over probabilities ``p`` (K,)."""
    g = jax.random.gumbel(rng, p.shape, jnp.float32)
    scores = jnp.log(jnp.maximum(p.astype(jnp.float32), 1e-20)) + g
    _, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=_interpret())
    return idx


@functools.partial(jax.jit, static_argnames=("k", "tile"))
def fused_gumbel_topk_sample(rng, p, k: int, tile: int = 8192):
    """Single-pass Plackett-Luce sample: the Gumbel perturbation happens
    inside the kernel, so scores never round-trip through HBM."""
    u = jax.random.uniform(rng, p.shape, jnp.float32)
    _, idx = fused_gumbel_topk_kernel_call(p.astype(jnp.float32), u, k, tile=tile, interpret=_interpret())
    return idx


@functools.partial(jax.jit, static_argnames=("tile",))
def e3cs_update_tiled(logw, p, sel_mask, x, frozen, scale, tile: int = 8192):
    """Fused, re-centered E3CS weight update (Eqs. 16-17) at fleet scale."""
    new_logw, tmax = e3cs_update_kernel_call(
        logw, p, sel_mask, x, frozen, scale, tile=tile, interpret=_interpret()
    )
    return new_logw - jnp.max(tmax)
