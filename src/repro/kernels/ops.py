"""Dispatching wrappers for the Pallas kernels.

Routing (kernel vs jnp reference, compiled vs interpret) is resolved **per
call** by ``repro.kernels.dispatch.kernel_route`` from the one documented
``REPRO_INTERPRET`` environment variable — unset/``auto`` picks the
per-backend default (compiled kernels on TPU, the Pallas interpreter on
CPU), ``1`` forces the kernel path (interpret off-TPU, the bit-identity
validation mode), ``0`` forces the jnp references.  The wrappers here are
deliberately *not* jitted: the env read happens on every call and the
resolved route is passed to the inner jit as a static argument, so flipping
the variable mid-process takes effect on the next call (pinned in
``tests/test_kernels.py``).

Launch tiles default to the autotune cache (``repro.kernels.autotune``):
``tile=None`` looks up the tuned config for the ``(kernel, K-bucket,
dtype, backend)`` at hand and falls back to the hardcoded defaults on a
cold cache.  Passing an explicit ``tile`` bypasses the cache entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .autotune import best_config
from .dispatch import kernel_route
from .e3cs_tiles import e3cs_update_kernel_call, fused_gumbel_topk_kernel_call
from .gumbel_topk import gumbel_topk_kernel_call
from .ref import e3cs_update_tiled_ref, gumbel_topk_ref

__all__ = ["gumbel_topk_sample", "fused_gumbel_topk_sample", "e3cs_update_tiled"]

_EPS = 1e-20


@functools.partial(jax.jit, static_argnames=("k", "tile", "use_kernel", "interpret"))
def _gumbel_topk_impl(rng, p, k: int, tile: int, use_kernel: bool, interpret: bool):
    g = jax.random.gumbel(rng, p.shape, jnp.float32)
    scores = jnp.log(jnp.maximum(p.astype(jnp.float32), _EPS)) + g
    if not use_kernel:
        return gumbel_topk_ref(scores, k)
    _, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=interpret)
    return idx


def gumbel_topk_sample(rng, p, k: int, tile: int = None):
    """Plackett-Luce k-subset sample over probabilities ``p`` (K,)."""
    use_kernel, interpret = kernel_route()
    if tile is None:
        tile = best_config("gumbel_topk", p.shape[0])["tile"]
    return _gumbel_topk_impl(rng, p, k, int(tile), use_kernel, interpret)


@functools.partial(jax.jit, static_argnames=("k", "tile", "use_kernel", "interpret"))
def _fused_gumbel_topk_impl(rng, p, k: int, tile: int, use_kernel: bool, interpret: bool):
    u = jax.random.uniform(rng, p.shape, jnp.float32)
    p = p.astype(jnp.float32)
    if not use_kernel:
        # jnp twin of the kernel's in-register perturbation + mask
        g = -jnp.log(-jnp.log(jnp.clip(u, _EPS, 1.0 - 1e-7)))
        s = jnp.where(p > 0.0, jnp.log(jnp.maximum(p, _EPS)) + g, -jnp.inf)
        return gumbel_topk_ref(s, k)
    _, idx = fused_gumbel_topk_kernel_call(p, u, k, tile=tile, interpret=interpret)
    return idx


def fused_gumbel_topk_sample(rng, p, k: int, tile: int = None):
    """Single-pass Plackett-Luce sample: the Gumbel perturbation happens
    inside the kernel, so scores never round-trip through HBM."""
    use_kernel, interpret = kernel_route()
    if tile is None:
        tile = best_config("gumbel_topk", p.shape[0])["tile"]
    return _fused_gumbel_topk_impl(rng, p, k, int(tile), use_kernel, interpret)


@functools.partial(jax.jit, static_argnames=("tile", "use_kernel", "interpret"))
def _e3cs_update_impl(logw, p, sel_mask, x, frozen, scale, tile: int, use_kernel: bool, interpret: bool):
    if not use_kernel:
        return e3cs_update_tiled_ref(logw, p, sel_mask, x, frozen, scale)
    new_logw, tmax = e3cs_update_kernel_call(
        logw, p, sel_mask, x, frozen, scale, tile=tile, interpret=interpret
    )
    return new_logw - jnp.max(tmax)


def e3cs_update_tiled(logw, p, sel_mask, x, frozen, scale, tile: int = None):
    """Fused, re-centered E3CS weight update (Eqs. 16-17) at fleet scale."""
    use_kernel, interpret = kernel_route()
    if tile is None:
        tile = best_config("e3cs_tiles", logw.shape[0])["tile"]
    return _e3cs_update_impl(logw, p, sel_mask, x, frozen, scale, int(tile), use_kernel, interpret)
