"""Gumbel top-k selection as a Pallas kernel — E3CS sampling at large K.

``A_t ~ multinomialNR(p/k, k)`` == top-k of ``log p + Gumbel`` (Yellott 1977).
At cross-device-FL scale (K ~ 10^5..10^6 clients) the selection itself becomes
a bandwidth-bound scan over the weight vector; this kernel streams the
perturbed scores through VMEM in tiles and maintains the running top-k in a
scratch buffer via k iterative max-extractions per tile (k << tile, so the
cost is one VPU max-reduction per candidate).

Layout: grid ``(n_tiles,)``; scratch holds (k, 2) [value, index] pairs merged
across tiles.  Output: (k,) int32 indices, descending by score.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gumbel_topk_kernel_call", "streaming_topk_body"]

NEG_INF = -1e30


def streaming_topk_body(s, val_ref, idx_ref, best_v, best_i, *, k, tile, n_tiles):
    """Shared streaming top-k merge used by every selection kernel.

    Takes this tile's already-masked scores ``s`` (tile,), merges them into
    the running (k,) [value, index] VMEM scratch by extracting the tile max k
    times (each accepted only if it beats the current k-th best), and on the
    last tile emits the buffers sorted descending.  Callers provide the score
    prelude (masking, perturbation fusion); everything below the scores is
    identical across kernels so it lives here once.
    """
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        best_v[...] = jnp.full_like(best_v, NEG_INF)
        best_i[...] = jnp.zeros_like(best_i)

    base = ti * tile

    def body(j, carry):
        s, bv, bi = carry
        m = jnp.max(s)
        am = jnp.argmax(s)
        gidx = base + am
        # current minimum of the top-k buffer
        kmin_pos = jnp.argmin(bv)
        kmin = bv[kmin_pos]
        better = m > kmin
        bv = bv.at[kmin_pos].set(jnp.where(better, m, kmin))
        bi = bi.at[kmin_pos].set(jnp.where(better, gidx, bi[kmin_pos]))
        s = s.at[am].set(NEG_INF)
        return s, bv, bi

    s, bv, bi = jax.lax.fori_loop(0, k, body, (s, best_v[...], best_i[...]))
    best_v[...] = bv
    best_i[...] = bi

    @pl.when(ti == n_tiles - 1)
    def _finish():
        order = jnp.argsort(-best_v[...])
        val_ref[...] = best_v[...][order]
        idx_ref[...] = best_i[...][order].astype(jnp.int32)


def _kernel(s_ref, val_ref, idx_ref, best_v, best_i, *, k, tile, n_tiles, K):
    ti = pl.program_id(0)
    s = s_ref[...].astype(jnp.float32)  # (tile,)
    pos = ti * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    s = jnp.where(pos < K, s, NEG_INF)
    streaming_topk_body(s, val_ref, idx_ref, best_v, best_i, k=k, tile=tile, n_tiles=n_tiles)


def gumbel_topk_kernel_call(scores: jax.Array, k: int, tile: int = 8192, interpret: bool = False):
    """scores: (K,) perturbed log-probabilities. Returns (values, indices)."""
    K = scores.shape[0]
    tile = min(tile, max(K, 8))
    K_p = math.ceil(K / tile) * tile
    if K_p != K:
        scores = jnp.pad(scores, (0, K_p - K), constant_values=NEG_INF)
    n_tiles = K_p // tile
    kernel = functools.partial(_kernel, k=k, tile=tile, n_tiles=n_tiles, K=K)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile,), lambda t: (t,))],
        out_specs=[
            pl.BlockSpec((k,), lambda t: (0,)),
            pl.BlockSpec((k,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((k,), jnp.float32), pltpu.VMEM((k,), jnp.int32)],
        interpret=interpret,
    )(scores)
    return vals, idx
