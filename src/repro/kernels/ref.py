"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

The fused-round references below are composed from *exactly* the staged
engine's ops in the staged engine's order (same expressions, same operand
order, same masking), so on backends where dispatch picks the reference
path the ``fused=True`` engine is bit-identical to the staged one by
construction — and the Pallas kernels in ``round_fused.py`` are validated
bit-for-bit against these in interpret mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.volatility import DEAD_LAG

from .unpack_bits import unpack_bits_ref, unpack_crumbs_ref

__all__ = [
    "gumbel_topk_ref",
    "e3cs_update_tiled_ref",
    "fused_alloc_select_ref",
    "fused_perturb_select_ref",
    "round_tail_ref",
]

_LAG_DEAD_CODE = 3  # 2-bit crumb sentinel (mirrors engine.round_program)


def gumbel_topk_ref(scores, k: int):
    """Top-k indices of perturbed scores (descending)."""
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)


def e3cs_update_tiled_ref(logw, p, sel_mask, x, frozen, scale):
    """jnp twin of ``e3cs_tiles.e3cs_update_kernel_call`` + recenter."""
    xhat = sel_mask * x / jnp.maximum(p, 1e-12)
    step = jnp.minimum(scale * xhat, 1.0)
    new = logw + jnp.where(frozen > 0, 0.0, step)
    return new - jnp.max(new)


def _select_scores(p, g, active):
    """Staged score assembly: ``perturbed_scores`` with the Gumbel draw
    hoisted out (``g`` must come from the identical ``jax.random.gumbel``
    call the staged sampler makes), plus the sharded engine's activity
    masking."""
    s = jnp.log(jnp.maximum(p, 1e-20)) + g
    if active is not None:
        s = jnp.where(active > 0, s, -jnp.inf)
    return s


def fused_alloc_select_ref(w, g, k: int, *, sigma, scalars, active=None):
    """Allocation epilogue + perturb + top-k in staged op order.

    ``scalars = (residual, cap, denom, use_cap)`` from
    ``engine.sharded.masked_prob_alloc_scalars``.  Returns
    ``(p, capped, vals, idx)`` with ``idx`` local (no shard offset) —
    bitwise the staged ``masked_prob_alloc`` epilogue followed by
    ``perturbed_scores`` + ``lax.top_k``.
    """
    residual, cap, denom, use_cap = scalars
    p = sigma + residual * jnp.minimum(w, cap) / denom
    capped = (p >= 1.0 - 1e-6) & use_cap
    p = jnp.clip(p, sigma, 1.0)
    if active is not None:
        p = p * active
        capped = capped & (active > 0)
    vals, idx = jax.lax.top_k(_select_scores(p, g, active), k)
    return p, capped, vals, idx.astype(jnp.int32)


def fused_perturb_select_ref(p, g, k: int, *, active=None):
    """Perturb + top-k only (the sorted-allocator path, where ``p`` is
    already staged).  Returns ``(vals, idx)``."""
    vals, idx = jax.lax.top_k(_select_scores(p, g, active), k)
    return vals, idx.astype(jnp.int32)


def round_tail_ref(
    obs,
    mask,
    p,
    capped,
    logw,
    loss_cache,
    credit,
    fb,
    *,
    kind: str,
    residual,
    eta: float,
    K_glob: int,
    decay=(),
    active: Optional[jax.Array] = None,
):
    """Observe-decode + E3CS elementwise update + credit rings, staged order.

    ``kind``: ``"bits"`` (packed sync trace row), ``"crumbs"`` (packed async
    lag row), ``"x"`` (dense success bits), ``"lag"`` (dense int32 lags).
    ``decay`` is the static per-slot late-credit schedule
    ``(alpha**1, ..., alpha**S)``; ``credit`` / ``fb`` are the ``(S, K)``
    rings (``None`` when absent).  ``residual`` is the traced scalar
    ``asarray(k, p.dtype) - K_glob * sigma`` computed by the caller with the
    staged expression.  Returns a dict of every tail product; the global
    recenter (needs a cross-tile / cross-shard max) stays with the caller.
    """
    K = mask.shape[0]
    lag = None
    if kind == "bits":
        x = unpack_bits_ref(obs, K)
    elif kind == "crumbs":
        codes = unpack_crumbs_ref(obs, K)
        lag = jnp.where(codes == _LAG_DEAD_CODE, DEAD_LAG, codes)
    elif kind == "x":
        x = obs
    elif kind == "lag":
        lag = obs
    else:
        raise ValueError(f"unknown obs kind {kind!r}")
    if lag is not None:
        x = (lag == 0).astype(jnp.float32)  # deadline-based selector feedback

    # Eq. 16/17 elementwise (recenter deferred): exactly e3cs_update's ops
    xhat = mask * x / jnp.maximum(p, 1e-12)
    step = residual * eta * xhat / K_glob
    step = jnp.minimum(step, 1.0)
    frozen = capped if active is None else capped | (active == 0)
    logw_pre = logw + jnp.where(frozen, 0.0, step)
    m = jnp.max(logw_pre) if active is None else jnp.max(
        jnp.where(active > 0, logw_pre, -jnp.inf)
    )
    out = {
        "x": x,
        "logw_pre": logw_pre,
        "m": m,
        "loss_cache": jnp.where(mask > 0, 1.0 - x, loss_cache),
    }
    if lag is not None:
        out["lag"] = lag

    S = len(decay)
    if credit is not None and S > 0:
        dec = jnp.asarray(list(decay), jnp.float32)
        lag_rows = jnp.arange(1, S + 1, dtype=jnp.int32)
        sched = mask[None, :] * (lag[None, :] == lag_rows[:, None]) * dec[:, None]
        out["arriving"] = credit[0, :]
        shifted = jnp.concatenate([credit[1:, :], jnp.zeros_like(credit[:1, :])], axis=0)
        out["credit"] = shifted + sched
        if fb is not None:
            xhat_rows = sched / jnp.maximum(p, 1e-12)
            rows = jnp.minimum(residual * eta * xhat_rows / K_glob, 1.0)
            rows = jnp.where(frozen, 0.0, rows)
            out["arr_fb"] = fb[0, :]
            fb_shift = jnp.concatenate([fb[1:, :], jnp.zeros_like(fb[:1, :])], axis=0)
            out["fb"] = fb_shift + rows
    return out
