"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "ssd_scan_ref", "gumbel_topk_ref"]


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd), H = G*KV. Returns (B,S,H,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) / jnp.sqrt(hd)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isfinite(w), w, 0.0)  # fully-masked rows
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 0):
    """Sequential SSD recurrence (ground truth; chunk arg ignored).

    x: (b,S,H,P); dt: (b,S,H); A: (H,); B/C: (b,S,G,N).
    Returns (y (b,S,H,P), final_state (b,H,N,P)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)[:, :, None, None]
        state = state * decay + jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, state)
        return state, y

    init = jnp.zeros((b, H, N, P), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Ch.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final.astype(x.dtype)


def gumbel_topk_ref(scores, k: int):
    """Top-k indices of perturbed scores (descending)."""
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)
