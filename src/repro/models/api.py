"""Model façade: one uniform interface over all families.

``build_model(cfg)`` returns a ``Model`` with pure functions:
    init(rng) -> (params, specs)         specs = logical-axis pytree
    loss(params, batch, rng) -> (loss, metrics)
    forward(params, batch) -> logits
    prefill(params, batch) -> (logits, caches)
    decode(params, tokens, caches) -> (logits, caches)
    init_caches(B, S_cache) -> caches

``input_specs(cfg, shape)`` builds jax.ShapeDtypeStruct stand-ins for the
dry-run (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import cnn as cnn_mod
from . import encdec as encdec_mod
from . import transformer as tr
from repro.configs.base import InputShape, ModelConfig

__all__ = ["Model", "build_model", "input_specs", "cross_entropy"]


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE in fp32. logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss: Callable
    forward: Callable
    prefill: Callable
    decode: Callable
    init_caches: Callable


def build_model(cfg: ModelConfig, window: int = 0, impl: str = "einsum") -> Model:
    if cfg.family == "cnn":
        return _build_cnn(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg, window)
    return _build_lm(cfg, window, impl)


def _build_lm(cfg, window, impl):
    def init(rng):
        return tr.model_init(rng, cfg)

    def loss(params, batch, rng=None):
        logits, _, (aux, mtp_logits) = tr.forward(params, cfg, batch, "train", window, impl)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # image positions carry no LM loss
            P = cfg.n_patches
            logits = logits[:, P:]
        ce = cross_entropy(logits[:, :-1], labels[:, 1:])
        total = ce + cfg.router_aux_coef * aux
        metrics = {"ce": ce, "aux": aux}
        if mtp_logits is not None:
            tl = mtp_logits[:, cfg.n_patches :] if cfg.family == "vlm" else mtp_logits
            # mtp_logits[:, t] predicts labels[t+2] (length S-1 vs labels S)
            mtp_ce = cross_entropy(tl[:, :-1], labels[:, 2:])
            total = total + 0.1 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    def forward(params, batch):
        logits, _, _ = tr.forward(params, cfg, batch, "train", window, impl)
        return logits

    def prefill(params, batch, max_len=None):
        logits, caches, _ = tr.forward(params, cfg, batch, "prefill", window, impl)
        S = next(iter(batch.values())).shape[1] if "tokens" not in batch else batch["tokens"].shape[1]
        if cfg.family == "vlm":
            S = batch["tokens"].shape[1] + cfg.n_patches
        margin = (max_len - S) if max_len else 64
        caches = tr.pad_caches(caches, margin, window)
        return logits, caches

    def decode(params, tokens, caches):
        return tr.decode_step(params, cfg, tokens, caches, window)

    def init_caches(B, S_cache, dtype=None):
        return tr.init_caches(cfg, B, S_cache, window, dtype or jnp.dtype(cfg.dtype))

    return Model(cfg, init, loss, forward, prefill, decode, init_caches)


def _build_encdec(cfg, window):
    def init(rng):
        return encdec_mod.encdec_init(rng, cfg)

    def loss(params, batch, rng=None):
        logits, _, _ = encdec_mod.encdec_forward(params, cfg, batch, "train", window)
        return cross_entropy(logits[:, :-1], batch["labels"][:, 1:]), {}

    def forward(params, batch):
        return encdec_mod.encdec_forward(params, cfg, batch, "train", window)[0]

    def prefill(params, batch, max_len=None):
        logits, caches, _ = encdec_mod.encdec_forward(params, cfg, batch, "prefill", window)
        S = batch["tokens"].shape[1]
        margin = (max_len - S) if max_len else 64
        if margin > 0 and window == 0:
            from .attention import KVCache

            c = caches["self"]
            pad = [(0, 0)] * c.k.ndim
            pad[2] = (0, margin)
            caches["self"] = KVCache(jnp.pad(c.k, pad), jnp.pad(c.v, pad), c.pos)
        return logits, caches

    def decode(params, tokens, caches):
        return encdec_mod.encdec_decode_step(params, cfg, tokens, caches, window)

    def init_caches(B, S_cache, dtype=None):
        return encdec_mod.encdec_init_caches(cfg, B, S_cache, window, dtype or jnp.dtype(cfg.dtype))

    return Model(cfg, init, loss, forward, prefill, decode, init_caches)


def _build_cnn(cfg):
    def init(rng):
        return cnn_mod.cnn_init(rng, cfg)

    def loss(params, batch, rng=None):
        logits = cnn_mod.cnn_forward(params, cfg, batch)
        ce = cross_entropy(logits, batch["y"])
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
        return ce, {"acc": acc}

    def forward(params, batch):
        return cnn_mod.cnn_forward(params, cfg, batch)

    def _na(*a, **k):
        raise NotImplementedError("CNN has no serving path")

    return Model(cfg, init, loss, forward, _na, _na, _na)


# ------------------------------------------------------------ input specs --


def input_specs(cfg: ModelConfig, shape: InputShape, window: int = 0) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape.

    For train/prefill: the token batch (+frontend stubs).  For decode: one
    new token per sequence plus the KV/state caches sized to ``seq_len``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "cnn":
        s = cnn_mod.CNN_SHAPES[cfg.name.replace("-smoke", "")]
        return {
            "x": jax.ShapeDtypeStruct((B, *s["img"]), jnp.float32),
            "y": jax.ShapeDtypeStruct((B,), i32),
        }
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            P = cfg.n_patches
            batch["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S - P), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_patch), jnp.bfloat16)
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one token + caches pre-filled to S
    model = build_model(cfg, window=window)
    caches = jax.eval_shape(lambda: model.init_caches(B, S))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "caches": caches,
    }
