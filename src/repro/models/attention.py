"""GQA/MQA/MHA attention with RoPE / M-RoPE, sliding window, and KV cache.

Three entry points share one score/softmax core:
  * ``attn_apply(..., mode="train")``   — full-sequence causal.
  * ``attn_apply(..., mode="prefill")`` — causal + returns the filled cache.
  * ``attn_decode``                     — one new token against a cache.

A ``window > 0`` enables sliding-window attention; in decode mode the cache
is a ring buffer of ``window`` slots, so `long_500k` serving keeps O(window)
memory for dense architectures (DESIGN.md §5).

The XLA einsum path is the default (robust for SPMD lowering);
``impl="chunked"`` swaps in the running-softmax blocked path for long
train/prefill sequences.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, apply_rope, apply_mrope
from .sharding import shard

__all__ = ["attn_init", "attn_apply", "attn_decode", "init_kv_cache", "KVCache"]


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, KV, hd)
    v: jax.Array  # (B, S_cache, KV, hd)
    pos: jax.Array  # scalar int32 — number of tokens already absorbed


def attn_init(pb: ParamBuilder, cfg):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pb.p("wq", (d, H, hd), ("embed", "q_heads", "head_dim"), fan_in=d)
    pb.p("wk", (d, KV, hd), ("embed", "kv_heads", "head_dim"), fan_in=d)
    pb.p("wv", (d, KV, hd), ("embed", "kv_heads", "head_dim"), fan_in=d)
    pb.p("wo", (H, hd, d), ("q_heads", "head_dim", "embed"), fan_in=H * hd)


def _project_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, softcap: Optional[float]):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd) with H = G*KV.  mask: (B,1,S,T) bool."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) / jnp.sqrt(hd).astype(jnp.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def _chunked_sdpa(q, k, v, causal: bool, window: int, softcap, chunk_q: int = 512, chunk_k: int = 1024):
    """Memory-efficient attention: double scan over (q-chunk, kv-chunk) with a
    running-softmax carry — the XLA-lowerable analogue of the flash kernel,
    used for long-sequence prefill where materialising (S, T) scores is
    impossible.  No backward pass needed (prefill only).

    q: (B,S,H,hd); k/v: (B,T,KV,hd).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    cq = min(chunk_q, S)
    ck = min(chunk_k, T)
    assert S % cq == 0 and T % ck == 0, (S, cq, T, ck)
    nq, nk = S // cq, T // ck
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qc = q.reshape(B, nq, cq, KV, G, hd)
    kc = k.reshape(B, nk, ck, KV, hd)
    vc = v.reshape(B, nk, ck, KV, hd)

    def q_block(qi, qb):
        # qb: (B, cq, KV, G, hd)
        def kv_step(carry, inp):
            m, l, acc = carry
            kj, kb, vb = inp
            s = jnp.einsum("bqkgh,btkh->bkgqt", qb, kb).astype(jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            q_pos = qi * cq + jnp.arange(cq)[:, None]
            k_pos = kj * ck + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= k_pos <= q_pos
            if window > 0:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bkgqt,btkh->bkgqh", p.astype(vb.dtype), vb).astype(
                jnp.float32
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0))
        )
        out = acc / jnp.where(l == 0, 1.0, l)[..., None]
        return jnp.moveaxis(out, 3, 1).reshape(B, cq, KV * G, hd).astype(q.dtype)  # (B,cq,H,hd)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)


def _causal_mask(S: int, T: int, offset: int, window: int) -> jax.Array:
    """(S, T) bool; query i attends key j iff j <= i+offset and within window."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def attn_apply(
    p,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    mode: str = "train",
    window: int = 0,
    impl: str = "einsum",
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    """Full-sequence attention. Returns (out, cache|None)."""
    B, S, _ = x.shape
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = cross_kv
        out = _sdpa(q, k, v, jnp.ones((B, 1, S, k.shape[1]), bool), cfg.attn_logit_softcap)
    else:
        q, k, v = _project_qkv(p, x, cfg, positions)
        k = shard(k, "batch", "seq", "kv_heads", "head_dim")
        v = shard(v, "batch", "seq", "kv_heads", "head_dim")
        if impl == "chunked":
            out = _chunked_sdpa(q, k, v, True, window, cfg.attn_logit_softcap)
        else:
            mask = _causal_mask(S, S, 0, window)[None, None]
            out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    out = shard(out, "batch", "seq", "q_heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    cache = None
    if mode == "prefill" and cross_kv is None:
        if window > 0:
            # keep only the trailing `window` keys (ring buffer, oldest first
            # rotated so slot (pos % window) is next to write)
            keep = min(window, S)
            kw = jnp.zeros((B, window, *k.shape[2:]), k.dtype).at[:, :keep].set(k[:, -keep:])
            vw = jnp.zeros((B, window, *v.shape[2:]), v.dtype).at[:, :keep].set(v[:, -keep:])
            # ring index: cache slot i holds key for position pos - window + ...
            # we store in chronological order starting at slot 0 == position S-keep
            cache = KVCache(kw, vw, jnp.asarray(S, jnp.int32))
        else:
            cache = KVCache(k, v, jnp.asarray(S, jnp.int32))
    return y, cache


def init_kv_cache(cfg, B: int, S_cache: int, window: int = 0, dtype=jnp.bfloat16) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    n = min(window, S_cache) if window > 0 else S_cache
    z = jnp.zeros((B, n, KV, hd), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def attn_decode(
    p,
    x: jax.Array,
    cfg,
    cache: KVCache,
    window: int = 0,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    positions: Optional[jax.Array] = None,
):
    """One-token step. x: (B, 1, d). Returns (out, new_cache)."""
    B = x.shape[0]
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = cross_kv
        out = _sdpa(q, k, v, jnp.ones((B, 1, 1, k.shape[1]), bool), cfg.attn_logit_softcap)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    pos = cache.pos  # number of tokens already in context
    if positions is None:
        positions = jnp.broadcast_to(pos, (B, 1))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(pos, (3, B, 1))
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    n_slots = cache.k.shape[1]
    slot = (pos % n_slots) if window > 0 else pos
    k = cache.k.at[:, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[:, slot].set(v_new[:, 0].astype(cache.v.dtype))
    k = shard(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "cache_seq", "kv_heads", "head_dim")
    # validity mask over slots
    slots = jnp.arange(n_slots)
    if window > 0:
        valid = (slots[None] <= slot) | (pos >= n_slots)  # ring: all valid once wrapped
        valid = valid & (slots[None] >= 0)
    else:
        valid = slots[None] <= pos
    mask = jnp.broadcast_to(valid[:, None, None, :], (B, 1, 1, n_slots))
    out = _sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, KVCache(k, v, pos + 1)
