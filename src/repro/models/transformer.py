"""Composable decoder-only stack covering dense / moe / ssm / hybrid / vlm.

Layers are grouped into *segments* of identical block kind (e.g. DeepSeek-V3 =
3x ``mla_mlp`` + 58x ``mla_moe``); each segment's parameters are stacked along
a leading ``layers`` axis and executed with ``lax.scan`` (+ optional remat) so
the HLO stays small for the 126-layer dry-runs.  Zamba2-style hybrids scan
over SSM layers and apply a weight-shared attention block every
``hybrid_attn_every`` layers (per-site KV caches).

Entry points:
  * ``model_init(rng, cfg)``                      -> (params, specs)
  * ``forward(params, cfg, batch, mode)``         -> logits [, caches] (+aux)
  * ``decode_step(params, cfg, tokens, caches)``  -> logits, caches
  * ``init_caches(cfg, B, S_cache, window)``      -> cache pytree
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import ParamBuilder, mlp_init, mlp_apply, norm_apply, norm_init
from .sharding import shard

__all__ = ["segments_of", "model_init", "forward", "decode_step", "init_caches", "vlm_positions"]


# --------------------------------------------------------------- segments --


def segments_of(cfg) -> List[Tuple[str, int]]:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return [("attn_mlp", cfg.n_layers)]
    if fam == "moe":
        a = "mla" if cfg.attn == "mla" else "attn"
        segs = []
        if cfg.n_dense_layers:
            segs.append((f"{a}_mlp", cfg.n_dense_layers))
        segs.append((f"{a}_moe", cfg.n_layers - cfg.n_dense_layers))
        return segs
    if fam == "ssm":
        return [("ssm", cfg.n_layers)]
    if fam == "hybrid":
        return [("ssm", cfg.n_layers)]  # shared attn handled separately
    raise ValueError(fam)


def _block_init(rng, cfg, kind: str):
    pb = ParamBuilder(rng, jnp.dtype(cfg.param_dtype).type)
    if kind == "ssm":
        norm_init(pb, "norm1", cfg.d_model, cfg.norm)
        ssm_mod.ssm_init(pb.child("ssm"), cfg)
        return pb.params, pb.specs
    attn_kind, ffn_kind = kind.split("_")
    norm_init(pb, "norm1", cfg.d_model, cfg.norm)
    if attn_kind == "mla":
        mla_mod.mla_init(pb.child("attn"), cfg)
    else:
        attn_mod.attn_init(pb.child("attn"), cfg)
    norm_init(pb, "norm2", cfg.d_model, cfg.norm)
    if ffn_kind == "moe":
        moe_mod.moe_init(pb.child("ffn"), cfg)
    else:
        d_ff = cfg.d_ff_dense if (cfg.family == "moe" and cfg.d_ff_dense) else cfg.d_ff
        mlp_init(pb.child("ffn"), cfg.d_model, d_ff, cfg.act)
    return pb.params, pb.specs


def _stack_init(rng, cfg, kind: str, n: int):
    rngs = jax.random.split(rng, n)
    params = jax.vmap(lambda r: _block_init(r, cfg, kind)[0])(rngs)
    _, specs = _block_init(rng, cfg, kind)  # shapes only; re-used for axes
    specs = jax.tree.map(lambda s: ("layers",) + s, specs, is_leaf=lambda s: isinstance(s, tuple))
    return params, specs


# ---------------------------------------------------------------- blocks ---


def _block_apply(p, x, cfg, kind: str, positions, mode: str, window: int, cache, impl: str):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p, "norm1", x, cfg.norm, cfg.norm_eps, plus_one=cfg.emb_scale)
    if kind == "ssm":
        if mode == "decode":
            y, cache = ssm_mod.ssm_decode(p["ssm"], h, cfg, cache)
        else:
            y, cache = ssm_mod.ssm_apply(p["ssm"], h, cfg, mode, impl)
        return x + y, cache, aux
    attn_kind, ffn_kind = kind.split("_")
    if attn_kind == "mla":
        if mode == "decode":
            y, cache = mla_mod.mla_decode(p["attn"], h, cfg, cache, window)
        else:
            y, cache = mla_mod.mla_apply(p["attn"], h, cfg, positions, mode, window, impl)
    else:
        if mode == "decode":
            y, cache = attn_mod.attn_decode(p["attn"], h, cfg, cache, window)
        else:
            y, cache = attn_mod.attn_apply(p["attn"], h, cfg, positions, mode, window, impl)
    x = x + y
    h = norm_apply(p, "norm2", x, cfg.norm, cfg.norm_eps, plus_one=cfg.emb_scale)
    if ffn_kind == "moe":
        y, aux = moe_mod.moe_apply(p["ffn"], h, cfg)
    else:
        y = mlp_apply(p["ffn"], h, cfg.act)
    return x + y, cache, aux


# ---------------------------------------------------------------- model ----


def model_init(rng, cfg):
    pb = ParamBuilder(rng, jnp.dtype(cfg.param_dtype).type)
    pb.p("tok_emb", (cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed")
    if not cfg.tie_embeddings:
        pb.p("out_head", (cfg.d_model, cfg.vocab), ("embed", "vocab"), fan_in=cfg.d_model)
    norm_init(pb, "final_norm", cfg.d_model, cfg.norm)
    if cfg.family == "vlm":
        pb.p("patch_proj", (cfg.d_patch, cfg.d_model), ("patch", "embed"), fan_in=cfg.d_patch)
    if cfg.mtp:
        pb.p("mtp_proj", (2 * cfg.d_model, cfg.d_model), (None, "embed"), fan_in=2 * cfg.d_model)
        norm_init(pb, "mtp_norm", cfg.d_model, cfg.norm)
    for si, (kind, n) in enumerate(segments_of(cfg)):
        params, specs = _stack_init(jax.random.fold_in(rng, 1000 + si), cfg, kind, n)
        pb.params[f"seg{si}"] = params
        pb.specs[f"seg{si}"] = specs
    if cfg.family == "hybrid":
        sp, ss = _block_init(jax.random.fold_in(rng, 777), cfg, "attn_mlp")
        spb = ParamBuilder(jax.random.fold_in(rng, 778), jnp.dtype(cfg.param_dtype).type)
        spb.p("w_concat", (2 * cfg.d_model, cfg.d_model), (None, "embed"), fan_in=2 * cfg.d_model)
        sp["w_concat"] = spb.params["w_concat"]
        ss["w_concat"] = spb.specs["w_concat"]
        pb.params["shared_attn"] = sp
        pb.specs["shared_attn"] = ss
    return pb.params, pb.specs


def _embed(params, cfg, batch):
    tokens = batch["tokens"]
    x = params["tok_emb"][tokens]
    if cfg.family == "vlm":
        patches = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.emb_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return shard(x.astype(jnp.dtype(cfg.dtype)), "batch", "seq", "act_embed")


def _logits(params, cfg, x):
    x = norm_apply(params, "final_norm", x, cfg.norm, cfg.norm_eps, plus_one=cfg.emb_scale)
    head = params["tok_emb"].T if cfg.tie_embeddings else params["out_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", "seq", "vocab")


def _hybrid_sites(cfg) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every if cfg.hybrid_attn_every else 0


def _run_segment(params, cfg, si, kind, x, positions, mode, window, caches, impl, emb0=None):
    """Scan a stacked segment. caches: stacked cache pytree or None."""
    seg = params[f"seg{si}"]
    n = jax.tree.leaves(seg)[0].shape[0]
    hybrid = cfg.family == "hybrid" and cfg.hybrid_attn_every
    shared = params.get("shared_attn")

    def body(carry, scanned):
        x, attn_caches, li = carry
        layer_p, cache_in = scanned
        x, cache_out, aux = _block_apply(layer_p, x, cfg, kind, positions, mode, window, cache_in, impl)
        x = shard(x, "batch", "seq", "act_embed")
        if hybrid:
            site = (li + 1) // cfg.hybrid_attn_every - 1
            apply_attn = (li + 1) % cfg.hybrid_attn_every == 0

            def do_attn(op):
                x, attn_caches = op
                h = jnp.concatenate([x, emb0], axis=-1)
                h = jnp.einsum("bsd,de->bse", h, shared["w_concat"])
                if mode == "decode":
                    c = jax.tree.map(lambda a: a[site], attn_caches)
                    h2, c2, _ = _block_apply(shared, h, cfg, "attn_mlp", positions, mode, window, c, impl)
                    attn_caches = jax.tree.map(lambda a, b: a.at[site].set(b), attn_caches, c2)
                else:
                    h2, c2, _ = _block_apply(shared, h, cfg, "attn_mlp", positions, mode, window, None, impl)
                    if mode == "prefill":
                        attn_caches = jax.tree.map(lambda a, b: a.at[site].set(b), attn_caches, c2)
                return x + h2, attn_caches

            x, attn_caches = jax.lax.cond(apply_attn, do_attn, lambda op: op, (x, attn_caches))
        return (x, attn_caches, li + 1), (cache_out, aux)

    fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body

    attn_caches = caches.get("shared") if (hybrid and caches is not None) else None
    seg_caches = caches.get(f"seg{si}") if (caches is not None and mode == "decode") else None
    if cfg.scan_layers:
        scan_xs = (seg, seg_caches) if seg_caches is not None else (seg, _dummy_caches(n))
        (x, attn_caches, _), (new_caches, auxs) = jax.lax.scan(fn, (x, attn_caches, jnp.zeros((), jnp.int32)), scan_xs)
        aux = jnp.sum(auxs)
    else:
        new_list, aux = [], jnp.zeros((), jnp.float32)
        carry = (x, attn_caches, jnp.zeros((), jnp.int32))
        for i in range(n):
            layer_p = jax.tree.map(lambda a: a[i], seg)
            c_in = jax.tree.map(lambda a: a[i], seg_caches) if seg_caches is not None else None
            carry, (c_out, a) = fn(carry, (layer_p, c_in))
            new_list.append(c_out)
            aux = aux + a
        x, attn_caches, _ = carry
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if new_list and new_list[0] is not None else None
        )
    return x, attn_caches, new_caches, aux


class _DummyCache:
    pass


def _dummy_caches(n):
    # lax.scan needs a scannable pytree even when the mode carries no caches;
    # an integer placeholder array keeps the structure trivial.
    return jnp.zeros((n, 1), jnp.int8)


def forward(params, cfg, batch, mode: str = "train", window: int = 0, impl: str = "einsum"):
    """Full-sequence forward. Returns (logits, caches, aux)."""
    x = _embed(params, cfg, batch)
    positions = batch.get("positions")
    if positions is None:
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    emb0 = x if cfg.family == "hybrid" else None
    caches_out: Dict[str, Any] = {}
    if mode == "prefill":
        caches = init_caches(cfg, x.shape[0], x.shape[1], window, dtype=jnp.dtype(cfg.dtype))
    else:
        caches = None
    aux_total = jnp.zeros((), jnp.float32)
    attn_caches_final = None
    for si, (kind, n) in enumerate(segments_of(cfg)):
        x, attn_caches_final, new_caches, aux = _run_segment(
            params, cfg, si, kind, x, positions, mode, window, caches, impl, emb0
        )
        aux_total = aux_total + aux
        if mode == "prefill" and new_caches is not None and not isinstance(new_caches, jnp.ndarray):
            caches_out[f"seg{si}"] = new_caches
    if mode == "prefill" and attn_caches_final is not None:
        caches_out["shared"] = attn_caches_final
    logits = _logits(params, cfg, x)
    if cfg.mtp and mode == "train":
        # DeepSeek-style multi-token prediction: fuse h_t with emb(token_{t+1})
        # to predict token_{t+2}; auxiliary logits returned via aux dict.
        emb_next = params["tok_emb"][batch["tokens"]][:, 1:]
        h = norm_apply(params, "mtp_norm", x[:, :-1], cfg.norm, cfg.norm_eps, plus_one=cfg.emb_scale)
        fused = jnp.einsum(
            "bsd,de->bse", jnp.concatenate([h, emb_next.astype(h.dtype)], -1), params["mtp_proj"]
        )
        mtp_logits = _logits(params, cfg, fused)
        return logits, caches_out or None, (aux_total, mtp_logits)
    return logits, (caches_out or None), (aux_total, None)


def decode_step(params, cfg, tokens, caches, window: int = 0):
    """tokens: (B, 1). caches: dict seg{i} -> stacked cache (+ 'shared')."""
    x = params["tok_emb"][tokens]
    if cfg.emb_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    x = x.astype(jnp.dtype(cfg.dtype))
    emb0 = x if cfg.family == "hybrid" else None
    new_caches = {}
    attn_caches = caches.get("shared")
    positions = None
    for si, (kind, n) in enumerate(segments_of(cfg)):
        x, attn_caches, seg_new, _ = _run_segment(
            params, cfg, si, kind, x, positions, "decode", window, {**caches, "shared": attn_caches}, "einsum", emb0
        )
        new_caches[f"seg{si}"] = seg_new
    if attn_caches is not None:
        new_caches["shared"] = attn_caches
    logits = _logits(params, cfg, x)
    return logits, new_caches


def init_caches(cfg, B: int, S_cache: int, window: int = 0, dtype=jnp.bfloat16):
    """Stacked decode caches per segment (+ hybrid shared-attn sites)."""
    out = {}
    for si, (kind, n) in enumerate(segments_of(cfg)):
        if kind == "ssm":
            c = ssm_mod.init_ssm_cache(cfg, B, dtype)
        elif kind.startswith("mla"):
            c = mla_mod.init_mla_cache(cfg, B, S_cache, window, dtype)
        else:
            c = attn_mod.init_kv_cache(cfg, B, S_cache, window, dtype)
        out[f"seg{si}"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), c)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        sites = _hybrid_sites(cfg)
        c = attn_mod.init_kv_cache(cfg, B, S_cache, window, dtype)
        out["shared"] = jax.tree.map(lambda a: jnp.broadcast_to(a, (sites,) + a.shape), c)
    return out


def pad_caches(caches, margin: int, window: int = 0):
    """Grow prefilled KV/latent caches by ``margin`` decode slots (seq axis=2
    of the layer-stacked tensors).  Ring-buffer (windowed) and SSM caches are
    fixed-size and pass through unchanged."""
    if margin <= 0 or window > 0 or caches is None:
        return caches

    def pad(leaf):
        c = leaf

        def grow(a):
            if a.ndim >= 3:
                pad_width = [(0, 0)] * a.ndim
                pad_width[2] = (0, margin)
                return jnp.pad(a, pad_width)
            return a

        if isinstance(c, attn_mod.KVCache):
            return attn_mod.KVCache(grow(c.k), grow(c.v), c.pos)
        if isinstance(c, mla_mod.MLACache):
            return mla_mod.MLACache(grow(c.c_kv), grow(c.k_rope), c.pos)
        return c

    return {
        name: pad(c) for name, c in caches.items()
    }


def cache_specs(cfg):
    """Logical-axis tuples mirroring ``init_caches`` structure."""
    out = {}
    for si, (kind, n) in enumerate(segments_of(cfg)):
        if kind == "ssm":
            c = ssm_mod.SSMCache(
                ("layers", "batch", None, "ssm_inner"),
                ("layers", "batch", "ssm_inner", "ssm_state", None),
                ("layers",),
            )
        elif kind.startswith("mla"):
            c = mla_mod.MLACache(
                ("layers", "batch", "cache_seq", None),
                ("layers", "batch", "cache_seq", None),
                ("layers",),
            )
        else:
            c = attn_mod.KVCache(
                ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                ("layers",),
            )
        out[f"seg{si}"] = c
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        out["shared"] = attn_mod.KVCache(
            ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
            ("layers",),
        )
    return out


def vlm_positions(cfg, B: int, S: int) -> jnp.ndarray:
    """Qwen2-VL M-RoPE position ids (3, B, S): one image of n_patches in a
    square grid followed by text."""
    P = cfg.n_patches
    import math

    g = int(math.sqrt(P))
    t_img = jnp.zeros((P,), jnp.int32)
    h_img = (jnp.arange(P) // g).astype(jnp.int32)
    w_img = (jnp.arange(P) % g).astype(jnp.int32)
    n_text = S - P
    text = jnp.arange(n_text, dtype=jnp.int32) + g  # offset past image extent
    pos3 = jnp.stack(
        [
            jnp.concatenate([t_img, text]),
            jnp.concatenate([h_img, text]),
            jnp.concatenate([w_img, text]),
        ]
    )
    return jnp.broadcast_to(pos3[:, None, :], (3, B, S))
