"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv frontend is STUBBED per the brief: the model
consumes precomputed frame embeddings ``frames: (B, enc_len, d_model)``.
Absolute sinusoidal positions on the encoder, learned positions on the
decoder, LayerNorm + GELU as in the original.  Decode precomputes per-layer
cross-attention K/V from the encoder output once and carries a growing
self-attention cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .layers import ParamBuilder, mlp_init, mlp_apply, norm_apply, norm_init, sinusoidal_positions
from .sharding import shard

__all__ = ["encdec_init", "encdec_forward", "encdec_encode", "encdec_decode_step", "encdec_init_caches"]

_MAX_DEC_POS = 65536  # learned decoder positions table (sized for the 32k serving shapes)


def _enc_block_init(rng, cfg):
    pb = ParamBuilder(rng, jnp.dtype(cfg.param_dtype).type)
    norm_init(pb, "norm1", cfg.d_model, cfg.norm)
    attn_mod.attn_init(pb.child("attn"), cfg)
    norm_init(pb, "norm2", cfg.d_model, cfg.norm)
    mlp_init(pb.child("ffn"), cfg.d_model, cfg.d_ff, cfg.act)
    return pb.params, pb.specs


def _dec_block_init(rng, cfg):
    pb = ParamBuilder(rng, jnp.dtype(cfg.param_dtype).type)
    norm_init(pb, "norm1", cfg.d_model, cfg.norm)
    attn_mod.attn_init(pb.child("self_attn"), cfg)
    norm_init(pb, "norm_x", cfg.d_model, cfg.norm)
    attn_mod.attn_init(pb.child("cross_attn"), cfg)
    norm_init(pb, "norm2", cfg.d_model, cfg.norm)
    mlp_init(pb.child("ffn"), cfg.d_model, cfg.d_ff, cfg.act)
    return pb.params, pb.specs


def _stack(rng, init_fn, cfg, n):
    params = jax.vmap(lambda r: init_fn(r, cfg)[0])(jax.random.split(rng, n))
    _, specs = init_fn(rng, cfg)
    specs = jax.tree.map(lambda s: ("layers",) + s, specs, is_leaf=lambda s: isinstance(s, tuple))
    return params, specs


def encdec_init(rng, cfg):
    pb = ParamBuilder(rng, jnp.dtype(cfg.param_dtype).type)
    pb.p("tok_emb", (cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed")
    pb.p("dec_pos", (_MAX_DEC_POS, cfg.d_model), (None, "embed"), init="embed")
    norm_init(pb, "enc_final", cfg.d_model, cfg.norm)
    norm_init(pb, "dec_final", cfg.d_model, cfg.norm)
    pb.params["enc"], pb.specs["enc"] = _stack(jax.random.fold_in(rng, 1), _enc_block_init, cfg, cfg.n_enc_layers)
    pb.params["dec"], pb.specs["dec"] = _stack(jax.random.fold_in(rng, 2), _dec_block_init, cfg, cfg.n_layers)
    return pb.params, pb.specs


def encdec_encode(params, cfg, frames):
    """frames: (B, enc_len, d_model) stub embeddings -> encoder output."""
    B, S, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoidal_positions(S, d).astype(jnp.dtype(cfg.dtype))[None]
    x = shard(x, "batch", "enc_seq", "embed")

    def body(x, p):
        h = norm_apply(p, "norm1", x, cfg.norm, cfg.norm_eps)
        # bidirectional: no positions (sinusoidal already added), full mask
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
        from .attention import _sdpa

        o = _sdpa(q, k, v, jnp.ones((B, 1, S, S), bool), None)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = norm_apply(p, "norm2", x, cfg.norm, cfg.norm_eps)
        return x + mlp_apply(p["ffn"], h, cfg.act), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc"])
    return norm_apply(params, "enc_final", x, cfg.norm, cfg.norm_eps)


def _cross_kv(p_dec, cfg, enc_out):
    """Precompute per-layer cross K/V: returns (L, B, T, KV, hd) pair."""

    def one(p):
        k = jnp.einsum("btd,dhk->bthk", enc_out, p["cross_attn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", enc_out, p["cross_attn"]["wv"])
        return k, v

    return jax.vmap(one)(p_dec)


def encdec_forward(params, cfg, batch, mode: str = "train", window: int = 0):
    """Teacher-forced decoder over (B, S) tokens; returns (logits, caches, aux)."""
    enc_out = encdec_encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["tok_emb"][tokens] + params["dec_pos"][:S][None]
    x = shard(x.astype(jnp.dtype(cfg.dtype)), "batch", "seq", "embed")
    xkv = _cross_kv(params["dec"], cfg, enc_out)

    def body(x, scanned):
        p, (xk, xv) = scanned
        h = norm_apply(p, "norm1", x, cfg.norm, cfg.norm_eps)
        y, cache = attn_mod.attn_apply(p["self_attn"], h, cfg, None, mode, window)
        x = x + y
        h = norm_apply(p, "norm_x", x, cfg.norm, cfg.norm_eps)
        y, _ = attn_mod.attn_apply(p["cross_attn"], h, cfg, None, "train", 0, cross_kv=(xk, xv))
        x = x + y
        h = norm_apply(p, "norm2", x, cfg.norm, cfg.norm_eps)
        return x + mlp_apply(p["ffn"], h, cfg.act), cache

    fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    x, caches = jax.lax.scan(fn, x, (params["dec"], xkv))
    x = norm_apply(params, "dec_final", x, cfg.norm, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["tok_emb"])
    logits = shard(logits, "batch", "seq", "vocab")
    out_caches = {"self": caches, "cross": xkv} if mode == "prefill" else None
    return logits, out_caches, (jnp.zeros((), jnp.float32), None)


def encdec_init_caches(cfg, B: int, S_cache: int, window: int = 0, dtype=jnp.bfloat16):
    c = attn_mod.init_kv_cache(cfg, B, S_cache, window, dtype)
    self_c = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), c)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    xk = jnp.zeros((cfg.n_layers, B, cfg.enc_len, KV, hd), dtype)
    return {"self": self_c, "cross": (xk, xk)}


def encdec_decode_step(params, cfg, tokens, caches, window: int = 0):
    """tokens: (B,1). caches: {'self': stacked KVCache, 'cross': (L,B,T,KV,hd)x2}."""
    pos = caches["self"].pos[0]
    x = params["tok_emb"][tokens] + params["dec_pos"][pos][None, None]
    x = x.astype(jnp.dtype(cfg.dtype))

    def body(x, scanned):
        p, cache, (xk, xv) = scanned
        h = norm_apply(p, "norm1", x, cfg.norm, cfg.norm_eps)
        y, cache = attn_mod.attn_decode(p["self_attn"], h, cfg, cache, window)
        x = x + y
        h = norm_apply(p, "norm_x", x, cfg.norm, cfg.norm_eps)
        y, _ = attn_mod.attn_decode(p["cross_attn"], h, cfg, None, 0, cross_kv=(xk, xv))
        x = x + y
        h = norm_apply(p, "norm2", x, cfg.norm, cfg.norm_eps)
        return x + mlp_apply(p["ffn"], h, cfg.act), cache

    x, new_self = jax.lax.scan(body, x, (params["dec"], caches["self"], caches["cross"]))
    x = norm_apply(params, "dec_final", x, cfg.norm, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["tok_emb"])
    return logits, {"self": new_self, "cross": caches["cross"]}
