"""DeepSeek-V2/V3 Multi-head Latent Attention (MLA).

Projections (per arXiv:2412.19437 §2.1.1):
    c_q  = W_dq x                (q_lora_rank)            -> norm
    q    = W_uq c_q              (H, qk_nope + qk_rope)   rope on the rope part
    c_kv = W_dkv x               (kv_lora_rank)           -> norm, **cached**
    k_r  = W_kr x                (qk_rope_head_dim)       rope, shared across heads, **cached**
    k    = [W_uk c_kv ; k_r]     (H, qk_nope + qk_rope)
    v    = W_uv c_kv             (H, v_head_dim)
    out  = W_o (attn @ v)

The decode cache stores only ``(c_kv, k_r)`` — 576 floats/token for V3 —
which is the technique's serving win.  ``mla_absorb=True`` additionally folds
``W_uk`` into the query and ``W_uv`` into the output projection at decode
time (the paper's "absorption"), so scores/values are computed directly in
the latent space: a beyond-paper perf option exercised in §Perf.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, apply_rope, rmsnorm
from .sharding import shard

__all__ = ["MLACache", "mla_init", "mla_apply", "mla_decode", "init_mla_cache"]


class MLACache(NamedTuple):
    c_kv: jax.Array  # (B, S, kv_lora_rank)
    k_rope: jax.Array  # (B, S, qk_rope_head_dim)
    pos: jax.Array


def mla_init(pb: ParamBuilder, cfg):
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    pb.p("w_dq", (d, qr), ("embed", "lora"), fan_in=d)
    pb.p("q_norm", (qr,), ("lora",), init="ones")
    pb.p("w_uq", (qr, H, dn + dr), ("lora", "q_heads", "head_dim"), fan_in=qr)
    pb.p("w_dkv", (d, kvr), ("embed", "lora"), fan_in=d)
    pb.p("kv_norm", (kvr,), ("lora",), init="ones")
    pb.p("w_kr", (d, dr), ("embed", "head_dim"), fan_in=d)
    pb.p("w_uk", (kvr, H, dn), ("lora", "q_heads", "head_dim"), fan_in=kvr)
    pb.p("w_uv", (kvr, H, dv), ("lora", "q_heads", "head_dim"), fan_in=kvr)
    pb.p("wo", (H, dv, d), ("q_heads", "head_dim", "embed"), fan_in=H * dv)


def _latents(p, x, cfg, positions):
    """Compute (q_nope, q_rope, c_kv, k_rope) with rope applied."""
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    c_q = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", c_q, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    c_kv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_kr"])
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg, mask, absorb: bool):
    """Score+combine. q_*: (B,S,H,*), c_kv: (B,T,r), k_rope: (B,T,dr)."""
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + cfg.qk_rope_head_dim, jnp.float32))
    if absorb:
        # fold W_uk into q: q_lat (B,S,H,r); scores vs latent cache directly
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
    else:
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, p["w_uk"])
        s_nope = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    if absorb:
        o_lat = jnp.einsum("bhst,btr->bshr", w, c_kv)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, p["w_uv"])
    else:
        v = jnp.einsum("btr,rhv->bthv", c_kv, p["w_uv"])
        out = jnp.einsum("bhst,bthv->bshv", w, v)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def _mla_attend_chunked(p, q_nope, q_rope, c_kv, k_rope, cfg, window: int, chunk_q: int = 512, chunk_k: int = 1024):
    """Memory-efficient MLA prefill: running softmax over latent-KV chunks.

    Always uses the absorbed form (scores directly against ``c_kv``), so the
    full (S, T) score matrix and the uncompressed per-head K are never
    materialised — the latent cache is both the memory format *and* the
    compute format.
    """
    B, S, H, dn = q_nope.shape
    T = c_kv.shape[1]
    r = c_kv.shape[-1]
    cq = min(chunk_q, S)
    ck = min(chunk_k, T)
    assert S % cq == 0 and T % ck == 0
    nq, nk = S // cq, T // ck
    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + cfg.qk_rope_head_dim, jnp.float32))
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["w_uk"])  # (B,S,H,r)
    qlc = q_lat.reshape(B, nq, cq, H, r)
    qrc = q_rope.reshape(B, nq, cq, H, -1)
    ckv = c_kv.reshape(B, nk, ck, r)
    krc = k_rope.reshape(B, nk, ck, -1)

    def q_block(qi, ql, qr):
        def kv_step(carry, inp):
            m, l, acc = carry
            kj, cb, krb = inp
            s = (jnp.einsum("bqhr,btr->bhqt", ql, cb) + jnp.einsum("bqhk,btk->bhqt", qr, krb)).astype(
                jnp.float32
            ) * scale
            q_pos = qi * cq + jnp.arange(cq)[:, None]
            k_pos = kj * ck + jnp.arange(ck)[None, :]
            mask = k_pos <= q_pos
            if window > 0:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            pr = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + pr.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhqt,btr->bhqr", pr.astype(cb.dtype), cb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, r), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), jnp.moveaxis(ckv, 1, 0), jnp.moveaxis(krc, 1, 0))
        )
        o_lat = (acc / jnp.where(l == 0, 1.0, l)[..., None]).astype(c_kv.dtype)  # (B,H,cq,r)
        out = jnp.einsum("bhqr,rhv->bqhv", o_lat, p["w_uv"])
        return out  # (B,cq,H,dv)

    outs = jax.lax.map(lambda a: q_block(a[0], a[1], a[2]), (jnp.arange(nq), jnp.moveaxis(qlc, 1, 0), jnp.moveaxis(qrc, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, -1)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def mla_apply(p, x, cfg, positions, mode: str = "train", window: int = 0, impl: str = "einsum"):
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions)
    c_kv = shard(c_kv, "batch", "seq", None)
    if impl == "chunked":
        y = _mla_attend_chunked(p, q_nope, q_rope, c_kv, k_rope, cfg, window)
    else:
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        mask = kj <= qi
        if window > 0:
            mask &= kj > qi - window
        y = _mla_attend(p, q_nope, q_rope, c_kv, k_rope, cfg, mask[None, None], cfg.mla_absorb)
    cache = None
    if mode == "prefill":
        if window > 0:
            keep = min(window, S)
            ck = jnp.zeros((B, window, c_kv.shape[-1]), c_kv.dtype).at[:, :keep].set(c_kv[:, -keep:])
            kr = jnp.zeros((B, window, k_rope.shape[-1]), k_rope.dtype).at[:, :keep].set(k_rope[:, -keep:])
            cache = MLACache(ck, kr, jnp.asarray(S, jnp.int32))
        else:
            cache = MLACache(c_kv, k_rope, jnp.asarray(S, jnp.int32))
    return y, cache


def init_mla_cache(cfg, B: int, S_cache: int, window: int = 0, dtype=jnp.bfloat16) -> MLACache:
    n = min(window, S_cache) if window > 0 else S_cache
    return MLACache(
        jnp.zeros((B, n, cfg.kv_lora_rank), dtype),
        jnp.zeros((B, n, cfg.qk_rope_head_dim), dtype),
        jnp.zeros((), jnp.int32),
    )


def mla_decode(p, x, cfg, cache: MLACache, window: int = 0):
    B = x.shape[0]
    pos = cache.pos
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions)
    n_slots = cache.c_kv.shape[1]
    slot = (pos % n_slots) if window > 0 else pos
    ck = cache.c_kv.at[:, slot].set(c_kv[:, 0].astype(cache.c_kv.dtype))
    kr = cache.k_rope.at[:, slot].set(k_rope[:, 0].astype(cache.k_rope.dtype))
    ck = shard(ck, "batch", "cache_seq", None)
    slots = jnp.arange(n_slots)
    if window > 0:
        valid = (slots[None] <= slot) | (pos >= n_slots)
    else:
        valid = slots[None] <= pos
    mask = jnp.broadcast_to(valid[:, None, None, :], (B, 1, 1, n_slots))
    y = _mla_attend(p, q_nope, q_rope, ck, kr, cfg, mask, cfg.mla_absorb)
    return y, MLACache(ck, kr, pos + 1)
