"""Shared building blocks: parameter factory, norms, MLPs, RoPE / M-RoPE."""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .sharding import shard

__all__ = [
    "ParamBuilder",
    "rmsnorm",
    "layernorm",
    "norm_apply",
    "mlp_init",
    "mlp_apply",
    "rope_freqs",
    "apply_rope",
    "apply_mrope",
    "sinusoidal_positions",
]


class ParamBuilder:
    """Creates parameters and records their logical sharding axes.

    ``pb = ParamBuilder(rng, dtype)`` then
    ``w = pb.p("wq", (d, H, hd), ("embed", "q_heads", "head_dim"), fan_in=d)``.
    ``pb.params`` / ``pb.specs`` hold mirrored pytrees.
    """

    def __init__(self, rng: jax.Array, dtype=jnp.float32, prefix: str = ""):
        self.rng = rng
        self.dtype = dtype
        self.params: Dict = {}
        self.specs: Dict = {}
        self._n = 0

    def _next(self) -> jax.Array:
        self._n += 1
        return jax.random.fold_in(self.rng, self._n)

    def p(self, name, shape, axes, init="normal", fan_in=None, scale=None):
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        elif init == "normal":
            std = scale if scale is not None else 1.0 / math.sqrt(fan_in or shape[0])
            v = jax.random.normal(self._next(), shape, jnp.float32).astype(self.dtype) * std
        elif init == "embed":
            v = jax.random.normal(self._next(), shape, jnp.float32).astype(self.dtype) * (scale or 0.02)
        else:
            raise ValueError(init)
        self.params[name] = v
        self.specs[name] = tuple(axes)
        return v

    def child(self, name) -> "ParamBuilder":
        pb = ParamBuilder(self._next(), self.dtype)
        self.params[name] = pb.params
        self.specs[name] = pb.specs
        return pb


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5, plus_one: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    g = w.astype(jnp.float32)
    if plus_one:
        g = 1.0 + g
    return (y * g).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_init(pb: ParamBuilder, name: str, d: int, kind: str):
    if kind == "rmsnorm":
        pb.p(name, (d,), ("embed",), init="ones")
    else:
        pb.p(name + "_w", (d,), ("embed",), init="ones")
        pb.p(name + "_b", (d,), ("embed",), init="zeros")


def norm_apply(params, name: str, x, kind: str, eps: float, plus_one: bool = False):
    if kind == "rmsnorm":
        return rmsnorm(x, params[name], eps, plus_one)
    return layernorm(x, params[name + "_w"], params[name + "_b"], eps)


# ---------------------------------------------------------------- MLP ------


def mlp_init(pb: ParamBuilder, d: int, d_ff: int, act: str):
    gated = act in ("silu", "geglu")
    if gated:
        pb.p("w_in", (d, 2, d_ff), ("mlp_embed", None, "mlp"), fan_in=d)
    else:
        pb.p("w_in", (d, d_ff), ("mlp_embed", "mlp"), fan_in=d)
    pb.p("w_out", (d_ff, d), ("mlp", "mlp_embed"), fan_in=d_ff)


def mlp_apply(p, x: jax.Array, act: str) -> jax.Array:
    """x: (..., d) -> (..., d).  Gated (SiLU/GeGLU) or plain (GELU/sqReLU)."""
    if act in ("silu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, p["w_in"])
        g, u = h[..., 0, :], h[..., 1, :]
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_in"])
        if act == "gelu":
            h = jax.nn.gelu(h, approximate=True)
        elif act == "sqrelu":
            r = jax.nn.relu(h)
            h = r * r
        else:
            raise ValueError(act)
    h = shard(h, *((None,) * (h.ndim - 1)), "mlp")
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


# ---------------------------------------------------------------- RoPE -----


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float, sections: Tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions3: (3, B, S) — temporal/height/width position
    ids; ``sections`` gives the number of frequency *pairs* taken from each
    component (sum == hd/2).
    """
    hd = x.shape[-1]
    assert sum(sections) * 2 == hd, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # component id per frequency pair: [0]*s0 + [1]*s1 + [2]*s2
    comp = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2)
    # select per-pair component: (B, S, hd/2)
    pos_sel = jnp.moveaxis(positions3.astype(jnp.float32), 0, -1)[..., comp]
    ang = pos_sel * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((n, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
