"""The paper's own FL workloads (§VI-A "Datasets and network structure").

* EMNIST-Letter net: two 5x5 conv layers (10 channels each) + 2x2 max-pool,
  FC 1280 -> 256 -> 26 softmax.
* CIFAR-10 net: two 5x5 conv layers (64 channels each) + 2x2 max-pool,
  FC 384 -> 192 -> 10 softmax.

Implemented with ``lax.conv_general_dilated`` — small enough to vmap across a
cohort of clients on CPU, which is exactly how the FL round executes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import ParamBuilder

__all__ = ["cnn_init", "cnn_forward", "CNN_SHAPES"]

# dataset image shapes (H, W, C) and fc sizes per paper
CNN_SHAPES = {
    "emnist-cnn": dict(img=(28, 28, 1), ch=10, fc1=1280, fc2=256, classes=26),
    "cifar-cnn": dict(img=(32, 32, 3), ch=64, fc1=384, fc2=192, classes=10),
}


def _spec(name):
    return CNN_SHAPES[name]


def cnn_init(rng, cfg):
    s = _spec(cfg.name.replace("-smoke", ""))
    H, W, C = s["img"]
    pb = ParamBuilder(rng, jnp.float32)
    pb.p("conv1", (5, 5, C, s["ch"]), (None, None, None, None), fan_in=5 * 5 * C)
    pb.p("b1", (s["ch"],), (None,), init="zeros")
    pb.p("conv2", (5, 5, s["ch"], s["ch"]), (None, None, None, None), fan_in=5 * 5 * s["ch"])
    pb.p("b2", (s["ch"],), (None,), init="zeros")
    # two 2x2 pools with 'SAME' convs: spatial H/4 * W/4
    flat = (H // 4) * (W // 4) * s["ch"]
    pb.p("fc1", (flat, s["fc1"]), (None, None), fan_in=flat)
    pb.p("fb1", (s["fc1"],), (None,), init="zeros")
    pb.p("fc2", (s["fc1"], s["fc2"]), (None, None), fan_in=s["fc1"])
    pb.p("fb2", (s["fc2"],), (None,), init="zeros")
    pb.p("head", (s["fc2"], s["classes"]), (None, None), fan_in=s["fc2"])
    pb.p("hb", (s["classes"],), (None,), init="zeros")
    return pb.params, pb.specs


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, cfg, batch):
    """batch: {'x': (B,H,W,C), 'y': (B,) int}. Returns logits (B, classes)."""
    x = batch["x"]
    h = jax.lax.conv_general_dilated(x, params["conv1"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = _pool(jax.nn.relu(h + params["b1"]))
    h = jax.lax.conv_general_dilated(h, params["conv2"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = _pool(jax.nn.relu(h + params["b2"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"] + params["fb1"])
    h = jax.nn.relu(h @ params["fc2"] + params["fb2"])
    return h @ params["head"] + params["hb"]
