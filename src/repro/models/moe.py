"""Mixture-of-Experts FFN: top-k routing, capacity-based dispatch/combine
(GShard/Switch pattern), shared experts, and a load-balance auxiliary loss.

Expert weights carry an ``experts`` logical axis (sharded over ``model`` —
expert parallelism); the dispatch/combine einsums then lower to the
all-to-all-style collectives the roofline analysis tracks.  Router compute is
fp32 for numerical stability.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import ParamBuilder
from .sharding import shard

__all__ = ["moe_init", "moe_apply"]


def moe_init(pb: ParamBuilder, cfg):
    d, E, dff = cfg.d_model, cfg.n_experts, cfg.d_expert
    gated = cfg.act in ("silu", "geglu")
    pb.p("router", (d, E), ("embed", "experts"), fan_in=d)
    if gated:
        pb.p("w_in", (E, d, 2, dff), ("experts", "embed", None, "expert_mlp"), fan_in=d)
    else:
        pb.p("w_in", (E, d, dff), ("experts", "embed", "expert_mlp"), fan_in=d)
    pb.p("w_out", (E, dff, d), ("experts", "expert_mlp", "embed"), fan_in=dff)
    if cfg.n_shared_experts:
        ds = cfg.n_shared_experts * dff
        if gated:
            pb.p("w_in_shared", (d, 2, ds), ("embed", None, "mlp"), fan_in=d)
        else:
            pb.p("w_in_shared", (d, ds), ("embed", "mlp"), fan_in=d)
        pb.p("w_out_shared", (ds, d), ("mlp", "embed"), fan_in=ds)


def _expert_ffn(p, x, act):
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    if act in ("silu", "geglu"):
        h = jnp.einsum("ecd,edgf->ecgf", x, p["w_in"])
        g, u = h[..., 0, :], h[..., 1, :]
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = jnp.einsum("ecd,edf->ecf", x, p["w_in"])
        if act == "gelu":
            h = jax.nn.gelu(h, approximate=True)
        else:
            r = jax.nn.relu(h)
            h = r * r
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _shared_ffn(p, x, act):
    if act in ("silu", "geglu"):
        h = jnp.einsum("nd,dgf->ngf", x, p["w_in_shared"])
        g, u = h[..., 0, :], h[..., 1, :]
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = jnp.einsum("nd,df->nf", x, p["w_in_shared"])
        h = jax.nn.gelu(h, approximate=True) if act == "gelu" else jax.nn.relu(h) ** 2
    return jnp.einsum("nf,fd->nd", h, p["w_out_shared"])


def moe_apply(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Dispatches to the einsum (small-scale) or scatter (large-scale) impl."""
    if getattr(cfg, "moe_impl", "einsum") == "scatter":
        return moe_apply_scatter(p, x, cfg)
    return moe_apply_einsum(p, x, cfg)


def moe_apply_einsum(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (y, aux_loss).

    Capacity-based top-k dispatch: every token emits its top-k expert choices;
    tokens beyond an expert's capacity ``C = ceil(N * top_k / E * cf)`` are
    dropped for that expert (their residual passes through — standard
    Switch/GShard semantics).  The (N, E, C) one-hot dispatch tensor limits
    this to small N*E*C — production scale uses ``moe_apply_scatter``.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    N = B * S
    xf = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (N, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)  # renormalise (deepseek-style)

    # load-balance aux loss (Switch eq. 4 generalised to top-k)
    me = probs.mean(0)  # (E,) mean router prob
    one_hot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (N,k,E)
    ce = one_hot_k.sum(1).mean(0) / k  # fraction of tokens per expert
    aux = E * jnp.sum(me * ce)

    capacity = max(1, int(N * k / E * cfg.capacity_factor))
    # position of each (token, choice) within its expert's queue
    flat_choice = one_hot_k.reshape(N * k, E)
    pos_in_expert = (jnp.cumsum(flat_choice, axis=0) - flat_choice).reshape(N, k, E)
    pos = jnp.einsum("nke,nke->nk", pos_in_expert, one_hot_k)  # (N,k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch tensor (N, k, E, C) -> combine weights; built sparsely via one-hots
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1, dtype=x.dtype)[..., :capacity]
    disp = jnp.einsum("nke,nkc->nec", one_hot_k.astype(x.dtype), pos_oh)  # (N,E,C)
    comb = jnp.einsum("nk,nke,nkc->nec", gate_vals.astype(x.dtype), one_hot_k.astype(x.dtype), pos_oh)

    xe = jnp.einsum("nec,nd->ecd", disp, xf)  # (E, C, d)
    xe = shard(xe, "experts", None, "embed")
    ye = _expert_ffn(p, xe, cfg.act)
    ye = shard(ye, "experts", None, "embed")
    y = jnp.einsum("nec,ecd->nd", comb, ye)  # (N, d)

    if cfg.n_shared_experts:
        y = y + _shared_ffn(p, xf, cfg.act)
    return y.reshape(B, S, d), aux.astype(jnp.float32)


def moe_apply_scatter(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Production-scale MoE dispatch via scatter/gather (no (N,E,C) one-hot).

    Each (token, choice) computes its slot = expert*C + position-in-expert
    (cross-device cumsum), tokens are scatter-added into the per-expert
    buffers (this *is* the all-to-all the roofline tracks), batched expert
    FFNs run on the ``experts``-sharded buffer, and results gather back.
    Over-capacity tokens drop (GShard semantics).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    N = B * S
    xf = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    one_hot_k = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    ce = one_hot_k.sum(1).mean(0) / k
    aux = E * jnp.sum(me * ce)

    flat_e = expert_idx.reshape(-1)  # (N*k,)
    flat_g = gate_vals.reshape(-1)
    # position-in-expert via a stable sort (O(N log N)) — a (N*k, E) one-hot
    # cumsum lowers to a quadratic reduce-window, which is catastrophic at
    # production N (confirmed by cost_analysis; see EXPERIMENTS.md §Perf).
    order = jnp.argsort(flat_e, stable=True)  # (N*k,)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)  # bincount
    starts = jnp.cumsum(counts) - counts  # (E,) tiny cumsum
    pos_sorted = jnp.arange(flat_e.shape[0], dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)
    C = max(1, int(N * k / E * cfg.capacity_factor))
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)  # E*C = trash slot

    tok = jnp.arange(N * k) // k
    src = jnp.take(xf, tok, axis=0) * keep[:, None].astype(xf.dtype)  # (N*k, d)
    xe = jnp.zeros((E * C + 1, d), x.dtype).at[slot].add(src)
    xe = xe[: E * C].reshape(E, C, d)
    xe = shard(xe, "experts", None, "embed")
    ye = _expert_ffn(p, xe, cfg.act)
    ye = shard(ye, "experts", None, "embed")
    ye_flat = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], 0)
    back = jnp.take(ye_flat, slot, axis=0) * flat_g[:, None].astype(ye.dtype)  # (N*k, d)
    y = back.reshape(N, k, d).sum(1)

    if cfg.n_shared_experts:
        y = y + _shared_ffn(p, xf, cfg.act)
    return y.reshape(B, S, d), aux.astype(jnp.float32)
