from .api import Model, build_model, input_specs, cross_entropy
from . import sharding, layers, attention, mla, moe, ssm, transformer, encdec, cnn  # noqa: F401
