"""Logical-axis sharding rules (MaxText-style) for the model stack.

Model code annotates tensors with *logical* axis names via ``shard(x, ...)``;
a rules table (installed with ``use_rules``) maps logical names to mesh axes.
Outside a mesh/rules context the annotations are no-ops, so the same model
code runs on a laptop CPU and on the 512-chip dry-run mesh.

Two base rule-sets implement DESIGN.md §3:

* ``cohort_rules`` — tensor-parallel over ``model``; the client axis of the
  vmapped cohort is injected by ``vmap(..., spmd_axis_name=...)``; per-client
  params otherwise replicated over ``data``.
* ``silo_rules``   — FSDP over (``pod``,``data``) + tensor-parallel over
  ``model``: batch and the ``embed`` dimension of every weight shard over the
  fsdp axes, head/mlp/vocab/expert dimensions over ``model``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["use_rules", "shard", "logical_to_spec", "cohort_rules", "silo_rules", "current_rules"]

_state = threading.local()


def current_rules() -> Optional[Dict[str, object]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Dict[str, object]]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_to_spec(axes: Sequence[Optional[str]], rules: Optional[Dict[str, object]] = None) -> P:
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P(*([None] * len(axes)))
    out = []
    used = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        # a mesh axis may appear at most once in a spec; later duplicates
        # fall back to replication (can happen for e.g. (experts, mlp) both
        # mapped to 'model').
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(x for x in ms if x not in used)
        used.update(ms)
        out.append(ms[0] if len(ms) == 1 else (ms if ms else None))
        if not ms:
            out[-1] = None
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    spec = logical_to_spec(axes, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh in scope


def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def cohort_rules(cfg, mesh_axis_sizes: Dict[str, int]) -> Dict[str, object]:
    """Tensor-parallel rules; client axis handled by vmap(spmd_axis_name)."""
    m = mesh_axis_sizes.get("model", 1)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_axis_sizes)
    return {
        "batch": fsdp,  # serving batch; during cohort training batch is per-client (unsharded)
        "client": fsdp,
        "seq": None,
        "cache_seq": None,
        "embed": None,
        "mlp_embed": None,  # d-dim of MLP weights (default: follows "embed")
        "act_embed": None,  # embed dim of *activations* (hillclimb: -> model)
        "q_heads": "model" if _divisible(max(cfg.n_heads, 1), m) else None,
        "kv_heads": "model" if _divisible(max(cfg.n_kv_heads, 1), m) else None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model" if _divisible(cfg.vocab, m) else None,
        "experts": "model" if cfg.n_experts and _divisible(cfg.n_experts, m) else None,
        "expert_mlp": None,
        "lora": None,
        "ssm_inner": "model" if (cfg.ssm_expand * cfg.d_model) % (m * max(cfg.ssm_headdim, 1)) == 0 else None,
        "ssm_state": None,
        "layers": None,
        "patch": None,
        "enc_seq": None,
    }


def silo_rules(cfg, mesh_axis_sizes: Dict[str, int]) -> Dict[str, object]:
    """FSDP + TP rules for huge archs (one client occupies the whole mesh)."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_axis_sizes)
    fsize = 1
    for a in fsdp:
        fsize *= mesh_axis_sizes[a]
    r = cohort_rules(cfg, mesh_axis_sizes)
    r.update(
        {
            "batch": fsdp,
            "embed": fsdp if _divisible(cfg.d_model, fsize) else None,
            "mlp_embed": fsdp if _divisible(cfg.d_model, fsize) else None,
            "expert_mlp": None,
        }
    )
    return r
