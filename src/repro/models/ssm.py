"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) layer.

Layout per layer (d_in = expand*d_model, H = d_in/headdim heads, P = headdim,
G = ngroups, N = ssm_state):

    in_proj:  d -> [z(d_in) | x(d_in) | B(G*N) | C(G*N) | dt(H)]
    conv1d:   depthwise causal width-4 over the (x|B|C) channels
    SSD:      y_t = C_t^T h_t ;  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T
    gate:     y = RMSNorm(y * silu(z)) ; out_proj: d_in -> d

Training/prefill uses the *chunked* SSD algorithm (quadratic within chunks of
length Q, linear across chunks via a carried (H,N,P) state).  Decode is the
O(1) recurrence with a conv ring state, which is what makes `long_500k`
serving tractable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import ParamBuilder, rmsnorm

__all__ = ["SSMCache", "ssm_init", "ssm_apply", "ssm_decode", "init_ssm_cache", "ssd_chunked"]


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, W-1, conv_channels) trailing inputs
    state: jax.Array  # (B, H, N, P) ssm state
    pos: jax.Array


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    H = d_in // P
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    return d_in, H, P, G, N


def ssm_init(pb: ParamBuilder, cfg):
    d = cfg.d_model
    d_in, H, P, G, N = _dims(cfg)
    conv_ch = d_in + 2 * G * N
    pb.p("in_proj", (d, 2 * d_in + 2 * G * N + H), ("embed", "ssm_inner"), fan_in=d)
    pb.p("conv_w", (cfg.ssm_conv_width, conv_ch), (None, "ssm_inner"), fan_in=cfg.ssm_conv_width)
    pb.p("conv_b", (conv_ch,), ("ssm_inner",), init="zeros")
    pb.p("A_log", (H,), ("ssm_inner",), init="zeros")  # A = -exp(A_log) = -1 at init
    pb.p("D", (H,), ("ssm_inner",), init="ones")
    pb.p("dt_bias", (H,), ("ssm_inner",), init="zeros")
    pb.p("gate_norm", (d_in,), ("ssm_inner",), init="ones")
    pb.p("out_proj", (d_in, d), ("ssm_inner", "embed"), fan_in=d_in)


def _split_proj(cfg, h):
    d_in, H, P, G, N = _dims(cfg)
    z = h[..., :d_in]
    xbc = h[..., d_in : 2 * d_in + 2 * G * N]
    dt = h[..., 2 * d_in + 2 * G * N :]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None, return_final=False):
    """Chunked SSD scan (pure jnp oracle; kernel mirrors this).

    Args:
      x:  (b, S, H, P) inputs (after conv/activation)
      dt: (b, S, H) positive step sizes
      A:  (H,) negative decay rates
      B:  (b, S, G, N); C: (b, S, G, N)
      chunk: chunk length Q (S % Q == 0)
    Returns y (b,S,H,P) [, final_state (b,H,N,P)].
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, S)
    if S % Q:  # pad to a chunk multiple; dt=0 makes padding inert
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = x.shape[1]
    nc = S_pad // Q
    rep = H // G

    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, G, N)
    Cc = C.reshape(b, nc, Q, G, N)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]  # (b,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay
    total = cum[:, :, -1]  # (b,nc,H)

    # ---- intra-chunk (quadratic within Q) ----
    # L[i,j] = exp(cum[i] - cum[j]) for j <= i else 0
    Li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(Li), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)  # (b,nc,Q,Q,H)
    att = scores * L * dtc[:, :, None, :, :]  # weight by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # ---- chunk states ----
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # (b,nc,Q,H)
    S_chunk = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", dtc * decay_to_end, Bh, xc)

    # ---- inter-chunk recurrence ----
    def step(carry, inp):
        s_prev = carry  # (b,H,N,P)
        s_c, tot_c = inp
        s_new = s_prev * jnp.exp(tot_c)[:, :, None, None] + s_c
        return s_new, s_prev

    init = initial_state if initial_state is not None else jnp.zeros((b, H, N, P), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(total, 1, 0))
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,H,N,P) state entering each chunk

    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, S_pad, H, P)[:, :S]
    if return_final:
        return y, final
    return y


def ssm_apply(p, x, cfg, mode: str = "train", impl: str = "einsum"):
    """x: (B,S,d) -> (B,S,d) [, cache]."""
    d_in, H, P, G, N = _dims(cfg)
    h = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(cfg, h)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in : d_in + G * N].reshape(*x.shape[:2], G, N)
    Cm = xbc[..., d_in + G * N :].reshape(*x.shape[:2], G, N)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    xh = xs.reshape(*x.shape[:2], H, P)
    y, final = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, return_final=True)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(*x.shape[:2], d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if mode == "prefill":
        W = cfg.ssm_conv_width
        # store raw pre-conv trailing inputs
        raw = jnp.einsum("bsd,de->bse", x, p["in_proj"])[..., d_in : 2 * d_in + 2 * G * N]
        conv_state = raw[:, -(W - 1) :, :]
        pad = W - 1 - conv_state.shape[1]
        if pad > 0:
            conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
        return out, SSMCache(conv_state, final, jnp.asarray(x.shape[1], jnp.int32))
    return out, None


def init_ssm_cache(cfg, B: int, dtype=jnp.bfloat16) -> SSMCache:
    d_in, H, P, G, N = _dims(cfg)
    conv_ch = d_in + 2 * G * N
    return SSMCache(
        jnp.zeros((B, cfg.ssm_conv_width - 1, conv_ch), dtype),
        jnp.zeros((B, H, N, P), dtype),
        jnp.zeros((), jnp.int32),
    )


def ssm_decode(p, x, cfg, cache: SSMCache):
    """One-token recurrent step. x: (B,1,d)."""
    d_in, H, P, G, N = _dims(cfg)
    h = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]  # (B, e)
    z = h[..., :d_in]
    xbc_new = h[..., d_in : 2 * d_in + 2 * G * N]
    dt = h[..., 2 * d_in + 2 * G * N :]
    # conv over ring of last W inputs
    inputs = jnp.concatenate([cache.conv, xbc_new[:, None, :]], axis=1)  # (B,W,C)
    conv = jnp.einsum("bwc,wc->bc", inputs, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv)
    xs = xbc[..., :d_in].reshape(-1, H, P)
    Bm = xbc[..., d_in : d_in + G * N].reshape(-1, G, N)
    Cm = xbc[..., d_in + G * N :].reshape(-1, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32)).astype(x.dtype)
    decay = jnp.exp(dt * A)[:, :, None, None]  # (B,H,1,1)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, Bh, xs)
    state = cache.state * decay + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + p["D"][None, :, None] * xs
    y = y.reshape(x.shape[0], d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, SSMCache(inputs[:, 1:], state, cache.pos + 1)
