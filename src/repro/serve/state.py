"""Elastic-restart persistence for the serving front end.

A server checkpoint is two files with one stem (``ckpt_<step>``):

* ``ckpt_<step>.json`` — the **meta sidecar**: engine kind, static config,
  the live job table (uid → slot/round/spec), and the sha256 + byte size of
  the array payload.  Human-readable, and the structural recipe:
  ``load_server`` rebuilds an identically-shaped engine from it *before*
  touching the array file (``repro.checkpoint.restore`` needs a
  structurally matching ``like`` tree).
* ``ckpt_<step>.ckpt`` — the evolving arrays (selector weights, round
  counters, PRNG keys, staleness/late-credit rings) through the repo's
  codec-tagged msgpack+zstd checkpoint format.

Crash safety is layered:

* **write order** — the array payload lands first (itself fsync'd +
  atomically renamed), the sidecar last (fsync'd + atomically renamed), so
  a stem without its sidecar is never considered restorable and a torn
  write never produces a sidecar pointing at missing bytes.
* **integrity** — the sidecar records ``ckpt_sha256``; ``validate_stem``
  recomputes it, so silent payload corruption (truncation, bit rot, a
  fault-injected flip) is detected rather than restored.
* **walk-back** — ``latest_server_checkpoint`` scans stems newest-first and
  returns the newest stem that *validates*, skipping corrupt or truncated
  ones; the supervisor in ``repro.serve.transport`` restarts from whatever
  it returns.
* **retention** — ``save_server(keep=N)`` prunes to the newest N stems, so
  a long-running server keeps a bounded window of restore points instead of
  an unbounded directory.

Restoring reproduces the engine **bit-identically**: every array the step
function reads is in the payload and every job's PRNG stream derives from
its own seed and round counter, so a restored server's subsequent cohorts
match an uninterrupted run exactly (pinned by ``tests/test_serve.py``).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Optional, Tuple

from repro import checkpoint as ckpt

from .engines import engine_from_meta

__all__ = [
    "save_server",
    "load_server",
    "latest_server_checkpoint",
    "validate_stem",
]

_PREFIX = "ckpt_"


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _fsync_dir(directory: str) -> None:
    """Durably record renames in the directory entry (best-effort: not all
    platforms allow opening a directory)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save_server(directory: str, engine, step: int, *, keep: int = 0, faults=None) -> str:
    """Write ``ckpt_<step>.{json,ckpt}`` crash-safely (payload first and
    fsync'd, sha256-carrying sidecar last) and prune to the newest ``keep``
    stems (0 = keep all).  ``faults`` is the chaos hook
    (:class:`repro.serve.faults.FaultPlan`): scheduled writes are corrupted
    *after* landing, so the restore walk-back has something to skip.
    Returns the stem path."""
    os.makedirs(directory, exist_ok=True)
    stem = os.path.join(directory, f"{_PREFIX}{step:08d}")
    ckpt.save(stem + ".ckpt", engine.arrays(), step=step)
    meta = {
        "step": step,
        "engine": engine.meta(),
        "ckpt_sha256": _sha256_file(stem + ".ckpt"),
        "ckpt_bytes": os.path.getsize(stem + ".ckpt"),
    }
    tmp = stem + ".json.tmp"
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, stem + ".json")
    _fsync_dir(directory)
    if faults is not None:
        faults.on_checkpoint(stem)
    if keep:
        for old in _stems(directory)[:-keep]:
            if old == stem:
                continue
            for suffix in (".json", ".ckpt"):
                try:
                    os.remove(old + suffix)
                except FileNotFoundError:
                    pass
    return stem


def _stems(directory: str) -> list:
    return sorted(
        os.path.join(directory, name[: -len(".json")])
        for name in os.listdir(directory)
        if name.startswith(_PREFIX) and name.endswith(".json")
    )


def validate_stem(stem: str) -> bool:
    """True iff the stem is restorable: sidecar parses, payload exists, and
    the payload's sha256 matches the sidecar's record (legacy sidecars
    without a digest validate on presence alone)."""
    try:
        with open(stem + ".json") as f:
            meta = json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    if "engine" not in meta or not os.path.exists(stem + ".ckpt"):
        return False
    want = meta.get("ckpt_sha256")
    if want is None:
        return True
    size = meta.get("ckpt_bytes")
    if size is not None and os.path.getsize(stem + ".ckpt") != size:
        return False
    return _sha256_file(stem + ".ckpt") == want


def latest_server_checkpoint(directory: str) -> Optional[str]:
    """Newest stem that validates (see :func:`validate_stem`), walking back
    past corrupt or truncated stems; None when nothing restorable exists."""
    if not os.path.isdir(directory):
        return None
    for stem in reversed(_stems(directory)):
        if validate_stem(stem):
            return stem
    return None


def load_server(stem: str) -> Tuple[object, int]:
    """Rebuild ``(engine, step)`` from a checkpoint stem: meta sidecar →
    engine shell (``engine_from_meta``) → array restore with the shell's own
    fresh arrays as the ``like`` tree."""
    with open(stem + ".json") as f:
        meta = json.load(f)
    engine = engine_from_meta(meta["engine"])
    arrays = ckpt.restore(stem + ".ckpt", like=engine.arrays())
    engine.load_arrays(arrays)
    return engine, int(meta["step"])
