"""Elastic-restart persistence for the serving front end.

A server checkpoint is two files with one stem (``ckpt_<step>``):

* ``ckpt_<step>.json`` — the **meta sidecar**: engine kind, static config,
  and the live job table (uid → slot/round/spec).  Human-readable, and the
  structural recipe: ``load_server`` rebuilds an identically-shaped engine
  from it *before* touching the array file (``repro.checkpoint.restore``
  needs a structurally matching ``like`` tree).
* ``ckpt_<step>.ckpt`` — the evolving arrays (selector weights, round
  counters, PRNG keys, staleness/late-credit rings) through the repo's
  codec-tagged msgpack+zstd checkpoint format.

Restoring reproduces the engine **bit-identically**: every array the step
function reads is in the payload and every job's PRNG stream derives from
its own seed and round counter, so a restored server's subsequent cohorts
match an uninterrupted run exactly (pinned by ``tests/test_serve.py``).
"""
from __future__ import annotations

import json
import os
from typing import Optional, Tuple

from repro import checkpoint as ckpt

from .engines import engine_from_meta

__all__ = ["save_server", "load_server", "latest_server_checkpoint"]

_PREFIX = "ckpt_"


def save_server(directory: str, engine, step: int) -> str:
    """Write ``ckpt_<step>.{json,ckpt}`` atomically-ish (meta last, so a
    stem without its sidecar is never considered restorable).  Returns the
    stem path."""
    os.makedirs(directory, exist_ok=True)
    stem = os.path.join(directory, f"{_PREFIX}{step:08d}")
    ckpt.save(stem + ".ckpt", engine.arrays(), step=step)
    tmp = stem + ".json.tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, "engine": engine.meta()}, f, indent=1, sort_keys=True)
    os.replace(tmp, stem + ".json")
    return stem


def latest_server_checkpoint(directory: str) -> Optional[str]:
    """Newest stem with BOTH files present, or None."""
    if not os.path.isdir(directory):
        return None
    stems = sorted(
        os.path.join(directory, name[: -len(".json")])
        for name in os.listdir(directory)
        if name.startswith(_PREFIX) and name.endswith(".json")
    )
    for stem in reversed(stems):
        if os.path.exists(stem + ".ckpt"):
            return stem
    return None


def load_server(stem: str) -> Tuple[object, int]:
    """Rebuild ``(engine, step)`` from a checkpoint stem: meta sidecar →
    engine shell (``engine_from_meta``) → array restore with the shell's own
    fresh arrays as the ``like`` tree."""
    with open(stem + ".json") as f:
        meta = json.load(f)
    engine = engine_from_meta(meta["engine"])
    arrays = ckpt.restore(stem + ".ckpt", like=engine.arrays())
    engine.load_arrays(arrays)
    return engine, int(meta["step"])
