"""The serving front end: socket transport + streaming batcher + restart.

``SelectionServer`` puts a request/response loop in front of a serving
engine (``repro.serve.engines``).  The moving parts:

* **connection handlers** — one thread per accepted connection, each running
  a strict request → response loop over the length-prefixed frames of
  ``repro.serve.protocol``.  Handlers never touch the engine: they parse,
  enqueue, and wait.
* **the streaming batcher** — ONE engine thread owns the engine.  It drains
  the admission queue, coalescing consecutive ``tick`` requests from
  *different* jobs into a single batched dispatch (the vmapped slot engine
  turns J waiting tenants into one device program).  A duplicate job, a
  control op (admit/retire/checkpoint), or an empty queue closes the batch.
  Per-job ordering is preserved; co-tenancy never changes any job's
  results (engine PRNG streams are per-job, pinned by ``tests/test_serve.py``).
* **the supervisor** — the engine thread runs under a restart loop.  A
  crashed engine step (a fault-injected :class:`~repro.serve.faults.EngineCrash`
  or any unexpected exception) fails the in-flight requests with
  ``error: "retry"``, then the supervisor restores the engine from the
  newest *valid* checkpoint (``latest_server_checkpoint`` walks back past
  corrupt stems), with exponential backoff on repeated restarts and a
  ``max_restarts`` budget — past it the server answers ``engine_down``.
  Each restart sets the ``degraded`` stat flag (cleared by the next clean
  dispatch), appends an ``engine_restart`` alert, and lands in the
  ``restarts`` / ``recovery_s`` gauges of the ``serve`` tap group.
* **idempotent ticks** — a ``tick`` may carry the client's ``round``.  The
  server keeps a small per-job last-response cache: a replayed round
  returns the cached cohort instead of double-applying feedback (the
  property that makes client retries safe), and a request whose round
  disagrees with the engine's cursor fails with ``round_desync`` carrying
  the ``expected`` round so the client can rewind and replay.
* **backpressure** — the queue is bounded (``max_queue``); when it is full
  new requests are **shed** immediately with ``error: "shed"`` rather than
  queued into unbounded latency.  Shed counts are reported per tick through
  the ``serve`` tap group.
* **timeouts** — every queued request carries a deadline
  (``request_timeout`` seconds); if the engine thread dequeues it too late
  the request fails with ``error: "timeout"`` instead of being executed —
  the engine never spends device time on an answer nobody is waiting for.
* **elastic restart** — with ``ckpt_dir`` set, the engine thread snapshots
  the full engine state (``repro.serve.state.save_server``) every
  ``ckpt_every`` served rounds and on graceful shutdown, pruning to the
  newest ``ckpt_keep`` stems.  A new server started from ``load_server``
  continues bit-identically.
* **graceful drain** — ``close()`` (or a ``shutdown`` request) stops
  admissions, answers everything already queued, checkpoints, then exits.
  A join that times out is surfaced (``hung_engine`` stat + log line), not
  silently leaked.  ``kill()`` is the crash path for restart tests: drops
  everything on the floor, no final checkpoint.
* **chaos** — ``faults=FaultPlan(...)`` injects the seeded fault schedule
  (engine crashes, checkpoint corruption, dropped responses, slow
  dispatches) of ``repro.serve.faults``; None (the default) leaves every
  hook a no-op.

Per-dispatch telemetry (queue depth, batch width, sheds, restarts and
recovery latency — the ``serve`` group of ``ROUND_TAPS``) and a
dispatch-latency ``LatencyHistogram`` accumulate on the server;
``attach_report`` hands them to a ``Reporter`` so server runs land in bench
JSON / run logs like any engine run.
"""
from __future__ import annotations

import logging
import queue
import socket
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import ROUND_TAPS, LatencyHistogram
from repro.obs.alerts import Alert, log_alerts

from . import protocol
from .engines import CapacityError, JobSpec, NumericsError
from .state import latest_server_checkpoint, load_server, save_server

__all__ = ["SelectionServer", "SERVE_WINDOW"]

SERVE_WINDOW = 16  # ticks per telemetry window when attaching to a Reporter

log = logging.getLogger("repro.serve")


class _Item:
    """One queued request: parsed op + the handler's rendezvous."""

    __slots__ = ("req", "deadline", "event", "response")

    def __init__(self, req: dict, deadline: float):
        self.req = req
        self.deadline = deadline
        self.event = threading.Event()
        self.response: Optional[dict] = None

    def respond(self, resp: dict) -> None:
        self.response = resp
        self.event.set()


def _err(code: str, message: str, **extra) -> dict:
    return {"ok": False, "error": code, "message": message, **extra}


class SelectionServer:
    """Serve one engine over a loopback/LAN socket (see module docstring).

    ``port=0`` binds an ephemeral port — read it back from ``address`` after
    ``start()``.  The server is also a context manager (``with`` = start /
    graceful close).
    """

    def __init__(
        self,
        engine,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        max_queue: int = 64,
        max_batch: int = 0,
        request_timeout: float = 30.0,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 0,
        ckpt_keep: int = 0,
        faults=None,
        max_restarts: int = 8,
        restart_backoff: float = 0.05,
        stop_timeout: float = 60.0,
    ):
        self.engine = engine
        self._host, self._port = host, int(port)
        self.max_queue = int(max_queue)
        self.max_batch = int(max_batch)  # 0 = no cap beyond queue coalescing
        self.request_timeout = float(request_timeout)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self.ckpt_keep = int(ckpt_keep)
        self.faults = faults
        if faults is not None:
            engine.faults = faults
        self.max_restarts = int(max_restarts)
        self.restart_backoff = float(restart_backoff)
        self.stop_timeout = float(stop_timeout)
        self._queue: "queue.Queue[_Item]" = queue.Queue(maxsize=self.max_queue)
        self._draining = threading.Event()
        self._stopped = threading.Event()
        self._engine_dead = threading.Event()  # restart budget exhausted
        self._lock = threading.Lock()  # connection set + stats
        self._conns: set = set()
        self._threads: List[threading.Thread] = []
        self._listener: Optional[socket.socket] = None
        self.stats: Dict[str, int] = {
            "admitted": 0, "retired": 0, "ticks": 0, "dispatches": 0,
            "shed": 0, "timeouts": 0, "errors": 0, "checkpoints": 0,
            "restarts": 0, "degraded": 0, "hung_engine": 0,
            "numerics": 0, "replayed": 0,
        }
        self._shed_window = 0  # sheds since the last dispatch row
        self._restart_window = 0  # restarts since the last dispatch row
        self._recovery_window = 0.0  # recovery seconds since the last dispatch row
        self._rounds_since_ckpt = 0
        self.rounds_served = 0
        self.serve_rows: List[Dict[str, float]] = []
        self.latency = LatencyHistogram(lo=1e-5, hi=60.0)
        self.recoveries: List[float] = []  # crash-to-restored latencies (s)
        self.alerts: List[Alert] = []  # engine_restart / numerics events
        self._tick_cache: Dict[int, Tuple[int, dict]] = {}  # uid -> (round, response)
        self.last_checkpoint: Optional[str] = None
        self._final_checkpoint = True  # kill() / close(checkpoint=False) clear it

    # -- lifecycle ---------------------------------------------------------

    @property
    def address(self) -> Tuple[str, int]:
        return self._host, self._port

    def start(self) -> "SelectionServer":
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lst.bind((self._host, self._port))
        self._port = lst.getsockname()[1]
        lst.listen(32)
        lst.settimeout(0.2)
        self._listener = lst
        for target, name in ((self._accept_loop, "serve-accept"), (self._engine_loop, "serve-engine")):
            t = threading.Thread(target=target, name=name, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def close(self, checkpoint: bool = True) -> None:
        """Graceful drain: stop admitting, answer the queue, optionally
        write a final checkpoint, then tear the sockets down.  A thread that
        outlives ``stop_timeout`` is surfaced — ``hung_engine`` stat + log
        line — instead of being silently leaked."""
        if self._stopped.is_set():
            return
        self._final_checkpoint = bool(checkpoint)
        self._draining.set()
        self._post_stop()
        for t in self._threads:
            t.join(timeout=self.stop_timeout)
        hung = [t.name for t in self._threads if t.is_alive()]
        if hung:
            with self._lock:
                self.stats["hung_engine"] = 1
            log.error(
                "close(): %s did not stop within %.1fs — thread leaked, "
                "final checkpoint may be missing", ", ".join(hung), self.stop_timeout,
            )
        self._teardown()

    def kill(self) -> None:
        """Crash path (for restart tests): no drain, no final checkpoint —
        queued requests and un-checkpointed state are lost, exactly like a
        process kill."""
        self._final_checkpoint = False
        self._draining.set()
        self._stopped.set()
        self._post_stop()
        self._teardown()

    def _post_stop(self) -> None:
        """Deliver the engine-thread stop sentinel without deadlocking on a
        full queue (the engine drains it; if the thread is already gone the
        sentinel is moot)."""
        try:
            self._queue.put(_Item({"op": "_stop"}, float("inf")), timeout=5.0)
        except queue.Full:
            pass

    def _teardown(self) -> None:
        self._stopped.set()
        if self._listener is not None:
            self._listener.close()
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()

    def __enter__(self) -> "SelectionServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- socket side -------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._draining.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.add(conn)
            t = threading.Thread(target=self._handle, args=(conn,), daemon=True)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        """One connection's request → response loop; parse errors poison the
        stream (respond once, then hang up).  The chaos hook may cut the
        connection instead of sending a response — the request already
        executed, exactly like a network failure between server and client
        (the idempotent tick cache is what makes the client's retry safe)."""
        try:
            while not self._stopped.is_set():
                try:
                    req = protocol.recv_message(conn)
                except protocol.ConnectionClosed:
                    break
                except protocol.ProtocolError as e:
                    protocol.send_message(conn, _err("bad_request", str(e)))
                    break
                resp = self._submit(req)
                if self.faults is not None and self.faults.on_response():
                    break  # fault-injected connection drop: response lost
                protocol.send_message(conn, resp)
                if req.get("op") == "shutdown":
                    break
        except OSError:
            pass
        finally:
            with self._lock:
                self._conns.discard(conn)
            conn.close()

    def _submit(self, req: dict) -> dict:
        """Admission control: queue the request for the engine thread and
        wait for its response (shed instead of queueing when full)."""
        if self._engine_dead.is_set():
            return _err("engine_down", "engine restart budget exhausted; server needs operator attention")
        if self._draining.is_set():
            return _err("draining", "server is draining; no new requests")
        item = _Item(req, time.monotonic() + self.request_timeout)
        try:
            self._queue.put_nowait(item)
        except queue.Full:
            with self._lock:
                self.stats["shed"] += 1
                self._shed_window += 1
            return _err("shed", f"admission queue at capacity ({self.max_queue})")
        # The engine thread guarantees a response for every queued item; the
        # extra margin only matters if it died mid-request.
        if not item.event.wait(self.request_timeout * 2 + 60.0):
            return _err("internal", "engine thread unresponsive")
        return item.response

    # -- engine side -------------------------------------------------------

    def _engine_loop(self) -> None:
        """The supervisor: run the batcher; on a crashed engine step restore
        from the newest valid checkpoint and keep serving.  In-flight
        requests were already failed with ``retry`` by ``_dispatch``; past
        the restart budget the queue is failed with ``engine_down`` and the
        server stays up only to answer that."""
        while True:
            try:
                self._engine_run()
                return
            except Exception as e:
                if self._stopped.is_set():
                    return
                if not self._recover(e):
                    self._engine_dead.set()
                    self._fail_pending("engine_down", "engine restart budget exhausted")
                    return
                if self._draining.is_set():
                    # the stop sentinel may already be consumed — finish the
                    # drain the crashed loop was (or would be) running
                    try:
                        self._drain_queue()
                        if self._final_checkpoint and self.ckpt_dir:
                            self._checkpoint()
                        return
                    except Exception as e2:  # crashed again mid-drain
                        if not self._recover(e2):
                            self._engine_dead.set()
                            self._fail_pending("engine_down", "engine restart budget exhausted")
                            return

    def _recover(self, exc: BaseException) -> bool:
        """One supervised restart: backoff, restore the engine from the
        newest *valid* checkpoint (walk-back skips corrupt stems), roll the
        served-round cursor back to the restore point and invalidate the
        tick cache.  Without a restorable checkpoint the in-memory engine
        carries on (the crash happened before any state mutated).  Returns
        False when the restart budget is exhausted."""
        t0 = time.monotonic()
        with self._lock:
            self.stats["restarts"] += 1
            self.stats["degraded"] = 1
            n = self.stats["restarts"]
        if n > self.max_restarts:
            log.error("engine crashed (%s) and the restart budget (%d) is exhausted", exc, self.max_restarts)
            return False
        time.sleep(min(1.0, self.restart_backoff * (2 ** (n - 1))))
        stem = latest_server_checkpoint(self.ckpt_dir) if self.ckpt_dir else None
        restored_step = None
        if stem is not None:
            engine, step = load_server(stem)
            if self.faults is not None:
                engine.faults = self.faults
            self.engine = engine
            self.rounds_served = restored_step = step
            self._rounds_since_ckpt = 0
        self._tick_cache.clear()
        dt = time.monotonic() - t0
        self.recoveries.append(dt)
        with self._lock:
            self._restart_window += 1
            self._recovery_window += dt
        self.alerts.append(Alert(
            "engine_restart", "critical",
            {"restart": n, "recovery_s": dt, "restored_step": restored_step,
             "checkpoint": stem, "error": repr(exc)},
            f"engine crashed ({exc}); restart {n}/{self.max_restarts} "
            + (f"restored step {restored_step} from {stem}" if stem else "continuing in-memory"),
        ))
        log.warning("engine restart %d/%d after %r: %s in %.3fs", n, self.max_restarts, exc,
                    f"restored step {restored_step}" if stem else "no valid checkpoint", dt)
        return True

    def _fail_pending(self, code: str, message: str) -> None:
        """Answer everything queued with an error (the engine is gone)."""
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                return
            if item.req.get("op") != "_stop":
                item.respond(_err(code, message))

    def _engine_run(self) -> None:
        while True:
            try:
                item = self._queue.get()
            except Exception:
                break
            batch: List[_Item] = []
            uids = set()
            stop = False
            while True:
                op = item.req.get("op")
                if op == "_stop":
                    stop = True
                    break
                if op == "tick":
                    uid = item.req.get("job")
                    if uid in uids:  # same job twice: preserve per-job order
                        self._dispatch(batch)
                        batch, uids = [], set()
                    batch.append(item)
                    uids.add(uid)
                    if self.max_batch and len(batch) >= self.max_batch:
                        self._dispatch(batch)
                        batch, uids = [], set()
                else:
                    try:
                        self._dispatch(batch)  # control ops serialize with ticks
                    except Exception:
                        # the crash must not strand the waiting control item
                        item.respond(_err("retry", "engine crashed before this request; retry"))
                        raise
                    batch, uids = [], set()
                    item.respond(self._control(item.req))
                    if op == "shutdown":  # remote shutdown == graceful drain
                        stop = True
                        break
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
            self._dispatch(batch)
            if stop:
                self._drain_queue()
                if self._final_checkpoint and self.ckpt_dir:
                    self._checkpoint()
                return

    def _drain_queue(self) -> None:
        """Answer everything still queued at shutdown (graceful drain)."""
        batch: List[_Item] = []
        uids = set()
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            op = item.req.get("op")
            if op == "_stop":
                continue
            if op == "tick":
                if item.req.get("job") in uids:
                    self._dispatch(batch)
                    batch, uids = [], set()
                batch.append(item)
                uids.add(item.req.get("job"))
            else:
                try:
                    self._dispatch(batch)
                except Exception:
                    item.respond(_err("retry", "engine crashed before this request; retry"))
                    raise
                batch, uids = [], set()
                item.respond(self._control(item.req))
        self._dispatch(batch)

    def _dispatch(self, batch: List[_Item]) -> None:
        """One batched engine tick for the coalesced requests.

        Requests carrying a ``round`` go through the idempotency check
        first: a replay of the engine's last-served round for that job is
        answered from the per-job response cache (feedback is NOT
        re-applied); any other disagreement with the engine's cursor fails
        with ``round_desync`` + the ``expected`` round.  An engine crash
        fails the in-flight requests with ``retry`` and re-raises to the
        supervisor; a refused non-finite update fails them with
        ``numerics`` and raises an alert, engine state untouched.
        """
        if not batch:
            return
        now = time.monotonic()
        live: List[_Item] = []
        items: List[Tuple[int, np.ndarray]] = []
        for item in batch:
            if now > item.deadline:
                with self._lock:
                    self.stats["timeouts"] += 1
                item.respond(_err("timeout", "request expired before dispatch"))
                continue
            uid = item.req.get("job")
            job = self.engine.jobs.get(uid)
            if job is None:
                item.respond(_err("unknown_job", f"no job {uid!r}"))
                continue
            r = item.req.get("round")
            if r is not None:
                # cursor = last served round + 1, read from the host-side
                # response cache — engine.job_round pulls a device scalar,
                # too slow for the per-tick hot path.  Cold cache (first
                # tick after admit, restore or a supervised recovery) is
                # exactly when the engine must be asked.
                cached = self._tick_cache.get(uid)
                cur = cached[0] + 1 if cached is not None else self.engine.job_round(uid)
                if int(r) != cur:
                    if cached is not None and cached[0] == int(r):
                        with self._lock:
                            self.stats["replayed"] += 1
                        item.respond(cached[1])
                        continue
                    item.respond(_err(
                        "round_desync",
                        f"job {uid} is at round {cur}, request carries round {r} "
                        "(replay from the expected round)",
                        expected=cur,
                    ))
                    continue
            spec: JobSpec = job["spec"]
            try:
                lag = protocol.feedback_lags(item.req, spec.K, self.engine.staleness)
            except protocol.ProtocolError as e:
                item.respond(_err("bad_request", str(e)))
                continue
            if lag is None:
                item.respond(_err("bad_request", "tick carries no feedback (x/xb/xl)"))
                continue
            live.append(item)
            items.append((uid, lag))
        if not items:
            return
        t0 = time.perf_counter()
        try:
            results = self.engine.tick(items)
        except (ValueError, TypeError, KeyError) as e:  # rejected batch: fail its requests
            with self._lock:
                self.stats["errors"] += len(live)
            for item in live:
                item.respond(_err("bad_request", str(e)))
            return
        except NumericsError as e:  # update refused, state intact: alert + fail
            with self._lock:
                self.stats["numerics"] += 1
                self.stats["errors"] += len(live)
            self.alerts.append(Alert(
                "numerics", "critical",
                {"dispatch": self.stats["dispatches"], "jobs": [u for u, _ in items]},
                str(e),
            ))
            log.error("non-finite selector update refused: %s", e)
            for item in live:
                item.respond(_err("numerics", str(e)))
            return
        except Exception as e:  # engine crashed: fail in-flight, wake the supervisor
            for item in live:
                item.respond(_err("retry", f"engine crashed mid-dispatch ({e}); retry"))
            raise
        self.latency.observe(time.perf_counter() - t0)
        with self._lock:
            self.stats["dispatches"] += 1
            self.stats["ticks"] += len(items)
            self.stats["degraded"] = 0  # a clean dispatch ends the degraded window
            shed = self._shed_window
            restarts = self._restart_window
            recovery = self._recovery_window
            self._shed_window = 0
            self._restart_window = 0
            self._recovery_window = 0.0
        self.serve_rows.append(
            {
                "queue_depth": float(self._queue.qsize()),
                "batch_jobs": float(len(items)),
                "shed": float(shed),
                "restarts": float(restarts),
                "recovery_s": float(recovery),
            }
        )
        self.rounds_served += len(items)
        self._rounds_since_ckpt += len(items)
        for item in live:
            resp = {"ok": True, **results[item.req["job"]]}
            self._tick_cache[item.req["job"]] = (resp["round"], resp)
            item.respond(resp)
        if (
            self.ckpt_dir
            and self.ckpt_every
            and self._rounds_since_ckpt >= self.ckpt_every
        ):
            self._checkpoint()

    def _control(self, req: dict) -> dict:
        """Admit/retire/checkpoint/info ops — engine-thread only, so they
        serialize with dispatches and mutate the engine race-free."""
        op = req.get("op")
        try:
            if op == "hello":
                return {
                    "ok": True,
                    "server": "repro-serve",
                    "engine": self.engine.kind,
                    "staleness": self.engine.staleness,
                    "jobs": len(self.engine.jobs),
                }
            if op == "admit":
                spec = JobSpec.from_json(req.get("spec") or {})
                uid = self.engine.admit(spec)
                with self._lock:
                    self.stats["admitted"] += 1
                return {"ok": True, "job": uid}
            if op == "retire":
                uid = req.get("job")
                if uid not in self.engine.jobs:
                    return _err("unknown_job", f"no job {uid!r}")
                self.engine.retire(uid)
                self._tick_cache.pop(uid, None)
                with self._lock:
                    self.stats["retired"] += 1
                return {"ok": True}
            if op == "stats":
                with self._lock:
                    stats = dict(self.stats)
                return {"ok": True, "stats": stats, "rounds_served": self.rounds_served}
            if op == "checkpoint":
                if not self.ckpt_dir:
                    return _err("bad_request", "server has no ckpt_dir")
                return {"ok": True, "path": self._checkpoint()}
            if op == "shutdown":
                self._draining.set()
                return {"ok": True, "message": "draining"}
            return _err("bad_request", f"unknown op {op!r}")
        except CapacityError as e:
            with self._lock:
                self.stats["shed"] += 1
                self._shed_window += 1
            return _err("capacity", str(e))
        except (ValueError, TypeError, KeyError) as e:
            with self._lock:
                self.stats["errors"] += 1
            return _err("bad_request", str(e))

    def _checkpoint(self) -> str:
        stem = save_server(
            self.ckpt_dir, self.engine, step=self.rounds_served,
            keep=self.ckpt_keep, faults=self.faults,
        )
        self._rounds_since_ckpt = 0
        self.last_checkpoint = stem
        with self._lock:
            self.stats["checkpoints"] += 1
        return stem

    # -- telemetry ---------------------------------------------------------

    def serve_series(self) -> Dict[str, np.ndarray]:
        """Per-dispatch gauge rows as arrays, keyed by the ``serve`` tap
        group schema."""
        names = ROUND_TAPS.gauge_names("serve")
        rows = self.serve_rows
        return {n: np.asarray([r[n] for r in rows], np.float64) for n in names}

    def attach_report(self, reporter, window: int = SERVE_WINDOW) -> None:
        """Emit this server's run into a ``Reporter``: the windowed ``serve``
        metric stream (gated by the tap group's directions) + the dispatch
        latency histogram + scalar stats."""
        if len(self.serve_rows) >= window:
            reporter.metrics_stream(
                "serve", self.serve_series(), window=window,
                better=ROUND_TAPS.directions("serve"),
            )
        reporter.histogram("dispatch", self.latency)
        reporter.update(rounds_served=self.rounds_served, **{f"n_{k}": v for k, v in self.stats.items()})
        if self.alerts:  # supervisor events (engine_restart / numerics)
            if reporter.log is not None:
                log_alerts(reporter.log, self.alerts)
            reporter.data.setdefault("alerts", []).extend(
                {"rule": a.rule, "severity": a.severity, "message": a.message, **a.detail}
                for a in self.alerts
            )
