"""Deterministic, seeded fault injection for the serving front end.

The paper's premise is client volatility; this module makes the *server*
volatile on purpose, so the fault-tolerance layer (supervised engine
recovery, idempotent retries, crash-safe checkpoints) can be proven rather
than trusted.  A :class:`FaultPlan` is a schedule of four fault kinds, each
keyed on a monotone event counter the serving stack already advances:

* **engine-step crashes** — ``on_engine_step`` raises :class:`EngineCrash`
  at scheduled engine dispatch indices (hooked at the top of
  ``SlotEngine.tick`` / ``ShardedEngine.tick``, before any state mutates).
  The transport's supervisor catches the crash, fails in-flight requests
  with ``error: "retry"`` and restores the engine from the newest *valid*
  checkpoint.
* **checkpoint corruption** — ``on_checkpoint`` truncates or bit-flips the
  ``.ckpt`` payload of scheduled checkpoint writes *after* they land on
  disk (hooked in ``repro.serve.state.save_server``).  The sha256 recorded
  in the meta sidecar no longer matches, so the restore walk-back must skip
  the stem.
* **connection drops** — ``on_response`` cuts the client's connection
  instead of sending scheduled responses (hooked in the transport's
  connection handler, *after* the request executed).  The client's reply is
  lost exactly like a network failure; only the idempotent tick cache makes
  the retry safe.
* **slow dispatches** — ``on_engine_step`` sleeps at scheduled indices
  before the step runs, stretching queue residency so deadline/backpressure
  paths see load without a load generator.

Schedules are explicit index tuples (bit-reproducible by construction) or
drawn once by :meth:`FaultPlan.sample` from a seeded generator.  A plan with
empty schedules is a no-op, and every hook is behind an ``if plan is not
None`` in the serving stack, so the hot path is untouched when chaos is off.

Counters advance under a lock; engine-step and checkpoint counters are
driven by the single engine thread (deterministic order), the response
counter by connection handlers (deterministic for a sequential client, the
chaos harness's shape).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, Optional, Tuple

__all__ = ["EngineCrash", "FaultPlan"]


class EngineCrash(RuntimeError):
    """A fault-injected crash of the engine step (the supervisor's cue)."""


@dataclasses.dataclass
class FaultPlan:
    """One seeded chaos schedule (see module docstring).

    All indices are 0-based event counts: ``crash_steps`` / ``slow_steps``
    count engine dispatches, ``corrupt_checkpoints`` counts checkpoint
    writes, ``drop_responses`` counts responses the transport was about to
    send.  ``fired()`` reports how many of each actually triggered, so a
    chaos test can assert its schedule really ran.
    """

    crash_steps: Tuple[int, ...] = ()
    corrupt_checkpoints: Tuple[int, ...] = ()
    drop_responses: Tuple[int, ...] = ()
    slow_steps: Optional[Dict[int, float]] = None
    corrupt_mode: str = "truncate"  # or "bitflip"

    def __post_init__(self):
        if self.corrupt_mode not in ("truncate", "bitflip"):
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}")
        self.crash_steps = tuple(int(i) for i in self.crash_steps)
        self.corrupt_checkpoints = tuple(int(i) for i in self.corrupt_checkpoints)
        self.drop_responses = tuple(int(i) for i in self.drop_responses)
        self.slow_steps = {int(k): float(v) for k, v in (self.slow_steps or {}).items()}
        self._lock = threading.Lock()
        self._n_step = 0
        self._n_ckpt = 0
        self._n_resp = 0
        self._fired = {"crash": 0, "corrupt": 0, "drop": 0, "slow": 0}

    @classmethod
    def sample(
        cls,
        seed: int,
        *,
        n_steps: int,
        crashes: int = 1,
        corruptions: int = 1,
        drops: int = 2,
        slow: int = 1,
        slow_s: float = 0.01,
        first_step: int = 4,
        corrupt_mode: str = "truncate",
    ) -> "FaultPlan":
        """Draw one schedule from a seeded generator: ``crashes`` engine
        crashes and ``slow`` slow dispatches among steps ``[first_step,
        n_steps)``, ``corruptions`` corrupted checkpoint writes (never the
        very first, so a valid restore point always exists), and ``drops``
        dropped responses.  Same seed, same plan — always."""
        import numpy as np

        rng = np.random.default_rng(seed)
        lo = min(first_step, max(n_steps - 1, 0))
        steps = rng.choice(
            np.arange(lo, max(n_steps, lo + 1)),
            size=min(crashes + slow, max(n_steps - lo, 1)),
            replace=False,
        )
        return cls(
            crash_steps=tuple(sorted(int(s) for s in steps[:crashes])),
            corrupt_checkpoints=tuple(sorted(1 + int(i) for i in rng.choice(
                max(n_steps // 4, 1), size=min(corruptions, max(n_steps // 4, 1)), replace=False
            ))),
            drop_responses=tuple(sorted(int(i) for i in rng.choice(
                np.arange(lo, max(n_steps, lo + 1)), size=min(drops, max(n_steps - lo, 1)),
                replace=False,
            ))),
            slow_steps={int(s): slow_s for s in steps[crashes:]},
            corrupt_mode=corrupt_mode,
        )

    # -- hooks (each no-op unless its schedule names the current index) ----

    def on_engine_step(self) -> None:
        """Engine-dispatch hook: sleep on a scheduled slow step, raise
        :class:`EngineCrash` on a scheduled crash step."""
        with self._lock:
            idx = self._n_step
            self._n_step += 1
            crash = idx in self.crash_steps
            delay = self.slow_steps.get(idx, 0.0)
            if crash:
                self._fired["crash"] += 1
            if delay:
                self._fired["slow"] += 1
        if delay:
            time.sleep(delay)
        if crash:
            raise EngineCrash(f"fault-injected crash at engine step {idx}")

    def on_checkpoint(self, stem: str) -> None:
        """Checkpoint-write hook: corrupt ``<stem>.ckpt`` in place on a
        scheduled write (truncate to half, or flip one payload byte)."""
        with self._lock:
            idx = self._n_ckpt
            self._n_ckpt += 1
            if idx not in self.corrupt_checkpoints:
                return
            self._fired["corrupt"] += 1
        path = stem + ".ckpt"
        size = os.path.getsize(path)
        if self.corrupt_mode == "truncate":
            with open(path, "r+b") as f:
                f.truncate(size // 2)
        else:
            with open(path, "r+b") as f:
                f.seek(size // 2)
                b = f.read(1)
                f.seek(size // 2)
                f.write(bytes([b[0] ^ 0xFF]))

    def on_response(self) -> bool:
        """Response hook: return True when the transport should cut the
        connection instead of sending this response."""
        with self._lock:
            idx = self._n_resp
            self._n_resp += 1
            if idx in self.drop_responses:
                self._fired["drop"] += 1
                return True
        return False

    # -- introspection -----------------------------------------------------

    def fired(self) -> Dict[str, int]:
        """How many faults of each kind actually triggered so far."""
        with self._lock:
            return dict(self._fired)
