"""Synchronous client for the selection serving front end.

One TCP connection, strict request → response.  The client is deliberately
thin — ``repro.serve.protocol`` framing plus op helpers — so the whole wire
contract stays visible in ``docs/serving.md``.  Server-side failures
(``shed``, ``timeout``, ``draining``, ``unknown_job``, ...) surface as
:class:`ServeError` with the wire ``code``; transport breakage surfaces as
the underlying ``ProtocolError`` / ``OSError``.

Feedback for ``tick`` can be posted three ways (see ``protocol``): packed
success bits (``bits=...``, sync servers), packed lag codes (``lags=...``,
async servers), or a plain list (``x=...``).
"""
from __future__ import annotations

import socket
from typing import Optional

from . import protocol

__all__ = ["ServeClient", "ServeError"]


class ServeError(RuntimeError):
    """A request the server answered with ``ok: false``."""

    def __init__(self, code: str, message: str = ""):
        super().__init__(f"{code}: {message}" if message else code)
        self.code = code


class ServeClient:
    """``ServeClient(host, port)`` or ``ServeClient.connect(server.address)``."""

    def __init__(self, host: str, port: int, timeout: Optional[float] = 120.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    @classmethod
    def connect(cls, address, timeout: Optional[float] = 120.0) -> "ServeClient":
        host, port = address
        return cls(host, port, timeout=timeout)

    def call(self, **req) -> dict:
        """One raw request → response round trip; raises ``ServeError`` on
        ``ok: false``."""
        protocol.send_message(self.sock, req)
        resp = protocol.recv_message(self.sock)
        if not resp.get("ok"):
            raise ServeError(resp.get("error", "unknown"), resp.get("message", ""))
        return resp

    # -- op helpers --------------------------------------------------------

    def hello(self) -> dict:
        return self.call(op="hello")

    def admit(self, **spec) -> int:
        """Admit a job (``JobSpec`` fields: K, k, rounds, sigma_frac, eta,
        quota, seed); returns the job uid all later ops use."""
        return self.call(op="admit", spec=spec)["job"]

    def tick(self, job: int, x=None, bits=None, lags=None) -> dict:
        """Post one round of feedback, get the next cohort:
        ``{"round", "cohort", "on_time", "stale"}``."""
        req = {"op": "tick", "job": job}
        if bits is not None:
            req["xb"] = protocol.encode_bits(bits)
        elif lags is not None:
            req["xl"] = protocol.encode_lags(lags)
        elif x is not None:
            req["x"] = [int(v) for v in x]
        return self.call(**req)

    def retire(self, job: int) -> None:
        self.call(op="retire", job=job)

    def stats(self) -> dict:
        return self.call(op="stats")

    def checkpoint(self) -> str:
        """Force a server checkpoint; returns the stem path."""
        return self.call(op="checkpoint")["path"]

    def shutdown(self) -> dict:
        """Ask the server to drain and stop (graceful)."""
        return self.call(op="shutdown")

    def close(self) -> None:
        self.sock.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
