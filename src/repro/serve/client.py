"""Synchronous client for the selection serving front end.

One TCP connection, strict request → response.  The client is deliberately
thin — ``repro.serve.protocol`` framing plus op helpers — so the whole wire
contract stays visible in ``docs/serving.md``.  Server-side failures
(``shed``, ``timeout``, ``draining``, ``unknown_job``, ...) surface as
:class:`ServeError` with the wire ``code`` (and the full decoded response
on ``.response``, e.g. ``round_desync`` carries the ``expected`` round).

Fault tolerance is layered on the server's determinism:

* **broken connections never poison the framing state** — a transport
  error mid-call (``ProtocolError`` / ``OSError``) marks the socket broken
  and closes it, so the next call reconnects from a clean frame boundary
  instead of desynchronizing the length-prefixed stream.
* **retries with exponential backoff + seeded jitter** — ``retries=N``
  makes ``call`` retry transport failures and server ``retry`` answers
  (the transport's "engine crashed mid-dispatch" response).  Only
  *idempotent* requests retry: control reads (``hello``/``stats``) and
  ``tick``s that carry a ``round`` — the server's per-job response cache
  answers a replayed round without double-applying feedback.  A round-less
  tick is NOT safe to resend blind, so it never auto-retries.
* **round tracking** — the client remembers each admitted job's next round
  and tags every ``tick`` with it, which is what makes the retry loop (and
  recovery-driven replay after a server restart) safe end to end.

Feedback for ``tick`` can be posted three ways (see ``protocol``): packed
success bits (``bits=...``, sync servers), packed lag codes (``lags=...``,
async servers), or a plain list (``x=...``).
"""
from __future__ import annotations

import random
import socket
import time
from typing import Dict, Optional

from . import protocol

__all__ = ["ServeClient", "ServeError"]

# server answers a retry of these can't corrupt state even without a round
_IDEMPOTENT_OPS = ("hello", "stats")


class ServeError(RuntimeError):
    """A request the server answered with ``ok: false``; the full decoded
    response rides on ``.response`` (``round_desync`` → ``expected``)."""

    def __init__(self, code: str, message: str = "", response: Optional[dict] = None):
        super().__init__(f"{code}: {message}" if message else code)
        self.code = code
        self.response = response or {}


class ServeClient:
    """``ServeClient(host, port)`` or ``ServeClient.connect(server.address)``.

    ``retries=N`` turns on the retry loop for idempotent requests (N
    reconnect-and-resend attempts after the first, exponential backoff
    starting at ``backoff`` seconds, capped at ``backoff_cap``, jittered by
    a generator seeded with ``seed`` so tests are reproducible).
    """

    def __init__(
        self,
        host: str,
        port: int,
        timeout: Optional[float] = 120.0,
        *,
        retries: int = 0,
        backoff: float = 0.02,
        backoff_cap: float = 1.0,
        seed: int = 0,
    ):
        self._addr = (host, int(port))
        self._timeout = timeout
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.backoff_cap = float(backoff_cap)
        self._rng = random.Random(seed)
        self.rounds: Dict[int, int] = {}  # job uid -> next round to request
        self.sock: Optional[socket.socket] = None
        self._connect()

    @classmethod
    def connect(cls, address, timeout: Optional[float] = 120.0, **kw) -> "ServeClient":
        host, port = address
        return cls(host, port, timeout=timeout, **kw)

    # -- connection management --------------------------------------------

    def _connect(self) -> None:
        self.sock = socket.create_connection(self._addr, timeout=self._timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def _break(self) -> None:
        """Mark the connection broken: a transport error mid-frame leaves
        the stream position unknown, so the socket must not be reused."""
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None

    def _sleep(self, attempt: int) -> None:
        delay = min(self.backoff_cap, self.backoff * (2 ** attempt))
        time.sleep(delay * (0.5 + self._rng.random()))  # jitter in [0.5x, 1.5x)

    @staticmethod
    def _retryable(req: dict) -> bool:
        op = req.get("op")
        return op in _IDEMPOTENT_OPS or (op == "tick" and "round" in req)

    # -- the wire ----------------------------------------------------------

    def call(self, **req) -> dict:
        """One request → response round trip; raises ``ServeError`` on
        ``ok: false``.  With ``retries`` set, idempotent requests (see
        module docstring) survive dropped connections and server ``retry``
        answers by reconnecting and resending with backoff."""
        attempts = 1 + (self.retries if self._retryable(req) else 0)
        last: Exception = RuntimeError("unreachable")
        for attempt in range(attempts):
            if attempt:
                self._sleep(attempt - 1)
            try:
                if self.sock is None:
                    self._connect()
                protocol.send_message(self.sock, req)
                resp = protocol.recv_message(self.sock)
            except (protocol.ProtocolError, OSError) as e:
                self._break()
                last = e
                continue
            if not resp.get("ok"):
                code = resp.get("error", "unknown")
                if code == "retry" and attempt + 1 < attempts:
                    last = ServeError(code, resp.get("message", ""), resp)
                    continue
                raise ServeError(code, resp.get("message", ""), resp)
            return resp
        raise last

    # -- op helpers --------------------------------------------------------

    def hello(self) -> dict:
        return self.call(op="hello")

    def admit(self, **spec) -> int:
        """Admit a job (``JobSpec`` fields: K, k, rounds, sigma_frac, eta,
        quota, seed); returns the job uid all later ops use."""
        uid = self.call(op="admit", spec=spec)["job"]
        self.rounds[uid] = 0
        return uid

    def tick(self, job: int, x=None, bits=None, lags=None, round: Optional[int] = None) -> dict:
        """Post one round of feedback, get the next cohort:
        ``{"round", "cohort", "on_time", "stale"}``.  The request carries a
        round number — ``round`` if given, else the tracked cursor for jobs
        this client admitted — which makes it idempotent (and retryable)
        server-side.  On success the cursor advances past the served round."""
        req = {"op": "tick", "job": job}
        r = round if round is not None else self.rounds.get(job)
        if r is not None:
            req["round"] = int(r)
        if bits is not None:
            req["xb"] = protocol.encode_bits(bits)
        elif lags is not None:
            req["xl"] = protocol.encode_lags(lags)
        elif x is not None:
            req["x"] = [int(v) for v in x]
        resp = self.call(**req)
        if job in self.rounds:
            self.rounds[job] = int(resp["round"]) + 1
        return resp

    def retire(self, job: int) -> None:
        self.call(op="retire", job=job)
        self.rounds.pop(job, None)

    def stats(self) -> dict:
        return self.call(op="stats")

    def checkpoint(self) -> str:
        """Force a server checkpoint; returns the stem path."""
        return self.call(op="checkpoint")["path"]

    def shutdown(self) -> dict:
        """Ask the server to drain and stop (graceful)."""
        return self.call(op="shutdown")

    def close(self) -> None:
        if self.sock is not None:
            self.sock.close()
            self.sock = None

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
