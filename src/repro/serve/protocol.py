"""Wire protocol for the selection serving front end.

One frame = a 4-byte big-endian unsigned length prefix + that many bytes of
UTF-8 JSON.  Both directions use the same framing; a frame larger than
``MAX_MESSAGE_BYTES`` is a protocol error (the peer is misbehaving or the
stream is corrupt — fail loudly, never try to resync).  The framing is
deliberately stdlib-only (``socket`` + ``struct`` + ``json``) so a client
needs nothing beyond Python to speak to the server; numpy is used only for
the optional packed feedback encodings.

Request objects carry ``{"op": <name>, ...}``; responses carry
``{"ok": true, ...}`` or ``{"ok": false, "error": <code>, "message": ...}``.
The op vocabulary, job lifecycle and failure codes are documented in
``docs/serving.md`` (kept executable by ``tests/test_docs.py``).

Feedback encodings for ``tick`` requests, smallest first:

* ``"xb": <base64>`` — 1-bit packed success bits (``np.packbits`` order,
  8 clients/byte): the sync wire twin of the repo's packed trace format.
* ``"xl": <base64>`` — uint8 completion-lag codes, one byte per client;
  ``LAG_NEVER`` (255) encodes a client that never completes (the engine's
  ``DEAD_LAG``).
* ``"x": [..]`` — a plain JSON list: success bits (sync) or lag codes
  (async, ``-1`` = never).  Convenient, ~10x the bytes.
"""
from __future__ import annotations

import base64
import json
import socket
import struct
from typing import Optional

import numpy as np

__all__ = [
    "MAX_MESSAGE_BYTES",
    "LAG_NEVER",
    "DEAD_LAG",
    "ProtocolError",
    "ConnectionClosed",
    "send_message",
    "recv_message",
    "encode_bits",
    "decode_bits",
    "encode_lags",
    "decode_lags",
    "feedback_lags",
]

MAX_MESSAGE_BYTES = 64 << 20  # one frame; ~6e7 clients as packed bits
LAG_NEVER = 255  # uint8 wire code for "never completes"
DEAD_LAG = -1  # engine-side sentinel (== repro.core.volatility.DEAD_LAG)
_HEADER = struct.Struct("!I")


class ProtocolError(RuntimeError):
    """Malformed frame or payload — the stream cannot be trusted further."""


class ConnectionClosed(ProtocolError):
    """The peer closed the connection at a frame boundary (clean EOF)."""


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool) -> bytes:
    """Read exactly ``n`` bytes; EOF at a frame boundary raises
    ``ConnectionClosed``, EOF mid-frame raises ``ProtocolError``."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if at_boundary and got == 0:
                raise ConnectionClosed("peer closed the connection")
            raise ProtocolError(f"connection closed mid-frame ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def send_message(sock: socket.socket, obj: dict) -> None:
    """Serialize ``obj`` as one length-prefixed JSON frame."""
    body = json.dumps(obj, allow_nan=False, separators=(",", ":")).encode()
    if len(body) > MAX_MESSAGE_BYTES:
        raise ProtocolError(f"frame of {len(body)} bytes exceeds MAX_MESSAGE_BYTES")
    sock.sendall(_HEADER.pack(len(body)) + body)


def recv_message(sock: socket.socket, max_bytes: int = MAX_MESSAGE_BYTES) -> dict:
    """Read one frame; raises ``ConnectionClosed`` on clean EOF."""
    (length,) = _HEADER.unpack(_recv_exact(sock, _HEADER.size, at_boundary=True))
    if length > max_bytes:
        raise ProtocolError(f"peer announced a {length}-byte frame (max {max_bytes})")
    body = _recv_exact(sock, length, at_boundary=False)
    try:
        obj = json.loads(body)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:  # non-UTF-8 bytes too
        raise ProtocolError(f"invalid JSON frame: {e}") from e
    if not isinstance(obj, dict):
        raise ProtocolError(f"frame is not a JSON object: {type(obj).__name__}")
    return obj


# -- feedback payload encodings ---------------------------------------------


def encode_bits(x) -> str:
    """1-bit pack a success-bit vector (anything nonzero = success)."""
    bits = np.asarray(x).astype(bool)
    return base64.b64encode(np.packbits(bits).tobytes()).decode()


def decode_bits(s: str, K: int) -> np.ndarray:
    """Inverse of ``encode_bits``; returns float32 ``(K,)`` success bits."""
    raw = np.frombuffer(base64.b64decode(s), np.uint8)
    if raw.size * 8 < K:
        raise ProtocolError(f"packed bits cover {raw.size * 8} clients, need {K}")
    return np.unpackbits(raw, count=K).astype(np.float32)


def encode_lags(lag) -> str:
    """Byte-pack a completion-lag vector; ``DEAD_LAG`` (or any negative /
    >=255 value) becomes the ``LAG_NEVER`` wire code."""
    a = np.asarray(lag, np.int64)
    out = np.where((a < 0) | (a >= LAG_NEVER), LAG_NEVER, a).astype(np.uint8)
    return base64.b64encode(out.tobytes()).decode()


def decode_lags(s: str, K: int) -> np.ndarray:
    """Inverse of ``encode_lags``; returns int32 ``(K,)`` lags with
    ``LAG_NEVER`` mapped back to ``DEAD_LAG``."""
    raw = np.frombuffer(base64.b64decode(s), np.uint8)
    if raw.size < K:
        raise ProtocolError(f"lag codes cover {raw.size} clients, need {K}")
    lag = raw[:K].astype(np.int32)
    return np.where(lag == LAG_NEVER, DEAD_LAG, lag)


def feedback_lags(req: dict, K: int, staleness: int) -> Optional[np.ndarray]:
    """Normalise a ``tick`` request's feedback into int32 ``(K,)`` lag codes
    (the engines' common currency): 0 = on time, ``1..S`` = that many rounds
    late, ``DEAD_LAG`` = never completes.  Sync servers (``staleness == 0``)
    accept success bits and map failure to ``DEAD_LAG``.  Returns None when
    the request carries no feedback field at all.
    """
    if "xb" in req:
        bits = decode_bits(req["xb"], K)
        return np.where(bits > 0, 0, DEAD_LAG).astype(np.int32)
    if "xl" in req:
        return decode_lags(req["xl"], K)
    if "x" in req:
        a = np.asarray(req["x"])
        if a.shape != (K,):
            raise ProtocolError(f"feedback shape {a.shape} != ({K},)")
        if staleness == 0:
            return np.where(a > 0, 0, DEAD_LAG).astype(np.int32)
        lag = a.astype(np.int32)
        return np.where(lag < 0, DEAD_LAG, lag)
    return None
