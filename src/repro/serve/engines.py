"""Serving engines: the compiled selection backends behind the transport.

Two backends, one interface (``admit`` / ``retire`` / ``tick`` / ``meta`` /
``arrays``):

* :class:`SlotEngine` — the multi-tenant **streaming batcher** backend.  J
  tenant jobs live as padding-mask *slots* of one ``(J, K_max)``-packed
  vmapped ``repro.engine.multi_job`` step, so a whole fleet tick is ONE
  device dispatch.  Admitting and retiring jobs edit slot rows
  (``slot_admit`` / ``slot_retire``) — data changes, shapes don't, so
  join/leave never recompiles.  When every slot is occupied the batch grows
  along a fixed **bucket ladder** (4, 8, 16, ... slots): the compile cache
  holds at most one step per bucket size, bounding compilation no matter how
  many jobs churn through.  ``staleness=S`` adds the bounded ``(J, S,
  K_max)`` late-credit ring from ``repro.engine.round_program`` (selector
  feedback stays deadline-based, the paper's policy; the ring is CEP/credit
  accounting).
* :class:`ShardedEngine` — the fleet-scale backend: each job is a full
  ``RoundProgram`` with the K axis sharded over the host mesh
  (``mesh=D``), compiled as a donated-state single-round step
  (``build_runner(carry_key=True, scan_length=1)``) so successive ticks
  resume the horizon bit-identically — the same contract the chunk-streamed
  replay path pins.  ``staleness=S`` serves the sharded-*async* composition,
  rings carried per job.  Jobs with the same geometry share one compiled
  step.

Both backends derive each job's PRNG stream from the job's own ``seed`` and
its own round counter — never from wall-clock, server ticks, or co-tenants
— so a job's selection sequence is a pure function of (spec, feedback
history).  That is the property that makes three things fall out:

* **batching invariance** — a job's cohorts are bit-identical whether it
  ticks alone or coalesced with any set of co-tenants;
* **elastic restart** — ``arrays()`` / ``load_arrays`` round-trip the whole
  evolving state (selector weights, round counters, PRNG keys, staleness
  and late-credit rings) through ``repro.checkpoint``, and a restored
  server continues bit-identically mid-horizon (``tests/test_serve.py``);
* **replayability** — a client that logs its feedback can re-derive every
  cohort the server ever issued.

Feedback is the population availability vector for the round being issued
(the paper's volatility bits), as completion-lag codes: 0 = on time,
``1..S`` = late, ``DEAD_LAG`` = never.  See ``docs/serving.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.volatility import DEAD_LAG
from repro.engine.multi_job import (
    MultiJobConfig,
    MultiJobState,
    make_multi_job,
    pad_slots,
    slot_admit,
    slot_retire,
)
from repro.engine.round_program import staleness_ring_step

from . import protocol

__all__ = [
    "JobSpec",
    "CapacityError",
    "NumericsError",
    "SlotEngine",
    "ShardedEngine",
    "engine_from_meta",
]

assert protocol.DEAD_LAG == DEAD_LAG, "wire and engine dead-lag sentinels drifted"


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One tenant job's declaration, as posted with the ``admit`` op.

    ``sigma_frac`` is the fairness floor as a fraction of the uniform rate
    ``k/K`` (``sigma = sigma_frac * k / K``); ``rounds`` is the job's
    declared horizon — the :class:`ShardedEngine` quota schedule spans it
    (the :class:`SlotEngine` holds sigma constant, the ``multi_job``
    semantics).  ``seed`` fully determines the job's PRNG stream.
    """

    K: int
    k: int
    rounds: int = 400
    sigma_frac: float = 0.5
    eta: float = 0.5
    quota: str = "const"
    seed: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "JobSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - fields
        if unknown:
            raise ValueError(f"unknown JobSpec fields {sorted(unknown)}")
        return cls(**{k: v for k, v in obj.items() if k in fields})


class CapacityError(RuntimeError):
    """No free slot and the bucket ladder is exhausted — shed the admit."""


class NumericsError(RuntimeError):
    """A selector update produced NaN/inf log-weights.  The update was
    **refused** — engine state is unchanged — so numerical blowup can never
    be silently checkpointed; the transport surfaces the refusal as an
    ``error: "numerics"`` response plus an alert."""


def _key_array(seed: int) -> jax.Array:
    return jax.random.PRNGKey(int(seed))


# ---------------------------------------------------------------------------
# SlotEngine — the streaming-batcher backend
# ---------------------------------------------------------------------------


class SlotEngine:
    """Multi-tenant vmapped engine with padding-mask slots (see module doc).

    ``buckets`` is the slot-count ladder; the engine starts at the smallest
    bucket and grows (``pad_slots``) when admits exceed capacity, paying one
    recompile per distinct bucket size ever reached.  ``k_cap`` bounds every
    job's cohort (the padded top-k width is static in the compiled step).
    """

    kind = "slots"

    def __init__(
        self,
        K_max: int = 4096,
        k_cap: Optional[int] = None,
        staleness: int = 0,
        alpha: float = 0.5,
        buckets: Sequence[int] = (4, 8, 16, 32, 64),
        n_iters: int = 48,
        tile: int = 8192,
    ):
        if not buckets or list(buckets) != sorted(set(int(b) for b in buckets)):
            raise ValueError(f"buckets must be a strictly increasing ladder, got {buckets!r}")
        self.K_max = int(K_max)
        self.k_cap = int(k_cap if k_cap is not None else max(8, K_max // 8))
        self.staleness = int(staleness)
        self.alpha = float(alpha)
        self.buckets = tuple(int(b) for b in buckets)
        self.n_iters, self.tile = int(n_iters), int(tile)
        self._steps: dict = {}  # J -> jitted step (bounded by the ladder)
        self._job_step = make_multi_job(self.k_cap, n_iters=self.n_iters, tile=self.tile)[0]
        J = self.buckets[0]
        self.cfg = MultiJobConfig(
            k=jnp.ones((J,), jnp.int32),
            sigma=jnp.zeros((J,), jnp.float32),
            eta=jnp.zeros((J,), jnp.float32),
            active=jnp.zeros((J, self.K_max), jnp.float32),
        )
        self.state = MultiJobState(
            logw=jnp.zeros((J, self.K_max), jnp.float32), t=jnp.zeros((J,), jnp.int32)
        )
        self.pending = jnp.zeros((J, self.staleness, self.K_max), jnp.float32)
        self.base_keys = jnp.stack([_key_array(0)] * J)
        self.jobs: Dict[int, dict] = {}  # uid -> {"slot": int, "spec": JobSpec}
        self._next_uid = 0
        self.faults = None  # chaos hook (repro.serve.faults.FaultPlan) or None

    # -- capacity ---------------------------------------------------------

    @property
    def n_slots(self) -> int:
        return self.cfg.active.shape[0]

    def _free_slot(self) -> int:
        used = {j["slot"] for j in self.jobs.values()}
        for s in range(self.n_slots):
            if s not in used:
                return s
        self._grow()
        return len(used)

    def _grow(self) -> None:
        ladder = [b for b in self.buckets if b > self.n_slots]
        if not ladder:
            raise CapacityError(
                f"all {self.n_slots} slots occupied and the bucket ladder "
                f"{self.buckets} is exhausted"
            )
        new_J = ladder[0]
        pad = new_J - self.n_slots
        self.cfg, self.state = pad_slots(self.cfg, self.state, new_J)
        self.pending = jnp.pad(self.pending, ((0, pad), (0, 0), (0, 0)))
        self.base_keys = jnp.concatenate(
            [self.base_keys, jnp.stack([_key_array(0)] * pad)]
        )

    # -- lifecycle --------------------------------------------------------

    def admit(self, spec: JobSpec) -> int:
        if spec.K > self.K_max:
            raise ValueError(f"job K={spec.K} exceeds the server's K_max={self.K_max}")
        if spec.k > self.k_cap:
            raise ValueError(f"job k={spec.k} exceeds the server's cohort cap k_cap={self.k_cap}")
        slot = self._free_slot()
        uid = self._next_uid
        self._next_uid += 1
        self.cfg = slot_admit(self.cfg, slot, spec.K, spec.k, spec.sigma_frac, spec.eta)
        self.state = MultiJobState(
            logw=self.state.logw.at[slot].set(0.0),
            t=self.state.t.at[slot].set(0),
        )
        if self.staleness:
            self.pending = self.pending.at[slot].set(0.0)
        self.base_keys = self.base_keys.at[slot].set(_key_array(spec.seed))
        self.jobs[uid] = {"slot": slot, "spec": spec}
        return uid

    def retire(self, uid: int) -> None:
        job = self.jobs.pop(uid)
        self.cfg = slot_retire(self.cfg, job["slot"])

    def job_round(self, uid: int) -> int:
        """The round the job's NEXT tick will serve (the idempotency cursor
        the transport's retry cache compares request rounds against)."""
        return int(np.asarray(self.state.t)[self.jobs[uid]["slot"]])

    # -- the batched serving step ----------------------------------------

    def _build_step(self, J: int):
        """One donated-state compiled dispatch for a J-slot batch: per-job
        keys derive from each job's own round counter, non-participating
        slots are gated back to their previous state (weights, counter and
        ring all unchanged — their ring must not shift on other tenants'
        ticks).  A non-finite updated log-weight anywhere gates the WHOLE
        batch back to its previous state (the NaN/inf guard — the gating
        must live inside the step because the inputs are donated) and is
        reported through the returned ``finite`` flag."""
        job_step, S, alpha = self._job_step, self.staleness, self.alpha

        def step(cfg, logw, t, pending, base_keys, lag, participate):
            keys = jax.vmap(jax.random.fold_in)(base_keys, t)
            x = (lag == 0).astype(jnp.float32) * cfg.active
            new_logw, new_t, out = jax.vmap(job_step)(cfg, logw, t, keys, x)
            # dead slots legitimately step to NaN (empty active mask) and are
            # gated out below — only participating slots can refuse the batch.
            # Only the PERSISTENT state (logw/t/pending) is gated on the
            # reduction: outputs are discarded on refusal anyway, and keeping
            # them off the reduction's critical path keeps the guard cheap.
            finite = jnp.all(jnp.isfinite(new_logw) | ~participate[:, None])
            pj = participate.astype(jnp.float32)
            keep = pj * finite.astype(jnp.float32)
            mask = out["mask"] * pj[:, None]
            arriving, new_pending = staleness_ring_step(pending, mask, lag, S, alpha)
            arriving = arriving * pj[:, None]
            logw = jnp.where(keep[:, None] > 0, new_logw, logw)
            t = jnp.where(participate & finite, new_t, t)
            if S:
                new_pending = jnp.where(keep[:, None, None] > 0, new_pending, pending)
            idx = jnp.where(participate[:, None], out["idx"], -1)
            on_time = jnp.sum(mask * x, axis=1)
            stale = jnp.sum(arriving, axis=1)
            return logw, t, new_pending, idx, on_time, stale, finite

        return jax.jit(step, donate_argnums=(1, 2, 3))

    def tick(self, items: List[Tuple[int, np.ndarray]]) -> Dict[int, dict]:
        """One batched dispatch: ``items`` maps job uid -> this round's lag
        codes ``(K_job,)`` (each uid at most once).  Returns per-uid results
        ``{"round", "cohort", "on_time", "stale"}``."""
        if self.faults is not None:
            self.faults.on_engine_step()
        J = self.n_slots
        if len({u for u, _ in items}) != len(items):
            raise ValueError("duplicate job uid in one batch (coalesce across dispatches)")
        participate = np.zeros((J,), bool)
        lag = np.zeros((J, self.K_max), np.int32)
        rounds_before = np.asarray(self.state.t)
        for uid, row in items:
            job = self.jobs[uid]
            slot, K = job["slot"], job["spec"].K
            row = np.asarray(row, np.int32).reshape(-1)
            if row.shape[0] != K:
                raise ValueError(f"job {uid}: feedback has {row.shape[0]} entries, K={K}")
            participate[slot] = True
            lag[slot, :K] = row
        step = self._steps.get(J)
        if step is None:
            step = self._steps[J] = self._build_step(J)
        logw, t, pending, idx, on_time, stale, finite = step(
            self.cfg, self.state.logw, self.state.t, self.pending,
            self.base_keys, jnp.asarray(lag), jnp.asarray(participate),
        )
        # reassign before any raise: the step donated the old buffers, and
        # on a refused (non-finite) update the state outputs ARE the old state
        self.state = MultiJobState(logw=logw, t=t)
        self.pending = pending
        # one host transfer for everything the response needs + the guard flag
        idx, on_time, stale, finite = jax.device_get((idx, on_time, stale, finite))
        if not bool(finite):
            raise NumericsError(
                "selector update produced non-finite log-weights; update refused"
            )
        results = {}
        for uid, _ in items:
            slot = self.jobs[uid]["slot"]
            cohort = idx[slot][idx[slot] >= 0]
            results[uid] = {
                "round": int(rounds_before[slot]),
                "cohort": cohort.tolist(),
                "on_time": float(on_time[slot]),
                "stale": float(stale[slot]),
            }
        return results

    # -- checkpoint surface ----------------------------------------------

    def meta(self) -> dict:
        """The static half of a checkpoint: everything needed to rebuild an
        identically-shaped engine (``engine_from_meta``) before restoring
        the array state into it."""
        return {
            "kind": self.kind,
            "K_max": self.K_max,
            "k_cap": self.k_cap,
            "staleness": self.staleness,
            "alpha": self.alpha,
            "buckets": list(self.buckets),
            "n_iters": self.n_iters,
            "tile": self.tile,
            "n_slots": self.n_slots,
            "next_uid": self._next_uid,
            "jobs": [
                {"uid": uid, "slot": j["slot"], "spec": j["spec"].to_json()}
                for uid, j in sorted(self.jobs.items())
            ],
        }

    def arrays(self):
        """The evolving array state (the checkpoint payload): weights, round
        counters, the staleness ring and the per-slot PRNG bases."""
        return {
            "logw": self.state.logw,
            "t": self.state.t,
            "pending": self.pending,
            "base_keys": self.base_keys,
        }

    def load_arrays(self, arrays) -> None:
        self.state = MultiJobState(logw=jnp.asarray(arrays["logw"]), t=jnp.asarray(arrays["t"]))
        self.pending = jnp.asarray(arrays["pending"])
        self.base_keys = jnp.asarray(arrays["base_keys"])

    @classmethod
    def from_meta(cls, meta: dict) -> "SlotEngine":
        eng = cls(
            K_max=meta["K_max"], k_cap=meta["k_cap"], staleness=meta["staleness"],
            alpha=meta["alpha"], buckets=meta["buckets"], n_iters=meta["n_iters"],
            tile=meta["tile"],
        )
        while eng.n_slots < meta["n_slots"]:
            eng._grow()
        for row in meta["jobs"]:
            spec = JobSpec.from_json(row["spec"])
            eng.cfg = slot_admit(eng.cfg, row["slot"], spec.K, spec.k, spec.sigma_frac, spec.eta)
            eng.jobs[row["uid"]] = {"slot": row["slot"], "spec": spec}
        eng._next_uid = meta["next_uid"]
        return eng


# ---------------------------------------------------------------------------
# ShardedEngine — fleet-scale jobs, one RoundProgram each
# ---------------------------------------------------------------------------


class ShardedEngine:
    """Each admitted job is one K-sharded ``RoundProgram`` stepped a round
    per tick (see module doc).  ``staleness=S`` serves sharded-async rounds
    with the ``(S, K/D)`` rings carried per job; ``feedback`` picks the
    selector policy (``"deadline"`` or ``"late_credit"``)."""

    kind = "sharded"

    def __init__(
        self,
        D: Optional[int] = None,
        staleness: int = 0,
        alpha: float = 0.5,
        block: int = 4,
        feedback: str = "deadline",
    ):
        from repro.launch.mesh import make_host_mesh

        self.mesh = make_host_mesh(D)
        self.D = int(self.mesh.devices.size)
        self.staleness = int(staleness)
        self.alpha = float(alpha)
        self.block = int(block)
        self.feedback = feedback
        self._runners: dict = {}  # geometry key -> (run, state0, program)
        self.jobs: Dict[int, dict] = {}
        self._next_uid = 0
        self.faults = None  # chaos hook (repro.serve.faults.FaultPlan) or None

    def _runner(self, spec: JobSpec):
        from repro.configs.base import FLConfig
        from repro.engine.round_program import RoundProgram

        geom = (spec.K, spec.k, spec.rounds, spec.quota, spec.sigma_frac, spec.eta)
        hit = self._runners.get(geom)
        if hit is not None:
            return hit
        fl = FLConfig(
            K=spec.K, k=spec.k, rounds=spec.rounds, scheme="e3cs", quota=spec.quota,
            quota_frac=spec.sigma_frac, eta=spec.eta, allocator="bisect",
            staleness_rounds=self.staleness, staleness_alpha=self.alpha,
        )
        program = RoundProgram.from_config(
            fl, mesh=self.mesh, override="dense", feedback=self.feedback, block=self.block
        )
        run, state0 = program.build_runner(outputs="full", carry_key=True, scan_length=1)
        self._runners[geom] = (run, state0, program)
        return self._runners[geom]

    def admit(self, spec: JobSpec) -> int:
        # geometry bounds (k <= K_pad/D for the per-shard top-k) are
        # enforced by RoundProgram.from_config inside _runner
        run, state0, program = self._runner(spec)
        uid = self._next_uid
        self._next_uid += 1
        self.jobs[uid] = {
            "spec": spec,
            "state": state0,
            "key": _key_array(spec.seed),
            "rings": program.init_rings() if self.staleness else (),
            "t": 0,
        }
        return uid

    def retire(self, uid: int) -> None:
        del self.jobs[uid]

    def job_round(self, uid: int) -> int:
        """The round the job's NEXT tick will serve (the idempotency cursor
        the transport's retry cache compares request rounds against)."""
        return int(self.jobs[uid]["t"])

    def tick(self, items: List[Tuple[int, np.ndarray]]) -> Dict[int, dict]:
        """Advance each job one round (dispatched per job — the K axis is
        already device-parallel; there is no J axis to batch here)."""
        if self.faults is not None:
            self.faults.on_engine_step()
        results = {}
        for uid, row in items:
            job = self.jobs[uid]
            spec: JobSpec = job["spec"]
            run, _, _ = self._runner(spec)
            row = np.asarray(row, np.int32).reshape(-1)
            if row.shape[0] != spec.K:
                raise ValueError(f"job {uid}: feedback has {row.shape[0]} entries, K={spec.K}")
            if self.staleness:
                xs = jnp.asarray(row, jnp.int32)[None, :]
                state, key, rings, masks, lags, ps, sigmas, arrived = run(
                    job["state"], job["key"], job["rings"], xs
                )
                stale = float(np.asarray(arrived[0][: spec.K]).sum())
            else:
                xs = jnp.asarray(row == 0, jnp.float32)[None, :]
                state, key, masks, xbits, ps, sigmas = run(job["state"], job["key"], xs)
                rings = None
                stale = 0.0
            # NaN/inf guard: the runner does not donate, so the old state is
            # intact — refuse the update before assigning anything
            if not bool(jnp.all(jnp.isfinite(state.e3cs.logw))):
                raise NumericsError(
                    f"job {uid}: selector update produced non-finite log-weights; "
                    "update refused"
                )
            if rings is not None:
                job["rings"] = rings
            job["state"], job["key"] = state, key
            mask = np.asarray(masks[0][: spec.K])
            cohort = np.nonzero(mask > 0)[0]
            on_time = float((mask * (row == 0)).sum())
            results[uid] = {
                "round": job["t"],
                "cohort": cohort.tolist(),
                "on_time": on_time,
                "stale": stale,
            }
            job["t"] += 1
        return results

    # -- checkpoint surface ----------------------------------------------

    def meta(self) -> dict:
        return {
            "kind": self.kind,
            "D": self.D,
            "staleness": self.staleness,
            "alpha": self.alpha,
            "block": self.block,
            "feedback": self.feedback,
            "next_uid": self._next_uid,
            "jobs": [
                {"uid": uid, "t": j["t"], "spec": j["spec"].to_json()}
                for uid, j in sorted(self.jobs.items())
            ],
        }

    def arrays(self):
        """Per-job evolving state keyed by uid (string keys: the checkpoint
        container round-trips through msgpack): the full ``ServerState``
        pytree, the carried PRNG key, and the staleness/late-credit rings."""
        return {
            str(uid): {"state": j["state"], "key": j["key"], "rings": list(j["rings"])}
            for uid, j in self.jobs.items()
        }

    def load_arrays(self, arrays) -> None:
        for uid, job in self.jobs.items():
            blob = arrays[str(uid)]
            job["state"] = jax.tree.map(jnp.asarray, blob["state"])
            job["key"] = jnp.asarray(blob["key"])
            job["rings"] = tuple(jnp.asarray(r) for r in blob["rings"])

    @classmethod
    def from_meta(cls, meta: dict) -> "ShardedEngine":
        eng = cls(
            D=meta["D"], staleness=meta["staleness"], alpha=meta["alpha"],
            block=meta["block"], feedback=meta["feedback"],
        )
        for row in meta["jobs"]:
            spec = JobSpec.from_json(row["spec"])
            uid = eng.admit(spec)
            eng.jobs[uid]["t"] = row["t"]
            if uid != row["uid"]:  # preserve original uids across restarts
                eng.jobs[row["uid"]] = eng.jobs.pop(uid)
        eng._next_uid = meta["next_uid"]
        return eng


def engine_from_meta(meta: dict):
    """Rebuild an engine shell from its checkpoint meta (static config +
    job table); the caller then restores the array state into it
    (``repro.serve.state.load_server`` does both)."""
    kinds = {SlotEngine.kind: SlotEngine, ShardedEngine.kind: ShardedEngine}
    kind = meta.get("kind")
    if kind not in kinds:
        raise ValueError(f"unknown engine kind {kind!r} (want one of {sorted(kinds)})")
    return kinds[kind].from_meta(meta)
