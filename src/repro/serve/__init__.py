"""repro.serve — the selection service: a callable front end for the engine.

The engine packages (``repro.engine``) compile selection *loops*; this
package makes them a *service* a fleet coordinator can call one round at a
time, without giving up the compiled steady state:

* :mod:`repro.serve.protocol` — stdlib-only wire format: length-prefixed
  JSON frames, packed feedback encodings (success bits / lag codes).
* :mod:`repro.serve.engines` — the serving backends: ``SlotEngine`` (J
  tenant jobs as padding-mask slots of one vmapped dispatch, bucket-ladder
  growth, no recompile on join/leave) and ``ShardedEngine`` (one K-sharded
  ``RoundProgram`` per job, sync or async).
* :mod:`repro.serve.transport` — ``SelectionServer``: socket front end,
  streaming batcher, bounded-queue backpressure (shed), request deadlines,
  periodic checkpoint, graceful drain.
* :mod:`repro.serve.state` — elastic restart: engine meta + array
  checkpoints through ``repro.checkpoint``; a restored server continues
  bit-identically mid-horizon.
* :mod:`repro.serve.client` — the thin synchronous client (reconnecting,
  with seeded-backoff retries for idempotent requests).
* :mod:`repro.serve.faults` — seeded chaos schedules (``FaultPlan``):
  engine crashes, checkpoint corruption, dropped connections, slow
  dispatches — all behind no-op defaults.

Wire contract and failure modes: ``docs/serving.md`` (kept executable by
``tests/test_docs.py``).
"""
from .client import ServeClient, ServeError
from .engines import CapacityError, JobSpec, NumericsError, ShardedEngine, SlotEngine, engine_from_meta
from .faults import EngineCrash, FaultPlan
from .state import latest_server_checkpoint, load_server, save_server, validate_stem
from .transport import SelectionServer

__all__ = [
    "ServeClient",
    "ServeError",
    "CapacityError",
    "JobSpec",
    "NumericsError",
    "SlotEngine",
    "ShardedEngine",
    "engine_from_meta",
    "EngineCrash",
    "FaultPlan",
    "save_server",
    "load_server",
    "latest_server_checkpoint",
    "validate_stem",
    "SelectionServer",
]
