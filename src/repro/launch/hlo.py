"""Parse collective ops + their operand bytes out of optimized HLO text.

``cost_analysis()`` does not report collective traffic, so the roofline's
third term comes from here: sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
post-SPMD module (DESIGN.md §6).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "parse_shape_bytes", "count_ops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def parse_shape_bytes(shape_str: str) -> int:
    """Bytes of 'bf16[16,128,4096]' or a tuple '(f32[2], bf16[3,4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind result bytes (per device, post-SPMD module).

    HLO lines look like
      ``%ar = bf16[4096]{0} all-reduce(bf16[4096]{0} %x), replica_groups=...``
    We take the *result* shape (between '=' and the op name), which for
    all-gather counts the gathered bytes and for reduce-scatter the scattered
    output — a per-device traffic proxy consistent across kinds.
    """
    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        for coll in _COLLECTIVES:
            tag = " " + coll
            pos = s.find(tag + "(")
            if pos < 0:
                pos = s.find(tag + "-start(")
            if pos < 0:
                continue
            eq = s.find("=")
            if eq < 0 or eq > pos:
                break
            shape_str = s[eq + 1 : pos]
            out[coll] += parse_shape_bytes(shape_str)
            counts[coll] += 1
            break
    out_total = dict(out)
    out_total["total"] = float(sum(out.values()))
    out_total.update({f"n_{k}": float(v) for k, v in counts.items()})
    return out_total


def count_ops(hlo_text: str, names=("fusion", "custom-call", "while", "dynamic-update-slice")) -> Dict[str, int]:
    c = {}
    for n in names:
        c[n] = len(re.findall(rf"\s{re.escape(n)}[\(\.]", hlo_text))
    return c
