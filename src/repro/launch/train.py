"""FL training driver (paper-scale workloads; runnable on this CPU box).

Trains a CNN or small-LM global model across K volatile clients with the
configured selection scheme, reproducing the paper's protocol end to end:

    python -m repro.launch.train --task emnist --scheme e3cs --quota inc \
        --rounds 120 --out results/train/e3cs_inc.json

``--task lm`` federates a small LM (the ``--arch`` smoke variant) over token
shards instead, demonstrating the same selector on transformer workloads.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import FLConfig, get_config
from repro.data import ClientStore, make_image_dataset, partition_iid, partition_primary_label
from repro.fl import FLServer
from repro.models import build_model, cross_entropy

TASKS = {
    "emnist": dict(cfg="emnist-cnn", classes=26, img=(28, 28, 1)),
    "cifar": dict(cfg="cifar-cnn", classes=10, img=(32, 32, 3)),
}


def build_task(task: str, fl: FLConfig):
    t = TASKS[task]
    cfg = get_config(t["cfg"])
    data = make_image_dataset(t["classes"], t["img"], n_train=fl.K * fl.samples_per_client // 2, n_test=4000, seed=fl.seed)
    part = partition_primary_label if fl.non_iid else partition_iid
    idxs = part(data["y"], fl.K, fl.samples_per_client, seed=fl.seed) if fl.non_iid else part(
        data["y"], fl.K, fl.samples_per_client, seed=fl.seed
    )
    store = ClientStore(data, idxs, seed=fl.seed)
    model = build_model(cfg)

    def eval_fn(params):
        x, y = store.eval_batch(2000)
        logits = model.forward(params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        acc = float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean())
        return acc, float(cross_entropy(logits, jnp.asarray(y)))

    return model, store, eval_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist", choices=list(TASKS))
    ap.add_argument("--scheme", default="e3cs")
    ap.add_argument("--quota", default="const")
    ap.add_argument("--quota-frac", type=float, default=0.5)
    ap.add_argument("--local-update", default="fedavg", choices=["fedavg", "fedprox"])
    ap.add_argument("--sampler", default="plackett_luce")
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--K", type=int, default=100)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--spc", type=int, default=80, help="samples per client")
    ap.add_argument("--batch", type=int, default=40)
    ap.add_argument("--epochs", type=int, nargs="+", default=[1, 2])
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--volatility", default="bernoulli")
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    fl = FLConfig(
        K=args.K,
        k=args.k,
        rounds=args.rounds,
        scheme=args.scheme,
        quota=args.quota,
        quota_frac=args.quota_frac,
        sampler=args.sampler,
        local_update=args.local_update,
        local_epochs=tuple(args.epochs),
        batch_size=args.batch,
        samples_per_client=args.spc,
        non_iid=not args.iid,
        volatility=args.volatility,
        seed=args.seed,
    )
    model, store, eval_fn = build_task(args.task, fl)
    srv = FLServer(model, fl, store, eval_fn)
    state = srv.init_state(jax.random.PRNGKey(fl.seed))
    t0 = time.time()
    state, hist = srv.run(state, eval_every=args.eval_every)
    out = {
        "config": dataclasses.asdict(fl),
        "task": args.task,
        "history": hist,
        "cep": float(state.cep),
        "sel_counts": np.asarray(state.sel_counts).tolist(),
        "wall_s": round(time.time() - t0, 1),
    }
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f)
    if args.ckpt:
        save(args.ckpt, {"params": state.params, "e3cs": state.e3cs}, step=args.rounds)
    print(json.dumps({k: out[k] for k in ("cep", "wall_s")} | {"final_acc": hist["acc"][-1] if hist["acc"] else None}))


if __name__ == "__main__":
    main()
