import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
)

"""Multi-pod dry-run driver (DESIGN.md §6).

For every (architecture x input-shape x mesh) this lowers + compiles the
jitted step with explicit shardings on the production mesh built from 512
host placeholder devices, then records ``memory_analysis()``,
``cost_analysis()`` and the collective bytes parsed from the optimized HLO
into ``results/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
    python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --skip-existing
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core.selection import e3cs_init, e3cs_probs, e3cs_update, sample_selection, selection_mask
from repro.launch.hlo import collective_bytes, count_ops
from repro.launch.mesh import axis_sizes, make_production_mesh
from repro.models import build_model, input_specs
from repro.models.sharding import cohort_rules, logical_to_spec, silo_rules, use_rules
from repro.models.transformer import cache_specs
from repro.optim import sgd

# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link (conservative single-link model)

# grad-accumulation microbatch counts for silo-mapped archs (memory planning)
MICRO = {"llama3-405b": 8, "deepseek-v3-671b": 8, "qwen2-vl-72b": 4, "qwen3-moe-30b-a3b": 2}
WINDOW_LONG = 8192  # sliding window for attention-family long_500k serving

_RULES_PATCH = {}  # hillclimb experiments patch the sharding rules here

SKIPS = {
    ("whisper-base", "long_500k"): (
        "enc-dec with a 448-token-class decoder; a 500k text self-attention cache is architecturally meaningless"
    ),
}


def _sds(shape, dtype, spec, mesh):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _spec_tree_to_sharding(spec_tree, mesh, rules):
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_to_spec(axes, rules)),
        spec_tree,
        is_leaf=lambda s: isinstance(s, tuple) and all(isinstance(a, (str, type(None))) for a in s),
    )


def _param_shapes_and_specs(model, cfg):
    captured = {}

    def f(r):
        params, specs = model.init(r)
        captured["specs"] = specs
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, captured["specs"]


def _attach(shapes_tree, sharding_tree):
    return jax.tree.map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), shapes_tree, sharding_tree)


def _replicated(tree, mesh):
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, P())), tree)


def serve_rules(cfg, sizes, kind: str):
    base = silo_rules(cfg, sizes) if cfg.fl_mapping == "silo" else cohort_rules(cfg, sizes)
    if kind == "decode" and (base.get("kv_heads") is None or cfg.attn == "mla"):
        # kv heads can't shard over `model` -> shard the cache sequence instead
        base["cache_seq"] = "model"
        base["kv_heads"] = None
    return base


def _batch_axis(name: str) -> int:
    return 1 if name == "positions" else 0


def _batch_sds(batch_spec, rules, mesh, extra_lead=()):
    """Shard the batch dim of each input per rules['batch']."""
    out = {}
    for name, s in batch_spec.items():
        spec = [None] * (len(extra_lead) + len(s.shape))
        spec[len(extra_lead) + _batch_axis(name)] = rules.get("batch")
        out[name] = _sds(tuple(extra_lead) + s.shape, s.dtype, P(*spec), mesh)
    return out


# ------------------------------------------------------------------ train --


def build_train_program(cfg: ModelConfig, shape: InputShape, mesh, n_micro_override=None):
    sizes = axis_sizes(mesh)
    fsdp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    n_fsdp = 1
    for a in fsdp_axes:
        n_fsdp *= sizes[a]
    model = build_model(cfg, impl="einsum")

    if cfg.fl_mapping == "silo":
        rules = silo_rules(cfg, sizes)
        rules.update(_RULES_PATCH)
        n_micro = n_micro_override or MICRO.get(cfg.name, 1)
        opt = sgd(1e-2, 0.9)

        def train_step(params, opt_state, batch, rng):

            def micro(acc, i):
                sl = {
                    k: jax.lax.dynamic_slice_in_dim(v, i * (v.shape[_batch_axis(k)] // n_micro),
                                                    v.shape[_batch_axis(k)] // n_micro, _batch_axis(k))
                    for k, v in batch.items()
                }
                (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, sl, rng)
                return jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads), loss

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            micro_fn = jax.checkpoint(micro) if n_micro > 1 else micro
            acc, losses = jax.lax.scan(micro_fn, acc0, jnp.arange(n_micro))
            grads = jax.tree.map(lambda g, p_: (g / n_micro).astype(p_.dtype), acc, params)
            new_params, new_opt = opt.update(params, grads, opt_state, 0)
            return new_params, new_opt, jnp.mean(losses)

        with use_rules(rules):
            pshapes, pspecs = _param_shapes_and_specs(model, cfg)
        psharding = _spec_tree_to_sharding(pspecs, mesh, rules)
        params_sds = _attach(pshapes, psharding)
        opt_shapes = jax.eval_shape(opt.init, pshapes)
        opt_sds = _attach(opt_shapes, psharding)  # momentum mirrors params
        batch_sds = _batch_sds(input_specs(cfg, shape), rules, mesh)
        rng_sds = _sds((2,), jnp.uint32, P(), mesh)
        return train_step, (params_sds, opt_sds, batch_sds, rng_sds), rules

    # ---- cohort mapping: the full paper round in one program ----
    rules = cohort_rules(cfg, sizes)
    rules["batch"] = None  # per-client batch lives inside a (pod,data) slice
    rules.update(_RULES_PATCH)
    n_clients = n_fsdp  # one client per (pod, data) slice
    B_cl = max(1, shape.global_batch // n_clients)
    K_virtual = 1024
    k_sel = n_clients
    opt = sgd(1e-2, 0.9)
    from repro.fl.client import make_local_update

    local = make_local_update(model, opt, "fedavg")
    spmd = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    vlocal = jax.vmap(local, in_axes=(None, 0, 0, 0), spmd_axis_name=spmd)

    def round_step(params, e3cs_state, batches, rng):
        sigma = jnp.float32(0.5 * k_sel / K_virtual)
        p, capped = e3cs_probs(e3cs_state, k_sel, sigma)
        r_sel, r_x, r_loc = jax.random.split(rng, 3)
        idx = sample_selection(r_sel, p, k_sel)
        mask = selection_mask(idx, K_virtual)
        x_full = jax.random.bernoulli(r_x, 0.7, (K_virtual,)).astype(jnp.float32)
        success = x_full[idx]
        step_mask = jnp.ones((k_sel, 1), jnp.float32)
        cohort, stats = vlocal(params, batches, step_mask, jax.random.split(r_loc, k_sel))
        from repro.fl.aggregation import aggregate

        new_params = aggregate(
            params, cohort, success, jnp.ones((k_sel,)), jnp.float32(K_virtual), K_virtual, "fedavg"
        )
        new_state = e3cs_update(e3cs_state, p, capped, mask, x_full, k_sel, sigma, 0.5)
        return new_params, new_state, stats["local_loss"].mean()

    with use_rules(rules):
        pshapes, pspecs = _param_shapes_and_specs(model, cfg)
    params_sds = _attach(pshapes, _spec_tree_to_sharding(pspecs, mesh, rules))
    e3cs_sds = _replicated(jax.eval_shape(lambda: e3cs_init(K_virtual)), mesh)
    base = input_specs(cfg, shape)
    batch_sds = {}
    client_axis = spmd
    for name, s in base.items():
        per_client = (B_cl,) + tuple(s.shape[1:]) if _batch_axis(name) == 0 else s.shape[:1] + (B_cl,) + tuple(s.shape[2:])
        shp = (k_sel, 1) + per_client
        spec = [client_axis] + [None] * (len(shp) - 1)
        batch_sds[name] = _sds(shp, s.dtype, P(*spec), mesh)
    rng_sds = _sds((2,), jnp.uint32, P(), mesh)
    return round_step, (params_sds, e3cs_sds, batch_sds, rng_sds), rules


# ------------------------------------------------------------------ serve --


def build_serve_program(cfg: ModelConfig, shape: InputShape, mesh):
    sizes = axis_sizes(mesh)
    kind = shape.kind
    window = WINDOW_LONG if (shape.name == "long_500k" and cfg.family != "ssm") else 0
    impl = "chunked" if (kind == "prefill" and shape.seq_len >= 8192) else "einsum"
    model = build_model(cfg, window=window, impl=impl)
    rules = serve_rules(cfg, sizes, kind)
    if shape.global_batch < 8:
        rules["batch"] = None  # batch=1 long-context decode: replicate batch
    rules.update(_RULES_PATCH)

    with use_rules(rules):
        pshapes, pspecs = _param_shapes_and_specs(model, cfg)
    params_sds = _attach(pshapes, _spec_tree_to_sharding(pspecs, mesh, rules))

    if kind == "prefill":
        batch_sds = _batch_sds(input_specs(cfg, shape, window=window), rules, mesh)

        def prefill_step(params, batch):
            logits, caches = model.prefill(params, batch)
            return logits[:, -1:], caches

        return prefill_step, (params_sds, batch_sds), rules

    # ---- decode ----
    cshapes = jax.eval_shape(lambda: model.init_caches(shape.global_batch, shape.seq_len))
    if cfg.family == "encdec":
        cax = {
            "self": type(cshapes["self"])(
                ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                ("layers",),
            ),
            "cross": (
                ("layers", "batch", "enc_seq", "kv_heads", "head_dim"),
                ("layers", "batch", "enc_seq", "kv_heads", "head_dim"),
            ),
        }
    else:
        cax = cache_specs(cfg)
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)
    flat_shapes = jax.tree.leaves(cshapes)
    flat_axes = jax.tree.flatten(cax, is_leaf=is_axes_leaf)[0]
    assert len(flat_shapes) == len(flat_axes), (len(flat_shapes), len(flat_axes))
    flat_sds = [
        _sds(s.shape, s.dtype, logical_to_spec(a, rules) if len(a) == len(s.shape) else P(), mesh)
        for s, a in zip(flat_shapes, flat_axes)
    ]
    caches_sds = jax.tree.unflatten(jax.tree.structure(cshapes), flat_sds)
    tok_sds = _sds((shape.global_batch, 1), jnp.int32, P(rules.get("batch"), None), mesh)

    def decode_step(params, tokens, caches):
        return model.decode(params, tokens, caches)

    return decode_step, (params_sds, tok_sds, caches_sds), rules


# -------------------------------------------------------------------- run --


def run_one(
    arch: str, shape_name: str, mesh_kind: str, out_dir: str, skip_existing: bool = True,
    overrides: Dict = None, tag: str = "", rules_patch: Dict = None,
) -> Dict:
    suffix = f"__{tag}" if tag else ""
    outfile = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if skip_existing and os.path.exists(outfile):
        with open(outfile) as f:
            rec = json.load(f)
            if rec.get("status") == "ok" or rec.get("status") == "skipped":
                return rec
    import dataclasses as _dc

    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    if rules_patch:
        global _RULES_PATCH
        _RULES_PATCH = dict(rules_patch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
           "overrides": overrides or {}, "rules_patch": rules_patch or {}, "tag": tag}
    if (arch, shape_name) in SKIPS:
        rec.update(status="skipped", reason=SKIPS[(arch, shape_name)])
        _write(outfile, rec)
        return rec
    t0 = time.time()
    try:
        override = os.environ.get("REPRO_DRYRUN_MESH")  # e.g. "4x2" or "2x2x2" (tests)
        if override:
            dims = tuple(int(x) for x in override.split("x"))
            axes = ("pod", "data", "model")[-len(dims):]
            from repro.launch.mesh import make_mesh

            mesh = make_mesh(dims, axes)
        else:
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        with mesh:
            if shape.kind == "train":
                fn, args, rules = build_train_program(cfg, shape, mesh)
            else:
                fn, args, rules = build_serve_program(cfg, shape, mesh)
            with use_rules(rules):
                lowered = jax.jit(fn).lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jaxlib returns [dict] per computation
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_chips = mesh.devices.size

        # corrected (scan-trip-count-aware) per-device metrics
        from repro.launch.metrics import corrected_metrics

        corr = corrected_metrics(
            cfg,
            shape,
            mesh,
            lambda c, s, m: build_train_program(c, s, m, n_micro_override=1),
            build_serve_program,
        )
        flops = corr["per_device_flops"]
        bytes_acc = corr["per_device_bytes"]
        coll_total = corr["per_device_coll"]
        terms = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_total / LINK_BW,
        }
        terms["bottleneck"] = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
        ).replace("_s", "")
        n_active = cfg.n_active_params()
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
        mem_fields = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes", "generated_code_size_in_bytes"):
            if hasattr(mem, k):
                mem_fields[k] = int(getattr(mem, k))
        rec.update(
            mesh_shape=list(mesh.devices.shape),
            n_chips=n_chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=mem_fields,
            cost_raw_scanbody={k: float(v) for k, v in cost.items() if isinstance(v, (int, float))},
            collectives_raw_scanbody=coll,
            corrected=corr,
            ops=count_ops(hlo),
            roofline=terms,
            model_flops=model_flops,
            hlo_flops_per_dev=flops,
            useful_flops_ratio=(model_flops / (flops * n_chips)) if flops else None,
            per_device_hbm_gb=round(
                sum(mem_fields.get(k, 0) for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes"))
                / 1e9,
                3,
            ),
        )
        print(mem)  # memory_analysis: proves it fits
    except Exception as e:  # noqa: BLE001
        rec.update(status="fail", error=f"{type(e).__name__}: {e}", traceback=traceback.format_exc()[-4000:])
    rec["elapsed_s"] = round(time.time() - t0, 1)
    globals()["_RULES_PATCH"] = {}
    _write(outfile, rec)
    return rec


def _write(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_one(arch, shape, mk, args.out, skip_existing=not args.no_skip_existing)
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"compute {r['compute_s']:.3e}s mem {r['memory_s']:.3e}s coll {r['collective_s']:.3e}s"
                        f" | {r['bottleneck']} | hbm/dev {rec['per_device_hbm_gb']}GB | compile {rec.get('compile_s', '?')}s"
                    )
                elif status == "fail":
                    extra = rec["error"][:200]
                else:
                    extra = rec.get("reason", "")[:80]
                print(f"[{status:7s}] {arch:22s} {shape:12s} {mk:6s} {extra}", flush=True)


if __name__ == "__main__":
    main()
