"""Corrected roofline metrics (DESIGN.md §6, EXPERIMENTS.md §Roofline).

``compiled.cost_analysis()`` counts each ``while`` (scan) body ONCE, so the
scan-over-layers proof programs undercount FLOPs/bytes/collectives by ~L.
This module compiles small *unrolled* variants of the same program at full
width (1-3 layers, ``scan_layers=False``) and extrapolates:

    total(kind) = m(V0) + sum_kind (n_full(kind) - n_V0(kind)) * delta(kind)

where ``delta(kind)`` is the exact marginal cost of one layer of that kind,
measured as the difference between two variants.  Chunked-attention prefill
(inner scans, again counted once) is handled analytically: the quadratic
attention FLOPs and flash-style bytes are added in closed form and the
(negligible, counted-once) scanned contribution is left in place.

Memory numbers are NOT extrapolated — the peak comes from the real scanned
program's ``memory_analysis()``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax

from repro.configs.base import InputShape, ModelConfig
from repro.launch.hlo import collective_bytes

__all__ = ["corrected_metrics", "attention_analytic"]


def _measure(cfg, shape, mesh, build_train, build_serve) -> Dict[str, float]:
    """Compile one variant and return per-device flops/bytes/collective bytes."""
    with mesh:
        if shape.kind == "train":
            fn, args, rules = build_train(cfg, shape, mesh)
        else:
            fn, args, rules = build_serve(cfg, shape, mesh)
        from repro.models.sharding import use_rules

        with use_rules(rules):
            compiled = jax.jit(fn).lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jaxlib returns [dict] per computation
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0.0)),
    }


def _variant(cfg: ModelConfig, **kw) -> ModelConfig:
    base = dict(scan_layers=False)  # mtp head kept: it is part of every variant's fixed cost
    base.update(kw)
    return dataclasses.replace(cfg, **base)


def _plan(cfg: ModelConfig):
    """Variant plan: list of (name, variant_cfg) + composition weights."""
    fam = cfg.family
    if fam == "moe" and cfg.n_dense_layers:
        a = _variant(cfg, n_layers=2, n_dense_layers=1)  # 1 dense + 1 moe
        b = _variant(cfg, n_layers=3, n_dense_layers=1)  # 1 dense + 2 moe
        c = _variant(cfg, n_layers=3, n_dense_layers=2)  # 2 dense + 1 moe
        # a = E + 1*dense + 1*moe ; b adds one moe ; c adds one dense:
        # total = a + (n_moe-1)*(b-a) + (n_dense-1)*(c-a)
        return {
            "variants": {"a": a, "b": b, "c": c},
            "compose": lambda m: {
                k: m["a"][k]
                + (cfg.n_layers - cfg.n_dense_layers - 1) * (m["b"][k] - m["a"][k])
                + (cfg.n_dense_layers - 1) * (m["c"][k] - m["a"][k])
                for k in ("flops", "bytes", "coll")
            },
        }
    if fam == "moe":
        a = _variant(cfg, n_layers=1)
        b = _variant(cfg, n_layers=2)
        return _two_point(cfg, a, b)
    if fam == "hybrid" and cfg.hybrid_attn_every:
        a = _variant(cfg, n_layers=1, hybrid_attn_every=0)
        b = _variant(cfg, n_layers=2, hybrid_attn_every=0)
        c = _variant(cfg, n_layers=1, hybrid_attn_every=1)  # 1 ssm + 1 shared site
        n_sites = cfg.n_layers // cfg.hybrid_attn_every
        return {
            "variants": {"a": a, "b": b, "c": c},
            "compose": lambda m: {
                k: m["a"][k]
                + (cfg.n_layers - 1) * (m["b"][k] - m["a"][k])  # ssm layers
                + n_sites * (m["c"][k] - m["a"][k])  # shared-attn sites
                for k in ("flops", "bytes", "coll")
            },
        }
    if fam == "encdec":
        a = _variant(cfg, n_layers=1, n_enc_layers=1)
        b = _variant(cfg, n_layers=2, n_enc_layers=1)
        c = _variant(cfg, n_layers=1, n_enc_layers=2)
        return {
            "variants": {"a": a, "b": b, "c": c},
            "compose": lambda m: {
                k: m["a"][k]
                + (cfg.n_layers - 1) * (m["b"][k] - m["a"][k])
                + (cfg.n_enc_layers - 1) * (m["c"][k] - m["a"][k])
                for k in ("flops", "bytes", "coll")
            },
        }
    # dense / vlm / ssm
    a = _variant(cfg, n_layers=1)
    b = _variant(cfg, n_layers=2)
    return _two_point(cfg, a, b)


def _two_point(cfg, a, b):
    return {
        "variants": {"a": a, "b": b},
        "compose": lambda m: {
            k: m["a"][k] + (cfg.n_layers - 1) * (m["b"][k] - m["a"][k]) for k in ("flops", "bytes", "coll")
        },
    }


def attention_analytic(cfg: ModelConfig, shape: InputShape, n_chips: int, window: int = 0) -> Dict[str, float]:
    """Closed-form quadratic-attention FLOPs + flash-style bytes per device
    (used for chunked prefill where the inner scans defeat cost_analysis)."""
    B, S = shape.global_batch, shape.seq_len
    W = min(window, S) if window else S
    if cfg.family == "ssm":
        return {"flops": 0.0, "bytes": 0.0}
    hd = cfg.resolved_head_dim
    if cfg.attn == "mla":
        H = cfg.n_heads
        dqk = cfg.kv_lora_rank + cfg.qk_rope_head_dim  # absorbed scores
        dv = cfg.kv_lora_rank
        per_layer = 2.0 * B * S * (W / 2 if not window else W) * H * (dqk + dv)
        n_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        H = cfg.n_heads
        per_layer = 2.0 * B * S * (W / 2 if not window else W) * H * (2 * hd)
        n_attn = cfg.n_layers // cfg.hybrid_attn_every if cfg.hybrid_attn_every else 0
    else:
        H = cfg.n_heads
        per_layer = 2.0 * B * S * (W / 2 if not window else W) * H * (2 * hd)
        n_attn = cfg.n_layers + (cfg.n_enc_layers if cfg.family == "encdec" else 0)
    flops = per_layer * n_attn
    # flash-style HBM traffic: Q read once, K/V streamed once per q-pass
    kv_dim = cfg.n_kv_heads * hd if cfg.attn != "mla" else (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
    bytes_ = n_attn * B * S * (2 * H * hd + 2 * kv_dim) * 2.0
    return {"flops": flops / n_chips, "bytes": bytes_ / n_chips}


def corrected_metrics(cfg, shape, mesh, build_train, build_serve) -> Dict:
    plan = _plan(cfg)
    measured = {name: _measure(v, shape, mesh, build_train, build_serve) for name, v in plan["variants"].items()}
    total = plan["compose"](measured)
    out = {"per_device_" + k: v for k, v in total.items()}
    if shape.kind == "prefill" and shape.seq_len >= 8192:
        extra = attention_analytic(cfg, shape, mesh.devices.size)
        out["per_device_flops"] += extra["flops"]
        out["per_device_bytes"] += extra["bytes"]
        out["attn_analytic"] = extra
    out["variants_raw"] = measured
    return out
