"""Selection-as-a-service driver: request queue -> batched engine step ->
per-job cohort responses.

Each FL job posts a *tick* request carrying last round's success-bit feedback;
the server drains up to J requests from the queue, packs them into one
``MultiJobEngine`` dispatch (a single compiled vmap over jobs), and answers
every request with its cohort (selected client ids + the allocation used).
Volatile clients are simulated per job with the paper's Bernoulli classes, or
— with ``--scenario <name>`` — replayed from a bit-packed trace of any
``repro.scenarios`` regime (diurnal, regional_outage, flash_crowd, ...),
recorded per job and unpacked row-by-row at enqueue time.

Reports throughput (ticks/s and client-decisions/s) and per-request latency
percentiles.  Runs genuinely on this CPU box:

    python -m repro.launch.select_serve --jobs 8 --clients 4096 --rounds 30
    python -m repro.launch.select_serve --smoke
"""
from __future__ import annotations

import argparse
import collections
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.volatility import paper_success_rates
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs

__all__ = ["run_service", "main"]


def run_service(
    J: int = 8,
    K_max: int = 4096,
    rounds: int = 30,
    seed: int = 0,
    n_iters: int = 48,
    tile: int = 8192,
    scenario: str | None = None,
):
    """Simulate the service loop; returns the throughput/latency report."""
    rng = np.random.default_rng(seed)
    # heterogeneous fleet: population, cohort, fairness and learning rate vary
    Ks = [int(K_max // (2 ** (j % 3))) for j in range(J)]
    ks = [max(4, Kj // 50) for Kj in Ks]
    fracs = [float(rng.choice([0.0, 0.5, 0.8])) for _ in range(J)]
    etas = [float(rng.choice([0.3, 0.5])) for _ in range(J)]
    cfg, k_max = pack_jobs(Ks, ks, fracs, etas, K_max=K_max)
    _, batched_step = make_multi_job(k_max, n_iters=n_iters, tile=tile)
    state = multi_job_init(cfg)

    rhos = np.stack([np.pad(paper_success_rates(Kj), (0, K_max - Kj)) for Kj in Ks])
    base_keys = jax.random.split(jax.random.PRNGKey(seed), J)

    # request queue: (enqueue_time, job_id, feedback bits)
    queue: collections.deque = collections.deque()
    latencies, n_ticks = [], 0
    if scenario is None:
        xs_host = (rng.random((rounds, J, K_max)) < rhos[None]).astype(np.float32)

        def feedback(t, j):
            return xs_host[t, j]

    else:
        from repro.scenarios import make_scenario, record_trace, unpack_trace

        # one bit-packed trace per job (jobs get distinct seeds); rows are
        # expanded only at enqueue time, the dense (rounds, J, K_max) trace
        # never exists
        traces = [
            record_trace(make_scenario(scenario, Kj, rounds, seed=seed + j)[0], rounds, seed=seed + j, chunk=min(64, rounds))
            for j, Kj in enumerate(Ks)
        ]

        def feedback(t, j):
            return np.pad(unpack_trace(traces[j][t], Ks[j]), (0, K_max - Ks[j]))

    # warm-up dispatch (compile once, off the clock)
    keys0 = jax.vmap(lambda kk: jax.random.fold_in(kk, rounds))(base_keys)
    xs0 = jnp.asarray(np.stack([feedback(0, j) for j in range(J)]))
    jax.block_until_ready(batched_step(cfg, state, keys0, xs0)[0].logw)

    t_start = time.perf_counter()
    n_decisions = 0
    for t in range(rounds):
        for j in range(J):
            queue.append((time.perf_counter(), j, feedback(t, j)))
        # drain one full batch of J requests into a single engine dispatch
        batch = [queue.popleft() for _ in range(min(J, len(queue)))]
        keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
        xs = jnp.asarray(np.stack([b[2] for b in batch]))
        state, out = batched_step(cfg, state, keys, xs)
        jax.block_until_ready(out["idx"])
        t_done = time.perf_counter()
        cohorts = np.asarray(out["idx"])  # (J, k_max), -1 padded
        for (t_enq, j, _), cohort in zip(batch, cohorts):
            latencies.append(t_done - t_enq)
            n_ticks += 1
            n_decisions += Ks[j]  # one accept/reject decision per live client
            assert (cohort >= 0).sum() == ks[j], (j, cohort)
    elapsed = time.perf_counter() - t_start

    lat = np.asarray(latencies) * 1e3
    report = {
        "jobs": J,
        "K_max": K_max,
        "rounds": rounds,
        "scenario": scenario or "paper_iid(static)",
        "ticks": n_ticks,
        "ticks_per_s": round(n_ticks / elapsed, 1),
        "client_decisions_per_s": round(n_decisions / elapsed, 1),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)), 3),
            "p95": round(float(np.percentile(lat, 95)), 3),
            "max": round(float(lat.max()), 3),
        },
        "cohort_sizes": ks,
        "populations": Ks,
    }
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4096, help="K_max: largest job population")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", type=str, default=None, help="repro.scenarios name to replay as feedback")
    ap.add_argument("--smoke", action="store_true", help="tiny CPU-friendly run")
    args = ap.parse_args()
    if args.smoke:
        args.jobs, args.clients, args.rounds = 4, 512, 10
    report = run_service(J=args.jobs, K_max=args.clients, rounds=args.rounds, seed=args.seed, scenario=args.scenario)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
