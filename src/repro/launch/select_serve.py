"""Selection-as-a-service driver: request queue -> batched engine step ->
per-job cohort responses.

Each FL job posts a *tick* request carrying last round's success-bit feedback;
the server drains up to J requests from the queue, packs them into one
``MultiJobEngine`` dispatch (a single compiled vmap over jobs), and answers
every request with its cohort (selected client ids + the allocation used).
Volatile clients are simulated per job with the paper's Bernoulli classes, or
— with ``--scenario <name>`` — replayed from a bit-packed trace of any
``repro.scenarios`` regime (diurnal, regional_outage, flash_crowd, ...),
recorded per job and unpacked row-by-row at enqueue time.

Reports throughput (ticks/s and client-decisions/s) and per-request latency
percentiles.  Runs genuinely on this CPU box:

    python -m repro.launch.select_serve --jobs 8 --clients 4096 --rounds 30
    python -m repro.launch.select_serve --smoke

``--async`` switches to the *compiled steady-state* path
(``run_service_compiled``): the whole serving horizon folds into one
``jax.lax.scan`` over ticks — no host round-trip per tick, engine state
donated — with overlapping in-flight rounds: each job's round outcome is a
completion-lag draw, and late-but-alive cohorts are credited ``alpha**lag``
from a bounded ``(J, S, K)`` staleness ring instead of being dropped while
the engine keeps issuing the next cohorts.  ``--staleness 0`` gives the
compiled synchronous loop (the ROADMAP "compiled service loop" item on its
own).  ``--mesh D`` serves one fleet-scale job with the **K axis sharded
over a D-device mesh** (``run_service_sharded``: the
``repro.engine.round_program`` round compiled over the horizon via
``RoundProgram.from_config`` — per-device state and flops divide by D; on a
CPU host force devices first with
``XLA_FLAGS=--xla_force_host_platform_device_count=D``).  ``--mesh D
--async`` composes the two: sharded **async** serving, the ``(S, K/D)``
staleness ring riding inside the compiled sharded loop.  Reports are
written to ``results/bench/BENCH_select_serve*.json`` so CI uploads them
with the benchmark artifacts.
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.volatility import BernoulliVolatility, BinaryLag, CompletionLag, paper_success_rates
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs
from repro.engine.round_program import staleness_ring_step
from repro.obs import ROUND_TAPS, Reporter, SketchSpec, SpanTimer

__all__ = ["run_service", "run_service_compiled", "run_service_sharded", "run_server", "main"]


def run_service(
    J: int = 8,
    K_max: int = 4096,
    rounds: int = 30,
    seed: int = 0,
    n_iters: int = 48,
    tile: int = 8192,
    scenario: str | None = None,
    reporter: Reporter | None = None,
):
    """Simulate the service loop; returns the throughput/latency report.

    Request latency is accumulated in a bucketed ``LatencyHistogram`` via a
    ``SpanTimer`` (O(n_buckets) memory — nothing is stored per request); the
    report's p50/p95/p99 come from the histogram, and with a ``reporter``
    the full bucket counts land in the JSONL run log too.
    """
    rng = np.random.default_rng(seed)
    # heterogeneous fleet: population, cohort, fairness and learning rate vary
    Ks, ks, fracs, etas = _heterogeneous_fleet(J, K_max, rng)
    cfg, k_max = pack_jobs(Ks, ks, fracs, etas, K_max=K_max)
    _, batched_step = make_multi_job(k_max, n_iters=n_iters, tile=tile)
    state = multi_job_init(cfg)

    rhos = np.stack([np.pad(paper_success_rates(Kj), (0, K_max - Kj)) for Kj in Ks])
    base_keys = jax.random.split(jax.random.PRNGKey(seed), J)

    # request queue: (enqueue_time, job_id, feedback bits)
    queue: collections.deque = collections.deque()
    spans = SpanTimer(lo=1e-6, hi=60.0)
    request_hist = spans.get("request")
    n_ticks = 0
    if scenario is None:
        xs_host = (rng.random((rounds, J, K_max)) < rhos[None]).astype(np.float32)

        def feedback(t, j):
            return xs_host[t, j]

    else:
        from repro.scenarios import make_scenario, record_trace, unpack_trace

        # one bit-packed trace per job (jobs get distinct seeds); rows are
        # expanded only at enqueue time, the dense (rounds, J, K_max) trace
        # never exists
        traces = [
            record_trace(make_scenario(scenario, Kj, rounds, seed=seed + j)[0], rounds, seed=seed + j, chunk=min(64, rounds))
            for j, Kj in enumerate(Ks)
        ]

        def feedback(t, j):
            return np.pad(unpack_trace(traces[j][t], Ks[j]), (0, K_max - Ks[j]))

    # warm-up dispatch (compile once, off the clock)
    keys0 = jax.vmap(lambda kk: jax.random.fold_in(kk, rounds))(base_keys)
    xs0 = jnp.asarray(np.stack([feedback(0, j) for j in range(J)]))
    jax.block_until_ready(batched_step(cfg, state, keys0, xs0)[0].logw)

    t_start = time.perf_counter()
    n_decisions = 0
    for t in range(rounds):
        for j in range(J):
            queue.append((time.perf_counter(), j, feedback(t, j)))
        # drain one full batch of J requests into a single engine dispatch
        batch = [queue.popleft() for _ in range(min(J, len(queue)))]
        keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
        xs = jnp.asarray(np.stack([b[2] for b in batch]))
        with spans.span("dispatch", annotate=True):
            state, out = batched_step(cfg, state, keys, xs)
            jax.block_until_ready(out["idx"])
        t_done = time.perf_counter()
        cohorts = np.asarray(out["idx"])  # (J, k_max), -1 padded
        for (t_enq, j, _), cohort in zip(batch, cohorts):
            request_hist.observe(t_done - t_enq)
            n_ticks += 1
            n_decisions += Ks[j]  # one accept/reject decision per live client
            assert (cohort >= 0).sum() == ks[j], (j, cohort)
    elapsed = time.perf_counter() - t_start

    report = {
        "jobs": J,
        "K_max": K_max,
        "rounds": rounds,
        "scenario": scenario or "paper_iid(static)",
        "ticks": n_ticks,
        "ticks_per_s": round(n_ticks / elapsed, 1),
        "client_decisions_per_s": round(n_decisions / elapsed, 1),
        "latency_ms": {
            "p50": round(request_hist.quantile(0.50) * 1e3, 3),
            "p95": round(request_hist.quantile(0.95) * 1e3, 3),
            "p99": round(request_hist.quantile(0.99) * 1e3, 3),
            "max": round(request_hist.max * 1e3, 3),
        },
        "cohort_sizes": ks,
        "populations": Ks,
    }
    if reporter is not None:
        reporter.histogram("request_latency", request_hist)
        reporter.histogram("dispatch_latency", spans.get("dispatch"))
    return report


def _heterogeneous_fleet(J: int, K_max: int, rng):
    """The service's standard heterogeneous job mix (shared by both paths)."""
    Ks = [int(K_max // (2 ** (j % 3))) for j in range(J)]
    ks = [max(4, Kj // 50) for Kj in Ks]
    fracs = [float(rng.choice([0.0, 0.5, 0.8])) for _ in range(J)]
    etas = [float(rng.choice([0.3, 0.5])) for _ in range(J)]
    return Ks, ks, fracs, etas


def run_service_compiled(
    J: int = 8,
    K_max: int = 4096,
    rounds: int = 30,
    seed: int = 0,
    staleness: int = 2,
    alpha: float = 0.5,
    p_late: float = 0.7,
    lag_decay: float = 0.5,
    n_iters: int = 48,
    tile: int = 8192,
    reps: int = 3,
    reporter: Reporter | None = None,
):
    """Compiled steady-state serving: the whole horizon in ONE ``lax.scan``.

    Per tick, inside the compiled program: a batched multi-job engine dispatch
    issues every job's next cohort, a completion-lag model decides which
    selected clients return on time / late / never, on-time bits feed the
    E3CS update, and a ``(J, S, K_max)`` staleness ring credits late arrivals
    ``alpha**lag`` ticks later — rounds overlap in flight instead of the
    service blocking on stragglers.  Engine state and the ring are donated,
    so steady-state serving runs allocation-free across ticks.

    ``staleness=0`` is the compiled *synchronous* loop (same drop semantics
    as ``run_service``, no ring in the program).  Returns the throughput
    report; per-request latency percentiles don't exist here (there is no
    host queue) — the per-tick cost is the latency.
    """
    S = int(staleness)
    rng = np.random.default_rng(seed)
    Ks, ks, fracs, etas = _heterogeneous_fleet(J, K_max, rng)
    cfg, k_max = pack_jobs(Ks, ks, fracs, etas, K_max=K_max)
    _, batched_step = make_multi_job(k_max, n_iters=n_iters, tile=tile)

    rhos = jnp.asarray(np.stack([np.pad(paper_success_rates(Kj), (0, K_max - Kj)) for Kj in Ks]))
    base = BernoulliVolatility(rhos)  # (J, K_max) marginals, one draw serves the fleet tick
    lag_model = (
        CompletionLag(base, p_late=p_late, lag_decay=lag_decay, max_lag=max(S, 1)) if S else BinaryLag(base)
    )
    base_keys = jax.random.split(jax.random.PRNGKey(seed), J)

    def tick(carry, t):
        state, pending, vs, key = carry
        key, k_vol = jax.random.split(key)
        keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
        lag, vs = lag_model.sample(k_vol, vs)  # (J, K_max) int32
        x = (lag == 0).astype(jnp.float32)
        state, out = batched_step(cfg, state, keys, x)
        mask = out["mask"]
        arriving, pending = staleness_ring_step(pending, mask, lag, S, alpha)
        stale = jnp.sum(arriving, axis=1)
        on_time = jnp.sum(mask * x, axis=1)
        return (state, pending, vs, key), (on_time, stale)

    ts = jnp.arange(rounds, dtype=jnp.int32)

    def _run(state, pending, vs, key):
        (state, pending, _, _), (on_time, stale) = jax.lax.scan(tick, (state, pending, vs, key), ts)
        return state, pending, on_time, stale

    # engine state + staleness ring donated: steady-state serving reuses their
    # buffers instead of reallocating (J, S, K_max) every horizon
    run = jax.jit(_run, donate_argnums=(0, 1))

    def fresh():
        return (
            multi_job_init(cfg),
            jnp.zeros((J, S, K_max), jnp.float32),
            lag_model.init_state(),
            jax.random.PRNGKey(seed + 1),
        )

    jax.block_until_ready(run(*fresh())[0].logw)  # compile off the clock
    elapsed = []
    for _ in range(reps):
        args = fresh()
        jax.block_until_ready(args[0].logw)
        t0 = time.perf_counter()
        state, pending, on_time, stale = run(*args)
        jax.block_until_ready(state.logw)
        elapsed.append(time.perf_counter() - t0)
    best = min(elapsed)
    n_decisions = rounds * sum(Ks)
    if reporter is not None:
        # per-tick fleet-wide credit series (summed over the J jobs) ->
        # the windowed stream CI diffs per PR
        reporter.metrics_stream(
            "serve_async",
            {"on_time": np.asarray(on_time).sum(1), "stale": np.asarray(stale).sum(1)},
            window=max(1, rounds // 10),
            better={"on_time": "higher", "stale": "none"},
        )
        # detector pass over the credit series: an on-time collapse mid-serve
        # lands as an ``alert`` event in this run's JSONL log
        reporter.alerts(series={"on_time": np.asarray(on_time).sum(1)})
    return {
        "mode": "compiled_async" if S else "compiled_sync",
        "jobs": J,
        "K_max": K_max,
        "rounds": rounds,
        "staleness": S,
        "alpha": alpha,
        "ticks": rounds * J,
        "ticks_per_s": round(rounds * J / best, 1),
        "client_decisions_per_s": round(n_decisions / best, 1),
        "tick_us": round(best / (rounds * J) * 1e6, 1),  # per job-tick, = 1e6/ticks_per_s
        "scan_step_us": round(best / rounds * 1e6, 1),  # per compiled step (all J jobs)
        "on_time_total": float(np.asarray(on_time).sum()),
        "stale_credit_total": float(np.asarray(stale).sum()),
        "cohort_sizes": ks,
        "populations": Ks,
    }


def run_service_sharded(
    K: int = 1_000_000,
    rounds: int = 50,
    D: int | None = None,
    k: int | None = None,
    seed: int = 0,
    block: int = 4,
    reps: int = 3,
    staleness: int = 0,
    alpha: float = 0.5,
    fused: bool = False,
    reporter: Reporter | None = None,
):
    """Compiled steady-state serving of ONE fleet-scale job with the K axis
    sharded over a device mesh (``--mesh D``).

    Stands the mesh up via ``repro.launch.mesh.make_host_mesh`` (CI forces 8
    CPU devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
    and folds the whole serving horizon into one ``lax.scan`` of the sharded
    round: per-client state, allocation and volatility draw live as ``(K/D,)``
    shards, cross-device traffic is one scalar ``psum`` per bisection block
    plus the ``(D·k,)`` top-k candidate gather.  Per-device memory and
    per-device flops both divide by D, which is what lets the serving loop
    hold populations the single-device path cannot.

    ``staleness=S > 0`` serves *async* rounds: outcomes are completion-lag
    draws and the ``(S, K/D)``-sharded pending-credit ring credits
    late-but-alive cohorts ``alpha**lag`` — the sharded-async composition
    that falls out of ``RoundProgram`` (the config is resolved by the same
    ``RoundProgram.from_config`` the training server uses).

    ``fused=True`` serves through the fused round path
    (``repro.kernels.round_fused``): allocation epilogue / perturb / top-k in
    one dispatch and the observe/update/credit tail in another — bit-identical
    selections, fewer passes over the ``(K/D,)`` shards.
    """
    from repro.configs.base import FLConfig
    from repro.engine.round_program import RoundProgram
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(D)
    D = mesh.devices.size
    k = k or max(8, K // 1000)
    S = int(staleness)
    fl = FLConfig(
        K=K, k=k, rounds=rounds, scheme="e3cs", quota_frac=0.5, allocator="bisect",
        volatility="bernoulli", staleness_rounds=S, staleness_alpha=alpha,
    )
    program = RoundProgram.from_config(fl, mesh=mesh, block=block, fused=fused)
    # serve with the in-scan taps AND sketch stages on: the same compiled
    # horizon that answers requests emits the ROUND_TAPS telemetry stream
    # plus the psum-merged client-axis sketch stream (fairness telemetry)
    sk_spec = SketchSpec(window=max(1, rounds // 5), n_regions=4)
    run, state0 = program.build_runner(outputs="lean", taps=True, sketch=sk_spec)
    key = jax.random.PRNGKey(seed)
    xs = jnp.zeros((rounds, 0), jnp.float32)
    jax.block_until_ready(run(state0, key, xs)[0].sel_counts)  # compile off the clock
    elapsed = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run(state0, key, xs)
        jax.block_until_ready(out[0].sel_counts)
        elapsed.append(time.perf_counter() - t0)
    best = min(elapsed)
    taps = out[-1]
    report = {
        "mode": "compiled_sharded_async" if S else "compiled_sharded",
        "mesh_devices": int(D),
        "K": K,
        "k": k,
        "rounds": rounds,
        "bisect_block": block,
        "fused": bool(fused),
        "rounds_per_s": round(rounds / best, 2),
        "client_decisions_per_s": round(rounds * K / best, 1),
        "round_us": round(best / rounds * 1e6, 1),
        "per_device_state_mb": round(4.0 * K / D / 1e6, 2),  # one (K/D,) float32 vector
        "tap_counters": {n: float(v) for n, v in taps["counters"].items()},
    }
    if S:
        state, on_time, stale, _, _ = out
        report.update({
            "staleness": S,
            "alpha": alpha,
            "on_time_total": float(np.asarray(on_time).sum()),
            "stale_credit_total": float(np.asarray(stale).sum()),
        })
    else:
        report["successes_total"] = float(np.asarray(out[1]).sum())
    if reporter is not None:
        reporter.metrics_stream(
            "serve_sharded",
            {n: np.asarray(v) for n, v in taps["series"].items()},
            window=max(1, rounds // 10),
            better=ROUND_TAPS.directions(),
        )
        # client-axis fairness telemetry + the detector pass: starvation /
        # outage / drift land as ``alert`` events in the serving run log
        fair = reporter.fairness_stream("fairness", taps["sketches"])
        reporter.alerts(
            series={n: np.asarray(v) for n, v in taps["series"].items()},
            fairness=fair,
            expected_selected=k,
        )
    return report


def run_server(args, reporter: Reporter):
    """``--serve``: stand up the real socket front end (``repro.serve``)
    instead of a self-driving loop.

    ``--mesh D`` serves K-sharded ``RoundProgram`` jobs (``ShardedEngine``);
    otherwise the vmapped multi-tenant ``SlotEngine`` handles up to the
    bucket-ladder top in jobs.  Under ``--smoke`` a built-in loopback client
    admits ``--jobs`` tenants, drives ``--rounds`` rounds each and shuts the
    server down — the CI-runnable end-to-end path; without it the server
    runs until interrupted (clients speak ``repro.serve.protocol`` /
    ``docs/serving.md``).

    ``--chaos SEED`` arms a seeded ``FaultPlan`` (engine crashes,
    checkpoint corruption, dropped connections, slow dispatches) against
    the server; the built-in smoke client drives round-tagged ticks with
    retries and rewinds on ``round_desync``, so the horizon completes
    through the injected faults — the CI chaos smoke.
    """
    import tempfile

    from repro.serve import FaultPlan, SelectionServer, ServeClient, ServeError, ShardedEngine, SlotEngine

    S = args.staleness if args.async_mode else 0
    K_max = args.clients or (512 if args.smoke else 4096)
    if args.mesh is not None:
        engine = ShardedEngine(D=args.mesh, staleness=S, alpha=args.alpha)
    else:
        engine = SlotEngine(K_max=K_max, staleness=S, alpha=args.alpha)
    plan = None
    tmp_ckpt = None
    ckpt_dir, ckpt_every = args.ckpt_dir, args.ckpt_every
    if args.chaos is not None:
        plan = FaultPlan.sample(
            args.chaos, n_steps=args.jobs * args.rounds,
            crashes=1, corruptions=1, drops=2, slow=1, slow_s=0.005,
            first_step=args.jobs + 2,
        )
        # recovery needs restore points: default a checkpoint cadence + dir
        if ckpt_dir is None:
            ckpt_dir = tmp_ckpt = tempfile.mkdtemp(prefix="serve_chaos_")
        ckpt_every = ckpt_every or max(2, args.rounds // 4)
    srv = SelectionServer(
        engine, port=args.port, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        ckpt_keep=4 if plan else 0, faults=plan,
        restart_backoff=0.01 if plan else 0.05,
    )
    srv.start()
    host, port = srv.address
    print(f"serving {engine.kind} engine (S={S}) on {host}:{port}"
          + (f" under chaos seed {args.chaos}" if plan else ""), flush=True)
    try:
        if args.smoke:
            rng = np.random.default_rng(args.seed)
            K = min(K_max, 256)
            with ServeClient.connect(srv.address, retries=8, seed=args.seed) as c:
                jobs = [c.admit(K=K, k=max(1, K // 16), seed=args.seed + j) for j in range(args.jobs)]
                cursors = {j: 0 for j in jobs}
                while any(t < args.rounds for t in cursors.values()):
                    for j in jobs:
                        t = cursors[j]
                        if t >= args.rounds:
                            continue
                        if S:
                            lag = rng.integers(0, S + 2, K)
                            feed = dict(lags=np.where(lag > S, -1, lag))
                        else:
                            feed = dict(bits=rng.random(K) < 0.7)
                        try:
                            out = c.tick(j, round=t, **feed)
                        except ServeError as e:
                            if e.code == "round_desync":
                                # recovery rolled the job back: replay from there
                                cursors[j] = int(e.response["expected"])
                                continue
                            raise
                        cursors[j] = out["round"] + 1
        else:
            while True:
                time.sleep(1.0)
    except KeyboardInterrupt:
        print("interrupt: draining", flush=True)
    finally:
        srv.close()
        srv.attach_report(reporter)
        if tmp_ckpt is not None:
            import shutil

            shutil.rmtree(tmp_ckpt, ignore_errors=True)
    report = {"address": f"{host}:{port}", "engine": engine.kind, "staleness": S}
    if plan is not None:
        fired = plan.fired()
        assert srv.stats["ticks"] >= args.jobs * args.rounds
        report.update(
            chaos_seed=args.chaos, fired=fired, restarts=srv.stats["restarts"],
            recovery_s_total=float(sum(srv.recoveries)), replayed=srv.stats["replayed"],
        )
        print(f"chaos survived: fired={fired} restarts={srv.stats['restarts']}", flush=True)
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=8)
    ap.add_argument("--clients", type=int, default=None,
                    help="K_max: largest job population (default 4096, or 512 under --smoke; "
                         "with --mesh: 1,000,000, or 65,536 under --smoke)")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", type=str, default=None, help="repro.scenarios name to replay as feedback")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="compiled lax.scan steady-state path with overlapping in-flight rounds")
    ap.add_argument("--staleness", type=int, default=2,
                    help="async buffer depth S (with --async, alone or combined with --mesh; 0 = compiled sync)")
    ap.add_argument("--alpha", type=float, default=0.5, help="staleness decay per round of lag")
    ap.add_argument("--fused", action="store_true",
                    help="with --mesh: serve through the fused round kernel path "
                         "(repro.kernels.round_fused) — bit-identical selections, "
                         "fewer passes over the per-device shards")
    ap.add_argument("--mesh", type=int, default=None, metavar="D",
                    help="serve one K-sharded job over a D-device mesh (forced CPU devices: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=D)")
    ap.add_argument("--serve", action="store_true",
                    help="stand up the real socket front end (repro.serve) instead of a "
                         "self-driving loop; combine with --mesh for K-sharded jobs, --async "
                         "for staleness-ring serving, --smoke for a loopback-driven CI run")
    ap.add_argument("--port", type=int, default=0, help="--serve listen port (0 = ephemeral)")
    ap.add_argument("--ckpt-dir", type=str, default=None,
                    help="--serve: checkpoint directory for elastic restart")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="--serve: checkpoint every N served rounds (0 = only on drain)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="--serve: arm a seeded FaultPlan (engine crashes, checkpoint "
                         "corruption, dropped connections, slow dispatches) and prove the "
                         "horizon completes through it")
    ap.add_argument("--smoke", action="store_true", help="tiny CPU-friendly run")
    args = ap.parse_args()
    if args.smoke:
        args.jobs, args.rounds = 4, 10
    K_max = args.clients or (512 if args.smoke else 4096)
    if args.serve:
        rep = Reporter("serve_front_cli", config=vars(args))
        report = run_server(args, rep)
    elif args.mesh is not None:
        K = args.clients or (65_536 if args.smoke else 1_000_000)
        S = args.staleness if args.async_mode else 0
        rep = Reporter("select_serve_sharded_async" if S else "select_serve_sharded", config=vars(args))
        report = run_service_sharded(
            K=K, rounds=args.rounds, D=args.mesh, seed=args.seed, staleness=S, alpha=args.alpha,
            fused=args.fused, reporter=rep,
        )
    elif args.async_mode:
        rep = Reporter("select_serve_async", config=vars(args))
        report = run_service_compiled(
            J=args.jobs, K_max=K_max, rounds=args.rounds, seed=args.seed,
            staleness=args.staleness, alpha=args.alpha, reporter=rep,
        )
    else:
        rep = Reporter("select_serve", config=vars(args))
        report = run_service(
            J=args.jobs, K_max=K_max, rounds=args.rounds, seed=args.seed, scenario=args.scenario,
            reporter=rep,
        )
    path = rep.save(report)
    with open(path) as f:
        print(f.read())  # the saved artifact IS the CLI output — one emission path


if __name__ == "__main__":
    main()
