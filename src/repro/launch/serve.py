"""Serving driver: batched prefill + decode of a (possibly FL-trained) model.

Runs genuinely on this CPU box for smoke-scale configs and doubles as the
serving-path demonstration for the assigned architectures:

    python -m repro.launch.serve --arch gemma-2b --smoke --batch 4 \
        --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.models.transformer import vlm_positions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced smoke variant (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg, window=args.window)
    rng = jax.random.PRNGKey(args.seed)
    params, _ = model.init(rng)

    B, S = args.batch, args.prompt_len
    tokens = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        P = cfg.n_patches
        batch["patch_embeds"] = jax.random.normal(jax.random.fold_in(rng, 2), (B, P, cfg.d_patch), jnp.float32)
        batch["positions"] = vlm_positions(cfg, B, S + P)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.fold_in(rng, 3), (B, cfg.enc_len, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches = jax.jit(model.prefill)(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    key = jax.random.fold_in(rng, 7)
    for i in range(args.gen):
        logits_i, caches = decode(params, tok, caches)
        key = jax.random.fold_in(key, i)
        if args.temperature > 0:
            tok = jax.random.categorical(key, logits_i[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits_i[:, -1:], -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(outs[-1])
    t_decode = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], 1)
    print(
        json.dumps(
            {
                "arch": cfg.name,
                "prefill_s": round(t_prefill, 3),
                "decode_tok_per_s": round(args.gen * B / t_decode, 2),
                "generated_shape": list(gen.shape),
                "sample_tokens": gen[0, :12].tolist(),
            }
        )
    )


if __name__ == "__main__":
    main()
