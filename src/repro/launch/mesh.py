"""Production meshes (DESIGN.md §6).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh is
(16, 16) = 256 chips with axes (data, model); the multi-pod mesh prepends a
``pod`` axis: (2, 16, 16) = 512 chips.
"""
from __future__ import annotations

from typing import Dict

import jax

__all__ = ["make_production_mesh", "make_mesh", "axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small fake-device meshes, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
