"""Production meshes (DESIGN.md §6).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh is
(16, 16) = 256 chips with axes (data, model); the multi-pod mesh prepends a
``pod`` axis: (2, 16, 16) = 512 chips.
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "make_host_mesh", "axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small fake-device meshes, e.g. (2, 4))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(D: int | None = None, axis_name: str = "shards"):
    """1-D mesh over the first D local devices — what the K-sharded selection
    engine (``repro.engine.sharded``) runs on.

    On a CPU host, multiple devices must be forced **before jax initialises**:

        XLA_FLAGS=--xla_force_host_platform_device_count=8

    (the CI sharded smoke and ``tests/conftest.py`` both set this).  Raises
    with that hint when fewer than D devices exist — the flag cannot be
    applied retroactively from here.
    """
    devs = jax.devices()
    D = len(devs) if D is None else int(D)
    if D < 1 or D > len(devs):
        raise RuntimeError(
            f"need {D} devices for the {axis_name!r} mesh but jax sees {len(devs)}; on a CPU host "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={max(D, 2)} before the process starts"
        )
    return jax.sharding.Mesh(np.asarray(devs[:D]), (axis_name,))


def axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
