"""Sampling ``k`` clients without replacement from a probability allocation.

The paper draws ``A_t ~ multinomialNR(p_t / k, k)`` via
``torch.multinomial(p, k, replacement=False)`` — i.e. *successive* sampling
without replacement, which is exactly the Plackett-Luce distribution over
k-prefixes.  The **Gumbel top-k trick** produces the identical distribution in
one parallel pass (Yellott 1977): perturb ``log p_i`` with iid Gumbel(0,1)
noise and take the top-k — TPU-friendly, O(K log K), jit-safe.

Plackett-Luce sampling does **not** make the inclusion probability of arm ``i``
equal to ``p_i`` (the paper's footnote-6 claim is only approximate).  To close
the gap with Theorem 1's assumption ``E[1{i in A_t}] = p_i`` we additionally
provide **Madow's systematic sampling**, which achieves exact inclusion
probabilities whenever ``sum(p) = k`` and ``p_i <= 1``.  Both are selectable;
`repro.kernels.gumbel_topk` provides a Pallas kernel for the former at
million-client scale.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = [
    "plackett_luce_sample",
    "perturbed_scores",
    "local_topk_candidates",
    "merge_topk_candidates",
    "systematic_sample",
    "sample_selection",
    "selection_mask",
]

_EPS = 1e-20


def perturbed_scores(rng: jax.Array, p: jax.Array) -> jax.Array:
    """The Plackett-Luce score field ``log p + Gumbel``: its exact top-k is a
    multinomialNR draw.  Factored out so the dense sampler and the K-sharded
    engine (``repro.engine.sharded``) perturb identically."""
    g = jax.random.gumbel(rng, p.shape, p.dtype)
    return jnp.log(jnp.maximum(p, _EPS)) + g


def plackett_luce_sample(rng: jax.Array, p: jax.Array, k: int) -> jax.Array:
    """Gumbel top-k == multinomial sampling without replacement (paper's).

    Returns the ``(k,)`` int32 indices of the selected clients.
    """
    _, idx = jax.lax.top_k(perturbed_scores(rng, p), k)
    return idx.astype(jnp.int32)


def local_topk_candidates(scores: jax.Array, k: int, offset) -> tuple[jax.Array, jax.Array]:
    """One shard's top-k candidates ``(values, global_indices)`` for a
    distributed top-k: local ``lax.top_k`` plus the shard's global offset."""
    v, i = jax.lax.top_k(scores, k)
    return v, i.astype(jnp.int32) + jnp.asarray(offset, jnp.int32)


def merge_topk_candidates(vals: jax.Array, idx: jax.Array, k: int) -> jax.Array:
    """Merge per-shard top-k candidates into the exact global top-k indices.

    ``vals`` / ``idx`` hold the D shards' candidates (any shape; flattened in
    shard-major order, each shard's block sorted descending as ``lax.top_k``
    emits it).  **Containment**: any member of the global top-k has fewer than
    k global scores above it, hence fewer than k *within its own shard*, so it
    appears in that shard's local top-k — the union of the D candidate lists
    always contains the global top-k, and one ``top_k`` over the ``D*k``
    candidates recovers it exactly.  **Tie order** also matches a dense
    ``lax.top_k`` (lowest index first): shards cover contiguous index ranges
    in order, and within a shard equal values are emitted in index order, so
    candidate position is index order among ties.
    """
    v = vals.reshape(-1)
    _, pos = jax.lax.top_k(v, k)
    return idx.reshape(-1)[pos].astype(jnp.int32)


def systematic_sample(rng: jax.Array, p: jax.Array, k: int) -> jax.Array:
    """Madow's systematic sampling: exact inclusion probabilities.

    With ``sum(p) = k`` and ``0 <= p_i <= 1``: draw ``u ~ U[0,1)`` and select
    every client whose cumulative interval ``[C_{i-1}, C_i)`` contains one of
    the points ``u, u+1, ..., u+k-1``. Because ``p_i <= 1`` no client can be
    hit twice, so exactly ``k`` distinct clients are chosen and
    ``P(i selected) = p_i`` exactly.

    A random permutation is applied first so that joint inclusion
    probabilities are not tied to client ordering.
    """
    K = p.shape[0]
    rng_perm, rng_u = jax.random.split(rng)
    perm = jax.random.permutation(rng_perm, K)
    p_perm = p[perm]
    c = jnp.cumsum(p_perm)
    c0 = jnp.concatenate([jnp.zeros((1,), p.dtype), c[:-1]])
    u = jax.random.uniform(rng_u, (), p.dtype)
    # client j is hit iff ceil(c0[j] - u) < ceil(c[j] - u)  <=>  an integer+u
    # point falls inside [c0, c). Count of hits is floor(c - u) - floor(c0 - u)
    hits = jnp.floor(c - u) - jnp.floor(c0 - u)
    mask = hits >= 1.0
    # exactly k hits; materialise indices via top_k on the mask with cumsum
    # tie-break to keep a deterministic order.
    score = jnp.where(mask, 1.0, 0.0) * (K - jnp.arange(K, dtype=p.dtype))
    _, pos = jax.lax.top_k(score, k)
    return perm[pos].astype(jnp.int32)


def sample_selection(rng: jax.Array, p: jax.Array, k: int, method: str = "plackett_luce") -> jax.Array:
    if method == "plackett_luce":
        return plackett_luce_sample(rng, p, k)
    if method == "systematic":
        return systematic_sample(rng, p, k)
    raise ValueError(f"unknown sampling method: {method!r}")


def selection_mask(idx: jax.Array, K: int) -> jax.Array:
    """``(K,)`` float mask with ones at selected indices."""
    return jnp.zeros((K,), jnp.float32).at[idx].set(1.0)


def inclusion_probability_mc(rng: jax.Array, p: jax.Array, k: int, n: int, method: str) -> jax.Array:
    """Monte-Carlo estimate of inclusion probabilities (test/benchmark util)."""
    K = p.shape[0]

    def body(r):
        return selection_mask(sample_selection(r, p, k, method), K)

    masks = jax.vmap(body)(jax.random.split(rng, n))
    return masks.mean(0)
