"""Probability allocation with overflow capping (paper Algorithm 2).

Given exponential weights ``w`` over ``K`` clients, a cardinality ``k`` and a
fairness quota ``sigma`` (with ``0 <= sigma <= k/K``), compute

    p_i = sigma + (k - K*sigma) * w'_i / sum_j w'_j            (Eq. 19)

where ``w'_i = min(w_i, (1 - sigma) * alpha)`` and ``alpha`` is the largest
value such that ``p_i <= 1`` for all ``i`` (Eqs. 21-24).  The set
``S = {i : w_i > (1 - sigma) * alpha}`` of capped ("overflowed") clients is
returned as a boolean mask; E3CS freezes the weights of capped clients in the
update step (Eq. 17).

Everything here is pure ``jnp`` and jit/vmap-safe: the per-case search of the
paper (iterate cases ``v`` with ``Psi_{i_v} <= alpha < Psi_{i_{v+1}}``) is
vectorized over all K cases via a sort + cumulative sums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["prob_alloc", "prob_alloc_reference"]

_EPS = 1e-12


def _alpha_search(w: jax.Array, k: float, K: int, sigma: jax.Array) -> jax.Array:
    """Solve ``alpha / sum_j min(w_j, (1-sigma) alpha) = 1/(k - K sigma)``.

    Vectorised version of the paper's case analysis (Eq. 24). Let
    ``Psi_i = w_i / (1 - sigma)`` and sort ascending. For case ``v`` (0-based:
    arms ``0..v`` uncapped, arms ``v+1..K-1`` capped):

        alpha_v = cumsum(w_sorted)[v] / (k - K sigma - (K - 1 - v)(1 - sigma))

    and the premise is ``Psi_sorted[v] <= alpha_v < Psi_sorted[v+1]``.
    """
    one_minus_sigma = 1.0 - sigma
    w_sorted = jnp.sort(w)
    psi = w_sorted / jnp.maximum(one_minus_sigma, _EPS)
    csum = jnp.cumsum(w_sorted)
    K_ = jnp.asarray(K, w.dtype)
    v = jnp.arange(K, dtype=w.dtype)
    # residual probability mass handed to uncapped arms in case v
    denom = (k - K_ * sigma) - (K_ - 1.0 - v) * one_minus_sigma
    alpha_v = csum / jnp.where(jnp.abs(denom) < _EPS, _EPS, denom)
    psi_next = jnp.concatenate([psi[1:], jnp.full((1,), jnp.inf, w.dtype)])
    # relative tolerance: with tied weights (all psi equal) and sigma -> k/K,
    # float32 roundoff otherwise leaves every strict case premise unsatisfied
    tol = 1e-5
    valid = (denom > _EPS) & (alpha_v >= psi[jnp.arange(K)] * (1 - tol) - 1e-9) & (
        alpha_v < psi_next * (1 + tol) + 1e-9
    )
    # The paper proves at least one case is valid (Claim 1). If several are
    # (degenerate ties), any satisfies the equation; take the largest alpha.
    alpha = jnp.max(jnp.where(valid, alpha_v, -jnp.inf))
    # Fallback (should not trigger): fully-even allocation alpha.
    fallback = jnp.min(w) / jnp.maximum(one_minus_sigma, _EPS)
    return jnp.where(jnp.isfinite(alpha), alpha, fallback)


def prob_alloc(w: jax.Array, k: int, sigma: jax.Array):
    """Paper Algorithm 2 (ProbAlloc).

    Args:
      w: ``(K,)`` positive exponential weights.
      k: cardinality of the selection (static int).
      sigma: scalar fairness quota in ``[0, k/K]``.

    Returns:
      ``(p, capped)`` where ``p`` is the ``(K,)`` selection-probability vector
      with ``sum(p) = k`` and ``sigma <= p_i <= 1``, and ``capped`` is the
      boolean overflow mask ``S_t``.
    """
    w = jnp.asarray(w)
    K = w.shape[0]
    sigma = jnp.asarray(sigma, w.dtype)
    residual = jnp.asarray(k, w.dtype) - K * sigma  # k - K*sigma >= 0

    w_sum = jnp.sum(w)
    p_plain = sigma + residual * w / jnp.maximum(w_sum, _EPS)
    overflow = jnp.max(p_plain) > 1.0 + 1e-9

    def capped_branch(_):
        alpha = _alpha_search(w, float(k), K, sigma)
        cap = (1.0 - sigma) * alpha
        w_c = jnp.minimum(w, cap)
        p = sigma + residual * w_c / jnp.maximum(jnp.sum(w_c), _EPS)
        # S_t = {i : w_i > (1-sigma) alpha} == the arms whose probability
        # saturated at 1; deriving it from p is robust to float ties at the
        # cap boundary.
        return p, p >= 1.0 - 1e-6

    def plain_branch(_):
        return p_plain, jnp.zeros((K,), bool)

    p, capped = jax.lax.cond(overflow, capped_branch, plain_branch, None)
    # Numerical hygiene: clamp and renormalise the residual mass so that the
    # downstream sampler sees a simplex-consistent vector.
    p = jnp.clip(p, sigma, 1.0)
    return p, capped


def prob_alloc_reference(w, k: int, sigma: float):
    """Brute-force iterative reference implementation (paper's literal case
    enumeration) used as the test oracle. Pure python/numpy-style; not jitted.
    """
    import numpy as np

    w = np.asarray(w, dtype=np.float64)
    K = w.shape[0]
    residual = k - K * sigma
    p = sigma + residual * w / w.sum()
    if p.max() <= 1.0 + 1e-12:
        return p, np.zeros(K, bool)
    # iterate the cases of Eq. (24)
    order = np.argsort(w)
    ws = w[order]
    psi = ws / max(1.0 - sigma, _EPS)
    best_alpha = None
    tol = 1e-5
    for v in range(K):
        denom = residual - (K - 1 - v) * (1.0 - sigma)
        if denom <= _EPS:
            continue
        alpha = ws[: v + 1].sum() / denom
        hi = psi[v + 1] if v + 1 < K else np.inf
        if psi[v] * (1 - tol) - 1e-9 <= alpha < hi * (1 + tol) + 1e-9:
            best_alpha = alpha if best_alpha is None else max(best_alpha, alpha)
    if best_alpha is None:
        # degenerate ties at sigma -> k/K: fall back to Claim 1's witness
        best_alpha = float(ws.min()) / max(1.0 - sigma, _EPS)
    cap = (1.0 - sigma) * best_alpha
    w_c = np.minimum(w, cap)
    p = sigma + residual * w_c / w_c.sum()
    return p, p >= 1.0 - 1e-6
