"""Hindsight-optimal CEP and regret (paper Definitions 1-2, Theorem 1).

The comparator of Definition 1 allocates, in addition to the fairness floor
``sigma_t`` handed to everyone, the residual probability mass ``k - K sigma_t``
through a quota vector ``q*`` with ``sum_i q*_i = 1`` (Fact 7) and
``q*_i (k - K sigma_t) <= 1 - sigma_t`` (Fact 9, i.e. p* <= 1).

Two comparator flavours are provided:

* ``static``    — the best *fixed* quota vector over the horizon (this is the
  comparator the Appendix-A telescoping argument actually supports, as in
  canonical Exp3);
* ``per_round`` — the stronger per-round optimum (upper bound on any static
  comparator; useful as a stress test — E3CS need not beat it, but Theorem 1
  is checked against ``static``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["oracle_cep", "empirical_expected_cep", "regret"]


def oracle_cep(xs: np.ndarray, k: int, sigmas: np.ndarray, mode: str = "static") -> float:
    """E[CEP*_T] per Eq. (26).

    Args:
      xs: (T, K) success bits.
      sigmas: (T,) fairness quotas.
    """
    xs = np.asarray(xs, np.float64)
    T, K = xs.shape
    sigmas = np.broadcast_to(np.asarray(sigmas, np.float64), (T,))
    residual = k - K * sigmas  # (T,)
    floor = float(np.sum(sigmas[:, None] * xs))  # sigma_t * n1_t summed

    if mode == "per_round":
        n1 = xs.sum(1)  # (T,)
        gain = np.minimum(residual, n1 * (1.0 - sigmas))
        return float(np.sum(gain)) + floor

    if mode == "static":
        # maximize sum_i q_i * s_i  s.t. sum q = 1, 0 <= q_i <= cap
        s = (residual[:, None] * xs).sum(0)  # (K,) value of unit quota on arm i
        with np.errstate(divide="ignore"):
            caps_t = np.where(residual > 1e-12, (1.0 - sigmas) / residual, np.inf)
        cap = float(np.min(caps_t)) if len(caps_t) else 1.0
        cap = min(cap, 1.0)
        order = np.argsort(-s)
        q = np.zeros(K)
        mass = 1.0
        for i in order:
            take = min(cap, mass)
            q[i] = take
            mass -= take
            if mass <= 1e-15:
                break
        return float(np.dot(q, s)) + floor

    raise ValueError(mode)


def empirical_expected_cep(ps: np.ndarray, xs: np.ndarray) -> float:
    """E[CEP^alg] = sum_t sum_i p_{i,t} x_{i,t} (Definition 2)."""
    return float(np.sum(np.asarray(ps, np.float64) * np.asarray(xs, np.float64)))


def regret(ps: np.ndarray, xs: np.ndarray, k: int, sigmas, mode: str = "static") -> float:
    return oracle_cep(xs, k, np.asarray(sigmas), mode) - empirical_expected_cep(ps, xs)
