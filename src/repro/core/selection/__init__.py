from .prob_alloc import prob_alloc, prob_alloc_reference
from .sampling import (
    plackett_luce_sample,
    systematic_sample,
    sample_selection,
    selection_mask,
    inclusion_probability_mc,
)
from .e3cs import (
    E3CSState,
    e3cs_init,
    e3cs_probs,
    e3cs_update,
    e3cs_round,
    theorem1_eta,
    theorem1_bound,
)
from .quota import make_quota_schedule
from .baselines import (
    random_select,
    fedcs_select,
    pow_d_select,
    PowDState,
    UCBState,
    ucb_init,
    ucb_select,
    ucb_update,
)
from .regret import oracle_cep, empirical_expected_cep, regret

__all__ = [n for n in dir() if not n.startswith("_")]
