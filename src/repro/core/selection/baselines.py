"""Baseline client-selection schemes evaluated in the paper (§VI-A2).

* ``random``  — vanilla FedAvg selection: uniform k-subset.
* ``fedcs``   — Nishio & Yonetani's FedCS adapted to the volatile context as
  the paper does: *prophetic* greedy choice of the k clients with the highest
  true success rate.
* ``pow_d``   — power-of-choice (Cho et al.): draw a candidate set of size
  ``d`` uniformly, query their current local loss, select the k with the
  largest loss.
* ``ucb``     — beyond-paper reference point: stochastic-bandit UCB1 on the
  empirical success rate with a fairness floor applied through the same
  ProbAlloc machinery (deterministic top-k on UCB scores).

Each selector is a pure state machine with the same shape as E3CS so the FL
round step can swap them under jit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .sampling import selection_mask

__all__ = [
    "random_select",
    "fedcs_select",
    "PowDState",
    "pow_d_select",
    "UCBState",
    "ucb_init",
    "ucb_select",
    "ucb_update",
]


def random_select(rng: jax.Array, K: int, k: int) -> jax.Array:
    """Uniform k-subset (paper's `Random`)."""
    return jax.random.permutation(rng, K)[:k].astype(jnp.int32)


def fedcs_select(success_rate: jax.Array, k: int, rng: jax.Array | None = None) -> jax.Array:
    """Prophetic FedCS: top-k by true success rate (ties broken randomly)."""
    score = success_rate
    if rng is not None:
        score = score + 1e-6 * jax.random.uniform(rng, score.shape)
    _, idx = jax.lax.top_k(score, k)
    return idx.astype(jnp.int32)


class PowDState(NamedTuple):
    local_loss: jax.Array  # (K,) last observed local loss per client


def pow_d_select(rng: jax.Array, local_loss: jax.Array, k: int, d: int) -> jax.Array:
    """power-of-choice: candidate set of size d (uniform), top-k by loss.

    The paper assumes loss reporting always succeeds even for volatile
    clients; we match that.
    """
    K = local_loss.shape[0]
    cand = jax.random.permutation(rng, K)[:d]
    cand_loss = local_loss[cand]
    _, pos = jax.lax.top_k(cand_loss, k)
    return cand[pos].astype(jnp.int32)


class UCBState(NamedTuple):
    succ: jax.Array  # (K,) cumulative observed successes
    pulls: jax.Array  # (K,) pull counts
    t: jax.Array


def ucb_init(K: int) -> UCBState:
    return UCBState(jnp.zeros((K,)), jnp.zeros((K,)), jnp.zeros((), jnp.int32))


def ucb_select(state: UCBState, k: int) -> jax.Array:
    t = jnp.maximum(state.t.astype(jnp.float32), 1.0)
    mean = state.succ / jnp.maximum(state.pulls, 1.0)
    bonus = jnp.sqrt(2.0 * jnp.log(t + 1.0) / jnp.maximum(state.pulls, 1.0))
    score = jnp.where(state.pulls == 0, jnp.inf, mean + bonus)
    _, idx = jax.lax.top_k(score, k)
    return idx.astype(jnp.int32)


def ucb_update(state: UCBState, idx: jax.Array, x: jax.Array) -> UCBState:
    mask = selection_mask(idx, state.succ.shape[0])
    return UCBState(
        succ=state.succ + mask * x,
        pulls=state.pulls + mask,
        t=state.t + 1,
    )
