"""Fairness-quota schedules ``sigma_t`` (paper §VI-A2 and §VI-B).

All schedules return a value in ``[0, k/K]`` (required for feasibility,
paper §IV-B2).  ``make_quota_schedule`` returns a jit-safe function of the
(traced) round index.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

__all__ = ["make_quota_schedule"]


def make_quota_schedule(name: str, k: int, K: int, T: int, frac: float = 0.0) -> Callable:
    """Build ``sigma(t)``.

    Names:
      * ``const``  — ``frac * k/K``  (E3CS-0 / E3CS-0.5 / E3CS-0.8 via frac)
      * ``inc``    — paper's E3CS-inc: 0 for t <= T/4, k/K afterwards
      * ``linear`` — beyond-paper: linear ramp 0 -> k/K over the horizon
      * ``cosine`` — beyond-paper: smooth ramp 0 -> k/K
    """
    cap = k / K

    if name == "const":
        v = jnp.asarray(frac * cap, jnp.float32)
        return lambda t: v
    if name == "inc":
        thresh = T // 4
        return lambda t: jnp.where(t >= thresh, cap, 0.0).astype(jnp.float32)
    if name == "linear":
        return lambda t: (cap * jnp.clip(t / max(T - 1, 1), 0.0, 1.0)).astype(jnp.float32)
    if name == "cosine":
        return lambda t: (cap * 0.5 * (1.0 - jnp.cos(jnp.pi * jnp.clip(t / max(T - 1, 1), 0.0, 1.0)))).astype(
            jnp.float32
        )
    raise ValueError(f"unknown quota schedule {name!r}")
