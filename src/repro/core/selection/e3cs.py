"""E3CS — Exp3-based Client Selection (paper Algorithm 1).

Functional, jit-safe implementation.  The selector is a pure state machine:

    state = e3cs_init(K)
    p, capped = e3cs_probs(state, k, sigma_t)          # Algorithm 2
    A_t = sample_selection(rng, p, k, method)          # multinomialNR
    state = e3cs_update(state, p, capped, sel_mask, x, k, sigma_t, eta)

The unbiased estimator and the weight update follow Eqs. (16)-(17): capped
(overflowed) arms are frozen, everyone else multiplies their weight by
``exp((k - K sigma) * eta * xhat / K)``.

Weights are stored in log-space (``logw``) — mathematically identical, but
immune to the floating-point overflow the paper's multiplicative form hits
after a few hundred successful rounds with eta=0.5.  ProbAlloc is invariant to
a common shift of ``logw``, so we re-center after every update.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .prob_alloc import prob_alloc
from .sampling import sample_selection, selection_mask

__all__ = ["E3CSState", "e3cs_init", "e3cs_probs", "e3cs_update", "e3cs_round"]


class E3CSState(NamedTuple):
    logw: jax.Array  # (K,) log exponential weights
    t: jax.Array  # scalar int32 round counter


def e3cs_init(K: int, dtype=jnp.float32) -> E3CSState:
    return E3CSState(logw=jnp.zeros((K,), dtype), t=jnp.zeros((), jnp.int32))


def e3cs_probs(state: E3CSState, k: int, sigma: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Probability allocation for the current round (Algorithm 2)."""
    w = jnp.exp(state.logw - jax.lax.stop_gradient(jnp.max(state.logw)))
    return prob_alloc(w, k, sigma)


def e3cs_update(
    state: E3CSState,
    p: jax.Array,
    capped: jax.Array,
    sel_mask: jax.Array,
    x: jax.Array,
    k: int,
    sigma: jax.Array,
    eta: float,
    K: int | None = None,
    axis_name: str | None = None,
    active: jax.Array | None = None,
) -> E3CSState:
    """Exponential-weight update, Eqs. (16)-(17).

    Args:
      p: (K,) allocation used for this round's draw.
      capped: (K,) bool overflow set ``S_t`` (frozen arms).
      sel_mask: (K,) {0,1} mask of ``A_t``.
      x: (K,) success bits ``x_{i,t}`` (only entries with sel_mask=1 are
         observed; others are multiplied by zero anyway).
      sigma: scalar fairness quota ``sigma_t``.
      eta: learning rate (static float).
      K: global population size when the arrays are one *shard* of the
         population (default: ``p.shape[0]``, the dense case).
      axis_name: mesh axis for the re-centering max (``pmax``) when sharded.
      active: optional 0/1 validity mask — padding slots are frozen like
         capped arms and pinned at 0 after re-centering.

    This is the single source of the Eq. 16/17 math for both the dense engine
    and the K-sharded round (``repro.engine.sharded``); with the defaults it
    is bit-identical to the historical dense-only update.
    """
    Kt = p.shape[0] if K is None else K
    xhat = sel_mask * x / jnp.maximum(p, 1e-12)  # Eq. (16)
    residual = jnp.asarray(k, p.dtype) - Kt * sigma
    step = residual * eta * xhat / Kt  # Eq. (17) exponent
    # Numerical safeguard: the regret proof's Taylor step (Fact 8) assumes the
    # exponent <= 1; with sigma=0 a rarely-selected arm can have p ~ 0 and an
    # unbounded importance weight, which would blow the weights up in fp32.
    # Clamping to the proof's regime keeps the update well-posed.
    step = jnp.minimum(step, 1.0)
    frozen = capped if active is None else capped | (active == 0)
    logw = state.logw + jnp.where(frozen, 0.0, step)
    # re-center (ProbAlloc is shift-invariant)
    m = jnp.max(logw) if active is None else jnp.max(jnp.where(active > 0, logw, -jnp.inf))
    if axis_name is not None:
        m = jax.lax.pmax(m, axis_name)
    logw = logw - m
    if active is not None:
        logw = logw * active  # keep padding slots pinned at 0
    return E3CSState(logw=logw, t=state.t + 1)


def e3cs_round(
    state: E3CSState,
    rng: jax.Array,
    x: jax.Array,
    k: int,
    sigma: jax.Array,
    eta: float,
    method: str = "plackett_luce",
):
    """One full bandit round against a success-bit vector ``x`` (K,).

    Returns ``(new_state, sel_idx, sel_mask, p)``. Used by the numerical
    experiments (Figs. 3-4) and as the selection block inside the FL round.
    """
    p, capped = e3cs_probs(state, k, sigma)
    idx = sample_selection(rng, p, k, method)
    mask = selection_mask(idx, p.shape[0])
    new_state = e3cs_update(state, p, capped, mask, x, k, sigma, eta)
    return new_state, idx, mask, p


def theorem1_eta(K: int, k: int, sigmas) -> float:
    """Optimal learning rate of Theorem 1: sqrt(K ln K / sum_t (k - K sigma_t))."""
    import numpy as np

    s = float(np.sum(k - K * np.asarray(sigmas)))
    return float(np.sqrt(K * np.log(K) / max(s, 1e-12)))


def theorem1_bound(K: int, k: int, sigmas, eta: float | None = None) -> float:
    """Regret upper bound of Theorem 1 (Eq. 28 / Eq. 29 when eta is None)."""
    import numpy as np

    s = float(np.sum(k - K * np.asarray(sigmas)))
    if eta is None:
        return 2.0 * float(np.sqrt(K * s * np.log(K)))
    return eta * s + K / eta * float(np.log(K))
