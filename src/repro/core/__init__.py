"""Paper core: E3CS stochastic client selection under volatile clients."""
from . import selection, volatility, fairness  # noqa: F401
