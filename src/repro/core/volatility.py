"""Volatile-client models: generators of the success bits ``x_{i,t}``.

The paper simulates ``x_{i,t} ~ Bern(rho_i)`` with four client classes
(rho in {0.1, 0.3, 0.6, 0.9}, K/4 clients each).  We additionally provide:

* ``markov``   — two-state Gilbert-Elliott channel per client, modelling the
  paper's motivating remark that crashes have *temporal correlation* (a failed
  client tends to stay failed for a while).  Marginal success rate is kept at
  ``rho_i`` so the classes remain comparable.
* ``deadline`` — mechanistic model: training time ~ shifted-Exp(compute_i) *
  epochs_i; failure iff time exceeds the round deadline or a transmission
  fault occurs.  This grounds the success bit in the paper's deadline-based
  aggregation story (Fig. 2).

All generators are pure: ``x = model.sample(rng, t)`` returns the full (K,)
bit-vector for round t (the scheduler only ever observes selected entries).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["paper_success_rates", "BernoulliVolatility", "MarkovVolatility", "DeadlineVolatility"]


def paper_success_rates(K: int, rates=(0.1, 0.3, 0.6, 0.9)) -> np.ndarray:
    """Paper §VI-A: equal split of K clients into len(rates) classes."""
    per = K // len(rates)
    out = np.concatenate([np.full(per, r) for r in rates])
    if out.shape[0] < K:  # remainder goes to the most stable class
        out = np.concatenate([out, np.full(K - out.shape[0], rates[-1])])
    return out.astype(np.float32)


@dataclass(frozen=True)
class BernoulliVolatility:
    """iid per-round success bits, x_{i,t} ~ Bern(rho_i)."""

    rho: jnp.ndarray  # (K,)

    def init_state(self):
        return jnp.zeros((self.rho.shape[0],), jnp.float32)

    def sample(self, rng: jax.Array, state):
        x = jax.random.bernoulli(rng, self.rho).astype(jnp.float32)
        return x, state


@dataclass(frozen=True)
class MarkovVolatility:
    """Gilbert-Elliott: per-client 2-state chain with stationary P(up)=rho.

    ``stickiness`` in [0,1) controls temporal correlation: transition
    probabilities are scaled so expected sojourn grows as 1/(1-stickiness)
    while the stationary distribution stays (rho, 1-rho).
    """

    rho: jnp.ndarray  # (K,)
    stickiness: float = 0.8

    def init_state(self):
        return self.rho  # P(up) at t=0 equals stationary

    def sample(self, rng: jax.Array, state):
        r_up, r_flip = jax.random.split(rng)
        up = jax.random.bernoulli(r_up, state).astype(jnp.float32)
        # transition: stay with prob s + (1-s)*stationary
        s = self.stickiness
        p_next = s * up + (1.0 - s) * self.rho
        return up, p_next


@dataclass(frozen=True)
class DeadlineVolatility:
    """Failure = local training time exceeds deadline, or transmission fault.

    time_i ~ epochs_i * base_i * (1 + Exp(jitter));  success iff
    time_i <= deadline and U > p_net_fail_i.
    """

    epochs: jnp.ndarray  # (K,) designated local epochs
    base_time: jnp.ndarray  # (K,) per-epoch compute time
    deadline: float
    p_net_fail: jnp.ndarray  # (K,)
    jitter: float = 0.5

    def init_state(self):
        return jnp.zeros((self.epochs.shape[0],), jnp.float32)

    def sample(self, rng: jax.Array, state):
        r_t, r_n = jax.random.split(rng)
        noise = jax.random.exponential(r_t, self.epochs.shape) * self.jitter
        t_i = self.epochs * self.base_time * (1.0 + noise)
        ok_time = (t_i <= self.deadline).astype(jnp.float32)
        ok_net = (~jax.random.bernoulli(r_n, self.p_net_fail)).astype(jnp.float32)
        return ok_time * ok_net, state
