"""Volatile-client models: generators of the success bits ``x_{i,t}``.

The paper simulates ``x_{i,t} ~ Bern(rho_i)`` with four client classes
(rho in {0.1, 0.3, 0.6, 0.9}, K/4 clients each).  We additionally provide:

* ``markov``   — two-state Gilbert-Elliott channel per client, modelling the
  paper's motivating remark that crashes have *temporal correlation* (a failed
  client tends to stay failed for a while).  Marginal success rate is kept at
  ``rho_i`` so the classes remain comparable.
* ``deadline`` — mechanistic model: training time ~ shifted-Exp(compute_i) *
  epochs_i; failure iff time exceeds the round deadline or a transmission
  fault occurs.  This grounds the success bit in the paper's deadline-based
  aggregation story (Fig. 2).

All generators are pure: ``x = model.sample(rng, t)`` returns the full (K,)
bit-vector for round t (the scheduler only ever observes selected entries).

Async extension: the paper's deadline mechanism treats "past the deadline" as
"dead", but production FL aggregates late-but-alive updates with a staleness
decay instead.  The *lag models* here generalise the success bit to a
completion lag, in the same ``(init_state, sample)`` protocol (so they carry
through ``engine.scan_sim``'s ``lax.scan`` and compose with every scenario
generator):

* ``sample`` returns a (K,) **int32 lag vector** instead of float bits:
  ``0`` = completed within the deadline (the old ``x=1``), ``l >= 1`` =
  completes ``l`` rounds late, ``DEAD_LAG`` (= -1) = never completes.
* ``BinaryLag`` wraps any success-bit model 1:1 (``x=1 -> 0``, ``x=0 ->
  DEAD_LAG``) and consumes *exactly* the base model's randomness, so the
  async engine with a ``BinaryLag`` reproduces the synchronous engine
  bit-for-bit at any buffer depth.
* ``CompletionLag`` is the generative model: a client that misses the
  deadline still completes with probability ``p_late``, after ``1 +
  Geometric(lag_decay)`` rounds (truncated at ``max_lag``); otherwise it is
  dead, which recovers the paper's drop semantics as ``p_late -> 0``.
* ``OnTimeBits`` is the inverse adapter: the success-bit view ``x = 1{lag ==
  0}`` of any lag model, consuming the lag model's randomness — the S=0
  synchronous reference for the async engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "paper_success_rates",
    "calibrate_deadline",
    "make_volatility",
    "BernoulliVolatility",
    "MarkovVolatility",
    "DeadlineVolatility",
    "DEAD_LAG",
    "BinaryLag",
    "CompletionLag",
    "OnTimeBits",
]

DEAD_LAG = -1  # lag value of a client that never completes


def paper_success_rates(K: int, rates=(0.1, 0.3, 0.6, 0.9), remainder: str = "stable") -> np.ndarray:
    """Paper §VI-A: equal split of K clients into len(rates) classes.

    When ``K % len(rates) != 0`` the split cannot be exact and the leftover
    clients have to land somewhere; ``remainder`` picks the policy:

    * ``"stable"`` (default, the historical behaviour): every leftover client
      joins the *most stable* class (``rates[-1]``).  This skews the fleet
      optimistic at small K — e.g. K=10 with the paper's rates has mean
      success 0.56 versus 0.475 for the ideal equal split — so results at
      non-divisible K are not strictly comparable to the paper's K=100.
    * ``"spread"`` — class sizes differ by at most one, extras assigned from
      the least stable class upward.  The mean skew per leftover client is
      bounded by ``max_r |r - mean(rates)| / K`` and is pessimistic rather
      than optimistic (the extras land on low-rho classes first).

    Clients remain ordered by class (contiguous blocks), which
    ``class_selection_stats`` and the benchmarks rely on.
    """
    per, rem = divmod(K, len(rates))
    if remainder == "stable":
        counts = [per] * len(rates)
        counts[-1] += rem
    elif remainder == "spread":
        counts = [per + (1 if i < rem else 0) for i in range(len(rates))]
    else:
        raise ValueError(f"unknown remainder policy {remainder!r} (want 'stable' or 'spread')")
    out = np.concatenate([np.full(n, r) for n, r in zip(counts, rates)])
    return out.astype(np.float32)


def calibrate_deadline(rho, epochs, deadline: float, jitter: float):
    """Solve the deadline model for ``(base_time, p_net_fail)`` so the joint
    marginal success probability equals ``rho`` per client.

    Split each client's failure rate evenly between network faults and
    deadline misses, then invert the time model:

        success = ok_time * ok_net,  P(ok_net) = 1 - p_net,
        P(ok_time) = P(epochs*base*(1 + jitter*Exp(1)) <= deadline)
                   = 1 - exp(-(deadline/(epochs*base) - 1)/jitter)

    Setting ``P(ok_time) = rho/(1-p_net) =: q`` and inverting gives
    ``base = deadline / (epochs * (1 - jitter*log(1-q)))``.
    Returns float64 arrays (callers cast to float32 at model construction).
    """
    rho64 = np.asarray(rho, np.float64)
    p_net = 0.5 * (1.0 - rho64)
    q = np.clip(rho64 / (1.0 - p_net), 0.0, 1.0 - 1e-9)
    base = deadline / (np.asarray(epochs, np.float64) * (1.0 - jitter * np.log1p(-q)))
    return base, p_net


def make_volatility(
    name: str,
    rho,
    *,
    stickiness: float = 0.8,
    seed: int = 0,
    epochs_choices: Tuple[int, ...] = (1, 2, 3, 4),
    deadline_slack: float = 1.5,
    jitter: float = 0.25,
):
    """Construct a named volatility model over success rates ``rho`` (K,).

    ``name`` must be one of ``bernoulli | markov | deadline``; anything else
    raises (no silent Bernoulli fallback).  The deadline model draws
    heterogeneous local-epoch counts with ``np.random.default_rng(seed)`` and
    calibrates ``base_time`` so the joint marginal matches ``rho``
    (``calibrate_deadline``).  Richer structured models (diurnal, regional
    outages, flash crowds, trace replay) live in ``repro.scenarios``.
    """
    rho = jnp.asarray(rho, jnp.float32)
    if name == "bernoulli":
        return BernoulliVolatility(rho)
    if name == "markov":
        return MarkovVolatility(rho, stickiness)
    if name == "deadline":
        rng = np.random.default_rng(seed)
        epochs = np.asarray(rng.choice(epochs_choices, rho.shape[0]), np.float32)
        deadline = float(np.median(epochs) * deadline_slack)
        base, p_net = calibrate_deadline(np.asarray(rho, np.float64), epochs, deadline, jitter)
        return DeadlineVolatility(
            epochs=jnp.asarray(epochs),
            base_time=jnp.asarray(base, jnp.float32),
            deadline=deadline,
            p_net_fail=jnp.asarray(p_net, jnp.float32),
            jitter=jitter,
        )
    raise ValueError(f"unknown volatility model {name!r} (want bernoulli | markov | deadline)")


@dataclass(frozen=True)
class BernoulliVolatility:
    """iid per-round success bits, x_{i,t} ~ Bern(rho_i)."""

    rho: jnp.ndarray  # (K,)

    def init_state(self):
        return jnp.zeros((self.rho.shape[0],), jnp.float32)

    def sample(self, rng: jax.Array, state):
        x = jax.random.bernoulli(rng, self.rho).astype(jnp.float32)
        return x, state


@dataclass(frozen=True)
class MarkovVolatility:
    """Gilbert-Elliott: per-client 2-state chain with stationary P(up)=rho.

    ``stickiness`` in [0,1) controls temporal correlation: transition
    probabilities are scaled so expected sojourn grows as 1/(1-stickiness)
    while the stationary distribution stays (rho, 1-rho).
    """

    rho: jnp.ndarray  # (K,)
    stickiness: float = 0.8

    def init_state(self):
        return self.rho  # P(up) at t=0 equals stationary

    def sample(self, rng: jax.Array, state):
        r_up, r_flip = jax.random.split(rng)
        up = jax.random.bernoulli(r_up, state).astype(jnp.float32)
        # transition: stay with prob s + (1-s)*stationary
        s = self.stickiness
        p_next = s * up + (1.0 - s) * self.rho
        return up, p_next


@dataclass(frozen=True)
class DeadlineVolatility:
    """Failure = local training time exceeds deadline, or transmission fault.

    time_i ~ epochs_i * base_i * (1 + Exp(jitter));  success iff
    time_i <= deadline and U > p_net_fail_i.
    """

    epochs: jnp.ndarray  # (K,) designated local epochs
    base_time: jnp.ndarray  # (K,) per-epoch compute time
    deadline: float
    p_net_fail: jnp.ndarray  # (K,)
    jitter: float = 0.5

    def init_state(self):
        return jnp.zeros((self.epochs.shape[0],), jnp.float32)

    def sample(self, rng: jax.Array, state):
        r_t, r_n = jax.random.split(rng)
        noise = jax.random.exponential(r_t, self.epochs.shape) * self.jitter
        t_i = self.epochs * self.base_time * (1.0 + noise)
        ok_time = (t_i <= self.deadline).astype(jnp.float32)
        ok_net = (~jax.random.bernoulli(r_n, self.p_net_fail)).astype(jnp.float32)
        return ok_time * ok_net, state


@dataclass(frozen=True)
class BinaryLag:
    """Degenerate lag view of a success-bit model: on time iff ``x=1``, dead
    otherwise.  No extra randomness is drawn — ``rng`` goes straight to the
    base model — so the async engine driven by a ``BinaryLag`` is bit-identical
    to the synchronous engine driven by ``base`` (pinned in tests)."""

    base: object  # any (init_state, sample) success-bit model

    @property
    def rho(self):
        return getattr(self.base, "rho", None)

    def init_state(self):
        return self.base.init_state()

    def sample(self, rng: jax.Array, state):
        x, vs = self.base.sample(rng, state)
        return jnp.where(x > 0, 0, DEAD_LAG).astype(jnp.int32), vs


@dataclass(frozen=True)
class CompletionLag:
    """Completion-lag draw over any success-bit model.

    ``base.sample`` decides who finishes within the deadline (``lag=0``, the
    paper's ``x=1``).  A client that misses it is not necessarily dead: with
    probability ``p_late`` it still completes, ``1 + Geometric(lag_decay)``
    rounds late (truncated at ``max_lag``); otherwise ``DEAD_LAG``.  Because
    the on-time set is exactly ``base``'s success set, the marginal on-time
    rate stays the base model's ``rho`` and ``p_late -> 0`` recovers the
    paper's synchronous drop semantics.
    """

    base: object  # any (init_state, sample) success-bit model
    p_late: float = 0.7
    lag_decay: float = 0.5  # P(one more round late) = 1 - lag_decay
    max_lag: int = 4

    @property
    def rho(self):
        return getattr(self.base, "rho", None)

    def on_time_model(self) -> "OnTimeBits":
        """The sync-drop view of this model (for S=0 equivalence tests)."""
        return OnTimeBits(self)

    def init_state(self):
        return self.base.init_state()

    def sample(self, rng: jax.Array, state):
        r_base, r_late, r_lag = jax.random.split(rng, 3)
        x, vs = self.base.sample(r_base, state)
        late = jax.random.bernoulli(r_late, jnp.full(x.shape, self.p_late, jnp.float32))
        u = jax.random.uniform(r_lag, x.shape, minval=1e-7, maxval=1.0)
        extra = jnp.floor(jnp.log(u) / jnp.log1p(-min(self.lag_decay, 1.0 - 1e-7))).astype(jnp.int32)
        lag_late = 1 + jnp.clip(extra, 0, self.max_lag - 1)
        lag = jnp.where(x > 0, 0, jnp.where(late, lag_late, DEAD_LAG))
        return lag.astype(jnp.int32), vs


@dataclass(frozen=True)
class OnTimeBits:
    """Success-bit view of a lag model: ``x = 1{lag == 0}``.

    Consumes the lag model's randomness verbatim, so a synchronous run under
    this model is the exact S=0 reference for the async engine under
    ``lag_model`` — same PRNG keys, same on-time sets.
    """

    lag_model: object

    @property
    def rho(self):
        return getattr(self.lag_model, "rho", None)

    def init_state(self):
        return self.lag_model.init_state()

    def sample(self, rng: jax.Array, state):
        lag, vs = self.lag_model.sample(rng, state)
        return (lag == 0).astype(jnp.float32), vs
