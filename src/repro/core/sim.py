"""Selection-only simulator (no model training): reproduces the paper's
numerical experiments (Figs. 3-4) and powers the regret benchmark.

Runs any scheme for T rounds against a volatility model and returns the
full (T, K) selection masks / success bits / probability allocations.

``selection_sim`` is a thin wrapper over the scan-compiled engine
(``repro.engine.scan_sim``), which runs the whole horizon as one compiled
program.  ``selection_sim_loop`` host-steps the SAME round body
(``repro.engine.round_program``) one jitted call per round — since PR 5 it
no longer carries its own copy of the pipeline; it exists to pin that a
host-driven loop and the compiled scan produce bit-identical trajectories
(``tests/test_engine.py``) and as the dispatch-overhead baseline for
``benchmarks/engine_scale.py``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.volatility import make_volatility, paper_success_rates

__all__ = ["selection_sim", "selection_sim_loop"]


def selection_sim(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    backend: str = "scan",
    vol=None,
    rho=None,
) -> Dict[str, np.ndarray]:
    """Run the numerical experiment; ``backend`` picks "scan" (compiled
    engine, default) or "loop" (legacy per-round Python loop).

    ``volatility`` names a built-in generator (``bernoulli | markov |
    deadline``; unknown names raise).  Alternatively pass ``vol`` — any object
    with the ``(init_state, sample)`` protocol, e.g. a ``repro.scenarios``
    model — plus optionally ``rho`` (the marginal-rate hint used by the
    fedcs baseline; defaults to ``vol.rho`` or the paper classes).
    """
    kw = dict(
        scheme=scheme, K=K, k=k, T=T, quota=quota, frac=frac, eta=eta, sampler=sampler,
        volatility=volatility, stickiness=stickiness, seed=seed, xs_override=xs_override,
        vol=vol, rho=rho,
    )
    if backend == "scan":
        from repro.engine.scan_sim import scan_selection_sim

        return scan_selection_sim(**kw)
    if backend == "loop":
        return selection_sim_loop(**kw)
    raise ValueError(f"unknown sim backend {backend!r}")


def selection_sim_loop(
    scheme: str,
    K: int = 100,
    k: int = 20,
    T: int = 2500,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    sampler: str = "plackett_luce",
    volatility: str = "bernoulli",
    stickiness: float = 0.8,
    seed: int = 0,
    xs_override: Optional[np.ndarray] = None,
    vol=None,
    rho=None,
) -> Dict[str, np.ndarray]:
    from repro.engine.round_program import RoundProgram  # deferred: the engine imports this module

    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta, sampler=sampler)
    if rho is None:
        rho = getattr(vol, "rho", None) if vol is not None else None
    rho = jnp.asarray(paper_success_rates(K) if rho is None else rho, jnp.float32)
    if vol is None:
        vol = make_volatility(volatility, rho, stickiness=stickiness, seed=seed)
    program = RoundProgram(
        fl=fl, vol=vol, rho=rho, override="dense" if xs_override is not None else "none"
    )
    step, state = program.build_step()
    step = jax.jit(step)
    carry = (state, jax.random.PRNGKey(seed))
    empty = jnp.zeros((0,), jnp.float32)
    masks, xs, ps, sigmas = [], [], [], []
    for t in range(T):
        x_over = jnp.asarray(xs_override[t], jnp.float32) if xs_override is not None else empty
        carry, (mask, x, p, sigma) = step(carry, x_over)
        masks.append(np.asarray(mask))
        xs.append(np.asarray(x))
        ps.append(np.asarray(p))
        sigmas.append(float(sigma))
    return {
        "masks": np.stack(masks),
        "xs": np.stack(xs),
        "ps": np.stack(ps),
        "sigmas": np.asarray(sigmas),
        "counts": np.stack(masks).sum(0),
    }
