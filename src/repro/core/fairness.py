"""Fairness / participation metrics used throughout the experiments.

The paper quantifies fairness qualitatively through selection-count box plots
(Fig. 3); we add the standard scalar summaries so the tradeoff can be put on
one axis: Jain's fairness index, normalized selection entropy, and the
coefficient of variation of selection counts.  CEP and success ratio follow
Eq. (8) and Fig. 4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "jain_index", "selection_entropy", "gini", "top_share",
    "cep", "success_ratio", "class_selection_stats",
]


def jain_index(counts: jax.Array) -> jax.Array:
    """Jain's fairness index in (1/K, 1]; 1 == perfectly even."""
    counts = counts.astype(jnp.float32)
    num = jnp.sum(counts) ** 2
    den = counts.shape[0] * jnp.sum(counts**2)
    return num / jnp.maximum(den, 1e-12)


def selection_entropy(counts: jax.Array) -> jax.Array:
    """Entropy of the empirical selection distribution, normalized to [0,1]."""
    counts = counts.astype(jnp.float32)
    p = counts / jnp.maximum(jnp.sum(counts), 1e-12)
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0))
    return h / jnp.log(counts.shape[0])


def gini(counts: jax.Array) -> jax.Array:
    """Exact Gini coefficient of selection counts in [0, 1); 0 == even.

    Sorted-rank formula ``G = (2 * sum_i i*c_(i) / (K * sum c)) - (K+1)/K``
    — the dense-state oracle for the grouped-data Gini the sketch stream
    streams at scale (``repro.obs.sketches.fairness_series``).
    """
    c = jnp.sort(counts.astype(jnp.float32))
    K = c.shape[0]
    total = jnp.maximum(jnp.sum(c), 1e-12)
    ranks = jnp.arange(1, K + 1, dtype=jnp.float32)
    return 2.0 * jnp.vdot(ranks, c) / (K * total) - (K + 1.0) / K


def top_share(counts: jax.Array, frac: float = 0.1) -> jax.Array:
    """Selection-mass share of the most-selected ``frac`` of clients (the
    exact twin of the sketch stream's fractional-bucket estimate)."""
    c = jnp.sort(counts.astype(jnp.float32))[::-1]
    K = c.shape[0]
    target = frac * K
    take = jnp.minimum(jnp.maximum(target - jnp.arange(K, dtype=jnp.float32), 0.0), 1.0)
    return jnp.vdot(take, c) / jnp.maximum(jnp.sum(c), 1e-12)


def cep(sel_masks: jax.Array, xs: jax.Array) -> jax.Array:
    """Cumulative effective participation: sum_t sum_{i in A_t} x_{i,t}."""
    return jnp.sum(sel_masks * xs)


def success_ratio(sel_masks: jax.Array, xs: jax.Array) -> jax.Array:
    """CEP / (T*k) as in Fig. 4 (top)."""
    return cep(sel_masks, xs) / jnp.maximum(jnp.sum(sel_masks), 1e-12)


def class_selection_stats(counts, class_sizes):
    """Per-class selection-count summaries reproducing Fig. 3's box plots.

    Args:
      counts: (K,) times-selected per client.
      class_sizes: list of ints summing to K, clients ordered by class.
    Returns list of dicts with min/q1/median/q3/max/mean per class.
    """
    import numpy as np

    counts = np.asarray(counts)
    out, off = [], 0
    for n in class_sizes:
        c = np.sort(counts[off : off + n])
        off += n
        out.append(
            dict(
                min=float(c.min()),
                q1=float(np.percentile(c, 25)),
                median=float(np.percentile(c, 50)),
                q3=float(np.percentile(c, 75)),
                max=float(c.max()),
                mean=float(c.mean()),
            )
        )
    return out
