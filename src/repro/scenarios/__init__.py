"""Scenario subsystem: volatility as a first-class, compiled workload axis.

Sub-modules:
  * ``traces``   — structured generators (diurnal, regional outages, flash
    crowds) behind the core ``(init_state, sample)`` protocol
  * ``replay``   — bit-packed trace recording + replay (8 clients/byte;
    K=1e6, T=2500 in ~312 MB) streamed through ``engine.scan_sim``
  * ``registry`` — named scenario configurations
  * ``harness``  — selector x scenario evaluation grid (per-cell compiled
    scans, plus the batched ``engine.multi_job`` dispatch)

See ``README.md`` in this directory for the trace format and scenario names.
"""
from .traces import DiurnalVolatility, FlashCrowdVolatility, RegionalOutageVolatility
from .replay import (
    ReplayLag,
    ReplayVolatility,
    lag_packed_width,
    load_packed_trace,
    pack_lags,
    pack_trace,
    packed_nbytes,
    packed_width,
    record_lag_trace,
    record_trace,
    replay_packed_stream,
    save_packed_trace,
    unpack_lags,
    unpack_trace,
)
from .registry import SCENARIOS, Scenario, get_scenario, list_scenarios, make_scenario
from .harness import evaluate_cell, format_grid, run_grid, run_grid_multi_job, run_replay

__all__ = [
    "DiurnalVolatility",
    "FlashCrowdVolatility",
    "RegionalOutageVolatility",
    "ReplayLag",
    "ReplayVolatility",
    "lag_packed_width",
    "load_packed_trace",
    "pack_lags",
    "pack_trace",
    "packed_nbytes",
    "packed_width",
    "record_lag_trace",
    "record_trace",
    "replay_packed_stream",
    "save_packed_trace",
    "unpack_lags",
    "unpack_trace",
    "SCENARIOS",
    "Scenario",
    "get_scenario",
    "list_scenarios",
    "make_scenario",
    "evaluate_cell",
    "format_grid",
    "run_grid",
    "run_grid_multi_job",
    "run_replay",
]
