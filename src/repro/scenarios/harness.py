"""Selector x scenario evaluation grid.

Two dispatch paths over the same metric surface:

* ``run_grid`` — every (selector, scenario) cell is one whole-horizon
  compiled run (``engine.scan_sim`` with the scenario's stateful model carried
  inside the ``lax.scan``).  Covers every selection scheme.
* ``run_grid_multi_job`` — the scenario axis mapped onto the batched
  multi-tenant engine (``engine.multi_job``): one vmapped E3CS engine row per
  scenario, one device dispatch per round serves the whole grid, success bits
  streamed per scenario from its generator.  This is the fleet-shaped way to
  evaluate one selector against many regimes at once.

Cells report CEP (Eq. 8), effective participation (CEP / T*k), Jain fairness
and normalized selection entropy; with ``staleness=S`` each cell additionally
runs the *async* engine on the same scenario (its generator wrapped in
``CompletionLag``) and reports the staleness-aware CEP — on-time successes
plus ``alpha**lag``-decayed late credit — so the grid scores sync vs async
side by side.  ``format_grid`` renders the table the ``scenarios`` benchmark
suite and ``examples/scenarios_demo.py`` print.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fairness import cep, gini, jain_index, selection_entropy, success_ratio, top_share
from repro.core.volatility import CompletionLag
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs
from repro.engine.scan_sim import async_selection_sim, scan_selection_sim

from .registry import make_scenario
from .replay import record_trace

__all__ = ["evaluate_cell", "run_grid", "run_grid_multi_job", "run_replay", "format_grid"]

DEFAULT_SELECTORS = ("e3cs", "random", "fedcs")


def _metrics(masks: np.ndarray, xs: np.ndarray) -> Dict[str, float]:
    counts = masks.sum(0)
    return {
        "cep": float(cep(jnp.asarray(masks), jnp.asarray(xs))),
        "eff_participation": float(success_ratio(jnp.asarray(masks), jnp.asarray(xs))),
        "jain": float(jain_index(jnp.asarray(counts))),
        "entropy": float(selection_entropy(jnp.asarray(counts))),
        "gini": float(gini(jnp.asarray(counts))),
        "top_decile_share": float(top_share(jnp.asarray(counts), 0.1)),
    }


def evaluate_cell(
    selector: str, scenario: str, K: int = 100, k: int = 20, T: int = 500,
    seed: int = 0, frac: float = 0.5,
    staleness: Optional[int] = None, alpha: float = 0.5,
    p_late: float = 0.7, lag_decay: float = 0.5,
    feedback: Optional[str] = None,
) -> Dict[str, float]:
    """One (selector, scenario) cell through the compiled scan engine.

    With ``staleness=S`` the cell is also run through the async engine (same
    scenario re-instantiated at the same seed, wrapped in ``CompletionLag``)
    and gains ``async_cep`` / ``async_eff`` — the staleness-aware CEP and
    effective participation, where a late-but-alive client's contribution
    counts ``alpha**lag`` instead of zero.

    With ``feedback="late_credit"`` (needs ``staleness``) the async engine is
    additionally run under the late-credit feedback policy — E3CS receives the
    decayed ``alpha**lag`` reward at the buffered selection-round allocation
    when a late client lands, instead of deadline-only feedback — and the row
    gains ``lc_cep`` / ``lc_eff`` (staleness-aware CEP under the policy),
    ``lc_jain`` vs ``async_jain`` (Jain fairness of the selection counts) and
    ``lc_drift`` (max |Δ log-weight| of the final E3CS state vs deadline
    feedback — how far the policy actually moves the estimator).  Both runs
    consume identical randomness, so every difference is the feedback policy.
    """
    if feedback not in (None, "deadline", "late_credit"):
        raise ValueError(f"unknown feedback policy {feedback!r} (want 'deadline' or 'late_credit')")
    if feedback == "late_credit" and staleness is None:
        raise ValueError("feedback='late_credit' needs staleness=S (the policy lives in the async engine)")
    vol, rho = make_scenario(scenario, K, T, seed)
    out = scan_selection_sim(selector, K=K, k=k, T=T, frac=frac, seed=seed, vol=vol, rho=rho)
    row = {"selector": selector, "scenario": scenario, "K": K, "k": k, "T": T}
    row.update(_metrics(out["masks"], out["xs"]))
    if staleness is not None:

        def async_run(fb):
            vol2, _ = make_scenario(scenario, K, T, seed)
            lag_model = CompletionLag(vol2, p_late=p_late, lag_decay=lag_decay, max_lag=max(int(staleness), 1))
            return async_selection_sim(
                selector, K=K, k=k, T=T, frac=frac, seed=seed,
                staleness=int(staleness), alpha=alpha, lag_model=lag_model, rho=rho,
                outputs="lean", feedback=fb,
            )

        aout = async_run("deadline")
        row["async_cep"] = aout["cep"]
        row["async_eff"] = aout["cep"] / (T * k)
        if feedback == "late_credit":
            # the policy only moves the E3CS estimator; for the other
            # selectors it is a compile-time no-op, so reuse the deadline run
            # instead of paying a third compiled horizon per cell
            lout = async_run("late_credit") if selector == "e3cs" else aout
            row["async_jain"] = float(jain_index(jnp.asarray(aout["sel_counts"])))
            row["lc_cep"] = lout["cep"]
            row["lc_eff"] = lout["cep"] / (T * k)
            row["lc_jain"] = float(jain_index(jnp.asarray(lout["sel_counts"])))
            row["lc_drift"] = float(np.abs(lout["final_logw"] - aout["final_logw"]).max())
    return row


def run_grid(
    selectors: Sequence[str] = DEFAULT_SELECTORS,
    scenarios: Sequence[str] = ("paper_iid", "markov", "diurnal"),
    K: int = 100, k: int = 20, T: int = 500, seed: int = 0, frac: float = 0.5,
    staleness: Optional[int] = 2, alpha: float = 0.5,
    feedback: Optional[str] = None,
    log=None,
) -> List[Dict[str, float]]:
    """The full grid, one compiled run per cell (two with ``staleness``: the
    sync drop semantics and the async staleness-buffer semantics; three with
    ``feedback="late_credit"``, adding the late-credit feedback policy).

    ``log`` is any sink with a ``grid_row(row)`` method — ``repro.obs``'s
    ``Reporter`` or ``RunLog`` — each cell is streamed to it as it finishes,
    so a killed sweep still leaves the completed rows in the JSONL run log.
    """
    rows = []
    for sc in scenarios:
        for sel in selectors:
            row = evaluate_cell(
                sel, sc, K=K, k=k, T=T, seed=seed, frac=frac, staleness=staleness, alpha=alpha,
                feedback=feedback,
            )
            if log is not None:
                log.grid_row(row)
            rows.append(row)
    return rows


def run_grid_multi_job(
    scenarios: Sequence[str], K: int = 100, k: int = 20, T: int = 300,
    seed: int = 0, sigma_frac: float = 0.5, eta: float = 0.5,
) -> List[Dict[str, float]]:
    """E3CS vs every scenario in ONE batched engine: job j == scenario j.

    Per round: each scenario's generator produces its (K,) success bits
    (jitted per scenario — their state pytrees differ), the rows are stacked
    and a single ``multi_job`` dispatch advances all J selectors.
    """
    J = len(scenarios)
    cfg, k_max = pack_jobs([K] * J, [k] * J, [sigma_frac] * J, [eta] * J)
    _, batched = make_multi_job(k_max)
    state = multi_job_init(cfg)

    vols = [make_scenario(sc, K, T, seed)[0] for sc in scenarios]
    samplers = [jax.jit(v.sample) for v in vols]
    vol_states = [v.init_state() for v in vols]
    base_keys = jax.random.split(jax.random.PRNGKey(seed), J)
    vol_keys = jax.random.split(jax.random.PRNGKey(seed + 1), J)

    ceps = np.zeros(J)
    counts = np.zeros((J, K))
    for t in range(T):
        keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
        xs_rows = []
        for j in range(J):
            x, vol_states[j] = samplers[j](jax.random.fold_in(vol_keys[j], t), vol_states[j])
            xs_rows.append(x)
        xs = jnp.stack(xs_rows)
        state, out = batched(cfg, state, keys, xs)
        mask = np.asarray(out["mask"])
        ceps += (mask * np.asarray(xs)).sum(1)
        counts += mask
    rows = []
    for j, sc in enumerate(scenarios):
        rows.append({
            "selector": "e3cs(multi_job)",
            "scenario": sc,
            "K": K, "k": k, "T": T,
            "cep": float(ceps[j]),
            "eff_participation": float(ceps[j] / (T * k)),
            "jain": float(jain_index(jnp.asarray(counts[j]))),
            "entropy": float(selection_entropy(jnp.asarray(counts[j]))),
        })
    return rows


def run_replay(
    selector, scenario: str, K: int = 100, k: int = 20, T: int = 500,
    seed: int = 0, frac: float = 0.5, chunk: int = 256,
):
    """Record the scenario ONCE (bit-packed), then evaluate selector(s)
    against the frozen trace via the packed scan path — the scenario
    subsystem's A/B primitive: every selector sees identical bits.

    ``selector`` may be a single scheme name (returns ``(row, packed)``) or a
    sequence of names (returns ``(rows, packed)``); either way the trace is
    recorded a single time and reused.
    """
    single = isinstance(selector, str)
    selectors = (selector,) if single else tuple(selector)
    vol, rho = make_scenario(scenario, K, T, seed)
    packed = record_trace(vol, T, seed=seed, chunk=min(chunk, T))
    rows = []
    for sel in selectors:
        out = scan_selection_sim(sel, K=K, k=k, T=T, frac=frac, seed=seed, rho=rho, packed_override=packed)
        row = {"selector": sel, "scenario": f"{scenario}(replay)", "K": K, "k": k, "T": T}
        row.update(_metrics(out["masks"], out["xs"]))
        rows.append(row)
    return (rows[0] if single else rows), packed


def format_grid(rows: List[Dict[str, float]]) -> str:
    """Fixed-width table: scenarios x selectors with the four metrics (plus
    the async staleness-aware CEP / effective-participation columns when the
    grid was run with ``staleness``, and the late-credit policy columns when
    it was run with ``feedback="late_credit"``)."""
    has_async = any("async_cep" in r for r in rows)
    has_lc = any("lc_cep" in r for r in rows)
    hdr = (
        f"{'scenario':<22} {'selector':<16} {'cep':>9} {'eff_part':>9} {'jain':>6} "
        f"{'gini':>6} {'top10%':>6} {'entropy':>8}"
    )
    if has_async:
        hdr += f" {'acep':>9} {'aeff':>7}"
    if has_lc:
        hdr += f" {'a_jain':>7} {'lc_cep':>9} {'lc_eff':>7} {'lc_jain':>7} {'lc_drift':>9}"
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        line = (
            f"{r['scenario']:<22} {r['selector']:<16} {r['cep']:>9.0f} "
            f"{r['eff_participation']:>9.3f} {r['jain']:>6.3f} "
            f"{r.get('gini', float('nan')):>6.3f} {r.get('top_decile_share', float('nan')):>6.3f} "
            f"{r['entropy']:>8.3f}"
        )
        if has_async:
            if "async_cep" in r:
                line += f" {r['async_cep']:>9.0f} {r['async_eff']:>7.3f}"
            else:
                line += f" {'-':>9} {'-':>7}"
        if has_lc:
            if "lc_cep" in r:
                line += (
                    f" {r['async_jain']:>7.3f} {r['lc_cep']:>9.0f} {r['lc_eff']:>7.3f}"
                    f" {r['lc_jain']:>7.3f} {r['lc_drift']:>9.2e}"
                )
            else:
                line += f" {'-':>7} {'-':>9} {'-':>7} {'-':>7} {'-':>9}"
        lines.append(line)
    return "\n".join(lines)
