"""Structured volatility: the availability patterns real device fleets show.

The paper's synthetic generators (``repro.core.volatility``) draw each
client's success bit from a *static* marginal.  Cross-device fleets are not
like that: phones charge overnight (diurnal cycles phase-shifted by
timezone), a datacenter or cell outage takes a whole region down at once
(correlated failures), and a viral event makes a crowd of devices appear and
then churn away.  Each model here is one of those mechanisms, expressed in
the same ``(init_state, sample)`` protocol, so it drops into the legacy loop,
``engine.scan_sim`` (state carried through the ``lax.scan``) and the trace
recorder (``repro.scenarios.replay``) unchanged.

All models expose ``rho`` — the *base* per-client rate the structure
modulates — and ``marginal_rate()``, the long-run marginal an omniscient
baseline (fedcs) should be handed.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["DiurnalVolatility", "RegionalOutageVolatility", "FlashCrowdVolatility"]


@dataclass(frozen=True)
class DiurnalVolatility:
    """Timezone-phased sinusoidal availability: rho_i(t) = rho_i + A sin(...).

    Client i's success probability oscillates around its base rate with a
    shared period (rounds per simulated day) and a per-client phase offset
    (its timezone).  State is the round index.  The marginal over whole
    periods equals ``rho`` wherever the sinusoid stays inside [lo, hi];
    clipping (very low/high base rates) pulls it toward the clip point.
    """

    rho: jnp.ndarray  # (K,) base success rates
    phase: jnp.ndarray  # (K,) in [0, 1): fraction-of-day offset
    amplitude: float = 0.35
    period: int = 48  # rounds per day
    lo: float = 0.005
    hi: float = 0.995

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def rate(self, t) -> jnp.ndarray:
        ang = 2.0 * jnp.pi * (t.astype(jnp.float32) / self.period + self.phase)
        return jnp.clip(self.rho + self.amplitude * jnp.sin(ang), self.lo, self.hi)

    def marginal_rate(self) -> jnp.ndarray:
        ts = jnp.arange(self.period, dtype=jnp.int32)
        return jax.vmap(self.rate)(ts).mean(0)

    def sample(self, rng: jax.Array, state):
        x = jax.random.bernoulli(rng, self.rate(state)).astype(jnp.float32)
        return x, state + 1


@dataclass(frozen=True)
class RegionalOutageVolatility:
    """Correlated regional outages: a shared per-region Gilbert-Elliott latent
    factor crossed with per-client noise.

    Each of ``n_regions`` regions carries a 2-state up/down chain (up->down
    w.p. ``p_fail``, down->up w.p. ``p_recover``); while a client's region is
    down its success rate collapses to ``rho * (1 - severity)``.  Failures
    within a region are therefore strongly correlated — the regime FedCS-style
    deadline schedulers and Oort's utility selection are stress-tested on.
    State is the (n_regions,) up/down vector (init: all up).
    """

    rho: jnp.ndarray  # (K,) base success rates
    region: jnp.ndarray  # (K,) int32 region ids in [0, n_regions)
    n_regions: int
    p_fail: float = 0.02
    p_recover: float = 0.25
    severity: float = 0.9

    def init_state(self):
        return jnp.ones((self.n_regions,), jnp.float32)

    def availability(self) -> float:
        """Stationary P(region up) of the Gilbert-Elliott chain."""
        return self.p_recover / (self.p_fail + self.p_recover)

    def marginal_rate(self) -> jnp.ndarray:
        a = self.availability()
        return self.rho * (a + (1.0 - a) * (1.0 - self.severity))

    def sample(self, rng: jax.Array, state):
        r_reg, r_cli = jax.random.split(rng)
        p_up = state * (1.0 - self.p_fail) + (1.0 - state) * self.p_recover
        up = jax.random.bernoulli(r_reg, p_up).astype(jnp.float32)
        factor = up[self.region]  # (K,)
        rate = self.rho * (1.0 - self.severity * (1.0 - factor))
        x = jax.random.bernoulli(r_cli, rate).astype(jnp.float32)
        return x, up


@dataclass(frozen=True)
class FlashCrowdVolatility:
    """Flash-crowd churn: a cohort surges in for a window, then churns away.

    Clients with ``crowd == 1`` sit at ``base_avail`` outside the window
    ``[t_start, t_end)``; at ``t_start`` they all arrive (availability
    ``peak``) and each round of the window they independently leave for good
    w.p. ``churn`` — the classic arrive-together/decay-out shape of event
    traffic.  Non-crowd clients keep their static ``rho``.  State is the
    (K,) still-present vector plus the round index.
    """

    rho: jnp.ndarray  # (K,) base rates (used for non-crowd clients)
    crowd: jnp.ndarray  # (K,) {0,1} flash-crowd membership
    t_start: int
    t_end: int
    churn: float = 0.05
    base_avail: float = 0.1
    peak: float = 0.95

    def init_state(self):
        return jnp.ones(self.rho.shape, jnp.float32), jnp.zeros((), jnp.int32)

    def marginal_rate(self) -> jnp.ndarray:
        # crowd clients spend most of a long horizon outside the window
        return jnp.where(self.crowd > 0, self.base_avail, self.rho)

    def sample(self, rng: jax.Array, state):
        alive, t = state
        r_x, r_leave = jax.random.split(rng)
        in_w = ((t >= self.t_start) & (t < self.t_end)).astype(jnp.float32)
        alive = jnp.where(t == self.t_start, jnp.ones_like(alive), alive)
        crowd_rate = in_w * (alive * self.peak + (1.0 - alive) * self.base_avail) + (1.0 - in_w) * self.base_avail
        rate = jnp.where(self.crowd > 0, crowd_rate, self.rho)
        x = jax.random.bernoulli(r_x, rate).astype(jnp.float32)
        leave = jax.random.bernoulli(r_leave, jnp.full(alive.shape, self.churn)).astype(jnp.float32) * in_w
        alive = alive * (1.0 - leave)
        return x, (alive, t + 1)
