"""Named scenario configurations: one id per volatility regime.

A *scenario* is a recipe ``make(K, T, seed) -> (vol, rho_hint)``: a volatility
model sized to the population/horizon plus the marginal-rate hint handed to
rate-omniscient baselines (fedcs).  Everything downstream — the evaluation
harness, the ``scenarios`` benchmark suite, the examples — addresses
scenarios by these names, so adding a row here automatically adds it to the
selector x scenario grid.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.volatility import MarkovVolatility, make_volatility, paper_success_rates

from .traces import DiurnalVolatility, FlashCrowdVolatility, RegionalOutageVolatility

__all__ = ["Scenario", "SCENARIOS", "get_scenario", "list_scenarios", "make_scenario"]


@dataclass(frozen=True)
class Scenario:
    name: str
    make: Callable  # (K: int, T: int, seed: int) -> (vol, rho_hint)
    description: str


def _paper_rho(K: int) -> jnp.ndarray:
    return jnp.asarray(paper_success_rates(K))


def _paper_iid(K, T, seed):
    rho = _paper_rho(K)
    return make_volatility("bernoulli", rho), rho


def _markov(K, T, seed, stickiness=0.8):
    rho = _paper_rho(K)
    return MarkovVolatility(rho, stickiness), rho


def _deadline(K, T, seed):
    rho = _paper_rho(K)
    return make_volatility("deadline", rho, seed=seed), rho


def _diurnal(K, T, seed):
    rho = _paper_rho(K)
    # timezones: K clients spread uniformly around the day, shuffled so a
    # volatility class is not confounded with a longitude band
    phase = np.random.default_rng(seed).permutation(K).astype(np.float32) / K
    vol = DiurnalVolatility(rho=rho, phase=jnp.asarray(phase), amplitude=0.35, period=max(8, min(48, T // 4)))
    return vol, vol.marginal_rate()


def _regional(K, T, seed, n_regions=8):
    rho = _paper_rho(K)
    # contiguous client blocks per region (clients stay ordered by class
    # within a region because classes repeat across regions at this scale)
    region = jnp.asarray(np.arange(K) * n_regions // K, jnp.int32)
    vol = RegionalOutageVolatility(rho=rho, region=region, n_regions=n_regions)
    return vol, vol.marginal_rate()


def _flash_crowd(K, T, seed):
    rho = _paper_rho(K)
    crowd = (np.random.default_rng(seed).random(K) < 0.3).astype(np.float32)
    t_start, t_end = T // 4, T // 4 + max(2, T // 4)
    vol = FlashCrowdVolatility(rho=rho, crowd=jnp.asarray(crowd), t_start=t_start, t_end=t_end)
    return vol, vol.marginal_rate()


SCENARIOS: Dict[str, Scenario] = {
    s.name: s
    for s in [
        Scenario("paper_iid", _paper_iid, "paper §VI-A: iid Bernoulli, 4 rate classes"),
        Scenario("markov", _markov, "Gilbert-Elliott per client, stickiness 0.8"),
        Scenario(
            "markov_sticky",
            lambda K, T, seed: _markov(K, T, seed, stickiness=0.95),
            "Gilbert-Elliott per client, stickiness 0.95 (long outages)",
        ),
        Scenario("deadline", _deadline, "mechanistic deadline misses + network faults, calibrated to rho"),
        Scenario("diurnal", _diurnal, "timezone-phased sinusoidal availability"),
        Scenario("regional_outage", _regional, "8-region correlated Gilbert-Elliott outages"),
        Scenario("flash_crowd", _flash_crowd, "30% crowd surges in for a window, churns out"),
    ]
}


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name]


def make_scenario(name: str, K: int, T: int, seed: int = 0) -> Tuple[object, jnp.ndarray]:
    """Instantiate scenario ``name`` -> ``(vol, rho_hint)``."""
    return get_scenario(name).make(K, T, seed)


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)
