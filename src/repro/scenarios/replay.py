"""Bit-packed availability traces: record once, replay at fleet scale.

A success-bit trace is one bit per client per round, but the naive float32
``(T, K)`` representation is 32 bits each — 10 GB at K=1e6, T=2500.  Packed
uint8 (8 clients/byte, little-endian within the byte, the ``np.packbits``
``bitorder="little"`` convention) the same trace is ~312 MB and fits on one
device, where ``engine.scan_sim``'s packed override expands each round's row
on the fly (``repro.kernels.unpack_bits``) without ever materialising the
dense *input* trace.  At that scale the per-round scan *outputs* are the
remaining (T, K) hazard — pair the packed override with
``build_scan_runner(..., outputs="lean")``, which emits only per-round
scalars and keeps cumulative counts in the carried state.
``tests/test_scenarios.py`` pins packed replay bit-identical to the dense
``xs_override`` path, and lean counts bit-identical to full outputs.

``record_trace`` rolls any ``(init_state, sample)`` volatility model forward
and packs on-device in round chunks, so recording a million-client trace
never holds more than ``chunk * K`` float32 at once.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.unpack_bits import unpack_bits_ref

__all__ = [
    "packed_width",
    "packed_nbytes",
    "pack_trace",
    "unpack_trace",
    "pack_bits_jnp",
    "record_trace",
    "ReplayVolatility",
]


def packed_width(K: int) -> int:
    """Bytes per packed round row: ceil(K / 8)."""
    return (K + 7) // 8


def packed_nbytes(T: int, K: int) -> int:
    """Total bytes of a packed (T, K) trace."""
    return T * packed_width(K)


def pack_trace(xs: np.ndarray) -> np.ndarray:
    """(..., K) {0,1} -> (..., ceil(K/8)) uint8, little-endian bit order."""
    return np.packbits(np.asarray(xs).astype(np.uint8), axis=-1, bitorder="little")


def unpack_trace(packed: np.ndarray, K: int) -> np.ndarray:
    """(..., B) uint8 -> (..., K) float32; inverse of ``pack_trace``."""
    bits = np.unpackbits(np.asarray(packed, np.uint8), axis=-1, bitorder="little")
    return bits[..., :K].astype(np.float32)


def pack_bits_jnp(x: jax.Array) -> jax.Array:
    """On-device pack: (..., K) {0,1} float -> (..., ceil(K/8)) uint8."""
    K = x.shape[-1]
    pad = (-K) % 8
    if pad:
        x = jnp.concatenate([x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], axis=-1)
    b = x.reshape(*x.shape[:-1], -1, 8).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint8)


def record_trace(vol, T: int, seed: int = 0, chunk: int = 256) -> np.ndarray:
    """Roll ``vol`` forward T rounds and return the packed (T, ceil(K/8))
    uint8 trace.  Sampling and packing happen on device in ``chunk``-round
    scans, so peak memory is ``chunk * K`` float32 regardless of T."""

    def step(carry, _):
        key, vs = carry
        key, k2 = jax.random.split(key)
        x, vs = vol.sample(k2, vs)
        return (key, vs), pack_bits_jnp(x)

    @jax.jit
    def run_chunk(carry):
        return jax.lax.scan(step, carry, None, length=chunk)

    carry = (jax.random.PRNGKey(seed), vol.init_state())
    rows = []
    done = 0
    while done < T:
        carry, packed = run_chunk(carry)
        rows.append(np.asarray(packed))
        done += chunk
    return np.concatenate(rows)[:T]


@dataclass(frozen=True)
class ReplayVolatility:
    """Replay a recorded packed trace through the ``(init_state, sample)``
    protocol: state is the round index, ``sample`` ignores the rng and
    expands row t on the fly (the packed array stays uint8 on device).

    Rounds past the end of the trace repeat the last row
    (``dynamic_index_in_dim`` clamps); size the trace to the horizon.
    """

    packed: jnp.ndarray  # (T, ceil(K/8)) uint8
    K: int

    @property
    def rho(self) -> jnp.ndarray:
        """Empirical marginal of the recorded trace (the fedcs hint),
        accumulated in row chunks so the dense (T, K) trace never exists."""
        packed = np.asarray(self.packed)
        T = packed.shape[0]
        total = np.zeros(self.K, np.float64)
        chunk = max(1, min(1024, T))
        for i in range(0, T, chunk):
            total += unpack_trace(packed[i : i + chunk], self.K).sum(0, dtype=np.float64)
        return jnp.asarray(total / T, jnp.float32)

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def sample(self, rng: jax.Array, state):
        row = jax.lax.dynamic_index_in_dim(self.packed, state, keepdims=False)
        return unpack_bits_ref(row, self.K), state + 1
