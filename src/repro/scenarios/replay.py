"""Bit-packed availability traces: record once, replay at fleet scale.

A success-bit trace is one bit per client per round, but the naive float32
``(T, K)`` representation is 32 bits each — 10 GB at K=1e6, T=2500.  Packed
uint8 (8 clients/byte, little-endian within the byte, the ``np.packbits``
``bitorder="little"`` convention) the same trace is ~312 MB and fits on one
device, where ``engine.scan_sim``'s packed override expands each round's row
on the fly (``repro.kernels.unpack_bits``) without ever materialising the
dense *input* trace.  At that scale the per-round scan *outputs* are the
remaining (T, K) hazard — pair the packed override with
``build_scan_runner(..., outputs="lean")``, which emits only per-round
scalars and keeps cumulative counts in the carried state.
``tests/test_scenarios.py`` pins packed replay bit-identical to the dense
``xs_override`` path, and lean counts bit-identical to full outputs.

``record_trace`` rolls any ``(init_state, sample)`` volatility model forward
and packs on-device in round chunks, so recording a million-client trace
never holds more than ``chunk * K`` float32 at once.

Lag traces (async engine): completion lags in ``{0, 1, 2, DEAD_LAG}`` pack
to **2 bits per client** ("crumbs", 4 clients/byte, little-endian within the
byte — crumb ``j`` of byte ``b`` is client ``4*b + j``; code 3 is the dead
sentinel).  ``record_lag_trace`` freezes any lag model the same chunked way,
and ``ReplayLag`` replays it through the lag protocol so frozen *async*
scenarios replay exactly like sync ones (``repro.kernels.unpack_crumbs``
expands rows inside the scan next to ``unpack_bits``).

Disk format: ``save_packed_trace`` writes the packed array as a plain ``.npy``
plus a ``<path>.meta.json`` sidecar ``{"kind": "bits"|"lags", "K": K,
"T": T, "clients_per_byte": 8|4}``; ``load_packed_trace`` reopens it as an
``np.memmap`` (zero-copy, demand-paged), and ``replay_packed_stream`` drives
the scan engine chunk-by-chunk from the memmap — each chunk is device_put on
its own, so replay horizons are bounded by disk, not host RAM.  Round-trip is
bit-exact: ``load(save(x)) == x`` and a streamed replay is bit-identical to
the in-memory packed replay (pinned in ``tests/test_scenarios.py``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.volatility import DEAD_LAG
from repro.kernels.unpack_bits import unpack_bits_ref, unpack_crumbs_ref

__all__ = [
    "packed_width",
    "packed_nbytes",
    "pack_trace",
    "unpack_trace",
    "pack_bits_jnp",
    "record_trace",
    "ReplayVolatility",
    "lag_packed_width",
    "pack_lags",
    "unpack_lags",
    "pack_lags_jnp",
    "record_lag_trace",
    "ReplayLag",
    "save_packed_trace",
    "load_packed_trace",
    "replay_packed_stream",
]

_LAG_DEAD_CODE = 3  # 2-bit sentinel for "never completes" (DEAD_LAG)


def packed_width(K: int) -> int:
    """Bytes per packed round row: ceil(K / 8)."""
    return (K + 7) // 8


def packed_nbytes(T: int, K: int) -> int:
    """Total bytes of a packed (T, K) trace."""
    return T * packed_width(K)


def pack_trace(xs: np.ndarray) -> np.ndarray:
    """(..., K) {0,1} -> (..., ceil(K/8)) uint8, little-endian bit order."""
    return np.packbits(np.asarray(xs).astype(np.uint8), axis=-1, bitorder="little")


def unpack_trace(packed: np.ndarray, K: int) -> np.ndarray:
    """(..., B) uint8 -> (..., K) float32; inverse of ``pack_trace``."""
    bits = np.unpackbits(np.asarray(packed, np.uint8), axis=-1, bitorder="little")
    return bits[..., :K].astype(np.float32)


def pack_bits_jnp(x: jax.Array) -> jax.Array:
    """On-device pack: (..., K) {0,1} float -> (..., ceil(K/8)) uint8."""
    K = x.shape[-1]
    pad = (-K) % 8
    if pad:
        x = jnp.concatenate([x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], axis=-1)
    b = x.reshape(*x.shape[:-1], -1, 8).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint8)


def _chunked_marginal(packed: np.ndarray, K: int, expand, T: int | None = None, chunk: int = 1024) -> np.ndarray:
    """Per-client mean of ``expand(rows) -> (n, K)`` over the first T packed
    rows, accumulated in row chunks so the dense trace never exists (memmap
    inputs page in only the touched rows)."""
    packed = np.asarray(packed)
    T = packed.shape[0] if T is None else T
    total = np.zeros(K, np.float64)
    chunk = max(1, min(chunk, T))
    for i in range(0, T, chunk):
        total += expand(packed[i : min(i + chunk, T)]).sum(0, dtype=np.float64)
    return (total / T).astype(np.float32)


def record_trace(vol, T: int, seed: int = 0, chunk: int = 256) -> np.ndarray:
    """Roll ``vol`` forward T rounds and return the packed (T, ceil(K/8))
    uint8 trace.  Sampling and packing happen on device in ``chunk``-round
    scans, so peak memory is ``chunk * K`` float32 regardless of T."""

    def step(carry, _):
        key, vs = carry
        key, k2 = jax.random.split(key)
        x, vs = vol.sample(k2, vs)
        return (key, vs), pack_bits_jnp(x)

    @jax.jit
    def run_chunk(carry):
        return jax.lax.scan(step, carry, None, length=chunk)

    carry = (jax.random.PRNGKey(seed), vol.init_state())
    rows = []
    done = 0
    while done < T:
        carry, packed = run_chunk(carry)
        rows.append(np.asarray(packed))
        done += chunk
    return np.concatenate(rows)[:T]


@dataclass(frozen=True)
class ReplayVolatility:
    """Replay a recorded packed trace through the ``(init_state, sample)``
    protocol: state is the round index, ``sample`` ignores the rng and
    expands row t on the fly (the packed array stays uint8 on device).

    Rounds past the end of the trace repeat the last row
    (``dynamic_index_in_dim`` clamps); size the trace to the horizon.
    """

    packed: jnp.ndarray  # (T, ceil(K/8)) uint8
    K: int

    @property
    def rho(self) -> jnp.ndarray:
        """Empirical marginal of the recorded trace (the fedcs hint),
        accumulated in row chunks so the dense (T, K) trace never exists."""
        return jnp.asarray(_chunked_marginal(self.packed, self.K, lambda rows: unpack_trace(rows, self.K)))

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def sample(self, rng: jax.Array, state):
        row = jax.lax.dynamic_index_in_dim(self.packed, state, keepdims=False)
        return unpack_bits_ref(row, self.K), state + 1


# ---------------------------------------------------------------------------
# 2-bit packed lag traces (async engine)
# ---------------------------------------------------------------------------


def lag_packed_width(K: int) -> int:
    """Bytes per packed lag row: ceil(K / 4) at 2 bits per client."""
    return (K + 3) // 4


def _lag_codes(lags: np.ndarray) -> np.ndarray:
    """int32 lags {0, 1, 2, DEAD_LAG} -> uint8 crumb codes {0, 1, 2, 3}."""
    lags = np.asarray(lags)
    if ((lags > 2) | ((lags < 0) & (lags != DEAD_LAG))).any():
        raise ValueError("2-bit lag traces hold lags {0, 1, 2} and DEAD_LAG only; record with max_lag <= 2")
    return np.where(lags < 0, _LAG_DEAD_CODE, lags).astype(np.uint8)


def pack_lags(lags: np.ndarray) -> np.ndarray:
    """(..., K) int32 lags in {0, 1, 2, DEAD_LAG} -> (..., ceil(K/4)) uint8."""
    codes = _lag_codes(lags)
    K = codes.shape[-1]
    pad = (-K) % 4
    if pad:  # pad with dead clients, never decoded past K
        codes = np.concatenate([codes, np.full((*codes.shape[:-1], pad), _LAG_DEAD_CODE, np.uint8)], axis=-1)
    quads = codes.reshape(*codes.shape[:-1], -1, 4).astype(np.uint16)
    shifts = np.arange(4, dtype=np.uint16) * 2
    return np.bitwise_or.reduce(quads << shifts, axis=-1).astype(np.uint8)


def unpack_lags(packed: np.ndarray, K: int) -> np.ndarray:
    """(..., B) uint8 -> (..., K) int32 lags; inverse of ``pack_lags``."""
    packed = np.asarray(packed, np.uint8)
    shifts = np.arange(4, dtype=np.uint8) * 2
    codes = (packed[..., None] >> shifts) & 3
    codes = codes.reshape(*packed.shape[:-1], packed.shape[-1] * 4)[..., :K].astype(np.int32)
    return np.where(codes == _LAG_DEAD_CODE, DEAD_LAG, codes)


def pack_lags_jnp(lag: jax.Array) -> jax.Array:
    """On-device lag pack: (..., K) int32 -> (..., ceil(K/4)) uint8.

    Codes are clamped into the 2-bit range so an out-of-range lag can never
    bleed bits into a neighbouring client's crumb; traced code cannot raise,
    so range *detection* is the recorder's job (``record_lag_trace`` tracks
    an overflow flag and raises host-side).
    """
    K = lag.shape[-1]
    codes = jnp.where(lag < 0, _LAG_DEAD_CODE, jnp.minimum(lag, 2)).astype(jnp.uint8)
    pad = (-K) % 4
    if pad:
        codes = jnp.concatenate(
            [codes, jnp.full((*codes.shape[:-1], pad), _LAG_DEAD_CODE, jnp.uint8)], axis=-1
        )
    quads = codes.reshape(*codes.shape[:-1], -1, 4)
    weights = jnp.left_shift(jnp.uint8(1), jnp.arange(4, dtype=jnp.uint8) * 2)
    return jnp.sum(quads * weights, axis=-1, dtype=jnp.uint8)


def record_lag_trace(lag_model, T: int, seed: int = 0, chunk: int = 256) -> np.ndarray:
    """Roll a lag model forward T rounds; returns the packed (T, ceil(K/4))
    uint8 crumb trace.  Same chunked on-device discipline as ``record_trace``
    (and the same per-round ``split(key)`` PRNG), so the two trace kinds are
    interchangeable to record.  Lags beyond 2 do not fit 2 bits — build the
    model with ``max_lag <= 2`` (the replayed async engine then needs
    ``staleness <= 2``, which is the regime the ROADMAP item names)."""
    max_lag = getattr(lag_model, "max_lag", None)
    if max_lag is not None and max_lag > 2:
        raise ValueError(f"2-bit lag traces hold lags up to 2; model has max_lag={max_lag}")

    def step(carry, _):
        key, vs, bad = carry
        key, k2 = jax.random.split(key)
        lag, vs = lag_model.sample(k2, vs)
        return (key, vs, bad | jnp.any(lag > 2)), pack_lags_jnp(lag)

    @jax.jit
    def run_chunk(carry):
        return jax.lax.scan(step, carry, None, length=chunk)

    carry = (jax.random.PRNGKey(seed), lag_model.init_state(), jnp.zeros((), bool))
    rows = []
    done = 0
    while done < T:
        carry, packed = run_chunk(carry)
        if bool(carry[2]):  # duck-typed models without a max_lag attribute
            raise ValueError("lag model emitted a lag > 2; 2-bit traces cannot represent it")
        rows.append(np.asarray(packed))
        done += chunk
    return np.concatenate(rows)[:T]


@dataclass(frozen=True)
class ReplayLag:
    """Replay a recorded 2-bit lag trace through the lag-model protocol
    (int32 lags: 0 on time, 1-2 late, ``DEAD_LAG`` never), so the async
    engine (``build_scan_runner(..., staleness=S)``) replays frozen volatile
    scenarios exactly like the sync ``ReplayVolatility`` path.  State is the
    round index; rows expand on the fly via ``repro.kernels.unpack_crumbs``."""

    packed: jnp.ndarray  # (T, ceil(K/4)) uint8
    K: int

    @property
    def rho(self) -> jnp.ndarray:
        """Empirical on-time marginal of the recorded trace, in row chunks."""
        return jnp.asarray(_chunked_marginal(self.packed, self.K, lambda rows: unpack_lags(rows, self.K) == 0))

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def sample(self, rng: jax.Array, state):
        row = jax.lax.dynamic_index_in_dim(self.packed, state, keepdims=False)
        codes = unpack_crumbs_ref(row, self.K)
        return jnp.where(codes == _LAG_DEAD_CODE, DEAD_LAG, codes), state + 1


# ---------------------------------------------------------------------------
# Disk-backed traces: mmap + chunked device feed
# ---------------------------------------------------------------------------


def save_packed_trace(path: str, packed: np.ndarray, K: int, kind: str = "bits") -> str:
    """Write a packed trace as ``<path>.npy`` + ``<path>.meta.json``.

    ``kind`` is ``"bits"`` (1-bit success trace, 8 clients/byte) or
    ``"lags"`` (2-bit lag trace, 4 clients/byte).  Returns the array path.
    """
    if kind not in ("bits", "lags"):
        raise ValueError(f"unknown trace kind {kind!r} (want 'bits' or 'lags')")
    packed = np.asarray(packed, np.uint8)
    want = packed_width(K) if kind == "bits" else lag_packed_width(K)
    if packed.ndim != 2 or packed.shape[1] != want:
        raise ValueError(f"{kind} trace for K={K} must be (T, {want}) uint8, got {packed.shape}")
    base = path[:-4] if path.endswith(".npy") else path
    np.save(base + ".npy", packed)
    meta = {"kind": kind, "K": int(K), "T": int(packed.shape[0]), "clients_per_byte": 8 if kind == "bits" else 4}
    with open(base + ".meta.json", "w") as f:
        json.dump(meta, f)
    return base + ".npy"


def load_packed_trace(path: str, mmap: bool = True):
    """Reopen a saved trace; returns ``(array, meta)`` where ``array`` is an
    ``np.memmap`` view (``mmap=True``) — rows are paged in from disk as the
    replay touches them, so the horizon never has to fit in host RAM."""
    base = path[:-4] if path.endswith(".npy") else path
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    arr = np.load(base + ".npy", mmap_mode="r" if mmap else None)
    if arr.shape[0] != meta["T"]:
        raise ValueError(f"trace length {arr.shape[0]} disagrees with sidecar T={meta['T']}")
    return arr, meta


def replay_packed_stream(
    scheme: str,
    path: str,
    k: int,
    T: int | None = None,
    chunk: int = 512,
    quota: str = "const",
    frac: float = 0.0,
    eta: float = 0.5,
    seed: int = 0,
    rho=None,
    staleness: int | None = None,
    alpha: float = 0.5,
    feedback: str = "deadline",
    taps: bool = False,
):
    """Replay a disk-resident packed trace through the scan engine in
    ``chunk``-round pieces: the memmap is sliced per chunk and each slice is
    device_put on its own, so peak host+device memory is ``chunk`` rows no
    matter how long the horizon — the trace streams from disk.

    A ``"bits"`` trace replays through the synchronous engine, bit-identical
    to an in-memory ``scan_selection_sim(..., packed_override=...)`` run; a
    ``"lags"`` trace replays through the *async* engine
    (``staleness`` defaults to 2, the most a 2-bit trace can hold;
    ``feedback`` picks the E3CS policy), bit-identical to an in-memory
    ``ReplayLag`` run.  Either way the quota schedule spans the full horizon
    (``sigma_t`` keys off the carried ``state.t``) and the PRNG key — plus,
    async, the staleness rings — are carried across chunks
    (``RoundProgram.build_runner(carry_key=True)``).  Returns the
    lean-outputs dict (per-round scalars + final counts; async adds
    ``on_time`` / ``stale`` / ``cep``; ``rho`` only when it was actually
    computed or supplied — only the ``fedcs`` selector consumes the
    marginal, so other schemes skip the extra streaming pass over the
    trace).

    ``taps=True`` threads the ``ROUND_TAPS`` counter pytree through the
    streamed carry and folds the per-chunk gauge rows back into one stream:
    the result gains a ``"taps"`` entry (``{"series": {gauge: (T,)},
    "counters": {...}}``), bit-identical to a one-shot taps run however the
    horizon is chunked (pinned in ``tests/test_obs.py``) — K=1e7 replays
    emit telemetry without abandoning the streaming memory envelope.
    """
    from repro.configs.base import FLConfig
    from repro.core.volatility import make_volatility
    from repro.engine.round_program import RoundProgram
    from repro.obs.taps import ROUND_TAPS

    packed, meta = load_packed_trace(path)
    is_lags = meta["kind"] == "lags"
    if is_lags:
        staleness = 2 if staleness is None else int(staleness)
    elif staleness is not None:
        raise ValueError("staleness applies to 'lags' traces; this trace holds success bits")
    K = meta["K"]
    T = meta["T"] if T is None else min(int(T), meta["T"])
    chunk = min(chunk, T)
    if rho is None and scheme == "fedcs":
        expand = (lambda rows: unpack_lags(rows, K) == 0) if is_lags else (lambda rows: unpack_trace(rows, K))
        rho = _chunked_marginal(packed, K, expand, T=T)
    rho_out = rho
    if rho is None:
        rho = np.zeros(K, np.float32)  # inert for every non-fedcs scheme
    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac, eta=eta)
    vol = make_volatility("bernoulli", jnp.asarray(rho))  # placeholder state; outcomes come from the trace
    program = RoundProgram(
        fl=fl, vol=vol, rho=rho, override="packed_lags" if is_lags else "packed",
        staleness=staleness, alpha=alpha, feedback=feedback,
    )
    run, state = program.build_runner(outputs="lean", carry_key=True, scan_length=chunk, taps=taps)
    run_tail = (
        program.build_runner(outputs="lean", carry_key=True, scan_length=T % chunk, taps=taps)[0]
        if T % chunk
        else None
    )
    key = jax.random.PRNGKey(seed)
    rings = program.init_rings() if is_lags else None
    tapc = ROUND_TAPS.init_counters() if taps else None
    cols = ([], []) if not is_lags else ([], [], [])
    rows = []
    for lo in range(0, T, chunk):
        hi = min(lo + chunk, T)
        step_run = run if hi - lo == chunk else run_tail
        xs = jnp.asarray(packed[lo:hi])  # one chunk of rows on device
        if is_lags:
            if taps:
                state, key, rings, tapc, *outs = step_run(state, key, rings, tapc, xs)
            else:
                state, key, rings, *outs = step_run(state, key, rings, xs)
        else:
            if taps:
                state, key, tapc, *outs = step_run(state, key, tapc, xs)
            else:
                state, key, *outs = step_run(state, key, xs)
        if taps:
            *outs, row = outs
            rows.append(row)
        for c, o in zip(cols, outs):
            c.append(np.asarray(o))
    if is_lags:
        on_time, stale, sigmas = (np.concatenate(c) for c in cols)
        out = {
            "on_time": on_time,
            "stale": stale,
            "sigmas": sigmas,
            "counts": np.asarray(state.sel_counts),
            "cep": float(state.cep),
        }
    else:
        successes, sigmas = (np.concatenate(c) for c in cols)
        out = {
            "successes": successes,
            "sigmas": sigmas,
            "counts": np.asarray(state.sel_counts),
        }
    if rho_out is not None:
        out["rho"] = np.asarray(rho_out)
    if taps:
        out["taps"] = {
            "series": {n: np.concatenate([np.asarray(r[n]) for r in rows]) for n in rows[0]},
            "counters": {n: float(v) for n, v in tapc.items()},
        }
    return out
