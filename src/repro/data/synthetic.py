"""Synthetic datasets with the *statistical structure* the paper's
experiments rely on (offline container — no EMNIST/CIFAR downloads).

``make_image_dataset`` draws class-conditional images: each class c gets a
random smooth prototype ``mu_c``; samples are ``mu_c + noise`` pushed through
a mild nonlinearity.  A CNN can genuinely learn this task (accuracy rises
from chance to >90%), and *biased client selection measurably hurts*: under
the primary-label partition, a model trained on a subset of clients overfits
their primary classes — exactly the mechanism behind the paper's Fig. 1/
fairness story.

``make_lm_dataset`` draws token streams from a per-client mixture of k-gram
Markov chains, giving the LM-scale FL runs heterogeneous local distributions.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

__all__ = ["make_image_dataset", "make_lm_dataset"]


def make_image_dataset(
    n_classes: int,
    img_shape: Tuple[int, int, int],
    n_train: int,
    n_test: int,
    seed: int = 0,
    noise: float = 0.9,
) -> Dict[str, np.ndarray]:
    """Returns {'x': (N,H,W,C), 'y': (N,), 'x_test', 'y_test'} float32/int32."""
    rng = np.random.default_rng(seed)
    H, W, C = img_shape
    # smooth prototypes: low-frequency random fields per class
    base = rng.normal(size=(n_classes, H // 4 + 1, W // 4 + 1, C)).astype(np.float32)
    protos = np.stack([_upsample(b, H, W) for b in base])  # (n_classes, H, W, C)
    protos /= protos.std(axis=(1, 2, 3), keepdims=True) + 1e-6

    def draw(n):
        y = rng.integers(0, n_classes, n).astype(np.int32)
        x = protos[y] + noise * rng.normal(size=(n, H, W, C)).astype(np.float32)
        x = np.tanh(x)
        return x.astype(np.float32), y

    x, y = draw(n_train)
    xt, yt = draw(n_test)
    return {"x": x, "y": y, "x_test": xt, "y_test": yt}


def _upsample(b: np.ndarray, H: int, W: int) -> np.ndarray:
    """Bilinear-ish upsample of a coarse field to (H, W, C)."""
    h0, w0, C = b.shape
    yi = np.linspace(0, h0 - 1, H)
    xi = np.linspace(0, w0 - 1, W)
    y0 = np.floor(yi).astype(int)
    x0 = np.floor(xi).astype(int)
    y1 = np.minimum(y0 + 1, h0 - 1)
    x1 = np.minimum(x0 + 1, w0 - 1)
    fy = (yi - y0)[:, None, None]
    fx = (xi - x0)[None, :, None]
    out = (
        b[y0][:, x0] * (1 - fy) * (1 - fx)
        + b[y0][:, x1] * (1 - fy) * fx
        + b[y1][:, x0] * fy * (1 - fx)
        + b[y1][:, x1] * fy * fx
    )
    return out.astype(np.float32)


def make_lm_dataset(vocab: int, n_tokens: int, n_chains: int = 8, seed: int = 0) -> np.ndarray:
    """Token stream from a mixture of sparse bigram chains (heterogeneous)."""
    rng = np.random.default_rng(seed)
    out = np.empty(n_tokens, np.int32)
    # sparse transition tables: each token can go to 16 candidates
    cands = rng.integers(0, vocab, (n_chains, min(vocab, 4096), 16))
    t = int(rng.integers(0, vocab))
    chain = int(rng.integers(0, n_chains))
    for i in range(n_tokens):
        if rng.random() < 0.001:
            chain = int(rng.integers(0, n_chains))
        row = cands[chain, t % cands.shape[1]]
        t = int(row[rng.integers(0, 16)])
        out[i] = t
    return out
