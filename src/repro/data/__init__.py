from .synthetic import make_image_dataset, make_lm_dataset
from .partition import (
    partition_iid,
    partition_primary_label,
    partition_dirichlet,
    split_local_test,
)
from .pipeline import ClientStore, lm_client_batches
