"""Client data partitioning (paper §VI-A "Simulation of data distribution").

* iid: each client samples |D_i| examples uniformly.
* primary-label non-iid (the paper's scheme): each client gets one primary
  label; 80% of its data carries that label, 20% is drawn from the rest.
* Dirichlet(alpha) non-iid (beyond paper; standard FL benchmark knob).

Each client reserves 10% of its shard for local testing, as in the paper.
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["partition_iid", "partition_primary_label", "partition_dirichlet", "split_local_test"]


def partition_iid(y: np.ndarray, K: int, per_client: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.choice(len(y), per_client, replace=True) for _ in range(K)]


def partition_primary_label(
    y: np.ndarray, K: int, per_client: int, primary_frac: float = 0.8, seed: int = 0
) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    by_class = {c: np.where(y == c)[0] for c in classes}
    rest = np.arange(len(y))
    out = []
    n_primary = int(primary_frac * per_client)
    for i in range(K):
        c = classes[rng.integers(0, len(classes))]
        prim = rng.choice(by_class[c], n_primary, replace=True)
        other_pool = rest[y[rest] != c]
        oth = rng.choice(other_pool, per_client - n_primary, replace=True)
        out.append(np.concatenate([prim, oth]))
    return out


def partition_dirichlet(y: np.ndarray, K: int, per_client: int, alpha: float = 0.3, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    by_class = {c: np.where(y == c)[0] for c in classes}
    out = []
    for i in range(K):
        mix = rng.dirichlet(alpha * np.ones(len(classes)))
        counts = rng.multinomial(per_client, mix)
        idx = [rng.choice(by_class[c], n, replace=True) for c, n in zip(classes, counts) if n > 0]
        out.append(np.concatenate(idx) if idx else np.empty(0, int))
    return out


def split_local_test(indices: List[np.ndarray], test_frac: float = 0.1, seed: int = 0):
    rng = np.random.default_rng(seed)
    train, test = [], []
    for idx in indices:
        perm = rng.permutation(idx)
        n_test = max(1, int(test_frac * len(perm)))
        test.append(perm[:n_test])
        train.append(perm[n_test:])
    return train, test
