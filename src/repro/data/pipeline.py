"""Batched data pipeline for the FL round.

Each round needs, for the k selected clients, ``E_i`` epochs of mini-batches
of size ``B``.  To keep the round jit-compatible, the host pre-gathers a
dense tensor of per-client batches — ``(k, n_steps, B, ...)`` — and the
jitted round scans it; variable epoch counts become a step mask.

For LM-scale runs, ``lm_client_batches`` carves a token stream into
per-client contiguous shards (heterogeneous bigram mixtures make them
non-iid) and emits (k, n_steps, B, S) token blocks.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

__all__ = ["ClientStore", "lm_client_batches"]


class ClientStore:
    """Holds the full dataset + per-client index lists; serves round batches."""

    def __init__(self, data: Dict[str, np.ndarray], client_indices: List[np.ndarray], seed: int = 0):
        self.data = data
        self.clients = client_indices
        self.rng = np.random.default_rng(seed)

    @property
    def K(self) -> int:
        return len(self.clients)

    def sizes(self) -> np.ndarray:
        return np.array([len(c) for c in self.clients], np.float32)

    def round_batches(self, selected: Sequence[int], epochs: np.ndarray, batch_size: int, n_steps: int = 0):
        """Gather (k, n_steps, B, ...) x/y tensors + (k, n_steps) step mask.

        ``n_steps`` defaults to ``max_i epochs_i * ceil(|D_i| / B)`` over the
        cohort, but callers should pass a *static* upper bound so the jitted
        round compiles once; clients with fewer steps are masked (their
        trailing steps are no-ops in the local-update scan).
        """
        sel = list(selected)
        steps_per_epoch = [max(1, len(self.clients[i]) // batch_size) for i in sel]
        if not n_steps:
            n_steps = max(int(e) * s for e, s in zip(epochs[sel], steps_per_epoch))
        xs, ys, mask = [], [], []
        for i, spe in zip(sel, steps_per_epoch):
            idx = self.clients[i]
            tot = min(int(epochs[i]) * spe, n_steps)
            batches = []
            for e in range(int(epochs[i])):
                perm = self.rng.permutation(idx)[: spe * batch_size]
                batches.append(perm.reshape(spe, batch_size))
            b = np.concatenate(batches, 0)[:tot]  # (tot, B)
            pad = n_steps - tot
            if pad > 0:
                b = np.concatenate([b, np.tile(b[-1:], (pad, 1))], 0)
            xs.append(self.data["x"][b])
            ys.append(self.data["y"][b])
            mask.append(np.concatenate([np.ones(tot), np.zeros(pad)]).astype(np.float32))
        return np.stack(xs), np.stack(ys), np.stack(mask)

    def eval_batch(self, n: int = 2048, test: bool = True):
        x = self.data["x_test" if test else "x"]
        y = self.data["y_test" if test else "y"]
        n = min(n, len(y))
        return x[:n], y[:n]


def lm_client_batches(stream: np.ndarray, K: int, k_sel: Sequence[int], n_steps: int, B: int, S: int, seed: int = 0):
    """(k, n_steps, B, S+1) token blocks from per-client stream shards."""
    rng = np.random.default_rng(seed)
    shard = len(stream) // K
    out = []
    for i in k_sel:
        lo = i * shard
        starts = rng.integers(lo, lo + shard - S - 1, (n_steps, B))
        blk = np.stack([[stream[s : s + S + 1] for s in row] for row in starts])
        out.append(blk)
    return np.stack(out).astype(np.int32)
