"""Llama-3.1 405B [arXiv:2407.21783] — GQA kv=8, 128k vocab."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-405b", family="dense", source="arXiv:2407.21783",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab=128256, act="silu", rope_theta=500000.0,
    fl_mapping="silo",
))
