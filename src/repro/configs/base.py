"""Config system: model architecture + FL + run configs.

Plain dataclasses (dependency-light), a registry keyed by ``--arch`` id, and
reduced *smoke* variants derived mechanically from any full config.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

__all__ = [
    "ModelConfig",
    "FLConfig",
    "InputShape",
    "INPUT_SHAPES",
    "register",
    "get_config",
    "list_archs",
    "smoke_variant",
]


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""  # citation for the config

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None  # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    act: str = "silu"  # silu | geglu | gelu | sqrelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    emb_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    attn_logit_softcap: Optional[float] = None

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0  # per-expert ffn width
    n_dense_layers: int = 0  # leading dense layers (deepseek-v3 uses 3)
    d_ff_dense: int = 0  # ffn width of those dense layers
    router_aux_coef: float = 0.001  # load-balance loss coefficient
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"  # einsum (small E) | scatter (production scale)
    mtp: bool = False  # deepseek multi-token-prediction aux head

    # attention flavour
    attn: str = "gqa"  # gqa | mla
    q_lora_rank: int = 0  # MLA
    kv_lora_rank: int = 0  # MLA
    qk_nope_head_dim: int = 0  # MLA
    qk_rope_head_dim: int = 0  # MLA
    v_head_dim: int = 0  # MLA
    mla_absorb: bool = False  # absorbed-matmul decode (beyond-paper perf)

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_ngroups: int = 1

    # hybrid (zamba2-style): shared attention block every N ssm layers
    hybrid_attn_every: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_len: int = 1500  # stubbed conv-frontend output frames

    # vlm (qwen2-vl): stubbed patch embeddings
    n_patches: int = 0
    d_patch: int = 0

    # serving
    sliding_window: int = 0  # 0 = full attention; >0 enables SWA serving mode

    # numerics / distribution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    fl_mapping: str = "cohort"  # cohort | silo (see DESIGN.md §3)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def n_params(self) -> int:
        """Approximate parameter count (used for memory planning & 6ND)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" or (self.family == "hybrid" and True):
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_headdim
            per = (
                d * (2 * d_in + 2 * self.ssm_ngroups * self.ssm_state + nheads)
                + d_in * d  # out proj
                + d_in * self.ssm_conv_width
                + 2 * nheads
            )
            ssm_total = per * L + emb
            if self.family == "ssm":
                return ssm_total
            # hybrid adds one shared attention+mlp block
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            mlp_mult = 3 if self.act in ("silu", "geglu") else 2
            return ssm_total + attn + mlp_mult * d * self.d_ff
        if self.attn == "mla":
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp_mult = 3 if self.act in ("silu", "geglu") else 2
        if self.family == "moe" and self.n_experts:
            n_moe = L - self.n_dense_layers
            moe = n_moe * (
                (self.n_experts + self.n_shared_experts) * mlp_mult * d * self.d_expert + d * self.n_experts
            )
            dense = self.n_dense_layers * mlp_mult * d * (self.d_ff_dense or self.d_ff)
            return emb + L * attn + moe + dense
        enc = 0
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.n_enc_layers * (attn + mlp_mult * d * self.d_ff)
            dec = L * (2 * attn + mlp_mult * d * self.d_ff)
            return emb + enc + dec
        return emb + L * (attn + mlp_mult * d * self.d_ff)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if self.family != "moe" or not self.n_experts:
            return self.n_params()
        mlp_mult = 3 if self.act in ("silu", "geglu") else 2
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = (
            d * self.q_lora_rank
            + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            + d * (self.kv_lora_rank + self.qk_rope_head_dim)
            + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            + self.n_heads * self.v_head_dim * d
            if self.attn == "mla"
            else d * self.resolved_head_dim * (self.n_heads + 2 * self.n_kv_heads)
            + self.n_heads * self.resolved_head_dim * d
        )
        n_moe = L - self.n_dense_layers
        active_moe = n_moe * ((self.moe_top_k + self.n_shared_experts) * mlp_mult * d * self.d_expert)
        dense = self.n_dense_layers * mlp_mult * d * (self.d_ff_dense or self.d_ff)
        return emb + L * attn + active_moe + dense


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning run config (paper Table I + selection scheme)."""

    K: int = 100  # total clients
    k: int = 20  # cohort size per round
    rounds: int = 400
    scheme: str = "e3cs"  # e3cs | random | fedcs | pow_d | ucb
    quota: str = "const"  # const | inc | linear | cosine
    quota_frac: float = 0.5  # sigma_t = frac * k/K for const
    eta: float = 0.5  # E3CS learning rate
    sampler: str = "plackett_luce"  # plackett_luce | systematic
    allocator: str = "sort"  # sort (paper case-analysis) | bisect (sort-free, shardable)
    pow_d: int = 40  # candidate-set size for pow-d
    # local update (o1)
    local_update: str = "fedavg"  # fedavg | fedprox
    prox_coef: float = 0.5
    local_epochs: Tuple[int, ...] = (1, 2, 3, 4)  # heterogeneous, sampled per client
    batch_size: int = 40
    lr: float = 1e-2
    momentum: float = 0.9
    # aggregation (o2)
    aggregation: str = "fedavg"  # fedavg (data-size weighted) | mean | epoch_weighted
    # async rounds: late-but-alive updates kept for S rounds, credited alpha**lag
    staleness_rounds: int = 0  # S: staleness buffer depth; 0 = sync deadline drop
    staleness_alpha: float = 0.5  # decay per round of lag
    late_prob: float = 0.7  # P(a missed-deadline client still completes)
    lag_decay: float = 0.5  # geometric lag tail: P(one more round) = 1 - lag_decay
    # volatility
    volatility: str = "bernoulli"  # builtin (bernoulli | markov | deadline) or a repro.scenarios name
    success_rates: Tuple[float, ...] = (0.1, 0.3, 0.6, 0.9)
    markov_stickiness: float = 0.8
    # data
    samples_per_client: int = 500
    non_iid: bool = True
    primary_frac: float = 0.8
    seed: int = 0


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import registers all known archs lazily

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():

    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    hd = 64
    kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep the GQA/MQA character: preserve heads-per-kv ratio where possible
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    kv = max(1, n_heads // ratio)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=min(cfg.d_ff, 512) or 512,
        vocab=min(cfg.vocab, 512),
        remat=False,
        dtype="float32",
        param_dtype="float32",
        fl_mapping="cohort",
    )
    if cfg.family == "moe":
        kw.update(
            n_experts=min(cfg.n_experts, 4),
            moe_top_k=min(cfg.moe_top_k, 2),
            d_expert=min(cfg.d_expert, 128) or 128,
            n_dense_layers=min(cfg.n_dense_layers, 1),
            d_ff_dense=min(cfg.d_ff_dense, 256) if cfg.d_ff_dense else 0,
        )
    if cfg.attn == "mla":
        kw.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=min(cfg.ssm_state, 16) or 16, ssm_headdim=32, ssm_chunk=32)
        if cfg.family == "hybrid":
            kw.update(n_layers=4, hybrid_attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, enc_len=64)
    if cfg.family == "vlm":
        kw.update(n_patches=16, d_patch=64)
        if cfg.mrope_sections is not None:
            # scale M-RoPE sections to the reduced head_dim (sum*2 == hd)
            kw.update(mrope_sections=(8, 12, 12))
    if cfg.sliding_window:
        kw.update(sliding_window=min(cfg.sliding_window, 64))
    return replace(cfg, **kw)
