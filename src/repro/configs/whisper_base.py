"""Whisper base [arXiv:2212.04356] — enc-dec; mel+conv frontend stubbed to
precomputed frame embeddings (B, 1500, 512)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="encdec", source="arXiv:2212.04356",
    n_layers=6, n_enc_layers=6, enc_len=1500,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865, act="gelu", norm="layernorm",
    fl_mapping="cohort",
))
