"""Qwen2-VL 72B [arXiv:2409.12191] — M-RoPE, dynamic-resolution ViT stubbed
to precomputed patch embeddings (d_patch=1280, the ViT hidden size)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm", source="arXiv:2409.12191",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, act="silu", rope_theta=1000000.0,
    mrope_sections=(16, 24, 24), n_patches=1024, d_patch=1280,
    fl_mapping="silo",
))
