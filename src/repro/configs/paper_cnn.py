"""The paper's own FL workloads (§VI-A): small CNNs for EMNIST-Letter and
CIFAR-10 (reproduced against synthetic class-conditional data of matching
shape — see repro.data.synthetic)."""
from .base import ModelConfig, register

# Encoded via the generic ModelConfig where sensible fields are reused;
# the CNN definitions live in repro.models.cnn (not the transformer stack).
EMNIST_CNN = register(ModelConfig(
    name="emnist-cnn", family="cnn", source="paper sec VI-A (EMNIST-Letter)",
    n_layers=2, d_model=10, d_ff=1280, vocab=26,  # conv channels / fc1 / classes
))
CIFAR_CNN = register(ModelConfig(
    name="cifar-cnn", family="cnn", source="paper sec VI-A (CIFAR-10)",
    n_layers=2, d_model=64, d_ff=384, vocab=10,
))
