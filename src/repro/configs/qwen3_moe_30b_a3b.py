"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, act="silu", rope_theta=1000000.0,
    n_experts=128, moe_top_k=8, n_shared_experts=0, d_expert=768, moe_impl="scatter",
    fl_mapping="silo",
))
