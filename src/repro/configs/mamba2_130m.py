"""Mamba2 130M [arXiv:2405.21060] — SSD (state-space duality), attn-free."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m", family="ssm", source="arXiv:2405.21060",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, tie_embeddings=True, norm="rmsnorm",
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    fl_mapping="cohort",
))
