"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8,
3 leading dense layers (d_ff 18432), MTP auxiliary head."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, act="silu", rope_theta=10000.0,
    n_experts=256, moe_top_k=8, n_shared_experts=1, d_expert=2048,
    n_dense_layers=3, d_ff_dense=18432, mtp=True, moe_impl="scatter",
    attn="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    fl_mapping="silo",
))
