"""Nemotron-4 15B [arXiv:2402.16819] — GQA kv=8, squared-ReLU MLP."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b", family="dense", source="arXiv:2402.16819",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000, act="sqrelu", norm="layernorm",
    rope_theta=10000.0, fl_mapping="cohort",
))
