"""Gemma 2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA, tied embeddings."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-2b", family="dense", source="arXiv:2403.08295",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="geglu", tie_embeddings=True, emb_scale=True,
    rope_theta=10000.0, fl_mapping="cohort",
))
