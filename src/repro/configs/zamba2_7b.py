"""Zamba2 7B [arXiv:2411.15242] — Mamba2 backbone + weight-shared attention
block applied every 6 SSM layers (81 layers total)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, act="geglu",
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    hybrid_attn_every=6, sliding_window=0,
    fl_mapping="cohort",
))
