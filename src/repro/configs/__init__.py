from .base import (
    ModelConfig, FLConfig, InputShape, INPUT_SHAPES,
    register, get_config, list_archs, smoke_variant,
)
from . import (  # noqa: F401  (registration side-effects)
    stablelm_1_6b, llama3_405b, qwen2_vl_72b, gemma_2b, deepseek_v3_671b,
    mamba2_130m, nemotron_4_15b, qwen3_moe_30b_a3b, zamba2_7b, whisper_base,
    paper_cnn,
)

ASSIGNED = [
    "stablelm-1.6b", "llama3-405b", "qwen2-vl-72b", "gemma-2b",
    "deepseek-v3-671b", "mamba2-130m", "nemotron-4-15b",
    "qwen3-moe-30b-a3b", "zamba2-7b", "whisper-base",
]
