"""Pytree checkpointing: msgpack + zstd, no external deps beyond stdlib-ish.

Layout: a single ``.ckpt`` file holding {tree structure, leaf metadata,
zstd-compressed concatenated leaf bytes}.  Works for params, optimizer and
server state (selector weights, round counters, rng keys).
"""
from __future__ import annotations

import io
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

import zlib

try:  # optional: prefer zstd for new checkpoints when available
    import zstandard
except ModuleNotFoundError:
    zstandard = None

_CODEC = "zstd" if zstandard is not None else "zlib"


def _compress(data: bytes) -> bytes:
    if _CODEC == "zstd":
        return zstandard.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 6)


def _decompress(data: bytes, codec: str) -> bytes:
    """Dispatch on the codec the checkpoint was *written* with: zlib is
    always decodable (stdlib), zstd only when the module is importable."""
    if codec == "zlib":
        return zlib.decompress(data)
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed; install 'zstandard' to restore")
        return zstandard.ZstdDecompressor().decompress(data)
    raise ValueError(f"unknown checkpoint codec {codec!r}")

__all__ = ["save", "restore", "latest_checkpoint"]


def _to_host(tree):
    return jax.tree.map(lambda x: np.asarray(x), tree)


def save(path: str, tree: Any, step: int = 0) -> str:
    host = _to_host(tree)
    leaves, treedef = jax.tree.flatten(host)
    meta = []
    buf = io.BytesIO()
    for leaf in leaves:
        a = np.asarray(leaf)
        # bfloat16 has no numpy dtype string portable via msgpack; view bytes
        dtype = str(a.dtype)
        meta.append({"shape": list(a.shape), "dtype": dtype})
        buf.write(np.ascontiguousarray(a).tobytes() if a.dtype != jnp.bfloat16 else a.view(np.uint16).tobytes())
    payload = {
        "step": step,
        "treedef": str(treedef),
        "structure": msgpack.packb(jax.tree.map(lambda _: 0, host), default=_pack_default),
        "meta": meta,
        "codec": _CODEC,
        "data": _compress(buf.getvalue()),
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, default=_pack_default))
        f.flush()
        os.fsync(f.fileno())  # a crash mid-write must never replace a good checkpoint
    os.replace(tmp, path)
    return path


def _pack_default(o):
    raise TypeError(f"unpackable {type(o)}")


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)
    raw = _decompress(payload["data"], payload.get("codec", "zstd"))
    leaves_like, treedef = jax.tree.flatten(like)
    out = []
    off = 0
    for leaf, meta in zip(leaves_like, payload["meta"]):
        shape = tuple(meta["shape"])
        dtype = meta["dtype"]
        if dtype == "bfloat16":
            n = int(np.prod(shape)) * 2
            a = jnp.asarray(np.frombuffer(raw[off : off + n], np.uint16).reshape(shape)).view(jnp.bfloat16)
        else:
            npdt = np.dtype(dtype)
            n = int(np.prod(shape)) * npdt.itemsize
            a = np.frombuffer(raw[off : off + n], npdt).reshape(shape)
        off += n
        out.append(jnp.asarray(a))
    return jax.tree.unflatten(treedef, out)


def latest_checkpoint(directory: str, prefix: str = "ckpt_"):
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory) if f.startswith(prefix) and f.endswith(".ckpt")]
    if not cands:
        return None
    best = max(cands, key=lambda f: int(f[len(prefix) : -5]))
    return os.path.join(directory, best)
