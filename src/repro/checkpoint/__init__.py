from .checkpoint import save, restore, latest_checkpoint
