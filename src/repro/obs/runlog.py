"""Schema-versioned JSONL run logs — the one event stream every runner,
benchmark, grid and serving loop writes through.

A run log is a sequence of JSON objects, one per line.  Every record
carries ``{"schema": SCHEMA_VERSION, "event": <type>, "run": <run id>}``
plus the event payload.  Event types:

``header``     run identity: name, config dict, emitted first.
``metrics``    one windowed metric stream (``taps.window_reduce`` output
               plus the gate-direction map) under a stream name.
``grid_row``   one (selector, scenario) row of a scenario-harness grid.
``histogram``  a bucketed latency histogram (``trace.LatencyHistogram``).
``summary``    final scalars (counters, throughput); emitted last.

``RunLog`` is the writer; ``read_runlog`` / ``validate_records`` the
reader side, used by the round-trip tests and by ``check_bench`` when
diffing run logs.  Writers tolerate a missing filesystem target only by
failing loudly — telemetry silently dropped is worse than a crash.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterator, List, Optional

from .paths import runlog_path

__all__ = ["SCHEMA_VERSION", "RunLog", "read_runlog", "validate_records", "EVENT_TYPES"]

SCHEMA_VERSION = 1
EVENT_TYPES = ("header", "metrics", "grid_row", "histogram", "summary")
# payload keys required per event type (beyond the envelope)
_REQUIRED: Dict[str, tuple] = {
    "header": ("name", "config"),
    "metrics": ("stream", "windows"),
    "grid_row": ("row",),
    "histogram": ("name", "hist"),
    "summary": ("data",),
}


def _jsonable(obj: Any) -> Any:
    """Coerce numpy / jax scalars and arrays into plain JSON types."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    if isinstance(obj, float) and obj != obj:  # NaN → null, valid JSON
        return None
    return obj


class RunLog:
    """Append-only JSONL writer for one run.

    ``RunLog("my_run", config={...})`` opens ``<results>/runlogs/my_run.jsonl``
    (via ``paths.runlog_path``) and writes the header; pass ``path=`` to
    override the location entirely.  Use as a context manager or call
    ``close``; ``summary`` is normally the last record you emit.
    """

    def __init__(self, run: str, config: Optional[dict] = None, path: Optional[str] = None):
        self.run = run
        self.path = path if path is not None else runlog_path(run)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._fh = open(self.path, "w")
        self.event("header", name=run, config=_jsonable(config or {}))

    # -- record emission -------------------------------------------------
    def event(self, event: str, **payload) -> dict:
        if event not in EVENT_TYPES:
            raise ValueError(f"unknown event type {event!r} (want one of {EVENT_TYPES})")
        missing = [k for k in _REQUIRED[event] if k not in payload]
        if missing:
            raise ValueError(f"event {event!r} missing required keys {missing}")
        rec = {"schema": SCHEMA_VERSION, "event": event, "run": self.run, **_jsonable(payload)}
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        return rec

    def metrics(self, stream: str, windows: dict, better: Optional[Dict[str, str]] = None) -> dict:
        """One windowed metric stream (the ``taps.window_reduce`` shape)."""
        return self.event("metrics", stream=stream, windows=windows, better=better or {})

    def grid_row(self, row: dict) -> dict:
        return self.event("grid_row", row=row)

    def histogram(self, name: str, hist) -> dict:
        """A ``trace.LatencyHistogram`` (or its ``to_record()`` dict)."""
        rec = hist.to_record() if hasattr(hist, "to_record") else dict(hist)
        return self.event("histogram", name=name, hist=rec)

    def summary(self, **data) -> dict:
        return self.event("summary", data=data)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> str:
        if not self._fh.closed:
            self._fh.close()
        return self.path

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_runlog(path: str) -> List[dict]:
    """Parse a JSONL run log into its records (empty lines skipped)."""
    records = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: invalid JSON ({e})") from e
    return records


def iter_metrics(records: List[dict]) -> Iterator[dict]:
    """The metric-stream records of a parsed run log."""
    return (r for r in records if r.get("event") == "metrics")


def validate_records(records: List[dict]) -> None:
    """Schema check for a parsed run log; raises ValueError on violation.

    Enforces: every record carries the envelope at a known schema version;
    the first record is the header; required payload keys per event type.
    """
    if not records:
        raise ValueError("empty run log")
    for i, rec in enumerate(records):
        if rec.get("schema") != SCHEMA_VERSION:
            raise ValueError(f"record {i}: schema {rec.get('schema')!r} != {SCHEMA_VERSION}")
        ev = rec.get("event")
        if ev not in EVENT_TYPES:
            raise ValueError(f"record {i}: unknown event {ev!r}")
        if "run" not in rec:
            raise ValueError(f"record {i}: missing run id")
        missing = [k for k in _REQUIRED[ev] if k not in rec]
        if missing:
            raise ValueError(f"record {i} ({ev}): missing keys {missing}")
    if records[0]["event"] != "header":
        raise ValueError("first record must be the header")
