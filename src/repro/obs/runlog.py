"""Schema-versioned JSONL run logs — the one event stream every runner,
benchmark, grid and serving loop writes through.

A run log is a sequence of JSON objects, one per line.  Every record
carries ``{"schema": SCHEMA_VERSION, "event": <type>, "run": <run id>,
"ts": <unix seconds>}`` plus the event payload.  Event types:

``header``     run identity: name, config dict, emitted first.
``metrics``    one windowed metric stream (``taps.window_reduce`` output
               plus the gate-direction map) under a stream name.
``grid_row``   one (selector, scenario) row of a scenario-harness grid.
``histogram``  a bucketed latency histogram (``trace.LatencyHistogram``).
``alert``      one rule-based detector firing (``repro.obs.alerts``):
               rule name, severity, and a detail dict locating the
               offending window/values.  Schema v2 only.
``summary``    final scalars (counters, throughput); emitted last.

Schema history: **v1** had no ``ts`` and no ``alert`` event; **v2** (current)
adds both.  The reader side (``read_runlog`` / ``validate_records``) accepts
v1 records unchanged — v1 requirements are enforced at v1, so old logs keep
validating — while the writer always emits v2.

``RunLog`` refuses to clobber an existing log (``FileExistsError``) unless
``overwrite=True``; ``unique=True`` instead picks the first free numbered
path (``<run>.jsonl``, ``<run>.2.jsonl``, ...) while keeping the ``run``
header name stable, so reruns coexist and tools that match runs by header
name (``scripts/obs_explore.py diff``) still pair them.  Writers tolerate a
missing filesystem target only by failing loudly — telemetry silently
dropped is worse than a crash.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, Iterator, List, Optional

from .paths import runlog_path

__all__ = [
    "SCHEMA_VERSION",
    "SUPPORTED_SCHEMAS",
    "RunLog",
    "read_runlog",
    "validate_records",
    "iter_metrics",
    "iter_alerts",
    "EVENT_TYPES",
]

SCHEMA_VERSION = 2
SUPPORTED_SCHEMAS = (1, 2)
EVENT_TYPES = ("header", "metrics", "grid_row", "histogram", "alert", "summary")
# event types that did not exist at v1 (a v1 record carrying one is invalid)
_V2_EVENTS = ("alert",)
# payload keys required per event type (beyond the envelope)
_REQUIRED: Dict[str, tuple] = {
    "header": ("name", "config"),
    "metrics": ("stream", "windows"),
    "grid_row": ("row",),
    "histogram": ("name", "hist"),
    "alert": ("rule", "severity", "detail"),
    "summary": ("data",),
}


def _sanitize(obj: Any) -> Any:
    """Map non-finite floats (NaN, +-inf) to null in an already-coerced
    plain-JSON tree — runs *after* numpy/jax coercion, so NaN inside arrays
    and numpy scalar NaN are caught too (they were not before v2)."""
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    return obj


def _jsonable(obj: Any) -> Any:
    """Coerce numpy / jax scalars and arrays into plain JSON types; the
    non-finite sweep happens after coercion (``_sanitize``)."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return _sanitize(obj.item())
    if hasattr(obj, "tolist"):
        return _sanitize(obj.tolist())
    return _sanitize(obj)


def _unique_path(path: str) -> str:
    """First free numbered sibling: ``x.jsonl``, ``x.2.jsonl``, ..."""
    if not os.path.exists(path):
        return path
    root, ext = os.path.splitext(path)
    n = 2
    while os.path.exists(f"{root}.{n}{ext}"):
        n += 1
    return f"{root}.{n}{ext}"


class RunLog:
    """Append-only JSONL writer for one run.

    ``RunLog("my_run", config={...})`` opens ``<results>/runlogs/my_run.jsonl``
    (via ``paths.runlog_path``) and writes the header; pass ``path=`` to
    override the location entirely.  An existing log at the target raises
    ``FileExistsError`` unless ``overwrite=True`` (clobber) or
    ``unique=True`` (write to the first free numbered sibling instead; the
    ``run`` name in every record stays as given).  Use as a context manager
    or call ``close``; ``summary`` is normally the last record you emit.
    """

    def __init__(
        self,
        run: str,
        config: Optional[dict] = None,
        path: Optional[str] = None,
        overwrite: bool = False,
        unique: bool = False,
    ):
        self.run = run
        self.path = path if path is not None else runlog_path(run)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        if os.path.exists(self.path) and not overwrite:
            if not unique:
                raise FileExistsError(
                    f"run log {self.path} already exists; pass overwrite=True to "
                    f"clobber it or unique=True to write a numbered sibling"
                )
            self.path = _unique_path(self.path)
        self._fh = open(self.path, "w")
        self.event("header", name=run, config=_jsonable(config or {}))

    # -- record emission -------------------------------------------------
    def event(self, event: str, **payload) -> dict:
        """Append one schema-checked record; returns it as written."""
        if event not in EVENT_TYPES:
            raise ValueError(f"unknown event type {event!r} (want one of {EVENT_TYPES})")
        missing = [k for k in _REQUIRED[event] if k not in payload]
        if missing:
            raise ValueError(f"event {event!r} missing required keys {missing}")
        rec = {
            "schema": SCHEMA_VERSION,
            "event": event,
            "run": self.run,
            "ts": round(time.time(), 3),
            **_jsonable(payload),
        }
        self._fh.write(json.dumps(rec, allow_nan=False) + "\n")
        self._fh.flush()
        return rec

    def metrics(self, stream: str, windows: dict, better: Optional[Dict[str, str]] = None) -> dict:
        """One windowed metric stream (the ``taps.window_reduce`` shape)."""
        return self.event("metrics", stream=stream, windows=windows, better=better or {})

    def grid_row(self, row: dict) -> dict:
        """One evaluation-grid row (selector × scenario sweeps)."""
        return self.event("grid_row", row=row)

    def histogram(self, name: str, hist) -> dict:
        """A ``trace.LatencyHistogram`` (or its ``to_record()`` dict)."""
        rec = hist.to_record() if hasattr(hist, "to_record") else dict(hist)
        return self.event("histogram", name=name, hist=rec)

    def alert(self, rule: str, severity: str, detail: dict, message: str = "") -> dict:
        """One detector firing (see ``repro.obs.alerts``)."""
        return self.event("alert", rule=rule, severity=severity, detail=detail, message=message)

    def summary(self, **data) -> dict:
        """The run's closing scalar digest (one per log, by convention)."""
        return self.event("summary", data=data)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> str:
        """Flush and close the log file; returns its path. Idempotent."""
        if not self._fh.closed:
            self._fh.close()
        return self.path

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_runlog(path: str) -> List[dict]:
    """Parse a JSONL run log into its records (empty lines skipped).
    Reads every supported schema version (v1 logs have no ``ts``)."""
    records = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: invalid JSON ({e})") from e
    return records


def iter_metrics(records: List[dict]) -> Iterator[dict]:
    """The metric-stream records of a parsed run log."""
    return (r for r in records if r.get("event") == "metrics")


def iter_alerts(records: List[dict]) -> Iterator[dict]:
    """The alert records of a parsed run log (always empty for v1 logs)."""
    return (r for r in records if r.get("event") == "alert")


def validate_records(records: List[dict]) -> None:
    """Schema check for a parsed run log; raises ValueError on violation.

    Enforces: every record carries the envelope at a *supported* schema
    version (v1 records validate under v1 rules: no ``ts``, no ``alert``);
    the first record is the header; required payload keys per event type.
    """
    if not records:
        raise ValueError("empty run log")
    for i, rec in enumerate(records):
        schema = rec.get("schema")
        if schema not in SUPPORTED_SCHEMAS:
            raise ValueError(
                f"record {i}: schema {schema!r} not in supported versions {SUPPORTED_SCHEMAS}"
            )
        ev = rec.get("event")
        if ev not in EVENT_TYPES:
            raise ValueError(f"record {i}: unknown event {ev!r}")
        if schema < 2 and ev in _V2_EVENTS:
            raise ValueError(f"record {i}: event {ev!r} requires schema >= 2, got {schema}")
        if schema >= 2 and "ts" not in rec:
            raise ValueError(f"record {i}: schema {schema} record missing timestamp 'ts'")
        if "run" not in rec:
            raise ValueError(f"record {i}: missing run id")
        missing = [k for k in _REQUIRED[ev] if k not in rec]
        if missing:
            raise ValueError(f"record {i} ({ev}): missing keys {missing}")
    if records[0]["event"] != "header":
        raise ValueError("first record must be the header")
