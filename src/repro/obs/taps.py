"""In-scan metric taps: a typed registry of counters and gauges carried as a
pytree inside the ``lax.scan`` carry.

A *tap* observes values the round body already computes (cohort mask,
credited successes, quota floor) and turns them into a uniform telemetry
schema without host callbacks and without touching the round's math or PRNG
stream — taps-on runs are bit-identical to taps-off runs (pinned against the
``tests/golden`` matrix in ``tests/test_obs.py``).

Three kinds:

* **gauge** — a per-round scalar, emitted as a scan output row.  Under a
  mesh each gauge is reduced across shards (``psum``) inside the scan body,
  so every placement emits the identical replicated value.
* **counter** — a running sum riding in the scan carry (the pytree the
  registry's ``init_counters`` builds); lands once in the run summary.
* **hist** — a bucketed host-side histogram (``repro.obs.trace``): latency
  quantiles for serving loops, where per-request storage is not an option.
  Hist taps never enter the scan.

Per-round gauge series are reduced into **step-windowed aggregates**
(``window_reduce``: p50 / p99 / mean / sum per window of W rounds) — the
shape the JSONL run logs and ``BENCH_*.json`` ``metrics`` streams carry, and
what ``scripts/check_bench.py`` diffs per window across PRs.

``ROUND_TAPS`` is the registry the ``RoundProgram`` taps stage emits; every
engine placement (local, ``mesh=D``, async ``S>0``) produces the same
schema.  To add a metric: add a ``TapSpec`` here, produce the gauge in
``round_program._make_step``'s tap block, and it flows through windows,
run logs, bench JSON and the CI gate with no further wiring.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TapSpec", "TapRegistry", "ROUND_TAPS", "window_reduce", "WINDOW_AGGS"]

KINDS = ("counter", "gauge", "hist")
# gate directions check_bench understands; "none" = report, never gate
DIRECTIONS = ("higher", "lower", "equal", "none")
WINDOW_AGGS = ("p50", "p99", "mean", "sum")


@dataclasses.dataclass(frozen=True)
class TapSpec:
    """One typed metric: its name, kind, gate direction and provenance.

    ``group`` partitions a registry into independent row schemas: the
    ``"round"`` group is the in-scan gauge row the engine emits every round;
    the ``"fairness"`` group names the client-axis series derived host-side
    from the sketch stream (``repro.obs.sketches.fairness_series``); the
    ``"serve"`` group is the per-dispatch row the serving transport samples
    (``repro.serve.transport``) — same windowing, run-log and gating
    machinery, different producers.
    """

    name: str
    kind: str
    doc: str = ""
    better: str = "none"  # how check_bench should gate the windowed p50
    source: Tuple[str, ...] = ()  # counters: gauge row keys summed per round ((), = +1/round)
    group: str = "round"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown tap kind {self.kind!r} (want one of {KINDS})")
        if self.better not in DIRECTIONS:
            raise ValueError(f"unknown gate direction {self.better!r} (want one of {DIRECTIONS})")
        if self.source and self.kind != "counter":
            raise ValueError(f"tap {self.name!r}: only counters accumulate a source")


class TapRegistry:
    """An ordered, name-unique set of ``TapSpec`` — the schema one taps
    stage emits."""

    def __init__(self, *specs: TapSpec):
        self.specs: Dict[str, TapSpec] = {}
        for s in specs:
            if s.name in self.specs:
                raise ValueError(f"duplicate tap {s.name!r}")
            self.specs[s.name] = s
        for s in self.counters():
            for src in s.source:
                if src not in self.specs or self.specs[src].kind != "gauge":
                    raise ValueError(f"counter {s.name!r} accumulates unknown gauge {src!r}")

    def __iter__(self):
        return iter(self.specs.values())

    def __contains__(self, name: str) -> bool:
        return name in self.specs

    def gauges(self, group: Optional[str] = None) -> Sequence[TapSpec]:
        """Gauge specs, optionally restricted to one ``group`` (None = all)."""
        return [s for s in self.specs.values() if s.kind == "gauge" and group in (None, s.group)]

    def counters(self) -> Sequence[TapSpec]:
        """Counter specs — monotone accumulators over their source gauges."""
        return [s for s in self.specs.values() if s.kind == "counter"]

    def gauge_names(self, group: Optional[str] = "round") -> Tuple[str, ...]:
        """Gauge names of one group (default: the in-scan ``"round"`` row
        schema, what the engine's tap stage emits); ``group=None`` = all."""
        return tuple(s.name for s in self.gauges(group))

    def directions(self, group: Optional[str] = None) -> Dict[str, str]:
        """Gate-direction map for the windowed gauge streams (all groups by
        default — extra keys are harmless to consumers of one stream)."""
        return {s.name: s.better for s in self.gauges(group)}

    def init_counters(self):
        """Zeroed counter pytree for the scan carry (jnp scalars)."""
        import jax.numpy as jnp

        return {s.name: jnp.zeros((), jnp.float32) for s in self.counters()}

    def accumulate(self, counters, row):
        """One scan-carry counter update from this round's gauge row."""
        out = {}
        for s in self.counters():
            inc = sum((row[f] for f in s.source), 0.0) if s.source else 1.0
            out[s.name] = counters[s.name] + inc
        return out

    def validate_row(self, row: dict, group: Optional[str] = "round"):
        """The schema contract: a tap row is exactly one group's gauge set."""
        want = set(self.gauge_names(group))
        got = set(row)
        if want != got:
            raise ValueError(f"tap row schema mismatch: missing {sorted(want - got)}, extra {sorted(got - want)}")


ROUND_TAPS = TapRegistry(
    TapSpec("selected", "gauge", "clients in this round's cohort", better="equal"),
    TapSpec("on_time", "gauge", "successes credited at the deadline (Eq. 8 numerator)", better="higher"),
    TapSpec("stale", "gauge", "decayed alpha**lag late credit arriving this round"),
    TapSpec("sigma", "gauge", "fairness quota floor in force this round"),
    TapSpec("capped_frac", "gauge", "fraction of the population at the ProbAlloc p<=1 cap"),
    TapSpec("rounds", "counter", "rounds executed"),
    TapSpec("cum_selected", "counter", "cumulative cohort slots issued", source=("selected",)),
    TapSpec("cum_credit", "counter", "running staleness-aware CEP", source=("on_time", "stale")),
    # client-axis fairness series, derived host-side from the sketch stream
    # (repro.obs.sketches.fairness_series) at the sketch cadence
    TapSpec("jain", "gauge", "exact Jain index of cumulative selection counts",
            better="higher", group="fairness"),
    TapSpec("gini", "gauge", "grouped-data Gini of cumulative selection counts",
            better="lower", group="fairness"),
    TapSpec("top_decile_share", "gauge", "selection-mass share of the most-selected 10% of clients",
            better="lower", group="fairness"),
    TapSpec("region_cep_skew", "gauge", "max per-region on-time credit rate over the fleet average",
            group="fairness"),
    # serving-loop gauges, sampled host-side per batched dispatch by the
    # transport (repro.serve.transport) — one row per server tick
    TapSpec("queue_depth", "gauge", "tick requests waiting in the admission queue",
            group="serve"),
    TapSpec("batch_jobs", "gauge", "tenant jobs coalesced into this dispatch",
            group="serve"),
    TapSpec("shed", "gauge", "requests shed this tick (queue at capacity)",
            better="lower", group="serve"),
    TapSpec("restarts", "gauge", "supervised engine restarts landed since the last dispatch",
            better="lower", group="serve"),
    TapSpec("recovery_s", "gauge", "seconds spent in crash recovery since the last dispatch",
            better="lower", group="serve"),
)


def window_reduce(series: Dict[str, np.ndarray], window: int, aggs: Sequence[str] = WINDOW_AGGS) -> dict:
    """Reduce per-round series into step-windowed aggregates.

    ``series`` maps metric name -> (T,) array; rounds are grouped into
    ``T // window`` full windows of ``window`` rounds (a trailing partial
    window is dropped and reported as ``dropped`` — windows stay comparable
    across runs).  Returns::

        {"window": W, "n_windows": n, "dropped": d,
         "aggs": {name: {"p50": [...], "p99": [...], "mean": [...], "sum": [...]}}}

    Percentiles use numpy's default linear interpolation, so values are
    hand-checkable (``tests/test_obs.py`` pins a 2-window example exactly).
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    out: dict = {"window": int(window), "aggs": {}}
    n_windows: Optional[int] = None
    for name, s in series.items():
        s = np.asarray(s, np.float64).reshape(-1)
        n = s.shape[0] // window
        if n_windows is None:
            n_windows, dropped = n, s.shape[0] - n * window
            out["n_windows"], out["dropped"] = int(n_windows), int(dropped)
        elif n != n_windows:
            raise ValueError(f"series {name!r} has {n} windows, expected {n_windows}")
        w = s[: n * window].reshape(n, window)
        cell = {}
        for agg in aggs:
            if agg == "p50":
                cell[agg] = np.percentile(w, 50, axis=1).tolist() if n else []
            elif agg == "p99":
                cell[agg] = np.percentile(w, 99, axis=1).tolist() if n else []
            elif agg == "mean":
                cell[agg] = w.mean(axis=1).tolist() if n else []
            elif agg == "sum":
                cell[agg] = w.sum(axis=1).tolist() if n else []
            else:
                raise ValueError(f"unknown aggregate {agg!r} (want a subset of {WINDOW_AGGS})")
        out["aggs"][name] = cell
    if n_windows is None:
        out["n_windows"], out["dropped"] = 0, 0
    return out
