"""The unified reporter: one emission path for every benchmark and runner.

A ``Reporter`` owns a run's outward-facing artifacts:

* the ``name,us_per_call,derived`` CSV rows the harness scrapes from
  stdout (unchanged convention),
* ``BENCH_<name>.json`` under the bench dir — now with an optional
  ``"metrics"`` block of windowed streams ``check_bench`` can diff,
* a paired JSONL run log (``runlog.RunLog``) carrying the same streams
  as structured events.

Benchmarks attach windowed metric streams with ``metrics_stream`` (handing
it the per-round series from a taps-enabled run); serving loops attach
latency histograms with ``histogram``.  ``save`` writes the bench JSON with
everything accumulated so far; the run log is written incrementally.

The ``"metrics"`` block in bench JSON looks like::

    "metrics": {
      "<stream>": {
        "window": W, "n_windows": n, "dropped": d,
        "better": {"on_time": "higher", ...},
        "aggs": {"on_time": {"p50": [...], "p99": [...], ...}, ...}
      }
    }

which is exactly what ``scripts/check_bench.py --metrics`` gates per
window.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from .alerts import AlertRules, detect_alerts, log_alerts
from .paths import bench_path
from .runlog import RunLog, _jsonable
from .sketches import fairness_series
from .taps import ROUND_TAPS, window_reduce

__all__ = ["Reporter"]


class Reporter:
    """One run's emission surface: CSV rows + bench JSON + JSONL run log.

    ``Reporter("async_scan", config={...})`` opens the paired run log
    eagerly; pass ``runlog=False`` for pure-JSON writers (e.g. table
    harvesters) that should not produce an event stream.  Reruns under the
    same name never truncate an earlier log: the run log is opened with
    ``unique=True`` (numbered sibling paths, stable ``run`` header name).
    """

    def __init__(self, name: str, config: Optional[dict] = None, runlog: bool = True):
        self.name = name
        self.data: dict = {}
        self.metrics: Dict[str, dict] = {}
        self.log: Optional[RunLog] = RunLog(name, config=config, unique=True) if runlog else None

    # -- stdout CSV (harness convention, unchanged) -----------------------
    def emit(self, name: str, us_per_call: float, derived: str = ""):
        """One ``name,us,derived`` CSV line on stdout (the harness format)."""
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    # -- structured streams ----------------------------------------------
    def update(self, **data) -> "Reporter":
        """Merge scalar results into the bench JSON payload."""
        self.data.update(data)
        return self

    def metrics_stream(
        self,
        stream: str,
        series: Dict[str, np.ndarray],
        window: int,
        better: Optional[Dict[str, str]] = None,
    ) -> dict:
        """Window-reduce per-round series and attach them as a named stream
        (bench JSON ``metrics`` block + a ``metrics`` run-log event)."""
        windows = window_reduce(series, window)
        block = dict(windows)
        block["better"] = dict(better or {})
        self.metrics[stream] = block
        if self.log is not None:
            self.log.metrics(stream, windows, better=better)
        return block

    def fairness_stream(self, stream: str, sketches) -> Dict[str, np.ndarray]:
        """Derive the client-axis fairness series from a runner's
        ``"sketches"`` payload and attach them as a metrics stream (window=1:
        the sketch cadence already windows the rounds).  Directions come
        from the ``fairness`` tap group, so ``check_bench`` gates the
        stream like any other."""
        series = fairness_series(sketches)
        self.metrics_stream(stream, series, window=1, better=ROUND_TAPS.directions("fairness"))
        return series

    def alerts(
        self,
        series: Optional[Dict[str, np.ndarray]] = None,
        fairness: Optional[Dict[str, np.ndarray]] = None,
        expected_selected: Optional[float] = None,
        rules: AlertRules = AlertRules(),
    ) -> list:
        """Run the rule-based detector pass (``repro.obs.alerts``) over tap
        + fairness series; append ``alert`` events to the run log and an
        ``alerts`` list to the bench JSON.  Returns the ``Alert`` list."""
        found = detect_alerts(series, fairness, expected_selected, rules)
        self.data["alerts"] = [
            {"rule": a.rule, "severity": a.severity, "message": a.message, **a.detail}
            for a in found
        ]
        if self.log is not None:
            log_alerts(self.log, found)
        return found

    def histogram(self, name: str, hist) -> dict:
        """Attach a latency histogram: summary into bench JSON under
        ``hists.<name>``, full buckets into the run log."""
        summary = hist.summary() if hasattr(hist, "summary") else dict(hist)
        self.data.setdefault("hists", {})[name] = summary
        if self.log is not None:
            self.log.histogram(name, hist)
        return summary

    def grid_row(self, row: dict) -> dict:
        """Forward one evaluation-grid row to the run log (no-op without one)."""
        if self.log is not None:
            self.log.grid_row(row)
        return row

    # -- persistence -------------------------------------------------------
    def save(self, obj: Optional[dict] = None, summary: bool = True) -> str:
        """Write ``BENCH_<name>.json`` (merging ``obj`` if given) and close
        the run log with a summary event."""
        import json

        if obj:
            self.data.update(obj)
        payload = dict(_jsonable(self.data))
        if self.metrics:
            payload["metrics"] = _jsonable(self.metrics)
        path = bench_path(self.name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        if self.log is not None:
            if summary:
                self.log.summary(**{k: v for k, v in payload.items() if not isinstance(v, (dict, list))})
            self.log.close()
        return path

    def close(self) -> None:
        """Close the run log without writing the bench JSON (see ``save``)."""
        if self.log is not None:
            self.log.close()

    def __enter__(self) -> "Reporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
