"""Fixed-size, mergeable in-scan sketches over the client axis.

At K=1e6 nobody can afford to haul per-client state to the host every round,
yet the paper's central tradeoff — effective participation vs fairness — is
*per-client*: which clients E3CS starves, which it over-selects, how credit
distributes across volatility regions.  A *sketch* compresses the K axis
into a handful of small dense arrays the scan can carry and emit as ys:

* ``count_hist`` / ``count_mass`` — clients (and their selection mass) per
  log2 bucket of cumulative selection count,
* ``p_hist`` — clients per uniform bucket of this round's allocation p,
* ``region_clients`` / ``region_selected`` / ``region_on_time`` —
  segment-sum rollups over a per-client region id (volatility class),
* ``lag_hist`` — cumulative outcome-code histogram over all selections
  (sync: on-time / failed; async: lag 0..S plus never-completed),
* ``sum_c`` / ``sum_c2`` — exact first two moments of the count vector
  (an exact streaming Jain index, whatever the bucketing).

Every field is a **sum over clients**, so sketches are mergeable by
addition: under a mesh each shard accumulates its local partial sums and
one ``psum`` of the emitted stream reconstructs the global sketch exactly —
every placement {local, ``mesh=D``, async ``S>0``} emits the identical
stream (pinned in ``tests/test_obs.py``).  Emission happens every
``window`` rounds (gated on the *global* round counter ``state.t``, so
chunked horizons window identically to one-shot ones) rather than per
round, keeping the ys O(T/W * B) however large K grows.

Sketches observe values the round already computes (the cohort mask, the
allocation, the cumulative counts) and never touch the PRNG stream or the
state math — sketches-on runs are bit-identical to the committed goldens.

The host side derives streamed **fairness series** from the sketch stream
(``fairness_series``): exact Jain index, grouped-data Gini, top-decile
selection share, and per-region CEP skew — registered as ``fairness``-group
gauges in ``ROUND_TAPS`` so they flow through ``window_reduce``, run logs,
bench JSON and the ``check_bench`` gate like any other metric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = [
    "SketchSpec",
    "SKETCH_FIELDS",
    "FAIRNESS_SERIES",
    "region_ids",
    "lag_bins",
    "sketch_carry0",
    "sketch_step",
    "sketch_to_numpy",
    "merge_sketches",
    "sketch_from_dense",
    "fairness_series",
]

# every field is a per-client sum -> merge = add; order is the emission order
SKETCH_FIELDS = (
    "count_hist", "count_mass", "p_hist",
    "region_clients", "region_selected", "region_on_time",
    "lag_hist", "sum_c", "sum_c2",
)
FAIRNESS_SERIES = ("jain", "gini", "top_decile_share", "region_cep_skew")


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Geometry of the in-scan client-axis sketch.

    ``window`` is the emission cadence W (one sketch row every W rounds,
    gated on the global round counter); ``count_bins`` buckets cumulative
    selection counts by ``floor(log2(c + 1))``; ``prob_bins`` buckets the
    round's allocation p uniformly on [0, 1]; ``regions`` is an optional
    (K,) int32 region-id vector (volatility class per client) rolled up by
    segment sum — when omitted, ``n_regions`` contiguous equal slabs of the
    client axis are used (the paper's ordered-by-rho class layout), and
    ``n_regions=1`` collapses the rollup to fleet totals.
    """

    window: int = 50
    count_bins: int = 12
    prob_bins: int = 10
    n_regions: int = 1
    regions: Optional[object] = None  # (K,) int32 region ids

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"sketch window must be >= 1, got {self.window}")
        if self.count_bins < 2 or self.prob_bins < 2:
            raise ValueError("sketch needs at least 2 count and 2 prob buckets")
        if self.n_regions < 1:
            raise ValueError(f"n_regions must be >= 1, got {self.n_regions}")
        if self.regions is not None:
            r = np.asarray(self.regions)
            if r.ndim != 1:
                raise ValueError(f"regions must be a 1-D id vector, got shape {r.shape}")
            if r.size and (int(r.min()) < 0 or int(r.max()) >= self.n_regions):
                raise ValueError(
                    f"region ids must lie in [0, {self.n_regions}), got "
                    f"[{int(r.min())}, {int(r.max())}]"
                )


def region_ids(spec: SketchSpec, K: int) -> np.ndarray:
    """The (K,) int32 region-id vector a program sketches under.

    ``spec.regions`` verbatim when given (validated against K), else
    ``n_regions`` contiguous equal slabs of the client axis.
    """
    if spec.regions is not None:
        r = np.asarray(spec.regions, np.int32)
        if r.shape != (K,):
            raise ValueError(f"regions shape {r.shape} != (K,) = ({K},)")
        return r
    if spec.n_regions == 1:
        return np.zeros((K,), np.int32)
    return ((np.arange(K, dtype=np.int64) * spec.n_regions) // K).astype(np.int32)


def lag_bins(staleness: Optional[int]) -> int:
    """Outcome-code bins L: sync rounds code {on-time, failed}; async rounds
    code the completion lag {0..S} plus a never-completed bin."""
    return 2 if staleness is None else int(staleness) + 2


def sketch_carry0(K_loc: int, L: int):
    """Zeroed per-shard sketch accumulators for the scan carry."""
    import jax.numpy as jnp

    return {
        "cum_on_time": jnp.zeros((K_loc,), jnp.float32),
        "lag_hist": jnp.zeros((L,), jnp.float32),
    }


def sketch_step(spec: SketchSpec, skc, mask, x, lag, p, counts, t, region, active, L: int):
    """One round of sketch accumulation + (window-gated) emission.

    All inputs are the *local shard slabs* the round body already holds:
    ``mask`` this round's cohort, ``x`` the on-time success bits, ``lag``
    the completion lags (None when sync), ``p`` the allocation, ``counts``
    the post-update cumulative selection counts, ``t`` the post-update
    global round counter, ``region`` (K_loc,) int32 ids, ``active`` a
    (K_loc,) 0/1 mask excluding shard padding (None = all active).

    Returns ``(skc', row)`` where ``row`` holds the local partial sums of
    ``SKETCH_FIELDS`` on emission rounds (``t % window == 0``) and zeros
    otherwise — merge across shards by addition (one ``psum`` of the ys
    stream), then keep every ``window``-th row.  Never touches the PRNG
    stream or any state math.
    """
    import jax
    import jax.numpy as jnp

    B, PB, R, W = spec.count_bins, spec.prob_bins, spec.n_regions, spec.window
    act = jnp.ones_like(counts) if active is None else active
    cum = skc["cum_on_time"] + mask * x
    if lag is None:
        code = (1 - x).astype(jnp.int32)  # 0 = on-time, 1 = failed
    else:
        code = jnp.where(lag < 0, L - 1, jnp.clip(lag, 0, L - 2)).astype(jnp.int32)
    # L is tiny and static: L masked reductions beat a K-wide scatter-add on
    # the per-round path (sums of 0/1 products stay exact in any order)
    lag_hist = skc["lag_hist"] + jnp.stack([jnp.sum(mask * (code == j)) for j in range(L)])

    def emit():
        cb = jnp.clip(jnp.floor(jnp.log2(counts + 1.0)), 0, B - 1).astype(jnp.int32)
        pb = jnp.clip(jnp.floor(p * PB), 0, PB - 1).astype(jnp.int32)
        ca = counts * act
        # XLA CPU scatter-add is serial (~us/element at K=1e6); with a
        # handful of buckets a one-hot matvec turns each histogram into a
        # fused dense reduction.  Every summand is an integer-valued float
        # below 2^24, so the sums are exact in any order — emission stays
        # bit-identical across placements.
        oh_c = (cb[:, None] == jnp.arange(B, dtype=jnp.int32)).astype(jnp.float32)
        oh_p = (pb[:, None] == jnp.arange(PB, dtype=jnp.int32)).astype(jnp.float32)
        oh_r = (region[:, None] == jnp.arange(R, dtype=jnp.int32)).astype(jnp.float32)
        return {
            "count_hist": act @ oh_c,
            "count_mass": ca @ oh_c,
            "p_hist": act @ oh_p,
            "region_clients": act @ oh_r,
            "region_selected": ca @ oh_r,
            "region_on_time": (cum * act) @ oh_r,
            "lag_hist": lag_hist,
            "sum_c": jnp.sum(ca),
            "sum_c2": jnp.vdot(counts, ca),
        }

    def skip():
        return {
            "count_hist": jnp.zeros((B,), jnp.float32),
            "count_mass": jnp.zeros((B,), jnp.float32),
            "p_hist": jnp.zeros((PB,), jnp.float32),
            "region_clients": jnp.zeros((R,), jnp.float32),
            "region_selected": jnp.zeros((R,), jnp.float32),
            "region_on_time": jnp.zeros((R,), jnp.float32),
            "lag_hist": jnp.zeros_like(lag_hist),
            "sum_c": jnp.zeros((), jnp.float32),
            "sum_c2": jnp.zeros((), jnp.float32),
        }

    row = jax.lax.cond((t % W) == 0, emit, skip)
    return {"cum_on_time": cum, "lag_hist": lag_hist}, row


# ---------------------------------------------------------------------------
# Host side: reference recompute, merging and fairness derivation
# ---------------------------------------------------------------------------


def sketch_to_numpy(stream) -> Dict[str, np.ndarray]:
    """Host view of a runner's ``"sketches"`` payload: float64 numpy."""
    return {n: np.asarray(stream[n], np.float64) for n in SKETCH_FIELDS}


def merge_sketches(*streams: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Merge independent sketch streams (shards, hosts) — plain addition,
    exact by construction (every field is a per-client sum)."""
    out = {n: np.asarray(streams[0][n], np.float64).copy() for n in SKETCH_FIELDS}
    for s in streams[1:]:
        for n in SKETCH_FIELDS:
            out[n] = out[n] + np.asarray(s[n], np.float64)
    return out


def sketch_from_dense(
    spec: SketchSpec,
    counts: np.ndarray,
    p: np.ndarray,
    cum_on_time: np.ndarray,
    lag_hist: np.ndarray,
    region: np.ndarray,
    active: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Recompute one emission row from dense per-client state (the test
    oracle for the in-scan sketch and the property test for psum merging)."""
    B, PB, R = spec.count_bins, spec.prob_bins, spec.n_regions
    counts = np.asarray(counts, np.float64)
    p = np.asarray(p, np.float64)
    cum = np.asarray(cum_on_time, np.float64)
    region = np.asarray(region, np.int64)
    act = np.ones_like(counts) if active is None else np.asarray(active, np.float64)
    cb = np.clip(np.floor(np.log2(counts + 1.0)), 0, B - 1).astype(np.int64)
    pb = np.clip(np.floor(p * PB), 0, PB - 1).astype(np.int64)
    ca = counts * act
    return {
        "count_hist": np.bincount(cb, weights=act, minlength=B)[:B],
        "count_mass": np.bincount(cb, weights=ca, minlength=B)[:B],
        "p_hist": np.bincount(pb, weights=act, minlength=PB)[:PB],
        "region_clients": np.bincount(region, weights=act, minlength=R)[:R],
        "region_selected": np.bincount(region, weights=ca, minlength=R)[:R],
        "region_on_time": np.bincount(region, weights=cum * act, minlength=R)[:R],
        "lag_hist": np.asarray(lag_hist, np.float64),
        "sum_c": np.asarray(ca.sum()),
        "sum_c2": np.asarray((counts * ca).sum()),
    }


def _top_share(count_hist: np.ndarray, count_mass: np.ndarray, frac: float) -> float:
    """Selection-mass share of the top ``frac`` of clients, walking the
    count buckets from the top with a fractional final bucket."""
    n = count_hist.sum()
    s = count_mass.sum()
    if n <= 0 or s <= 0:
        return 0.0
    target = frac * n
    taken = 0.0
    mass = 0.0
    for b in range(count_hist.shape[0] - 1, -1, -1):
        nb, sb = count_hist[b], count_mass[b]
        if nb <= 0:
            continue
        if taken + nb <= target:
            taken += nb
            mass += sb
        else:
            mass += sb * (target - taken) / nb
            break
    return float(mass / s)


def fairness_series(stream: Dict[str, np.ndarray], top_frac: float = 0.1) -> Dict[str, np.ndarray]:
    """Derive the streamed fairness gauges from a sketch stream.

    ``stream`` maps ``SKETCH_FIELDS`` to (n_emits, ...) arrays (a runner's
    ``"sketches"`` payload).  Returns (n_emits,) float64 series:

    * ``jain`` — exact Jain index ``sum_c^2 / (n_active * sum_c2)`` (the
      moments are exact, not bucketed),
    * ``gini`` — grouped-data Gini from the count histogram (trapezoid
      Lorenz over the log2 buckets; within-bucket equality assumed),
    * ``top_decile_share`` — selection-mass share of the most-selected
      ``top_frac`` of clients (fractional top bucket),
    * ``region_cep_skew`` — max per-region per-client on-time credit rate
      over the fleet-average rate (1.0 = perfectly balanced regions).
    """
    s = sketch_to_numpy(stream)
    n_emits = s["count_hist"].shape[0]
    out = {name: np.zeros((n_emits,), np.float64) for name in FAIRNESS_SERIES}
    for i in range(n_emits):
        nh, mh = s["count_hist"][i], s["count_mass"][i]
        n_act, sum_c, sum_c2 = nh.sum(), float(s["sum_c"][i]), float(s["sum_c2"][i])
        out["jain"][i] = sum_c * sum_c / (n_act * sum_c2) if n_act > 0 and sum_c2 > 0 else 0.0
        if sum_c > 0 and n_act > 0:
            p_b = nh / n_act
            cum_l = np.cumsum(mh) / sum_c
            prev_l = np.concatenate([[0.0], cum_l[:-1]])
            out["gini"][i] = 1.0 - float(np.sum(p_b * (prev_l + cum_l)))
        out["top_decile_share"][i] = _top_share(nh, mh, top_frac)
        rc, ro = s["region_clients"][i], s["region_on_time"][i]
        tot_c, tot_o = rc.sum(), ro.sum()
        if tot_c > 0 and tot_o > 0:
            rates = np.where(rc > 0, ro / np.maximum(rc, 1.0), 0.0)
            out["region_cep_skew"][i] = float(rates.max() / (tot_o / tot_c))
        else:
            out["region_cep_skew"][i] = 1.0
    return out
