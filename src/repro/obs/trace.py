"""Stage-level tracing and host-side latency histograms.

Two instruments, one per side of the dispatch boundary:

* ``stage(name)`` — a trace annotation for *device* code.  Inside traced
  jax code it is ``jax.named_scope``: zero runtime cost, the stage name
  lands in HLO op metadata so ``jax.profiler`` traces (and XLA dumps) show
  allocate / select / observe / credit / update as named regions of the
  round.  Outside a trace it still works as a plain context manager, and on
  the host thread it additionally opens a ``jax.profiler.TraceAnnotation``
  so host-side profiler timelines pick the span up too.
* ``SpanTimer`` — a wall-clock span timer for *host* code (the serving
  loop): each ``span(name)`` context feeds a ``LatencyHistogram``, giving
  real p50/p99 latency from bucketed counts — O(n_buckets) memory, never
  per-request storage.

``LatencyHistogram`` buckets are log-spaced between ``lo`` and ``hi``
seconds; quantiles interpolate within the winning bucket on cumulative
counts, while min/max/sum/count are tracked exactly so means and extremes
are not bucket-quantized.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, Optional

import numpy as np

__all__ = ["stage", "SpanTimer", "LatencyHistogram"]


@contextlib.contextmanager
def stage(name: str):
    """Annotate a named pipeline stage on whichever side we are running.

    Under ``jax.jit`` tracing this scopes op names (free at runtime); on the
    host it also opens a profiler TraceAnnotation so spans appear in
    ``jax.profiler`` timelines.  Degrades to a no-op context if jax is
    missing or its profiler API moved.
    """
    try:
        import jax

        on_host = True
        try:
            on_host = jax.core.trace_state_clean()
        except Exception:
            pass
        # named_scope is always safe: inside a trace it names ops, outside it
        # is a cheap push/pop on jax's name stack.
        with jax.named_scope(name):
            ann = None
            if on_host:
                try:
                    ann = jax.profiler.TraceAnnotation(name)
                    ann.__enter__()
                except Exception:
                    ann = None
            try:
                yield
            finally:
                if ann is not None:
                    ann.__exit__(None, None, None)
    except ImportError:
        yield


class LatencyHistogram:
    """Log-bucketed latency accumulator with exact min/max/sum/count.

    ``n_buckets`` edges are geometrically spaced over ``[lo, hi]`` seconds;
    observations outside the range clamp into the end buckets.  Quantiles
    interpolate linearly within the selected bucket, and are additionally
    clamped to the exact observed [min, max] so tiny samples cannot report
    a quantile outside the data.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 10.0, n_buckets: int = 64):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
        self.edges = np.geomspace(lo, hi, n_buckets + 1)
        self.counts = np.zeros(n_buckets, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = 0.0

    def observe(self, seconds: float) -> None:
        """Record one sample; negative or non-finite values are dropped."""
        s = float(seconds)
        if not np.isfinite(s) or s < 0:
            return
        i = int(np.searchsorted(self.edges, s, side="right")) - 1
        self.counts[min(max(i, 0), len(self.counts) - 1)] += 1
        self.count += 1
        self.sum += s
        self.min = min(self.min, s)
        self.max = max(self.max, s)

    def quantile(self, q: float) -> float:
        """Approximate quantile (``q`` in [0, 1]) from bucket counts."""
        if self.count == 0:
            return float("nan")
        target = q * self.count
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        i = min(i, len(self.counts) - 1)
        prev = cum[i - 1] if i > 0 else 0
        in_bucket = self.counts[i]
        frac = (target - prev) / in_bucket if in_bucket else 0.0
        lo, hi = self.edges[i], self.edges[i + 1]
        return float(min(max(lo + frac * (hi - lo), self.min), self.max))

    @property
    def mean(self) -> float:
        """Exact mean of the observed samples (NaN when empty)."""
        return self.sum / self.count if self.count else float("nan")

    def summary(self) -> Dict[str, float]:
        """The JSON-ready digest the runlog/report layer emits."""
        return {
            "count": int(self.count),
            "mean_s": self.mean,
            "min_s": self.min if self.count else float("nan"),
            "max_s": self.max,
            "p50_s": self.quantile(0.50),
            "p90_s": self.quantile(0.90),
            "p99_s": self.quantile(0.99),
        }

    def to_record(self) -> dict:
        """Full serializable state (edges + counts) for the JSONL stream."""
        return {
            "edges_s": self.edges.tolist(),
            "counts": self.counts.tolist(),
            **self.summary(),
        }


class SpanTimer:
    """Wall-clock span timing into per-name ``LatencyHistogram`` s.

    >>> spans = SpanTimer()
    >>> with spans.span("request"):
    ...     serve_one()
    >>> spans.hist["request"].quantile(0.99)
    """

    def __init__(self, lo: float = 1e-6, hi: float = 10.0, n_buckets: int = 64):
        self._args = (lo, hi, n_buckets)
        self.hist: Dict[str, LatencyHistogram] = {}

    def get(self, name: str) -> LatencyHistogram:
        """The ``name`` histogram, created on first use."""
        h = self.hist.get(name)
        if h is None:
            h = self.hist[name] = LatencyHistogram(*self._args)
        return h

    @contextlib.contextmanager
    def span(self, name: str, annotate: bool = False):
        """Time a block into the ``name`` histogram; with ``annotate`` the
        span also lands in profiler timelines via ``stage``."""
        h = self.get(name)
        ctx: contextlib.AbstractContextManager = stage(name) if annotate else contextlib.nullcontext()
        t0 = time.perf_counter()
        with ctx:
            yield
        h.observe(time.perf_counter() - t0)

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-span digests, keyed by span name."""
        return {name: h.summary() for name, h in self.hist.items()}

    def quantile(self, name: str, q: float) -> Optional[float]:
        """Quantile of one span's histogram; None if the span never ran."""
        h = self.hist.get(name)
        return h.quantile(q) if h else None
