"""One results layout for every artifact the repo writes.

Before the metrics spine, artifact paths were decided ad hoc per writer:
bench JSON landed under ``$REPRO_BENCH_OUT`` (default ``results/bench``)
but the late-credit grid was hardwired to ``results/`` — so redirecting a
run's output moved *some* of its artifacts.  This module is the single
resolution point:

    <root>/                      results_root()
      bench/                     bench_dir()      -- BENCH_<name>.json (+ baseline/)
      runlogs/                   runlog_dir()     -- <run>.jsonl event streams
      <name>.json|.txt           artifact_path()  -- grid tables & other run products

``REPRO_RESULTS`` overrides the root directly.  For backwards
compatibility ``REPRO_BENCH_OUT`` still overrides the bench dir; when it
is the only override, the root is its parent (so ``REPRO_BENCH_OUT=/tmp/x/bench``
routes runlogs to ``/tmp/x/runlogs`` and grid artifacts to ``/tmp/x/``).
Env vars are read at call time, never cached, so tests and harness code
can redirect a single run.
"""
from __future__ import annotations

import os

__all__ = [
    "results_root", "bench_dir", "runlog_dir", "autotune_dir",
    "artifact_path", "bench_path", "runlog_path", "autotune_path",
]


def results_root() -> str:
    """The root of the results tree (no directory is created)."""
    root = os.environ.get("REPRO_RESULTS")
    if root:
        return root
    bench = os.environ.get("REPRO_BENCH_OUT")
    if bench:
        parent = os.path.dirname(os.path.normpath(bench))
        return parent or "."
    return "results"


def bench_dir() -> str:
    """Where ``BENCH_<name>.json`` files (and ``baseline/``) live."""
    return os.environ.get("REPRO_BENCH_OUT") or os.path.join(results_root(), "bench")


def runlog_dir() -> str:
    """Where JSONL run logs live."""
    return os.path.join(results_root(), "runlogs")


def autotune_dir() -> str:
    """Where the kernel autotune cache lives (``REPRO_AUTOTUNE_DIR``
    overrides; the committed per-box baseline sits at the default)."""
    return os.environ.get("REPRO_AUTOTUNE_DIR") or os.path.join(results_root(), "autotune")


def _ensure(path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    return path


def artifact_path(filename: str) -> str:
    """A non-bench run artifact (grid tables, figures) under the root;
    creates the directory."""
    return _ensure(os.path.join(results_root(), filename))


def bench_path(name: str) -> str:
    """``BENCH_<name>.json`` under the bench dir; creates the directory."""
    return _ensure(os.path.join(bench_dir(), f"BENCH_{name}.json"))


def runlog_path(run: str) -> str:
    """``<run>.jsonl`` under the runlog dir; creates the directory."""
    return _ensure(os.path.join(runlog_dir(), f"{run}.jsonl"))


def autotune_path(name: str = "autotune") -> str:
    """``<name>.json`` under the autotune dir; creates the directory."""
    return _ensure(os.path.join(autotune_dir(), f"{name}.json"))
