"""repro.obs — the metrics spine.

Three layers, one schema:

* :mod:`repro.obs.taps` — in-scan metric taps (typed counter/gauge
  registry, windowed aggregates) the ``RoundProgram`` taps stage emits.
* :mod:`repro.obs.runlog` — schema-versioned JSONL run logs every runner,
  grid and serving loop writes through.
* :mod:`repro.obs.trace` — stage-level trace annotations for device code
  and bucketed host-side latency histograms.

* :mod:`repro.obs.sketches` — fixed-size mergeable client-axis sketches
  (count/probability/lag histograms, per-region rollups) carried in the
  scan, plus the fairness series derived from them.
* :mod:`repro.obs.alerts` — rule-based outage/starvation/drift detection
  over tap + sketch streams, appended to run logs as ``alert`` events.

plus :mod:`repro.obs.paths` (one results layout) and
:mod:`repro.obs.report` (the unified Reporter benchmarks emit through).

This package must stay importable without the engine: it imports only
numpy / stdlib at module scope (jax lazily), so ``repro.engine`` can
depend on it without cycles.
"""
from .alerts import Alert, AlertRules, detect_alerts, log_alerts
from .paths import artifact_path, bench_dir, bench_path, results_root, runlog_dir, runlog_path
from .report import Reporter
from .runlog import SCHEMA_VERSION, RunLog, iter_alerts, iter_metrics, read_runlog, validate_records
from .sketches import SKETCH_FIELDS, SketchSpec, fairness_series, merge_sketches, sketch_from_dense
from .taps import ROUND_TAPS, TapRegistry, TapSpec, window_reduce
from .trace import LatencyHistogram, SpanTimer, stage

__all__ = [
    "artifact_path", "bench_dir", "bench_path", "results_root", "runlog_dir", "runlog_path",
    "Reporter",
    "SCHEMA_VERSION", "RunLog", "read_runlog", "validate_records", "iter_metrics", "iter_alerts",
    "SKETCH_FIELDS", "SketchSpec", "fairness_series", "merge_sketches", "sketch_from_dense",
    "Alert", "AlertRules", "detect_alerts", "log_alerts",
    "ROUND_TAPS", "TapRegistry", "TapSpec", "window_reduce",
    "LatencyHistogram", "SpanTimer", "stage",
]
