"""Rule-based alert detection over tap and sketch streams.

A detector pass is the operational half of the telemetry spine: the taps
tell you *what happened per round*, the sketches *to whom* — alerts turn
both into a short list of "something needs a look" events appended to the
JSONL run log (schema v2 ``alert`` records), so a CI artifact or a serving
dashboard surfaces regressions without anyone eyeballing raw series.

Four rule families, all deterministic host-side numpy over series the
runners already emit (no new device work):

* **outage** — the windowed mean of per-round on-time credit collapses
  below a fraction of the best prior window (a volatility cliff, a dead
  region, a broken trace).
* **starvation** — the client-axis fairness series degrade past thresholds:
  Jain below ``jain_min``, or the most-selected decile of clients holding
  more than ``top_share_max`` of all selection mass (E3CS's exploration
  floor failing to spread load).
* **engine_restart** — the serving supervisor's ``restarts`` gauge (the
  ``serve`` tap group) is nonzero: the engine crashed and was restored
  from a checkpoint at least once during the run.
* **drift** — the engine's invariants move: the cohort size leaves the
  configured k (``selected`` must equal k every round), or the fraction of
  probability-capped clients sustains above ``cap_frac_max`` (the allocator
  saturating, CEP gains about to flatline).

``detect_alerts`` returns ``Alert`` records; ``log_alerts`` appends them to
a ``RunLog``.  ``repro.obs.report.Reporter.alerts`` wires both into the
benchmark/serving emission path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Alert", "AlertRules", "detect_alerts", "log_alerts", "SEVERITIES"]

SEVERITIES = ("warn", "critical")


@dataclasses.dataclass(frozen=True)
class Alert:
    """One detector firing: rule name, severity, locating detail."""

    rule: str
    severity: str
    detail: dict
    message: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r} (want one of {SEVERITIES})")


@dataclasses.dataclass(frozen=True)
class AlertRules:
    """Thresholds for the detector pass (defaults sized for the paper's
    regimes: a halved window of credit is an outage, Jain below 0.4 or a
    decile hoarding 60% of selections is starvation)."""

    outage_drop: float = 0.5  # window mean on_time below this fraction of best prior window
    jain_min: float = 0.4
    top_share_max: float = 0.6
    cap_frac_max: float = 0.5
    window: int = 0  # rounds per detector window; 0 = T // 10 (min 1)


def _window_means(s: np.ndarray, window: int) -> np.ndarray:
    n = s.shape[0] // window
    return s[: n * window].reshape(n, window).mean(axis=1) if n else np.zeros((0,))


def detect_alerts(
    series: Optional[Dict[str, np.ndarray]] = None,
    fairness: Optional[Dict[str, np.ndarray]] = None,
    expected_selected: Optional[float] = None,
    rules: AlertRules = AlertRules(),
) -> List[Alert]:
    """Run the detector pass.

    ``series`` is a per-round tap series dict (``{"on_time": (T,), ...}``,
    any subset); ``fairness`` a sketch-cadence fairness dict
    (``sketches.fairness_series`` output, any subset); ``expected_selected``
    the configured cohort size k.  Missing inputs skip their rules — the
    pass degrades gracefully to whatever telemetry a runner produced.
    """
    alerts: List[Alert] = []
    series = {k: np.asarray(v, np.float64).reshape(-1) for k, v in (series or {}).items()}
    fairness = {k: np.asarray(v, np.float64).reshape(-1) for k, v in (fairness or {}).items()}

    # --- outage: windowed on-time credit collapse -----------------------
    on_time = series.get("on_time")
    if on_time is not None and on_time.size:
        W = rules.window or max(1, on_time.shape[0] // 10)
        means = _window_means(on_time, W)
        best = -np.inf
        for w, m in enumerate(means):
            if w and best > 0 and m < rules.outage_drop * best:
                alerts.append(Alert(
                    "outage", "critical",
                    {"window": int(w), "rounds_per_window": int(W),
                     "on_time_mean": float(m), "prior_best": float(best)},
                    f"on-time credit fell to {m:.2f}/round in window {w} "
                    f"(best prior window {best:.2f})",
                ))
                break  # one firing per run is enough to flag it
            best = max(best, float(m))

    # --- starvation: fairness series past thresholds --------------------
    jain = fairness.get("jain")
    if jain is not None and jain.size and float(jain[-1]) < rules.jain_min:
        alerts.append(Alert(
            "starvation", "warn",
            {"jain": float(jain[-1]), "jain_min": rules.jain_min,
             "emission": int(jain.shape[0] - 1)},
            f"Jain index {jain[-1]:.3f} below floor {rules.jain_min}",
        ))
    top = fairness.get("top_decile_share")
    if top is not None and top.size and float(top[-1]) > rules.top_share_max:
        alerts.append(Alert(
            "starvation", "warn",
            {"top_decile_share": float(top[-1]), "top_share_max": rules.top_share_max,
             "emission": int(top.shape[0] - 1)},
            f"top decile of clients holds {top[-1]:.1%} of selection mass "
            f"(cap {rules.top_share_max:.0%})",
        ))

    # --- drift: engine invariants moving --------------------------------
    selected = series.get("selected")
    if selected is not None and selected.size and expected_selected is not None:
        off = np.flatnonzero(selected != float(expected_selected))
        if off.size:
            alerts.append(Alert(
                "drift", "critical",
                {"metric": "selected", "expected": float(expected_selected),
                 "rounds_off": int(off.size), "first_round": int(off[0]),
                 "value": float(selected[off[0]])},
                f"cohort size left k={expected_selected} in {off.size} rounds "
                f"(first at round {int(off[0])})",
            ))
    # --- engine_restart: the serving supervisor had to recover ----------
    restarts = series.get("restarts")
    if restarts is not None and restarts.size:
        n = float(restarts.sum())
        if n > 0:
            recovery = series.get("recovery_s")
            alerts.append(Alert(
                "engine_restart", "warn",
                {"restarts": n,
                 "recovery_s": float(recovery.sum()) if recovery is not None else 0.0,
                 "first_dispatch": int(np.flatnonzero(restarts)[0])},
                f"{n:.0f} supervised engine restart(s) during the run",
            ))

    capped = series.get("capped_frac")
    if capped is not None and capped.size:
        W = rules.window or max(1, capped.shape[0] // 10)
        means = _window_means(capped, W)
        if means.size and float(means[-1]) > rules.cap_frac_max:
            alerts.append(Alert(
                "drift", "warn",
                {"metric": "capped_frac", "window_mean": float(means[-1]),
                 "cap_frac_max": rules.cap_frac_max, "window": int(means.shape[0] - 1)},
                f"{means[-1]:.1%} of clients at the probability cap "
                f"(threshold {rules.cap_frac_max:.0%})",
            ))
    return alerts


def log_alerts(log, alerts: List[Alert]) -> List[dict]:
    """Append ``Alert`` records to a ``RunLog`` (schema v2 ``alert`` events)."""
    return [log.alert(a.rule, a.severity, a.detail, a.message) for a in alerts]
