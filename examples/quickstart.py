"""Quickstart: federated training with E3CS client selection in ~40 lines.

Runs the paper's protocol end-to-end on CPU in about two minutes: 100
volatile clients (Bernoulli success rates 0.1/0.3/0.6/0.9), non-iid
primary-label shards of a synthetic 26-class image task, the paper's CNN,
deadline aggregation, and the E3CS-inc fairness schedule.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig, get_config
from repro.data import ClientStore, make_image_dataset, partition_primary_label
from repro.fl import FLServer
from repro.models import build_model, cross_entropy

fl = FLConfig(
    K=100, k=20, rounds=20, scheme="e3cs", quota="inc",
    samples_per_client=60, batch_size=20, local_epochs=(1, 2), seed=0,
)

data = make_image_dataset(n_classes=26, img_shape=(28, 28, 1), n_train=4000, n_test=1500, seed=0)
shards = partition_primary_label(data["y"], fl.K, fl.samples_per_client, seed=0)
store = ClientStore(data, shards)
model = build_model(get_config("emnist-cnn"))


def eval_fn(params):
    x, y = store.eval_batch(1000)
    logits = model.forward(params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
    return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean()), float(cross_entropy(logits, jnp.asarray(y)))


server = FLServer(model, fl, store, eval_fn)
state = server.init_state(jax.random.PRNGKey(0))
state, history = server.run(state, eval_every=5)

print(f"rounds={fl.rounds}  CEP={int(state.cep)}/{fl.rounds * fl.k}")
print("accuracy trajectory:", [round(a, 3) for a in history["acc"]])
counts = np.asarray(state.sel_counts).reshape(4, -1).sum(1)
print("selections by volatility class (rho=0.1/0.3/0.6/0.9):", counts.astype(int).tolist())
