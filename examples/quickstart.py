"""Quickstart: the E3CS selection engine in ~30 lines, compiled end to end.

Builds the paper's protocol straight from an ``FLConfig`` through
``RoundProgram.from_config`` — the single knob-resolution path every runner
in this repo uses — and scans a whole selection horizon in one compiled
program: 10,000 volatile clients (Bernoulli success classes 0.1/0.3/0.6/0.9),
E3CS exponential-weight selection with the incremental fairness schedule,
deadline-based feedback.  Runs in a few seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

From the same config, everything else is composition, not new code:
``staleness_rounds=S`` makes the horizon asynchronous (late cohorts credited
``alpha**lag`` from a bounded ring), ``mesh=make_host_mesh(D)`` shards the
client axis over D devices, and ``repro.serve`` puts a socket in front of
the compiled step (see examples/serve_demo.py).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig
from repro.engine import RoundProgram

fl = FLConfig(K=10_000, k=200, rounds=300, scheme="e3cs", quota="inc", seed=0)
program = RoundProgram.from_config(fl)  # volatility: the paper's Bernoulli classes

# one jitted lax.scan over the whole horizon; feedback is drawn in-engine
run, state0 = program.build_runner(outputs="lean", taps=True)
xs = jnp.zeros((fl.rounds, 0), jnp.float32)  # no external feedback stream
state, successes, sigmas, taps = run(state0, jax.random.PRNGKey(fl.seed), xs)

cep = float(jnp.sum(successes))  # cumulative effective participation (paper Eq. 8)
print(f"rounds={fl.rounds}  K={fl.K}  cohort k={fl.k}")
print(f"CEP: {cep:.0f} / {fl.rounds * fl.k} issued slots "
      f"({cep / (fl.rounds * fl.k):.1%} effective)")
print(f"fairness quota sigma: {float(sigmas[0]):.4f} -> {float(sigmas[-1]):.4f} (inc schedule)")

counts = np.asarray(state.sel_counts).reshape(4, -1).sum(1)
print("selections by volatility class (rho=0.1/0.3/0.6/0.9):", counts.astype(int).tolist())
per_round = {name: float(np.mean(series)) for name, series in taps["series"].items()}
print("per-round telemetry (means):",
      {name: round(v, 2) for name, v in sorted(per_round.items())})
