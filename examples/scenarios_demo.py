"""Scenario subsystem tour: structured volatility, the selector x scenario
grid, and bit-packed trace replay.

Three stops, CPU-friendly (~1 minute):

1. Evaluate three selectors against four availability regimes (iid paper
   classes, sticky Markov, diurnal cycles, correlated regional outages) —
   each cell one compiled whole-horizon scan.
2. Map the scenario axis onto the batched multi-job engine: one vmapped
   E3CS row per scenario, a single device dispatch per round.
3. Record the regional-outage scenario as a bit-packed trace (8 clients per
   byte) and replay it through the scan — selections bit-identical to the
   dense path at 1/32 the trace memory.

    PYTHONPATH=src python examples/scenarios_demo.py
"""
import numpy as np

from repro.engine.scan_sim import scan_selection_sim
from repro.scenarios import (
    format_grid,
    make_scenario,
    record_trace,
    run_grid,
    run_grid_multi_job,
    unpack_trace,
)

K, k, T = 100, 20, 400
SCENARIOS = ("paper_iid", "markov_sticky", "diurnal", "regional_outage")

print(f"== selector x scenario grid (K={K}, k={k}, T={T}) ==")
rows = run_grid(("e3cs", "random", "fedcs"), SCENARIOS, K=K, k=k, T=T, seed=0)
print(format_grid(rows))

print("\n== scenario axis on the batched multi-job engine ==")
mj = run_grid_multi_job(SCENARIOS, K=K, k=k, T=150, seed=0)
print(format_grid(mj))

print("\n== bit-packed replay ==")
vol, rho = make_scenario("regional_outage", K, T, seed=0)
packed = record_trace(vol, T, seed=0)
dense = unpack_trace(packed, K)
a = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, rho=rho, packed_override=packed)
b = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, rho=rho, xs_override=dense)
print(f"trace: {packed.nbytes / 1e3:.1f} KB packed vs {dense.nbytes / 1e3:.1f} KB dense (32x)")
print(f"selections bit-identical to dense replay: {np.array_equal(a['masks'], b['masks'])}")
print(f"CEP on the frozen trace: {a['masks'].ravel() @ a['xs'].ravel():.0f} / {T * k}")
