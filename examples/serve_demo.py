"""Serving demo: the selection engine behind a real socket, end to end.

Stands up a ``SelectionServer`` (``repro.serve``) on the loopback, then acts
as two tenant FL coordinators: admit two jobs of different shapes, drive
volatile rounds through the streaming batcher, checkpoint, **kill the
server**, restore a new one from disk mid-horizon, and finish — printing
the selection overlap so you can see the restored stream is the same one.

Every byte crosses a TCP socket using the stdlib-only wire protocol of
``docs/serving.md`` — this demo is exactly what an external coordinator
would do, minus the model training between ticks.

    PYTHONPATH=src python examples/serve_demo.py
    PYTHONPATH=src python examples/serve_demo.py --rounds 40 --staleness 2
"""
import argparse
import tempfile

import numpy as np

from repro.serve import (
    SelectionServer,
    ServeClient,
    SlotEngine,
    latest_server_checkpoint,
    load_server,
)


def volatile_round(rng, K, S):
    """Completion lags for one round: 0 = on time, 1..S = late, -1 = never."""
    lag = rng.integers(0, S + 2, K).astype(np.int32)
    return np.where(lag > S, -1, lag)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--staleness", type=int, default=2, help="late-credit ring depth S")
    args = ap.parse_args()
    S, half = args.staleness, args.rounds // 2
    rng = np.random.default_rng(0)
    ckpt_dir = tempfile.mkdtemp(prefix="serve_demo_")

    def fresh_engine():
        return SlotEngine(K_max=512, k_cap=32, staleness=S, buckets=(4, 8))

    print(f"=== first life: 2 tenants, {half} rounds each ===")
    srv = SelectionServer(fresh_engine(), ckpt_dir=ckpt_dir, ckpt_every=20)
    srv.start()
    host, port = srv.address
    print(f"server on {host}:{port}, checkpoints -> {ckpt_dir}")

    c = ServeClient(host, port)
    jobs = [c.admit(K=384, k=24, seed=1), c.admit(K=128, k=8, seed=2)]
    Ks = {jobs[0]: 384, jobs[1]: 128}
    cohorts = {j: [] for j in jobs}
    for t in range(half):
        for j in jobs:
            out = c.tick(j, lags=volatile_round(rng, Ks[j], S))
            cohorts[j].append(out["cohort"])
    print(f"round {half - 1} cohort sizes:",
          {j: len(cohorts[j][-1]) for j in jobs})
    print("forced checkpoint:", c.checkpoint())
    c.close()
    srv.kill()  # crash, not drain: whatever wasn't checkpointed is gone
    print("server killed (no drain)")

    print(f"=== second life: restore and finish the horizon ===")
    stem = latest_server_checkpoint(ckpt_dir)
    engine, step = load_server(stem)
    print(f"restored {stem} at {step} served rounds, jobs {sorted(engine.jobs)}")
    with SelectionServer(engine, ckpt_dir=ckpt_dir) as srv2:
        c = ServeClient.connect(srv2.address)
        for t in range(half, args.rounds):
            for j in jobs:
                out = c.tick(j, lags=volatile_round(rng, Ks[j], S))
                cohorts[j].append(out["cohort"])
        stats = c.stats()
        c.close()
    print(f"finished: {args.rounds} rounds/job, second-life stats {stats['stats']}")
    for j in jobs:
        uniq = len({i for coh in cohorts[j] for i in coh})
        print(f"job {j}: K={Ks[j]}, {uniq} distinct clients selected across the horizon")
    print("(restart is bit-identical: tests/test_serve.py pins cohort equality "
          "against an uninterrupted run)")


if __name__ == "__main__":
    main()
