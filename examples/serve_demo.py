"""Serving demo: batched prefill + sampled decode on any assigned arch's
smoke variant — exercising the same prefill/decode paths the multi-pod
dry-run lowers at production scale (incl. the Mamba2 O(1)-state decode and
MLA latent cache).

    PYTHONPATH=src python examples/serve_demo.py --arch deepseek-v3-671b
    PYTHONPATH=src python examples/serve_demo.py --arch mamba2-130m --gen 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.models import build_model
from repro.models.transformer import vlm_positions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, _ = model.init(rng)
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_patch), jnp.float32)
        batch["positions"] = vlm_positions(cfg, B, S + cfg.n_patches)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, cfg.enc_len, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches = jax.jit(model.prefill)(params, batch, max_len=S + args.gen + 8)
    jax.block_until_ready(logits)
    print(f"[{cfg.name}] prefill B={B} S={S}: {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(args.gen):
        logits, caches = decode(params, tok, caches)
        tok = jax.random.categorical(jax.random.fold_in(rng, i), logits[:, -1] / 0.8)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in toks], 1)
    print(f"decode: {args.gen} steps, {B*args.gen/dt:.1f} tok/s (incl. first-call compile)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
