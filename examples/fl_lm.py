"""Federated *language-model* training with E3CS — the cohort mapping from
DESIGN.md §3 at CPU scale: each selected client owns a shard of a
heterogeneous token stream (a distinct bigram-mixture dialect) and runs local
SGD on a reduced StableLM-family decoder; the masked deadline aggregation and
the Exp3 weight update are the exact production code paths the dry-run lowers
at 512 chips.

    PYTHONPATH=src python examples/fl_lm.py --rounds 25
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig, get_config, smoke_variant
from repro.core.selection import make_quota_schedule
from repro.core.volatility import BernoulliVolatility, paper_success_rates
from repro.fl.round import init_server_state, make_cohort_round
from repro.data import make_lm_dataset, lm_client_batches
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=25)
    ap.add_argument("--K", type=int, default=32)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--scheme", default="e3cs")
    args = ap.parse_args()

    cfg = smoke_variant(get_config("stablelm-1.6b"))
    model = build_model(cfg)
    fl = FLConfig(K=args.K, k=args.k, rounds=args.rounds, scheme=args.scheme, lr=5e-3)
    quota = make_quota_schedule("inc", fl.k, fl.K, fl.rounds)
    rho = jnp.asarray(paper_success_rates(fl.K))
    vol = BernoulliVolatility(rho)
    select, round_fn = make_cohort_round(model, fl, quota, vol, rho)
    select, round_fn = jax.jit(select), jax.jit(round_fn)

    stream = make_lm_dataset(cfg.vocab, 200_000, n_chains=args.K, seed=0)
    params, _ = model.init(jax.random.PRNGKey(0))
    state = init_server_state(params, fl.K, vol.init_state())
    key = jax.random.PRNGKey(1)
    n_steps = 2
    for t in range(fl.rounds):
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        blocks = lm_client_batches(stream, fl.K, np.asarray(idx), n_steps, args.batch, args.seq, seed=t)
        batches = {
            "tokens": jnp.asarray(blocks[..., :-1]),
            "labels": jnp.asarray(blocks[..., :-1]),
        }
        step_mask = jnp.ones((fl.k, n_steps), jnp.float32)
        sizes = jnp.full((fl.k,), 1.0)
        state, metrics = round_fn(
            state, idx, p, capped, sigma, batches, step_mask, sizes,
            jnp.float32(fl.K), jnp.ones((fl.k,)), k2,
        )
        if t % 5 == 0 or t == fl.rounds - 1:
            print(
                f"round {t:3d}  local_loss={float(metrics['mean_local_loss']):.3f}  "
                f"effective={int(metrics['n_success'])}/{fl.k}  CEP={int(metrics['cep'])}"
            )
    counts = np.asarray(state.sel_counts).reshape(4, -1).sum(1).astype(int)
    print("selections by volatility class:", counts.tolist())


if __name__ == "__main__":
    main()
