"""End-to-end paper reproduction driver.

Phase 1 (fast, exact): the numerical experiments — Fig. 3 selection
distributions, Fig. 4 CEP/success-ratio curves, Theorem-1 regret check.

Phase 2 (real training): EMNIST-like non-iid FL comparing E3CS-0 / E3CS-inc /
FedCS / Random — reproducing the paper's qualitative claims (CEP accelerates
early convergence; fairness decides final accuracy).

    PYTHONPATH=src python examples/paper_repro.py [--rounds 60] [--full]
"""
import argparse
import json

import numpy as np

from repro.core.fairness import jain_index
from repro.core.selection import regret, theorem1_bound, theorem1_eta
from repro.core.sim import selection_sim


def phase1(T=1000):
    print(f"== Phase 1: selection dynamics over {T} rounds (K=100, k=20) ==")
    import jax.numpy as jnp

    rows = []
    for name, kw in [
        ("FedCS", dict(scheme="fedcs")),
        ("E3CS-0", dict(scheme="e3cs", frac=0.0)),
        ("E3CS-0.5", dict(scheme="e3cs", frac=0.5)),
        ("E3CS-0.8", dict(scheme="e3cs", frac=0.8)),
        ("E3CS-inc", dict(scheme="e3cs", quota="inc")),
        ("Random", dict(scheme="random")),
        ("pow-d", dict(scheme="pow_d")),
    ]:
        sim = selection_sim(T=T, **kw)
        cep = float((sim["masks"] * sim["xs"]).sum())
        jain = float(jain_index(jnp.asarray(sim["counts"])))
        by_class = sim["counts"].reshape(4, -1).sum(1).astype(int).tolist()
        rows.append((name, cep, jain, by_class))
        print(f"  {name:10s} CEP={cep:7.0f}  Jain={jain:.3f}  class-counts={by_class}")
    order = [r[0] for r in sorted(rows, key=lambda r: -r[1])]
    print("  CEP order:", " > ".join(order), "(paper Fig.4: FedCS > E3CS-0 > 0.5 > 0.8 ~ inc > Random > pow-d)")

    # Theorem 1
    K, k, T2 = 50, 10, 500
    sigmas = np.zeros(T2)
    eta = theorem1_eta(K, k, sigmas)
    sim = selection_sim("e3cs", K=K, k=k, T=T2, frac=0.0, eta=eta, seed=1)
    R = regret(sim["ps"], sim["xs"], k, sigmas, "static")
    print(f"  Theorem 1: empirical regret {R:.1f} <= bound {theorem1_bound(K, k, sigmas, eta):.1f}")


def phase2(rounds=60):
    print(f"== Phase 2: real FL training ({rounds} rounds, non-iid EMNIST-like) ==")
    import jax
    import jax.numpy as jnp

    from repro.configs import FLConfig, get_config
    from repro.data import ClientStore, make_image_dataset, partition_primary_label
    from repro.fl import FLServer
    from repro.models import build_model, cross_entropy

    data = make_image_dataset(26, (28, 28, 1), 4000, 1500, seed=0)
    shards = partition_primary_label(data["y"], 100, 60, seed=0)
    store = ClientStore(data, shards)
    model = build_model(get_config("emnist-cnn"))

    def eval_fn(params):
        x, y = store.eval_batch(1000)
        logits = model.forward(params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean()), float(
            cross_entropy(logits, jnp.asarray(y))
        )

    results = {}
    for name, kw in [
        ("E3CS-0", dict(scheme="e3cs", quota="const", quota_frac=0.0)),
        ("E3CS-inc", dict(scheme="e3cs", quota="inc")),
        ("FedCS", dict(scheme="fedcs")),
        ("Random", dict(scheme="random")),
    ]:
        fl = FLConfig(K=100, k=20, rounds=rounds, samples_per_client=60, batch_size=20,
                      local_epochs=(1, 2), seed=0, **kw)
        srv = FLServer(model, fl, store, eval_fn)
        state = srv.init_state(jax.random.PRNGKey(0))
        state, hist = srv.run(state, eval_every=max(2, rounds // 10))
        results[name] = dict(acc=hist["acc"], cep=float(state.cep))
        print(f"  {name:10s} CEP={int(state.cep):4d}  acc@mid={hist['acc'][len(hist['acc'])//2]:.3f}  final={hist['acc'][-1]:.3f}")
    print(json.dumps({k: dict(final=v["acc"][-1], cep=v["cep"]) for k, v in results.items()}, indent=1))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--full", action="store_true", help="paper-scale horizons (hours on CPU)")
    args = ap.parse_args()
    phase1(T=2500 if args.full else 1000)
    phase2(rounds=400 if args.full else args.rounds)
