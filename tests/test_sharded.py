"""K-sharded engine tests.

Pins the PR-1 architecture promise now that it is wired to a real mesh:

* ``prob_alloc_shmap`` on a forced 8-device CPU mesh == the local bisection
  (``masked_prob_alloc``) == the paper's literal case-enumeration oracle;
* the compiled sharded allocator contains **no sort** and exactly **one
  all-reduce inside the bisection loop** (collective count is independent of
  the iteration count);
* the distributed Plackett-Luce top-k (per-shard top-k + candidate merge) is
  *exactly* the dense ``plackett_luce_sample`` given the same perturbed
  scores, ragged shards and ties included;
* the mesh=1 sharded scan is **bit-identical** to the unsharded engine
  (``allocator="bisect"``) across all five schemes;
* the fused ``bisect_tiles`` kernel matches its jnp reference in interpret
  mode (bit-exact against same-order accumulation), and block-mode bisection
  matches plain bisection;
* ``masked_prob_alloc`` keeps float64 weights in float64 (x64 mode) instead
  of downcasting through the scalar-cast path.

The 8-device host comes from ``tests/conftest.py`` setting
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax loads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selection import plackett_luce_sample, prob_alloc_reference
from repro.core.selection.sampling import merge_topk_candidates, perturbed_scores
from repro.engine.scan_sim import scan_selection_sim
from repro.engine.sharded import (
    distributed_topk,
    masked_prob_alloc,
    plackett_luce_shmap,
    prob_alloc_shmap,
    sharded_selection_sim,
)
from repro.kernels.bisect_tiles import bisect_block_sums_kernel_call, bisect_block_sums_ref
from repro.launch.mesh import make_host_mesh
from repro.scenarios.replay import pack_trace

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
)


@pytest.fixture(scope="module")
def mesh8():
    return make_host_mesh(8)


@pytest.fixture(scope="module")
def mesh1():
    return make_host_mesh(1)


@needs8
class TestProbAllocShmap:
    @pytest.mark.parametrize("K", [100, 1000, 10_007, 100_000])
    @pytest.mark.parametrize("sigma_frac", [0.0, 0.5])
    def test_matches_local_and_oracle(self, mesh8, K, sigma_frac):
        rng = np.random.default_rng(K)
        k = max(1, K // 5)
        sigma = sigma_frac * k / K
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))  # heavy tail forces capping
        p, capped = prob_alloc_shmap(w, k, sigma, mesh8)
        pm, cm = masked_prob_alloc(w, k, sigma)
        # acceptance bar: <= 1e-6 in p vs the single-device path
        np.testing.assert_allclose(np.asarray(p), np.asarray(pm), atol=1e-6)
        assert (np.asarray(capped) == np.asarray(cm)).all()
        pr, cr = prob_alloc_reference(np.asarray(w), k, sigma)
        np.testing.assert_allclose(np.asarray(p), pr, atol=1e-5)
        assert (np.asarray(capped) == cr).all()
        assert abs(float(np.asarray(p).sum()) - k) < 1e-3 * k + 1e-3

    def test_active_mask_ragged(self, mesh8):
        rng = np.random.default_rng(7)
        K, k = 531, 60
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))
        active = jnp.asarray((rng.random(K) < 0.8).astype(np.float32))
        p, _ = prob_alloc_shmap(w, k, 0.0, mesh8, active=active)
        pm, _ = masked_prob_alloc(w, k, 0.0, active=active)
        np.testing.assert_allclose(np.asarray(p), np.asarray(pm), atol=1e-6)
        assert np.asarray(p)[np.asarray(active) == 0].sum() == 0.0

    def test_block_mode_matches_plain(self, mesh8):
        rng = np.random.default_rng(2)
        K, k = 50_000, 5000
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))
        p1, c1 = masked_prob_alloc(w, k, 0.03)
        for block in (2, 4, 6):
            pb, cb = masked_prob_alloc(w, k, 0.03, block=block)
            np.testing.assert_allclose(np.asarray(pb), np.asarray(p1), atol=1e-6)
            assert (np.asarray(cb) == np.asarray(c1)).all()
        ps, _ = prob_alloc_shmap(w, k, 0.03, mesh8, block=4)
        np.testing.assert_allclose(np.asarray(ps), np.asarray(p1), atol=1e-6)

    def test_hlo_no_sort_one_psum_per_step(self, mesh8):
        # the architecture promise: one scalar all-reduce per bisection step
        # (it lives in the loop body, so the instruction count is independent
        # of n_iters) and no sort anywhere in the compiled allocator
        w = jnp.asarray(np.random.default_rng(0).gamma(0.3, 1.0, 4096).astype(np.float32))

        def hlo(n_iters):
            f = jax.jit(lambda w: prob_alloc_shmap(w, 512, 0.05, mesh8, n_iters=n_iters)[0])
            return f.lower(w).compile().as_text()

        h48, h12 = hlo(48), hlo(12)
        assert "sort(" not in h48, "sharded ProbAlloc must not materialise a global sort"
        n48, n12 = h48.count("all-reduce("), h12.count("all-reduce(")
        assert n48 == n12, "all-reduce count must not grow with bisection steps (one per step, in the loop body)"
        # loop-body psum + the 4 bracket/normalisation reductions (K_act,
        # w_sum, w_max, final capped sum)
        assert 0 < n48 <= 6, h48.count("all-reduce(")


@needs8
class TestDistributedTopK:
    @pytest.mark.parametrize("K,k", [(100, 10), (10_000, 100)])
    def test_equals_dense_plackett_luce(self, mesh8, K, k):
        # same perturbed score field => the per-shard top-k union provably
        # contains the global top-k, and the merge recovers it exactly
        rng = np.random.default_rng(K)
        p = jnp.asarray(rng.random(K).astype(np.float32))
        key = jax.random.PRNGKey(3)
        idx_dense = plackett_luce_sample(key, p, k)
        idx_dist = distributed_topk(perturbed_scores(key, p), k, mesh8)
        assert np.array_equal(np.asarray(idx_dense), np.asarray(idx_dist))

    def test_tie_order_matches_dense(self, mesh8):
        # integer-valued scores force cross-shard ties; lax.top_k breaks ties
        # by lowest index and the candidate merge must preserve that
        rng = np.random.default_rng(0)
        s = jnp.asarray(rng.integers(0, 4, 1000).astype(np.float32))
        _, dense = jax.lax.top_k(s, 37)
        dist = distributed_topk(s, 37, mesh8)
        assert np.array_equal(np.asarray(dense, np.int32), np.asarray(dist))

    def test_ragged_shards(self, mesh8):
        s = jnp.asarray(np.random.default_rng(1).normal(size=101).astype(np.float32))
        _, dense = jax.lax.top_k(s, 12)
        assert np.array_equal(np.asarray(dense, np.int32), np.asarray(distributed_topk(s, 12, mesh8)))

    def test_k_larger_than_shard_raises(self, mesh8):
        with pytest.raises(ValueError, match="shard width"):
            distributed_topk(jnp.zeros(64), 16, mesh8)

    def test_merge_containment_property(self):
        # direct check of the proof obligation: global top-k ⊆ union of
        # per-shard top-ks, for every shard width
        rng = np.random.default_rng(5)
        s = rng.normal(size=96).astype(np.float32)
        k = 7
        _, top = jax.lax.top_k(jnp.asarray(s), k)
        for D in (2, 4, 8):
            shards = s.reshape(D, -1)
            vals, idxs = [], []
            for d in range(D):
                v, i = jax.lax.top_k(jnp.asarray(shards[d]), k)
                vals.append(np.asarray(v))
                idxs.append(np.asarray(i) + d * shards.shape[1])
            union = set(np.concatenate(idxs).tolist())
            assert set(np.asarray(top).tolist()) <= union
            merged = merge_topk_candidates(jnp.asarray(np.stack(vals)), jnp.asarray(np.stack(idxs)), k)
            assert np.array_equal(np.asarray(merged), np.asarray(top, np.int32))

    def test_plackett_luce_shmap_draws(self, mesh8):
        # production sampler: valid duplicate-free cohorts, deterministic per
        # key, and mass concentrates on high-p arms
        p = jnp.asarray(np.concatenate([np.full(32, 0.01), np.full(32, 0.99)]).astype(np.float32))
        counts = np.zeros(64)
        for s in range(200):
            idx = np.asarray(plackett_luce_shmap(jax.random.PRNGKey(s), p, 8, mesh8))
            assert len(set(idx.tolist())) == 8 and (idx >= 0).all() and (idx < 64).all()
            counts[idx] += 1
        again = np.asarray(plackett_luce_shmap(jax.random.PRNGKey(199), p, 8, mesh8))
        assert (again >= 0).all()  # deterministic re-draw works
        assert counts[32:].sum() > 5 * counts[:32].sum()


class TestShardedScanBitIdentity:
    SCHEMES = [
        ("e3cs", dict(frac=0.5)),
        ("e3cs", dict(frac=0.0, volatility="markov")),
        ("e3cs", dict(quota="inc")),
        ("random", {}),
        ("ucb", {}),
        ("fedcs", {}),
        ("pow_d", {}),
    ]

    @pytest.mark.parametrize("scheme,kw", SCHEMES, ids=[f"{s}-{i}" for i, (s, _) in enumerate(SCHEMES)])
    def test_mesh1_matches_unsharded(self, mesh1, scheme, kw):
        a = sharded_selection_sim(scheme, mesh1, K=100, k=20, T=120, **kw)
        b = scan_selection_sim(scheme, K=100, k=20, T=120, allocator="bisect", **kw)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        assert np.array_equal(a["counts"], b["counts"])
        np.testing.assert_allclose(a["sigmas"], b["sigmas"], atol=0)
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)

    def test_mesh1_packed_override_matches_unsharded(self, mesh1):
        rng = np.random.default_rng(0)
        xs = rng.binomial(1, 0.6, (80, 96)).astype(np.float32)
        packed = pack_trace(xs)
        a = sharded_selection_sim("e3cs", mesh1, K=96, k=12, T=80, frac=0.25, packed_override=packed)
        b = scan_selection_sim("e3cs", K=96, k=12, T=80, frac=0.25, packed_override=packed, allocator="bisect")
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])


@needs8
class TestShardedScanD8:
    def test_dense_equals_packed_when_widths_align(self, mesh8):
        # K = 8 * D bytes-aligned => the dense and packed paths shard to the
        # same width, so the PRNG streams coincide and runs are bit-identical
        rng = np.random.default_rng(3)
        K, T = 128, 60
        xs = rng.binomial(1, 0.5, (T, K)).astype(np.float32)
        a = sharded_selection_sim("e3cs", mesh8, K=K, k=10, T=T, frac=0.5, xs_override=xs)
        b = sharded_selection_sim("e3cs", mesh8, K=K, k=10, T=T, frac=0.5, packed_override=pack_trace(xs))
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        np.testing.assert_array_equal(a["xs"], xs)

    def test_cardinality_and_lean_counts(self, mesh8):
        full = sharded_selection_sim("e3cs", mesh8, K=100, k=10, T=90, frac=0.5, seed=4)
        lean = sharded_selection_sim("e3cs", mesh8, K=100, k=10, T=90, frac=0.5, seed=4, outputs="lean")
        np.testing.assert_array_equal(full["masks"].sum(1), np.full(90, 10.0))
        assert np.array_equal(full["counts"], lean["counts"])
        np.testing.assert_allclose((full["masks"] * full["xs"]).sum(1), lean["successes"], atol=1e-4)

    def test_fleet_learns_stable_clients(self, mesh8):
        # behavioural check at D=8: E3CS mass concentrates on the rho=0.9
        # class exactly like the unsharded engine
        out = sharded_selection_sim("e3cs", mesh8, K=128, k=16, T=400, frac=0.0, seed=0, outputs="lean")
        per_class = out["counts"].reshape(4, -1).sum(1)
        assert per_class[3] > 2 * per_class[0], per_class

    def test_block_bisect_inside_scan(self, mesh8):
        a = sharded_selection_sim("e3cs", mesh8, K=96, k=8, T=60, frac=0.5, block=4)
        assert (a["masks"].sum(1) == 8).all()
        assert a["counts"].sum() == 8 * 60

    def test_build_scan_runner_mesh_kwarg(self, mesh8):
        # the public engine entry point threads the sharded round through the
        # same (run, state0) contract as the unsharded builder
        from repro.configs.base import FLConfig
        from repro.core.volatility import make_volatility, paper_success_rates
        from repro.engine.scan_sim import build_scan_runner

        fl = FLConfig(K=100, k=10, rounds=40, scheme="e3cs", quota_frac=0.5, allocator="bisect")
        rho = paper_success_rates(100)
        vol = make_volatility("bernoulli", jnp.asarray(rho))
        run, state0 = build_scan_runner(fl, vol, rho, outputs="lean", mesh=mesh8)
        state, successes, sigmas = run(state0, jax.random.PRNGKey(0), jnp.zeros((40, 0), jnp.float32))
        assert successes.shape == (40,)
        assert float(np.asarray(state.sel_counts)[:100].sum()) == 400.0
        # carry_key composes with the mesh since the RoundProgram unification:
        # two 20-round chunks reproduce the one-shot horizon bit-for-bit
        run_c, s0c = build_scan_runner(fl, vol, rho, outputs="lean", mesh=mesh8, carry_key=True, scan_length=20)
        st, key = s0c, jax.random.PRNGKey(0)
        succ = []
        for _ in range(2):
            st, key, s, _ = run_c(st, key, jnp.zeros((20, 0), jnp.float32))
            succ.append(np.asarray(s))
        assert np.array_equal(np.concatenate(succ), np.asarray(successes))
        np.testing.assert_array_equal(np.asarray(st.sel_counts), np.asarray(state.sel_counts))


class TestBisectTilesKernel:
    @pytest.mark.parametrize("K,tile", [(64, 128), (1000, 256), (8193, 1024)])
    def test_kernel_matches_ref(self, K, tile):
        rng = np.random.default_rng(K)
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))
        caps = jnp.asarray(np.sort(rng.gamma(0.3, 1.0, 15)).astype(np.float32))
        out = bisect_block_sums_kernel_call(w, caps, tile=tile, interpret=True)
        # bit-exact against same-order (sequential per-tile) accumulation
        acc = np.zeros(15, np.float32)
        wp = np.pad(np.asarray(w), (0, (-K) % tile))
        for lo in range(0, wp.shape[0], tile):
            acc = acc + np.asarray(bisect_block_sums_ref(jnp.asarray(wp[lo : lo + tile]), caps, tile=tile))
        np.testing.assert_array_equal(np.asarray(out), acc)
        # and within float roundoff of the vectorised two-level reference
        np.testing.assert_allclose(np.asarray(out), np.asarray(bisect_block_sums_ref(w, caps, tile=tile)), rtol=1e-6)

    def test_single_tile_bit_exact_vs_ref(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.gamma(0.3, 1.0, 500).astype(np.float32))
        caps = jnp.asarray(np.linspace(0.01, 2.0, 7).astype(np.float32))
        a = bisect_block_sums_ref(w, caps, tile=512)
        b = bisect_block_sums_kernel_call(w, caps, tile=512, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAllocatorDtype:
    """Satellite: float64 weights must solve in float64 (x64 mode), not be
    squeezed through float32 scalar casts or a flat 1e-30 epsilon."""

    @pytest.mark.parametrize("dtype", ["float32", "float64"])
    def test_dtype_preserved_and_accurate(self, dtype):
        rng = np.random.default_rng(11)
        K, k = 5000, 500
        sigma = 0.25 * k / K
        if dtype == "float64":
            with jax.experimental.enable_x64():
                w = jnp.asarray(rng.gamma(0.3, 1.0, K))
                assert w.dtype == jnp.float64
                p, capped = masked_prob_alloc(w, k, sigma)
                assert p.dtype == jnp.float64
                pr, cr = prob_alloc_reference(np.asarray(w), k, sigma)
                np.testing.assert_allclose(np.asarray(p), pr, atol=1e-12)
                assert (np.asarray(capped) == cr).all()
                # the traced-scalar path must not downcast either
                p2, _ = jax.jit(lambda w, kk, s: masked_prob_alloc(w, kk, s))(
                    w, jnp.asarray(float(k)), jnp.asarray(sigma)
                )
                assert p2.dtype == jnp.float64
                np.testing.assert_allclose(np.asarray(p2), pr, atol=1e-12)
        else:
            w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))
            p, _ = masked_prob_alloc(w, k, sigma)
            assert p.dtype == jnp.float32
            pr, _ = prob_alloc_reference(np.asarray(w), k, sigma)
            np.testing.assert_allclose(np.asarray(p), pr, atol=1e-5)

    def test_float64_block_mode(self):
        rng = np.random.default_rng(12)
        with jax.experimental.enable_x64():
            w = jnp.asarray(rng.gamma(0.3, 1.0, 2000))
            p1, _ = masked_prob_alloc(w, 200, 0.01)
            p4, _ = masked_prob_alloc(w, 200, 0.01, block=4)
            assert p4.dtype == jnp.float64
            np.testing.assert_allclose(np.asarray(p4), np.asarray(p1), atol=1e-10)
