"""Scenario subsystem tests: bit-packed replay == dense replay (bit-exact),
stateful scenario models inside the scan == the legacy per-round loop,
structured-trace statistics (diurnal marginals, regional correlation, flash
crowd windows, Markov stationarity), volatility dispatch satellites, and the
registry/harness surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sim import selection_sim, selection_sim_loop
from repro.core.volatility import MarkovVolatility, make_volatility, paper_success_rates
from repro.engine.scan_sim import scan_selection_sim
from repro.kernels.unpack_bits import unpack_bits_kernel_call, unpack_bits_ref
from repro.scenarios import (
    DiurnalVolatility,
    FlashCrowdVolatility,
    RegionalOutageVolatility,
    ReplayVolatility,
    evaluate_cell,
    get_scenario,
    list_scenarios,
    make_scenario,
    pack_trace,
    record_trace,
    run_grid_multi_job,
    unpack_trace,
)
from repro.scenarios.replay import pack_bits_jnp, packed_nbytes, packed_width


def roll(vol, T, seed=0):
    """Sample a volatility model T rounds via a compiled scan -> (T, K)."""

    def step(carry, _):
        key, vs = carry
        key, k2 = jax.random.split(key)
        x, vs = vol.sample(k2, vs)
        return (key, vs), x

    _, xs = jax.lax.scan(step, (jax.random.PRNGKey(seed), vol.init_state()), None, length=T)
    return np.asarray(xs)


class TestPackedTraces:
    @pytest.mark.parametrize("K", [5, 8, 17, 100, 1000])
    def test_pack_unpack_roundtrip(self, K):
        rng = np.random.default_rng(K)
        xs = rng.binomial(1, 0.5, (13, K)).astype(np.float32)
        packed = pack_trace(xs)
        assert packed.shape == (13, packed_width(K)) and packed.dtype == np.uint8
        np.testing.assert_array_equal(unpack_trace(packed, K), xs)

    def test_pack_bits_jnp_matches_numpy(self):
        rng = np.random.default_rng(0)
        xs = rng.binomial(1, 0.3, (7, 61)).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(pack_bits_jnp(jnp.asarray(xs))), pack_trace(xs))

    @pytest.mark.parametrize("K,tile_b", [(7, 1024), (64, 4), (1000, 16), (8192, 1024)])
    def test_unpack_kernel_interpret_matches_ref(self, K, tile_b):
        rng = np.random.default_rng(K)
        packed = jnp.asarray(rng.integers(0, 256, packed_width(K)), jnp.uint8)
        out = unpack_bits_kernel_call(packed, K, tile_b=tile_b, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(unpack_bits_ref(packed, K)))

    def test_packed_nbytes(self):
        assert packed_nbytes(2500, 1_000_000) == 2500 * 125_000  # ~312 MB


class TestPackedReplayThroughScan:
    def test_bit_identical_to_dense_override(self):
        # the tentpole acceptance criterion
        rng = np.random.default_rng(0)
        xs = rng.binomial(1, 0.5, (120, 100)).astype(np.float32)
        packed = pack_trace(xs)
        a = scan_selection_sim("e3cs", K=100, k=20, T=120, frac=0.25, xs_override=xs)
        b = scan_selection_sim("e3cs", K=100, k=20, T=120, frac=0.25, packed_override=packed)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)

    def test_replay_volatility_model_matches_override(self):
        # the (init_state, sample) replay object carries the round index in
        # vol_state and must reproduce the override path bit-for-bit
        rng = np.random.default_rng(1)
        xs = rng.binomial(1, 0.6, (80, 64)).astype(np.float32)
        packed = pack_trace(xs)
        vol = ReplayVolatility(packed=jnp.asarray(packed), K=64)
        a = scan_selection_sim("e3cs", K=64, k=12, T=80, frac=0.5, vol=vol)
        b = scan_selection_sim("e3cs", K=64, k=12, T=80, frac=0.5, rho=np.asarray(vol.rho), xs_override=xs)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])

    def test_lean_outputs_match_full(self):
        # lean mode changes what the scan EMITS, never the state math: counts
        # bit-identical, per-round successes == row-sums of the full outputs
        from repro.configs.base import FLConfig
        from repro.engine.scan_sim import build_scan_runner

        K, k, T = 64, 12, 50
        rho = paper_success_rates(K)
        packed = pack_trace(np.random.default_rng(2).binomial(1, 0.6, (T, K)).astype(np.float32))
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="const", quota_frac=0.5)
        vol = make_volatility("bernoulli", rho)
        key = jax.random.PRNGKey(0)
        xs_in = jnp.asarray(packed)
        run_f, s0f = build_scan_runner(fl, vol, rho, override="packed")
        run_l, s0l = build_scan_runner(fl, vol, rho, override="packed", outputs="lean")
        st_f, masks, xs, ps, _ = run_f(s0f, key, xs_in)
        st_l, succ, _ = run_l(s0l, key, xs_in)
        np.testing.assert_array_equal(np.asarray(st_f.sel_counts), np.asarray(st_l.sel_counts))
        np.testing.assert_array_equal(np.asarray(succ), (np.asarray(masks) * np.asarray(xs)).sum(1))

    def test_run_replay_shares_one_trace_across_selectors(self):
        from repro.scenarios import run_replay

        rows, packed = run_replay(("e3cs", "random"), "paper_iid", K=40, k=8, T=30)
        assert [r["selector"] for r in rows] == ["e3cs", "random"]
        assert packed.shape == (30, 5)

    def test_record_trace_chunked_equals_one_shot(self):
        vol, _ = make_scenario("markov", 40, 60, seed=3)
        np.testing.assert_array_equal(record_trace(vol, 60, seed=7, chunk=16), record_trace(vol, 60, seed=7, chunk=60))

    def test_both_overrides_rejected(self):
        xs = np.zeros((4, 8), np.float32)
        with pytest.raises(ValueError):
            scan_selection_sim("e3cs", K=8, k=2, T=4, xs_override=xs, packed_override=pack_trace(xs))


class TestStatefulVolInScan:
    """Scenario models carried inside the lax.scan match the legacy
    per-round loop bit-for-bit (same PRNG discipline, pytree states)."""

    def _vols(self, K, T):
        rho = jnp.asarray(paper_success_rates(K))
        rng = np.random.default_rng(0)
        return {
            "markov": MarkovVolatility(rho, 0.9),
            "diurnal": DiurnalVolatility(rho=rho, phase=jnp.asarray(rng.random(K, np.float32)), period=16),
            "regional": RegionalOutageVolatility(rho=rho, region=jnp.asarray(np.arange(K) % 4, jnp.int32), n_regions=4),
            "flash_crowd": FlashCrowdVolatility(  # tuple vol_state
                rho=rho, crowd=jnp.asarray((np.arange(K) < K // 2).astype(np.float32)), t_start=10, t_end=40
            ),
        }

    @pytest.mark.parametrize("name", ["markov", "diurnal", "regional", "flash_crowd"])
    def test_scan_matches_loop(self, name):
        K, k, T = 48, 10, 60
        vol = self._vols(K, T)[name]
        a = selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, vol=vol, backend="scan")
        b = selection_sim_loop("e3cs", K=K, k=k, T=T, frac=0.5, vol=vol)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)

    def test_string_markov_equals_object_markov(self):
        rho = jnp.asarray(paper_success_rates(32))
        a = selection_sim("e3cs", K=32, k=8, T=40, volatility="markov", stickiness=0.8, backend="scan")
        b = selection_sim("e3cs", K=32, k=8, T=40, vol=MarkovVolatility(rho, 0.8), backend="scan")
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])


class TestTraceStatistics:
    def test_markov_stationarity_across_stickiness(self):
        # satellite: the invariant MarkovVolatility claims in its docstring —
        # the stationary marginal stays rho for any stickiness
        K, T = 64, 6000
        rho = paper_success_rates(K)
        for s in (0.0, 0.5, 0.9):
            xs = roll(MarkovVolatility(jnp.asarray(rho), s), T, seed=int(s * 10))
            per_class = xs.mean(0).reshape(4, -1).mean(1)
            np.testing.assert_allclose(per_class, [0.1, 0.3, 0.6, 0.9], atol=0.03, err_msg=f"stickiness={s}")

    def test_diurnal_marginal_and_cycle(self):
        K, period = 32, 16
        rho = jnp.full((K,), 0.5)
        phase = jnp.asarray(np.random.default_rng(0).random(K, np.float32))
        vol = DiurnalVolatility(rho=rho, phase=phase, amplitude=0.3, period=period)
        xs = roll(vol, 200 * period)
        # marginal over whole periods ~ rho (no clipping at these rates)
        np.testing.assert_allclose(xs.mean(0), 0.5, atol=0.06)
        # but within a day the rate genuinely swings: peak-vs-trough spread
        by_tod = xs.reshape(-1, period, K).mean(0)  # (period, K) empirical rate
        assert float((by_tod.max(0) - by_tod.min(0)).mean()) > 0.4

    def test_regional_outage_correlation_structure(self):
        K = 16
        vol = RegionalOutageVolatility(
            rho=jnp.full((K,), 0.8),
            region=jnp.asarray(np.arange(K) // 8, jnp.int32),
            n_regions=2,
            p_fail=0.1,
            p_recover=0.3,
            severity=0.9,
        )
        xs = roll(vol, 4000)
        c = np.corrcoef(xs.T)
        within = np.mean([c[i, j] for i in range(8) for j in range(8) if i != j])
        cross = np.mean([c[i, j] for i in range(8) for j in range(8, 16)])
        assert within > 0.2, within  # shared regional factor binds the block
        assert abs(cross) < 0.1, cross  # regions fail independently
        # marginal matches the closed form the rho-hint uses
        np.testing.assert_allclose(xs.mean(), float(vol.marginal_rate().mean()), atol=0.03)

    def test_flash_crowd_window(self):
        K = 60
        crowd = jnp.asarray((np.arange(K) < 30).astype(np.float32))
        vol = FlashCrowdVolatility(
            rho=jnp.full((K,), 0.5), crowd=crowd, t_start=20, t_end=60, churn=0.05, base_avail=0.1, peak=0.95
        )
        xs = roll(vol, 100)
        crowd_rate_pre = xs[:20, :30].mean()
        crowd_rate_early = xs[20:30, :30].mean()
        crowd_rate_post = xs[60:, :30].mean()
        assert crowd_rate_pre < 0.2  # dormant before the event
        assert crowd_rate_early > 0.6  # surge at window start
        assert crowd_rate_post < 0.2  # churned away after
        np.testing.assert_allclose(xs[:, 30:].mean(), 0.5, atol=0.05)  # non-crowd unaffected


class TestVolatilityDispatch:
    def test_deadline_routes_and_matches_across_backends(self):
        # satellite: "deadline" used to silently fall back to Bernoulli
        a = selection_sim("e3cs", K=40, k=8, T=50, volatility="deadline", backend="scan")
        b = selection_sim("e3cs", K=40, k=8, T=50, volatility="deadline", backend="loop")
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        # deadline marginals differ from Bernoulli draws with the same key
        c = selection_sim("e3cs", K=40, k=8, T=50, volatility="bernoulli", backend="scan")
        assert not np.array_equal(a["xs"], c["xs"])

    @pytest.mark.parametrize("backend", ["scan", "loop"])
    def test_unknown_volatility_raises(self, backend):
        with pytest.raises(ValueError, match="unknown volatility"):
            selection_sim("e3cs", K=8, k=2, T=4, volatility="bogus", backend=backend)

    def test_make_volatility_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown volatility"):
            make_volatility("bogus", jnp.ones(4) * 0.5)


class TestPaperSuccessRatesRemainder:
    def test_divisible_unchanged(self):
        out = paper_success_rates(100)
        assert out.shape == (100,)
        np.testing.assert_array_equal(np.unique(out, return_counts=True)[1], [25, 25, 25, 25])

    def test_stable_policy_is_legacy_behaviour(self):
        # satellite: remainder lands in the most stable class (documented skew)
        out = paper_success_rates(10)
        np.testing.assert_array_equal(out, np.float32([0.1, 0.1, 0.3, 0.3, 0.6, 0.6, 0.9, 0.9, 0.9, 0.9]))
        assert out.mean() == pytest.approx(0.56, abs=1e-6)  # optimistic vs ideal 0.475

    def test_spread_policy_bounds_class_imbalance(self):
        out = paper_success_rates(10, remainder="spread")
        _, counts = np.unique(out, return_counts=True)
        np.testing.assert_array_equal(counts, [3, 3, 2, 2])
        assert out.mean() == pytest.approx(0.42, abs=1e-6)  # pessimistic, not optimistic
        assert abs(out.mean() - 0.475) < abs(paper_success_rates(10).mean() - 0.475) + 0.03

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="remainder"):
            paper_success_rates(10, remainder="bogus")


class TestRegistryAndHarness:
    def test_all_scenarios_run_through_scan(self):
        for name in list_scenarios():
            vol, rho = make_scenario(name, 32, 30, seed=0)
            assert np.asarray(rho).shape == (32,)
            assert np.all((np.asarray(rho) >= 0) & (np.asarray(rho) <= 1))
            out = scan_selection_sim("e3cs", K=32, k=8, T=30, frac=0.5, vol=vol, rho=rho)
            np.testing.assert_array_equal(out["masks"].sum(1), np.full(30, 8.0))

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("bogus")

    def test_evaluate_cell_metrics(self):
        row = evaluate_cell("random", "paper_iid", K=40, k=8, T=50)
        assert row["cep"] > 0
        assert 0.0 < row["eff_participation"] <= 1.0
        assert 0.0 < row["jain"] <= 1.0
        assert 0.0 < row["entropy"] <= 1.0

    def test_select_serve_scenario_feedback(self):
        from repro.launch.select_serve import run_service

        report = run_service(J=2, K_max=64, rounds=5, seed=0, scenario="diurnal")
        assert report["scenario"] == "diurnal"
        assert report["ticks"] == 10

    def test_multi_job_grid_learns_per_scenario(self):
        rows = run_grid_multi_job(["paper_iid", "markov_sticky"], K=40, k=8, T=120, seed=0)
        assert len(rows) == 2
        for r in rows:
            assert r["cep"] > 0.3 * 120 * 8  # well above the 0.45-ish floor times slack
            assert 0.0 < r["jain"] <= 1.0


class TestLagTraces:
    """2-bit packed completion-lag traces: 4 clients/byte, codes {0,1,2,dead}
    (satellite of the K-sharding PR; ROADMAP "packed lag traces" follow-on)."""

    def test_pack_roundtrip_np_and_jnp(self):
        from repro.core.volatility import DEAD_LAG
        from repro.scenarios import lag_packed_width, pack_lags, unpack_lags
        from repro.scenarios.replay import pack_lags_jnp

        rng = np.random.default_rng(0)
        lags = rng.choice([0, 1, 2, DEAD_LAG], size=(9, 101)).astype(np.int32)
        packed = pack_lags(lags)
        assert packed.shape == (9, lag_packed_width(101)) and packed.dtype == np.uint8
        assert np.array_equal(unpack_lags(packed, 101), lags)
        assert np.array_equal(np.asarray(pack_lags_jnp(jnp.asarray(lags))), packed)

    def test_out_of_range_lag_rejected(self):
        from repro.scenarios import pack_lags

        with pytest.raises(ValueError, match="2-bit"):
            pack_lags(np.asarray([[0, 3, 1, 2]], np.int32))

    def test_unpack_crumbs_kernel_matches_ref(self):
        from repro.kernels.unpack_bits import unpack_crumbs_kernel_call, unpack_crumbs_ref

        rng = np.random.default_rng(1)
        for K in (4, 37, 4096):
            packed = jnp.asarray(rng.integers(0, 256, (K + 3) // 4, dtype=np.uint8))
            a = unpack_crumbs_ref(packed, K)
            b = unpack_crumbs_kernel_call(packed, K, tile_b=16, interpret=True)
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_recorded_lag_replay_bit_identical_to_dense(self):
        # a frozen async scenario replays through the scan exactly like a
        # dense lag trace would — same masks, lags and staleness-aware CEP
        from repro.core.volatility import BernoulliVolatility, CompletionLag
        from repro.engine.scan_sim import async_selection_sim
        from repro.scenarios import ReplayLag, record_lag_trace, unpack_lags

        K, T = 64, 50
        base = BernoulliVolatility(jnp.asarray(paper_success_rates(K)))
        lm = CompletionLag(base, p_late=0.6, lag_decay=0.5, max_lag=2)
        trace = record_lag_trace(lm, T, seed=3, chunk=16)
        assert trace.shape == (T, (K + 3) // 4)
        replay = ReplayLag(packed=jnp.asarray(trace), K=K)

        class DenseLagReplay:
            def __init__(self, lags):
                self.lags = jnp.asarray(lags)

            def init_state(self):
                return jnp.zeros((), jnp.int32)

            def sample(self, rng, state):
                return jax.lax.dynamic_index_in_dim(self.lags, state, keepdims=False), state + 1

        dense = DenseLagReplay(unpack_lags(trace, K))
        rho = np.asarray(replay.rho)
        a = async_selection_sim("e3cs", K=K, k=8, T=T, staleness=2, lag_model=replay, rho=rho)
        b = async_selection_sim("e3cs", K=K, k=8, T=T, staleness=2, lag_model=dense, rho=rho)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["lags"], b["lags"])
        assert a["cep"] == b["cep"]
        # the trace really contains late completions, not just binary bits
        assert ((unpack_lags(trace, K) > 0).sum()) > 0

    def test_record_rejects_wide_lag_models(self):
        from repro.core.volatility import BernoulliVolatility, CompletionLag
        from repro.scenarios import record_lag_trace

        base = BernoulliVolatility(jnp.asarray(paper_success_rates(16)))
        with pytest.raises(ValueError, match="max_lag"):
            record_lag_trace(CompletionLag(base, max_lag=4), 4)


class TestDiskTraces:
    """mmap-backed packed traces: disk-bounded replay horizons (satellite;
    ROADMAP "trace IO" follow-on)."""

    def test_save_load_roundtrip_is_memmap(self, tmp_path):
        vol = make_volatility("bernoulli", jnp.asarray(paper_success_rates(96)))
        packed = record_trace(vol, 30, seed=1, chunk=16)
        from repro.scenarios import load_packed_trace, save_packed_trace

        path = save_packed_trace(str(tmp_path / "trace"), packed, 96, kind="bits")
        arr, meta = load_packed_trace(path)
        assert isinstance(arr, np.memmap)
        assert meta == {"kind": "bits", "K": 96, "T": 30, "clients_per_byte": 8}
        assert np.array_equal(np.asarray(arr), packed)

    def test_lag_kind_roundtrip(self, tmp_path):
        from repro.core.volatility import DEAD_LAG
        from repro.scenarios import load_packed_trace, pack_lags, save_packed_trace, unpack_lags

        lags = np.random.default_rng(0).choice([0, 1, 2, DEAD_LAG], size=(12, 50)).astype(np.int32)
        path = save_packed_trace(str(tmp_path / "lags"), pack_lags(lags), 50, kind="lags")
        arr, meta = load_packed_trace(path)
        assert meta["clients_per_byte"] == 4
        assert np.array_equal(unpack_lags(np.asarray(arr), 50), lags)

    def test_shape_validation(self, tmp_path):
        from repro.scenarios import save_packed_trace

        with pytest.raises(ValueError, match="must be"):
            save_packed_trace(str(tmp_path / "bad"), np.zeros((5, 3), np.uint8), 96, kind="bits")

    def test_streamed_replay_bit_identical_to_in_memory(self, tmp_path):
        # chunked memmap feed (incl. a ragged tail chunk) == one-shot packed
        # replay: same counts, same per-round successes, same quota schedule
        from repro.scenarios import replay_packed_stream, save_packed_trace

        K, T = 96, 70
        vol = make_volatility("bernoulli", jnp.asarray(paper_success_rates(K)))
        packed = record_trace(vol, T, seed=1, chunk=32)
        path = save_packed_trace(str(tmp_path / "tr"), packed, K, kind="bits")
        stream = replay_packed_stream("e3cs", path, k=12, chunk=16, frac=0.5)
        assert "rho" not in stream  # marginal pass skipped: only fedcs consumes it
        mem = scan_selection_sim("e3cs", K=K, k=12, T=T, frac=0.5, packed_override=packed, seed=0)
        assert np.array_equal(stream["counts"], mem["counts"])
        np.testing.assert_allclose(stream["successes"], (mem["masks"] * mem["xs"]).sum(1), atol=0)
        np.testing.assert_allclose(stream["sigmas"], mem["sigmas"], atol=0)

    def test_truncated_horizon_rho_stays_a_probability(self, tmp_path):
        # regression: the streamed marginal must not read rows past T — with
        # T < trace length the old slice summed the whole trace but divided
        # by T, pushing rho past 1
        from repro.scenarios import replay_packed_stream, save_packed_trace

        K = 64
        vol = make_volatility("bernoulli", jnp.asarray(paper_success_rates(K)))
        packed = record_trace(vol, 2000, seed=2, chunk=128)
        path = save_packed_trace(str(tmp_path / "tr"), packed, K, kind="bits")
        out = replay_packed_stream("fedcs", path, k=8, T=1500, chunk=512)
        assert out["rho"].max() <= 1.0
        true_marginal = unpack_trace(packed[:1500], K).mean(0)
        np.testing.assert_allclose(out["rho"], true_marginal, atol=1e-6)
        assert out["successes"].shape == (1500,)
