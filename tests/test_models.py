"""Per-architecture smoke tests (reduced same-family variants) + model math.

Every assigned architecture: instantiate the smoke variant, run one forward
and one train step on CPU, assert output shapes and finiteness; run the
serving path (prefill + decode) where the family has one, and check
prefill->decode consistency against the full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_variant
from repro.models import build_model
from repro.models.transformer import vlm_positions
from repro.optim import sgd

B, S = 2, 32


def _batch(cfg, with_labels=True):
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tok}
    if with_labels:
        batch["labels"] = tok
    if cfg.family == "vlm":
        P = cfg.n_patches
        batch["tokens"] = batch["tokens"][:, : S - P]
        if with_labels:
            batch["labels"] = batch["labels"][:, : S - P]
        batch["patch_embeds"] = jnp.asarray(rng.normal(size=(B, P, cfg.d_patch)), jnp.float32)
        batch["positions"] = vlm_positions(cfg, B, S)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.normal(size=(B, cfg.enc_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch
    # one SGD step reduces nothing catastrophic and keeps finiteness
    opt = sgd(1e-2, 0.9)
    (l0, _), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    new_params, _ = opt.update(params, grads, opt.init(params), 0)
    l1, _ = model.loss(new_params, batch)
    assert jnp.isfinite(l1)
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads))


DECODABLE = [a for a in ASSIGNED if a != "whisper-base"] + ["whisper-base"]


@pytest.mark.parametrize("arch", DECODABLE)
def test_smoke_prefill_decode_consistency(arch):
    cfg = smoke_variant(get_config(arch))
    if cfg.family == "moe":
        # dropless capacity so decode (tiny N) routes identically to prefill
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, with_labels=False)
    logits_p, caches = jax.jit(model.prefill)(params, batch)
    assert jnp.isfinite(logits_p).all()
    tok = jnp.argmax(logits_p[:, -1:], -1).astype(jnp.int32)
    logits_d, caches = jax.jit(model.decode)(params, tok, caches)
    assert logits_d.shape == (B, 1, cfg.vocab)
    assert jnp.isfinite(logits_d).all()
    # consistency: decoding token t+1 must match the full forward's logits
    if cfg.family in ("dense", "ssm", "hybrid", "moe"):
        full_tokens = jnp.concatenate([batch["tokens"], tok], axis=1)
        logits_full = model.forward(params, {"tokens": full_tokens})
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, -1]), atol=2e-3, rtol=2e-3
        )


def test_sliding_window_decode_matches_windowed_forward():
    cfg = dataclasses.replace(smoke_variant(get_config("gemma-2b")), sliding_window=16)
    model = build_model(cfg, window=16)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, with_labels=False)
    logits_p, caches = model.prefill(params, batch)
    tok = jnp.argmax(logits_p[:, -1:], -1).astype(jnp.int32)
    logits_d, _ = model.decode(params, tok, caches)
    full_tokens = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_full = build_model(cfg, window=16).forward(params, {"tokens": full_tokens})
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]), np.asarray(logits_full[:, -1]), atol=2e-3, rtol=2e-3)


def test_mla_absorbed_equals_naive():
    from repro.models import mla as mla_mod
    from repro.models.layers import ParamBuilder

    cfg = smoke_variant(get_config("deepseek-v3-671b"))
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    mla_mod.mla_init(pb, cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 24, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24)[None], (2, 24))
    y1, _ = mla_mod.mla_apply(pb.params, x, dataclasses.replace(cfg, mla_absorb=False), pos)
    y2, _ = mla_mod.mla_apply(pb.params, x, dataclasses.replace(cfg, mla_absorb=True), pos)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_mla_decode_latent_cache_consistency():
    # dropless MoE capacity so routing is identical between prefill and decode
    cfg = dataclasses.replace(smoke_variant(get_config("deepseek-v3-671b")), capacity_factor=64.0)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, with_labels=False)
    logits_p, caches = model.prefill(params, batch)
    tok = jnp.argmax(logits_p[:, -1:], -1).astype(jnp.int32)
    logits_d, _ = model.decode(params, tok, caches)
    full = model.forward(params, {"tokens": jnp.concatenate([batch["tokens"], tok], 1)})
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]), np.asarray(full[:, -1]), atol=3e-3, rtol=3e-3)


def test_moe_scatter_equals_einsum_and_dropless_at_high_capacity():
    from repro.models import moe as moe_mod
    from repro.models.layers import ParamBuilder

    cfg = dataclasses.replace(smoke_variant(get_config("qwen3-moe-30b-a3b")), capacity_factor=8.0)
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    moe_mod.moe_init(pb, cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1, a1 = moe_mod.moe_apply_einsum(pb.params, x, cfg)
    y2, a2 = moe_mod.moe_apply_scatter(pb.params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_ssd_chunk_invariance():
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    b, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    args = (
        jnp.asarray(rng.normal(size=(b, S, H, P)), jnp.float32),
        jnp.asarray(rng.uniform(0.01, 0.3, (b, S, H)), jnp.float32),
        jnp.asarray(-rng.uniform(0.5, 1, (H,)), jnp.float32),
        jnp.asarray(rng.normal(size=(b, S, G, N)), jnp.float32),
        jnp.asarray(rng.normal(size=(b, S, G, N)), jnp.float32),
    )
    y16 = ssd_chunked(*args, 16)
    y64 = ssd_chunked(*args, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=1e-4, rtol=1e-4)


def test_chunked_attention_matches_einsum():
    from repro.models.attention import _causal_mask, _chunked_sdpa, _sdpa

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    o1 = _chunked_sdpa(q, k, v, True, 0, None, chunk_q=16, chunk_k=16)
    o2 = _sdpa(q, k, v, _causal_mask(64, 64, 0, 0)[None, None], None)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)


def test_param_counts_match_nominal_sizes():
    expected = {
        "llama3-405b": 405e9,
        "deepseek-v3-671b": 671e9,
        "qwen2-vl-72b": 72e9,
        "qwen3-moe-30b-a3b": 30e9,
        "gemma-2b": 2.5e9,
        "stablelm-1.6b": 1.6e9,
        "mamba2-130m": 0.13e9,
    }
    for arch, n in expected.items():
        got = get_config(arch).n_params()
        assert 0.85 * n <= got <= 1.15 * n, (arch, got, n)
    # MoE active params: DeepSeek-V3 ~37B, Qwen3-30B-A3B ~3.3B
    assert 0.9 * 37e9 <= get_config("deepseek-v3-671b").n_active_params() <= 1.1 * 37e9
    assert 0.8 * 3.3e9 <= get_config("qwen3-moe-30b-a3b").n_active_params() <= 1.2 * 3.3e9
