"""Metrics-spine tests: in-scan taps bit-identity against the committed
goldens, windowed aggregates hand-checked, JSONL run-log round-trip, the
latency histogram, the results layout, and the check_bench gate edges.

The taps contract under test: ``taps=True`` adds one trailing
``{"series", "counters"}`` payload to every runner's outputs and changes
NOTHING else — the masks/lags/state streams must still equal
``tests/golden/round_program_goldens.npz`` bit-for-bit, in every placement.
"""
import importlib.util
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.volatility import CompletionLag, make_volatility, paper_success_rates
from repro.engine.round_program import RoundProgram
from repro.engine.scan_sim import async_selection_sim, scan_selection_sim
from repro.engine.sharded import sharded_selection_sim
from repro.obs import (
    ROUND_TAPS,
    LatencyHistogram,
    Reporter,
    RunLog,
    SpanTimer,
    TapRegistry,
    TapSpec,
    read_runlog,
    stage,
    validate_records,
    window_reduce,
)
from repro.obs import paths as obs_paths
from repro.obs.runlog import SCHEMA_VERSION, iter_metrics
from repro.scenarios.replay import pack_trace

K, k, T, SEED, FRAC = 128, 16, 50, 3, 0.5
GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden", "round_program_goldens.npz"))
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_module(relpath, name):
    spec = importlib.util.spec_from_file_location(name, os.path.join(REPO, relpath))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def check_bench():
    return _load_module("scripts/check_bench.py", "check_bench")


@pytest.fixture(scope="module")
def mesh8():
    from repro.launch.mesh import make_host_mesh

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8 (set in conftest)")
    return make_host_mesh(8)


def _rho():
    return paper_success_rates(K)


def _lag_model():
    return CompletionLag(make_volatility("bernoulli", _rho()), p_late=0.7, lag_decay=0.5, max_lag=2)


class TestTapsBitIdentity:
    """taps=True reproduces the pre-taps goldens bit-for-bit — the telemetry
    stage must not touch the PRNG stream or the round math."""

    def test_sync_d1_golden(self):
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, taps=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d1_e3cs_counts"])
        taps = out["taps"]
        assert set(taps["series"]) == set(ROUND_TAPS.gauge_names())
        assert all(v.shape == (T,) for v in taps["series"].values())
        np.testing.assert_array_equal(taps["series"]["selected"], out["masks"].sum(1))
        assert taps["counters"]["rounds"] == float(T)
        assert taps["counters"]["cum_selected"] == float(out["masks"].sum())
        # sync rounds have no staleness buffer: the stale gauge is flat zero
        np.testing.assert_array_equal(taps["series"]["stale"], np.zeros(T))

    def test_sync_d8_golden(self, mesh8):
        out = sharded_selection_sim("e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, taps=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d8_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d8_e3cs_counts"])
        np.testing.assert_array_equal(out["taps"]["series"]["selected"], np.full(T, float(k)))

    def test_async_d1_golden(self):
        out = async_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
            lag_model=_lag_model(), rho=_rho(), taps=True,
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_e3cs_masks"])
        assert np.array_equal(out["lags"].astype(np.int8), GOLD["async_d1_e3cs_lags"])
        assert np.float32(out["cep"]) == GOLD["async_d1_e3cs_cep"]
        taps = out["taps"]
        np.testing.assert_allclose(taps["series"]["on_time"], out["on_time"], atol=1e-4)
        np.testing.assert_allclose(taps["series"]["stale"], out["stale"], atol=1e-4)
        assert taps["counters"]["cum_credit"] == pytest.approx(float(out["cep"]), rel=1e-5)

    def test_async_same_stream_every_placement(self, mesh8):
        """The schema contract: the D=8 sharded-async tap stream equals the
        D=1 stream (psum-reduced gauges are placement-invariant).  Uses the
        packed-lag replay + `random` selector composition, where D=8 is
        bit-identical to D=1 (generated e3cs runs draw shard-local
        randomness, so only mesh=1 matches those — covered below)."""
        lp = GOLD["lag_trace_packed"]
        fl = FLConfig(K=K, k=k, rounds=T, scheme="random", quota_frac=FRAC)

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
                              staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True)
            st, on_time, stale, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lp))
            return st, np.asarray(on_time), np.asarray(stale), payload

        st1, on1, stale1, tap1 = go(None)
        st8, on8, stale8, tap8 = go(mesh8)
        np.testing.assert_array_equal(on1, on8)
        np.testing.assert_array_equal(stale1, stale8)
        assert float(st1.cep) == float(st8.cep)
        for name in ROUND_TAPS.gauge_names():
            np.testing.assert_allclose(
                np.asarray(tap1["series"][name]), np.asarray(tap8["series"][name]), atol=1e-4, err_msg=name
            )
        for name, v in tap1["counters"].items():
            assert float(v) == pytest.approx(float(tap8["counters"][name]), rel=1e-5), name

    def test_async_mesh1_stream_matches_dense_e3cs(self):
        """Generated e3cs async: a 1-device mesh is bit-identical to the
        dense engine — taps included."""
        from repro.launch.mesh import make_host_mesh

        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True)
            st, on_time, stale, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
            return st, np.asarray(on_time), np.asarray(stale), payload

        st1, on1, stale1, tap1 = go(None)
        stm, onm, stalem, tapm = go(make_host_mesh(1))
        np.testing.assert_array_equal(on1, onm)
        np.testing.assert_array_equal(stale1, stalem)
        for name in ROUND_TAPS.gauge_names():
            np.testing.assert_array_equal(
                np.asarray(tap1["series"][name]), np.asarray(tapm["series"][name]), err_msg=name
            )

    def test_async_d8_taps_off_unchanged(self, mesh8):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(taps):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh8)
            run, s0 = pm.build_runner(outputs="lean", taps=taps)
            return run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))

        st_off, on_off, stale_off, _ = go(False)
        st_on, on_on, stale_on, _, _ = go(True)
        np.testing.assert_array_equal(np.asarray(on_off), np.asarray(on_on))
        np.testing.assert_array_equal(np.asarray(stale_off), np.asarray(stale_on))
        np.testing.assert_array_equal(np.asarray(st_off.sel_counts), np.asarray(st_on.sel_counts))

    def test_taps_with_carry_key_raises(self):
        fl = FLConfig(K=32, k=4, rounds=8, scheme="e3cs", quota_frac=FRAC)
        pm = RoundProgram(fl=fl, vol=make_volatility("bernoulli", paper_success_rates(32)),
                          rho=paper_success_rates(32))
        with pytest.raises(ValueError, match="carry_key"):
            pm.build_runner(taps=True, carry_key=True)


class TestTapRegistry:
    def test_round_taps_schema(self):
        assert set(ROUND_TAPS.gauge_names()) == {"selected", "on_time", "stale", "sigma", "capped_frac"}
        assert ROUND_TAPS.directions()["selected"] == "equal"
        assert ROUND_TAPS.directions()["on_time"] == "higher"

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            TapSpec("x", "nope")
        with pytest.raises(ValueError):
            TapSpec("x", "gauge", better="sideways")

    def test_accumulate_sources(self):
        reg = TapRegistry(
            TapSpec("a", "gauge"),
            TapSpec("b", "gauge"),
            TapSpec("ticks", "counter"),
            TapSpec("total", "counter", source=("a", "b")),
        )
        c = reg.init_counters()
        row = {"a": jnp.float32(2.0), "b": jnp.float32(3.0)}
        c = reg.accumulate(c, row)
        c = reg.accumulate(c, row)
        assert float(c["ticks"]) == 2.0
        assert float(c["total"]) == 10.0


class TestWindowReduce:
    def test_hand_checked(self):
        # [1..7] window 3: two full windows, one element dropped;
        # p99 interpolates linearly inside each 3-sample window
        out = window_reduce({"v": np.arange(1.0, 8.0)}, window=3)
        assert out["n_windows"] == 2 and out["dropped"] == 1
        aggs = out["aggs"]["v"]
        np.testing.assert_allclose(aggs["sum"], [6.0, 15.0])
        np.testing.assert_allclose(aggs["mean"], [2.0, 5.0])
        np.testing.assert_allclose(aggs["p50"], [2.0, 5.0])
        np.testing.assert_allclose(aggs["p99"], [2.98, 5.98])

    def test_tiny_three_client_horizon(self):
        # a K=3, k=1 horizon: the selected gauge is exactly 1 every round,
        # so every windowed aggregate of it is hand-computable
        out = scan_selection_sim("random", K=3, k=1, T=8, frac=0.0, seed=0, taps=True)
        red = window_reduce(out["taps"]["series"], window=4)
        assert red["n_windows"] == 2 and red["dropped"] == 0
        np.testing.assert_allclose(red["aggs"]["selected"]["sum"], [4.0, 4.0])
        np.testing.assert_allclose(red["aggs"]["selected"]["p50"], [1.0, 1.0])
        np.testing.assert_allclose(red["aggs"]["selected"]["mean"], [1.0, 1.0])
        assert out["taps"]["counters"]["cum_selected"] == 8.0

    def test_mismatched_lengths_raise(self):
        with pytest.raises(ValueError):
            window_reduce({"a": np.arange(6.0), "b": np.arange(5.0)}, window=3)


class TestRunLogRoundTrip:
    def test_full_round_trip(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        hist = LatencyHistogram()
        hist.observe(0.002)
        with RunLog("unit", config={"K": 4}, path=path) as log:
            log.metrics("s1", window_reduce({"v": np.arange(8.0)}, window=4), better={"v": "higher"})
            log.grid_row({"selector": "e3cs", "cep": 1.0})
            log.histogram("lat", hist.to_record())
            log.summary(done=True)
        records = read_runlog(path)
        validate_records(records)
        assert records[0]["event"] == "header"
        assert records[0]["schema"] == SCHEMA_VERSION
        assert records[0]["config"] == {"K": 4}
        events = [r["event"] for r in records]
        assert events == ["header", "metrics", "grid_row", "histogram", "summary"]
        streams = {r["stream"]: r for r in iter_metrics(records)}
        assert "s1" in streams and streams["s1"]["windows"]["n_windows"] == 2
        assert streams["s1"]["better"] == {"v": "higher"}

    def test_jsonable_coercion(self, tmp_path):
        path = str(tmp_path / "np.jsonl")
        with RunLog("unit", path=path) as log:
            log.summary(a=np.float32(1.5), b=jnp.int32(2), c=float("nan"), d=np.arange(3))
        rec = read_runlog(path)[-1]["data"]
        assert rec["a"] == 1.5 and rec["b"] == 2 and rec["c"] is None and rec["d"] == [0, 1, 2]

    def test_validate_rejects_bad(self):
        with pytest.raises(ValueError):
            validate_records([])
        with pytest.raises(ValueError):  # missing required payload key
            validate_records([{"schema": SCHEMA_VERSION, "event": "metrics", "run": "x"}])
        with pytest.raises(ValueError):  # wrong schema version
            validate_records([{"schema": 99, "event": "header", "run": "x", "name": "x", "config": {}}])
        with pytest.raises(ValueError):  # first record must be the header
            validate_records([
                {"schema": SCHEMA_VERSION, "event": "summary", "run": "x", "data": {}},
            ])


class TestReporter:
    def test_bench_json_with_metrics_block(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_RESULTS", str(tmp_path))
        rep = Reporter("unit", config={"smoke": True})
        rep.metrics_stream("s", {"v": np.arange(10.0)}, window=5, better={"v": "higher"})
        path = rep.save({"rounds_per_s": 42.0})
        assert path == str(tmp_path / "bench" / "BENCH_unit.json")
        blob = json.load(open(path))
        assert blob["rounds_per_s"] == 42.0
        assert blob["metrics"]["s"]["n_windows"] == 2
        assert blob["metrics"]["s"]["better"] == {"v": "higher"}
        records = read_runlog(str(tmp_path / "runlogs" / "unit.jsonl"))
        validate_records(records)
        assert records[-1]["event"] == "summary"


class TestPaths:
    def test_env_layout(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_RESULTS", raising=False)
        monkeypatch.delenv("REPRO_BENCH_OUT", raising=False)
        assert obs_paths.results_root() == "results"
        monkeypatch.setenv("REPRO_BENCH_OUT", str(tmp_path / "r" / "bench"))
        assert obs_paths.results_root() == str(tmp_path / "r")
        assert obs_paths.bench_dir() == str(tmp_path / "r" / "bench")
        monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "override"))
        assert obs_paths.results_root() == str(tmp_path / "override")
        assert obs_paths.artifact_path("x.json") == str(tmp_path / "override" / "x.json")
        assert obs_paths.bench_path("n").endswith(os.path.join("bench", "BENCH_n.json"))
        assert obs_paths.runlog_path("n").endswith(os.path.join("runlogs", "n.jsonl"))


class TestLatencyHistogram:
    def test_quantiles_bracket_samples(self):
        h = LatencyHistogram(lo=1e-4, hi=1.0, n_buckets=32)
        samples = [0.001, 0.002, 0.004, 0.008, 0.016]
        for s in samples:
            h.observe(s)
        s = h.summary()
        assert s["count"] == 5
        assert s["min_s"] == 0.001 and s["max_s"] == 0.016
        assert s["min_s"] <= s["p50_s"] <= s["max_s"]
        assert s["p50_s"] <= s["p99_s"] <= s["max_s"]
        assert s["mean_s"] == pytest.approx(np.mean(samples), rel=1e-6)
        rec = h.to_record()
        assert len(rec["counts"]) == 32 and sum(rec["counts"]) == 5

    def test_out_of_range_clamped(self):
        h = LatencyHistogram(lo=1e-3, hi=1e-2, n_buckets=8)
        h.observe(1e-6)
        h.observe(5.0)
        assert h.quantile(0.0) >= 1e-6
        assert math.isfinite(h.quantile(0.99))

    def test_span_timer(self):
        t = SpanTimer()
        with t.span("work"):
            pass
        with t.span("work", annotate=True):
            pass
        assert t.get("work").summary()["count"] == 2
        assert "work" in t.summary()


class TestStage:
    def test_host_and_traced(self):
        with stage("unit.host"):
            x = jnp.ones(4)

        @jax.jit
        def f(v):
            with stage("unit.traced"):
                return v * 2

        np.testing.assert_array_equal(np.asarray(f(x)), np.full(4, 2.0))


class TestCheckBench:
    def _compare(self, cb, new, base, tol=0.3, metrics_only=False):
        if metrics_only:
            checked_m, regs_m, notes_m = cb.compare_metrics(new, base, tol)
            return checked_m, regs_m, [], notes_m
        cs, rs, imps, ns = cb.compare_scalars(new, base, tol)
        cm, rm, nm = cb.compare_metrics(new, base, tol)
        return cs + cm, rs + rm, imps, ns + nm

    def test_scalar_regression_and_improvement(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"a": {"rounds_per_s": 5.0}, "b": {"ticks_per_s": 20.0}},
            {"a": {"rounds_per_s": 10.0}, "b": {"ticks_per_s": 10.0}},
        )
        assert checked == 2
        assert [r[0] for r in regs] == ["a.rounds_per_s"]
        assert [i[0] for i in imps] == ["b.ticks_per_s"]

    def test_zero_and_nonfinite_baselines_noted(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"a": {"rounds_per_s": 5.0}, "b": {"rounds_per_s": 5.0}},
            {"a": {"rounds_per_s": 0.0}, "b": {"rounds_per_s": float("nan")}},
        )
        assert checked == 0 and not regs
        assert any("<= 0" in n for n in notes)
        assert any("non-finite" in n for n in notes)

    def test_one_sided_keys_noted_not_failed(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"new_only": {"rounds_per_s": 5.0}},
            {"old_only": {"rounds_per_s": 5.0}},
        )
        assert checked == 0 and not regs
        assert any("no baseline" in n for n in notes)
        assert any("baseline only" in n for n in notes)

    def _metrics_doc(self, p50, window=5, direction="higher"):
        return {"metrics": {"s": {
            "window": window, "n_windows": len(p50), "dropped": 0,
            "better": {"v": direction},
            "aggs": {"v": {"p50": list(p50), "p99": list(p50), "mean": list(p50), "sum": list(p50)}},
        }}}

    def test_metrics_direction_gates(self, check_bench):
        base = self._metrics_doc([10.0, 10.0])
        ok = self._metrics_doc([9.0, 11.0])
        bad = self._metrics_doc([10.0, 6.0])
        assert not self._compare(check_bench, ok, base, metrics_only=True)[1]
        regs = self._compare(check_bench, bad, base, metrics_only=True)[1]
        assert [r[0] for r in regs] == ["metrics.s.v.p50[1]"]
        # "lower" flips the inequality
        base_l = self._metrics_doc([10.0], direction="lower")
        assert not self._compare(check_bench, self._metrics_doc([12.0], direction="lower"),
                                 base_l, metrics_only=True)[1]
        assert self._compare(check_bench, self._metrics_doc([14.0], direction="lower"),
                             base_l, metrics_only=True)[1]
        # "equal" gates any drift; "none" never gates
        base_e = self._metrics_doc([10.0], direction="equal")
        assert self._compare(check_bench, self._metrics_doc([10.0001], direction="equal"),
                             base_e, metrics_only=True)[1]
        base_n = self._metrics_doc([10.0], direction="none")
        assert not self._compare(check_bench, self._metrics_doc([0.0], direction="none"),
                                 base_n, metrics_only=True)[1]

    def test_window_mismatch_skipped(self, check_bench):
        base = self._metrics_doc([10.0, 10.0])
        new = self._metrics_doc([10.0, 10.0, 10.0])
        checked, regs, _, notes = self._compare(check_bench, new, base, metrics_only=True)
        assert checked == 0 and not regs
        assert any("windows" in n for n in notes)
        new_w = self._metrics_doc([10.0, 10.0], window=7)
        _, regs, _, notes = self._compare(check_bench, new_w, base, metrics_only=True)
        assert not regs and any("window" in n for n in notes)

    def test_metrics_block_not_gated_as_leaves(self, check_bench):
        doc = self._metrics_doc([10.0])
        assert dict(check_bench.numeric_leaves(doc)) == {}


class TestTimeFn:
    def test_both_modes(self):
        common = _load_module("benchmarks/common.py", "bench_common")
        us_block = common.time_fn(lambda: jnp.ones(8) * 2, iters=2, warmup=1)
        us_pipe = common.time_fn(lambda: jnp.ones(8) * 2, iters=2, warmup=1, blocking=False)
        assert us_block > 0 and us_pipe > 0
