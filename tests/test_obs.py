"""Metrics-spine tests: in-scan taps bit-identity against the committed
goldens, the client-axis sketch layer (dense-recompute oracle, psum-merge
property, placement invariance, fairness series), chunked carry_key+taps
streams, windowed aggregates hand-checked, JSONL run-log round-trip (schema
v2: timestamps, alerts, NaN sanitation, overwrite protection), the alert
detector, the run-log explorer CLI, the latency histogram, the results
layout, and the check_bench gate edges.

The taps contract under test: ``taps=True`` adds one trailing
``{"series", "counters"}`` payload to every runner's outputs and changes
NOTHING else — the masks/lags/state streams must still equal
``tests/golden/round_program_goldens.npz`` bit-for-bit, in every placement.
``sketch=<SketchSpec>`` extends that contract: the payload gains a
``"sketches"`` stream and every other output still matches the goldens.
"""
import importlib.util
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.fairness import gini as gini_exact
from repro.core.fairness import jain_index
from repro.core.fairness import top_share as top_share_exact
from repro.core.volatility import CompletionLag, make_volatility, paper_success_rates
from repro.engine.round_program import RoundProgram
from repro.engine.scan_sim import async_selection_sim, scan_selection_sim
from repro.engine.sharded import sharded_selection_sim
from repro.obs import (
    ROUND_TAPS,
    SKETCH_FIELDS,
    AlertRules,
    LatencyHistogram,
    Reporter,
    RunLog,
    SketchSpec,
    SpanTimer,
    TapRegistry,
    TapSpec,
    detect_alerts,
    fairness_series,
    iter_alerts,
    merge_sketches,
    read_runlog,
    sketch_from_dense,
    stage,
    validate_records,
    window_reduce,
)
from repro.obs import paths as obs_paths
from repro.obs.alerts import Alert
from repro.obs.runlog import SCHEMA_VERSION, iter_metrics
from repro.obs.sketches import FAIRNESS_SERIES, lag_bins, region_ids
from repro.scenarios.replay import pack_trace

K, k, T, SEED, FRAC = 128, 16, 50, 3, 0.5
GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden", "round_program_goldens.npz"))
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_module(relpath, name):
    spec = importlib.util.spec_from_file_location(name, os.path.join(REPO, relpath))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def check_bench():
    return _load_module("scripts/check_bench.py", "check_bench")


@pytest.fixture(scope="module")
def mesh8():
    from repro.launch.mesh import make_host_mesh

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8 (set in conftest)")
    return make_host_mesh(8)


def _rho():
    return paper_success_rates(K)


def _lag_model():
    return CompletionLag(make_volatility("bernoulli", _rho()), p_late=0.7, lag_decay=0.5, max_lag=2)


class TestTapsBitIdentity:
    """taps=True reproduces the pre-taps goldens bit-for-bit — the telemetry
    stage must not touch the PRNG stream or the round math."""

    def test_sync_d1_golden(self):
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, taps=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d1_e3cs_counts"])
        taps = out["taps"]
        assert set(taps["series"]) == set(ROUND_TAPS.gauge_names())
        assert all(v.shape == (T,) for v in taps["series"].values())
        np.testing.assert_array_equal(taps["series"]["selected"], out["masks"].sum(1))
        assert taps["counters"]["rounds"] == float(T)
        assert taps["counters"]["cum_selected"] == float(out["masks"].sum())
        # sync rounds have no staleness buffer: the stale gauge is flat zero
        np.testing.assert_array_equal(taps["series"]["stale"], np.zeros(T))

    def test_sync_d8_golden(self, mesh8):
        out = sharded_selection_sim("e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, taps=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d8_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d8_e3cs_counts"])
        np.testing.assert_array_equal(out["taps"]["series"]["selected"], np.full(T, float(k)))

    def test_async_d1_golden(self):
        out = async_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
            lag_model=_lag_model(), rho=_rho(), taps=True,
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_e3cs_masks"])
        assert np.array_equal(out["lags"].astype(np.int8), GOLD["async_d1_e3cs_lags"])
        assert np.float32(out["cep"]) == GOLD["async_d1_e3cs_cep"]
        taps = out["taps"]
        np.testing.assert_allclose(taps["series"]["on_time"], out["on_time"], atol=1e-4)
        np.testing.assert_allclose(taps["series"]["stale"], out["stale"], atol=1e-4)
        assert taps["counters"]["cum_credit"] == pytest.approx(float(out["cep"]), rel=1e-5)

    def test_async_same_stream_every_placement(self, mesh8):
        """The schema contract: the D=8 sharded-async tap stream equals the
        D=1 stream (psum-reduced gauges are placement-invariant).  Uses the
        packed-lag replay + `random` selector composition, where D=8 is
        bit-identical to D=1 (generated e3cs runs draw shard-local
        randomness, so only mesh=1 matches those — covered below)."""
        lp = GOLD["lag_trace_packed"]
        fl = FLConfig(K=K, k=k, rounds=T, scheme="random", quota_frac=FRAC)

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
                              staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True)
            st, on_time, stale, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lp))
            return st, np.asarray(on_time), np.asarray(stale), payload

        st1, on1, stale1, tap1 = go(None)
        st8, on8, stale8, tap8 = go(mesh8)
        np.testing.assert_array_equal(on1, on8)
        np.testing.assert_array_equal(stale1, stale8)
        assert float(st1.cep) == float(st8.cep)
        for name in ROUND_TAPS.gauge_names():
            np.testing.assert_allclose(
                np.asarray(tap1["series"][name]), np.asarray(tap8["series"][name]), atol=1e-4, err_msg=name
            )
        for name, v in tap1["counters"].items():
            assert float(v) == pytest.approx(float(tap8["counters"][name]), rel=1e-5), name

    def test_async_mesh1_stream_matches_dense_e3cs(self):
        """Generated e3cs async: a 1-device mesh is bit-identical to the
        dense engine — taps included."""
        from repro.launch.mesh import make_host_mesh

        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True)
            st, on_time, stale, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
            return st, np.asarray(on_time), np.asarray(stale), payload

        st1, on1, stale1, tap1 = go(None)
        stm, onm, stalem, tapm = go(make_host_mesh(1))
        np.testing.assert_array_equal(on1, onm)
        np.testing.assert_array_equal(stale1, stalem)
        for name in ROUND_TAPS.gauge_names():
            np.testing.assert_array_equal(
                np.asarray(tap1["series"][name]), np.asarray(tapm["series"][name]), err_msg=name
            )

    def test_async_d8_taps_off_unchanged(self, mesh8):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(taps):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh8)
            run, s0 = pm.build_runner(outputs="lean", taps=taps)
            return run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))

        st_off, on_off, stale_off, _ = go(False)
        st_on, on_on, stale_on, _, _ = go(True)
        np.testing.assert_array_equal(np.asarray(on_off), np.asarray(on_on))
        np.testing.assert_array_equal(np.asarray(stale_off), np.asarray(stale_on))
        np.testing.assert_array_equal(np.asarray(st_off.sel_counts), np.asarray(st_on.sel_counts))

    def test_sketch_validation(self):
        fl = FLConfig(K=32, k=4, rounds=8, scheme="e3cs", quota_frac=FRAC)
        pm = RoundProgram(fl=fl, vol=make_volatility("bernoulli", paper_success_rates(32)),
                          rho=paper_success_rates(32))
        with pytest.raises(ValueError, match="taps"):
            pm.build_runner(sketch=SketchSpec(window=4))
        with pytest.raises(ValueError, match="one-shot"):
            pm.build_runner(taps=True, carry_key=True, sketch=SketchSpec(window=4))


def _sync_program(mesh=None, allocator="sort"):
    """The exact composition behind the sync goldens (``scan_selection_sim``
    / ``sharded_selection_sim`` defaults at K,k,T,SEED,FRAC)."""
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="const", quota_frac=FRAC,
                  eta=0.5, sampler="plackett_luce", allocator=allocator)
    rho = jnp.asarray(paper_success_rates(K))
    vol = make_volatility("bernoulli", rho, stickiness=0.8, seed=SEED)
    return RoundProgram(fl=fl, vol=vol, rho=rho, mesh=mesh)


def _async_program(mesh=None, K_=K, k_=k):
    fl = FLConfig(K=K_, k=k_, rounds=T, scheme="e3cs", quota="const", quota_frac=FRAC,
                  eta=0.5, sampler="plackett_luce",
                  allocator="bisect" if mesh is not None else "sort")
    rho = paper_success_rates(K_)
    lag = CompletionLag(make_volatility("bernoulli", rho), p_late=0.7, lag_decay=0.5, max_lag=2)
    return RoundProgram(fl=fl, vol=lag, rho=rho, staleness=2, alpha=0.5, mesh=mesh)


class TestSketches:
    """The client-axis sketch layer: golden bit-identity, the dense-state
    oracle, psum-merge placement properties, and the fairness series."""

    W = 10
    SPEC = SketchSpec(window=W, count_bins=8, prob_bins=10, n_regions=4)

    # -- golden bit-identity ------------------------------------------------

    def test_sync_d1_sketch_on_matches_golden(self):
        run, s0 = _sync_program().build_runner(outputs="full", taps=True, sketch=self.SPEC)
        _, masks, xs, ps, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        assert np.array_equal(pack_trace(np.asarray(masks)), GOLD["sync_d1_e3cs_masks"])
        assert set(payload["sketches"]) == set(SKETCH_FIELDS)
        assert all(np.asarray(v).shape[0] == T // self.W for v in payload["sketches"].values())
        # oracle: every emission row equals the dense recompute at that round
        self._check_emissions(payload["sketches"], np.asarray(masks), np.asarray(xs),
                              np.asarray(ps), None, K)

    def test_sync_d8_sketch_on_matches_golden(self, mesh8):
        run, s0 = _sync_program(mesh8, allocator="bisect").build_runner(
            outputs="full", taps=True, sketch=self.SPEC
        )
        _, masks, xs, ps, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        masks = np.asarray(masks)[:, :K]
        assert np.array_equal(pack_trace(masks), GOLD["sync_d8_e3cs_masks"])
        self._check_emissions(payload["sketches"], np.asarray(masks), np.asarray(xs)[:, :K],
                              np.asarray(ps)[:, :K], None, K)

    def test_async_d1_sketch_on_matches_golden(self):
        run, s0 = _async_program().build_runner(outputs="full", taps=True, sketch=self.SPEC)
        _, masks, lags, ps, _, _, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        assert np.array_equal(pack_trace(np.asarray(masks)), GOLD["async_d1_e3cs_masks"])
        assert np.array_equal(np.asarray(lags).astype(np.int8), GOLD["async_d1_e3cs_lags"])
        self._check_emissions(payload["sketches"], np.asarray(masks), None,
                              np.asarray(ps), np.asarray(lags), K)

    def _check_emissions(self, sketches, masks, xs, ps, lags, K_true, active=None):
        """Every emitted sketch row equals ``sketch_from_dense`` of the run's
        own dense per-client state at that emission round."""
        spec, W = self.SPEC, self.W
        Kp = masks.shape[1]
        region = region_ids(spec, K_true)
        if Kp != K_true:  # shard padding: ids pad with 0, active mask excludes
            region = np.pad(region, (0, Kp - K_true))
        act = np.asarray(active, np.float64) if active is not None else (
            (np.arange(Kp) < K_true).astype(np.float64)
        )
        L = lag_bins(None if lags is None else 2)
        x_ontime = xs if lags is None else (lags == 0).astype(np.float64)
        code = (1 - x_ontime).astype(np.int64) if lags is None else np.where(
            lags < 0, L - 1, np.clip(lags, 0, L - 2)
        ).astype(np.int64)
        n_emits = T // W
        for i in range(n_emits):
            t = (i + 1) * W  # emission fires on the post-increment round counter
            counts = masks[:t].sum(0)
            cum = (masks[:t] * x_ontime[:t]).sum(0)
            lag_hist = np.zeros(L)
            np.add.at(lag_hist, code[:t].reshape(-1), (masks[:t]).reshape(-1))
            want = sketch_from_dense(spec, counts, ps[t - 1], cum, lag_hist, region, act)
            for n in SKETCH_FIELDS:
                np.testing.assert_allclose(
                    np.asarray(sketches[n][i], np.float64), want[n], rtol=1e-6,
                    err_msg=f"{n} @ emission {i}",
                )

    # -- placement invariance ----------------------------------------------

    def test_sync_mesh1_sketch_matches_dense_golden(self):
        """mesh=1 completes the sync golden matrix: bit-identical to the
        dense bisect engine (``sync_d1_e3cs_bisect_masks``), sketch stream
        byte-for-byte included."""
        from repro.launch.mesh import make_host_mesh

        def go(mesh):
            run, s0 = _sync_program(mesh, allocator="bisect").build_runner(
                outputs="full", taps=True, sketch=self.SPEC
            )
            _, masks, *_, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
            return np.asarray(masks), payload

        m1, p1 = go(None)
        mm, pm_ = go(make_host_mesh(1))
        assert np.array_equal(pack_trace(m1), GOLD["sync_d1_e3cs_bisect_masks"])
        np.testing.assert_array_equal(m1, mm)
        for n in SKETCH_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(p1["sketches"][n]), np.asarray(pm_["sketches"][n]), err_msg=n
            )

    def test_async_mesh1_sketch_matches_dense(self):
        """Generated e3cs async: mesh=1 emits the byte-identical sketch
        stream to the dense engine (the async mesh=1 cell of the matrix)."""
        from repro.launch.mesh import make_host_mesh

        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True, sketch=self.SPEC)
            *_, payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
            return payload

        p1, pm_ = go(None), go(make_host_mesh(1))
        for n in SKETCH_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(p1["sketches"][n]), np.asarray(pm_["sketches"][n]), err_msg=n
            )

    def test_sketch_stream_placement_invariant(self, mesh8):
        """Local and mesh=8 emit the byte-identical sketch stream under a
        replayed lag trace (the composition where the PRNG paths coincide;
        generated volatility draws shard-local randomness)."""
        lp = GOLD["lag_trace_packed"]
        fl = FLConfig(K=K, k=k, rounds=T, scheme="random", quota_frac=FRAC)

        def go(mesh):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
                              staleness=2, alpha=0.5, mesh=mesh)
            run, s0 = pm.build_runner(outputs="lean", taps=True, sketch=self.SPEC)
            *_, payload = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lp))
            return payload

        p1, p8 = go(None), go(mesh8)
        for n in SKETCH_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(p1["sketches"][n]), np.asarray(p8["sketches"][n]), err_msg=n
            )
        for name in ROUND_TAPS.gauge_names():
            np.testing.assert_allclose(
                np.asarray(p1["series"][name]), np.asarray(p8["series"][name]), atol=1e-4, err_msg=name
            )

    def test_sharded_sketch_merge_property_ragged_async(self, mesh8):
        """Satellite: the psum-merged D=8 sketch of a ragged-K async run
        equals the dense recompute of that run's own (T, K_pad) streams —
        the merge is exact addition, shard padding excluded via the active
        mask."""
        K_r, k_r = 130, 12  # K_pad = 136, ragged final shard
        pm = _async_program(mesh8, K_=K_r, k_=k_r)
        run, s0 = pm.build_runner(outputs="full", taps=True, sketch=self.SPEC)
        _, masks, lags, ps, _, _, payload = run(
            s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32)
        )
        masks, lags, ps = np.asarray(masks), np.asarray(lags), np.asarray(ps)
        Kp = masks.shape[1]
        assert Kp == 136
        spec, W = self.SPEC, self.W
        region = np.pad(region_ids(spec, K_r), (0, Kp - K_r))
        act = (np.arange(Kp) < K_r).astype(np.float64)
        L = lag_bins(2)
        x_ontime = (lags == 0).astype(np.float64)
        code = np.where(lags < 0, L - 1, np.clip(lags, 0, L - 2)).astype(np.int64)
        for i in range(T // W):
            t = (i + 1) * W
            counts = masks[:t].sum(0)
            cum = (masks[:t] * x_ontime[:t]).sum(0)
            lag_hist = np.zeros(L)
            np.add.at(lag_hist, code[:t].reshape(-1), masks[:t].reshape(-1))
            want = sketch_from_dense(spec, counts, ps[t - 1], cum, lag_hist, region, act)
            for n in SKETCH_FIELDS:
                np.testing.assert_allclose(
                    np.asarray(payload["sketches"][n][i], np.float64), want[n], rtol=1e-6,
                    err_msg=f"{n} @ emission {i}",
                )

    def test_merge_sketches_is_addition(self):
        rng = np.random.default_rng(0)
        a = {n: rng.random((3, 4)) for n in SKETCH_FIELDS}
        b = {n: rng.random((3, 4)) for n in SKETCH_FIELDS}
        m = merge_sketches(a, b)
        for n in SKETCH_FIELDS:
            np.testing.assert_allclose(m[n], a[n] + b[n])

    # -- fairness series ----------------------------------------------------

    def test_fairness_series_uniform_fleet(self):
        """Uniform counts: Jain 1, Gini 0, top-decile share = 10%, region
        skew 1 — all exact, whatever the bucketing."""
        spec = SketchSpec(window=1, count_bins=8, prob_bins=4, n_regions=4)
        Kn = 200
        counts = np.full(Kn, 5.0)
        region = region_ids(spec, Kn)
        row = sketch_from_dense(spec, counts, np.full(Kn, 0.5), counts, np.zeros(2), region)
        stream = {n: np.asarray(v)[None] for n, v in row.items()}
        fair = fairness_series(stream)
        assert fair["jain"][0] == pytest.approx(1.0)
        assert fair["gini"][0] == pytest.approx(0.0, abs=1e-12)
        assert fair["top_decile_share"][0] == pytest.approx(0.1)
        assert fair["region_cep_skew"][0] == pytest.approx(1.0)

    def test_fairness_series_vs_exact_oracles(self):
        """On a real run: sketch Jain is *exact* (streamed moments), grouped
        Gini / top-decile track the ``core.fairness`` exact twins."""
        run, s0 = _sync_program().build_runner(outputs="full", taps=True, sketch=self.SPEC)
        state, masks, *_ , payload = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        fair = fairness_series(payload["sketches"])
        masks = np.asarray(masks)
        for i in range(T // self.W):
            counts = jnp.asarray(masks[: (i + 1) * self.W].sum(0))
            assert fair["jain"][i] == pytest.approx(float(jain_index(counts)), rel=1e-5)
            assert abs(fair["gini"][i] - float(gini_exact(counts))) < 0.12
            assert abs(fair["top_decile_share"][i] - float(top_share_exact(counts))) < 0.12
        assert np.all(fair["region_cep_skew"] >= 1.0)

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SketchSpec(window=0)
        with pytest.raises(ValueError):
            SketchSpec(count_bins=1)
        with pytest.raises(ValueError):
            SketchSpec(n_regions=0)
        with pytest.raises(ValueError):
            SketchSpec(n_regions=2, regions=np.array([0, 1, 2]))
        with pytest.raises(ValueError):
            region_ids(SketchSpec(n_regions=2, regions=np.array([0, 1])), K=3)
        np.testing.assert_array_equal(region_ids(SketchSpec(n_regions=2), 4), [0, 0, 1, 1])


class TestCarryKeyTapsStreams:
    """Satellite: ``taps=True`` + ``carry_key=True`` threads the counter
    pytree through the streamed carry — chunked horizons emit the bit-
    identical tap stream to one-shot runs, in every placement."""

    C = 10  # chunk length; T = 50 -> 5 chunks

    def _drive_chunks(self, pm, async_mode, mesh=False):
        run_full, s0 = pm.build_runner(outputs="lean", carry_key=True, taps=True)
        run_chunk, _ = pm.build_runner(outputs="lean", carry_key=True, taps=True,
                                       scan_length=self.C)
        key = jax.random.PRNGKey(SEED)
        tapc = ROUND_TAPS.init_counters()
        xs = jnp.zeros((T, 0), jnp.float32)
        if async_mode:
            rings = pm.init_rings()
            state, key_f, rings_f, tapc_f, *outs_f, row_f = run_full(s0, key, rings, tapc, xs)
            state_c, key_c, rings_c, tapc_c = s0, key, pm.init_rings(), ROUND_TAPS.init_counters()
            rows = []
            for c in range(T // self.C):
                state_c, key_c, rings_c, tapc_c, *outs, row = run_chunk(
                    state_c, key_c, rings_c, tapc_c, jnp.zeros((self.C, 0), jnp.float32)
                )
                rows.append(row)
        else:
            state, key_f, tapc_f, *outs_f, row_f = run_full(s0, key, tapc, xs)
            state_c, key_c, tapc_c = s0, key, ROUND_TAPS.init_counters()
            rows = []
            for c in range(T // self.C):
                state_c, key_c, tapc_c, *outs, row = run_chunk(
                    state_c, key_c, tapc_c, jnp.zeros((self.C, 0), jnp.float32)
                )
                rows.append(row)
        series_f = {n: np.asarray(row_f[n]) for n in ROUND_TAPS.gauge_names()}
        series_c = {n: np.concatenate([np.asarray(r[n]) for r in rows]) for n in ROUND_TAPS.gauge_names()}
        for n in ROUND_TAPS.gauge_names():
            np.testing.assert_array_equal(series_f[n], series_c[n], err_msg=n)
        for n, v in tapc_f.items():
            assert float(v) == float(tapc_c[n]), n
        np.testing.assert_array_equal(np.asarray(state.sel_counts), np.asarray(state_c.sel_counts))

    def test_local_sync_chunked_equals_oneshot(self):
        self._drive_chunks(_sync_program(), async_mode=False)

    def test_local_async_chunked_equals_oneshot(self):
        self._drive_chunks(_async_program(), async_mode=True)

    def test_sharded_sync_chunked_equals_oneshot(self, mesh8):
        self._drive_chunks(_sync_program(mesh8, allocator="bisect"), async_mode=False)

    def test_replay_packed_stream_emits_taps(self, tmp_path):
        """K=big horizons replayed in chunks emit the same telemetry as the
        one-shot in-memory run."""
        from repro.scenarios import replay_packed_stream, save_packed_trace

        lp = GOLD["lag_trace_packed"]
        path = save_packed_trace(str(tmp_path / "lags"), lp, K, kind="lags")
        out = replay_packed_stream("e3cs", path, k, chunk=16, frac=FRAC, seed=SEED, taps=True)
        ref = async_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
            packed_lag_override=lp, outputs="lean", taps=True,
        )
        for n in ROUND_TAPS.gauge_names():
            np.testing.assert_array_equal(
                out["taps"]["series"][n], ref["taps"]["series"][n], err_msg=n
            )
        for n, v in out["taps"]["counters"].items():
            assert v == pytest.approx(ref["taps"]["counters"][n], rel=1e-6), n


class TestAlerts:
    def test_severity_validation(self):
        with pytest.raises(ValueError):
            Alert("outage", "apocalyptic", {})

    def test_outage_fires_on_windowed_collapse(self):
        on_time = np.concatenate([np.full(40, 10.0), np.full(10, 1.0)])
        alerts = detect_alerts(series={"on_time": on_time}, rules=AlertRules(window=10))
        assert [a.rule for a in alerts] == ["outage"]
        assert alerts[0].severity == "critical"
        assert alerts[0].detail["window"] == 4
        # healthy series: silent
        assert detect_alerts(series={"on_time": np.full(50, 10.0)}, rules=AlertRules(window=10)) == []

    def test_starvation_fires_on_fairness_thresholds(self):
        fair = {"jain": np.array([0.9, 0.3]), "top_decile_share": np.array([0.2, 0.8])}
        alerts = detect_alerts(fairness=fair)
        assert sorted(a.rule for a in alerts) == ["starvation", "starvation"]
        assert all(a.severity == "warn" for a in alerts)
        assert detect_alerts(fairness={"jain": np.array([0.8]), "top_decile_share": np.array([0.3])}) == []

    def test_drift_fires_on_cohort_and_cap(self):
        alerts = detect_alerts(
            series={"selected": np.array([16.0, 16.0, 15.0]), "capped_frac": np.full(10, 0.9)},
            expected_selected=16,
            rules=AlertRules(window=5),
        )
        rules = sorted(a.rule for a in alerts)
        assert rules == ["drift", "drift"]
        sel = next(a for a in alerts if a.detail.get("metric") == "selected")
        assert sel.severity == "critical" and sel.detail["first_round"] == 2

    def test_empty_inputs_silent(self):
        assert detect_alerts() == []
        assert detect_alerts(series={}, fairness={}) == []


class TestRunLogV2:
    def test_alert_event_round_trip(self, tmp_path):
        path = str(tmp_path / "a.jsonl")
        with RunLog("unit", path=path) as log:
            log.alert("outage", "critical", {"window": 3}, "credit fell")
            log.summary(done=True)
        records = read_runlog(path)
        validate_records(records)
        alerts = list(iter_alerts(records))
        assert len(alerts) == 1
        assert alerts[0]["rule"] == "outage" and alerts[0]["severity"] == "critical"
        assert all("ts" in r for r in records)

    def test_nan_sanitized_everywhere(self, tmp_path):
        """Satellite regression: NaN/inf inside numpy scalars AND arrays
        serialize as null — the file must contain no bare NaN tokens."""
        path = str(tmp_path / "nan.jsonl")
        with RunLog("unit", path=path) as log:
            log.summary(
                a=np.float64("nan"), b=float("inf"),
                c=np.array([1.0, np.nan, np.inf]), d={"deep": jnp.float32(np.nan)},
            )
        raw = open(path).read()
        assert "NaN" not in raw and "Infinity" not in raw
        data = read_runlog(path)[-1]["data"]
        assert data["a"] is None and data["b"] is None
        assert data["c"] == [1.0, None, None]
        assert data["d"]["deep"] is None

    def test_overwrite_protection(self, tmp_path):
        """Satellite: a rerun under the same name refuses to truncate the
        existing log unless overwrite=True; unique=True writes a numbered
        sibling with the header run name unchanged."""
        path = str(tmp_path / "r.jsonl")
        RunLog("r", path=path).close()
        with pytest.raises(FileExistsError):
            RunLog("r", path=path)
        log2 = RunLog("r", path=path, unique=True)
        assert log2.path == str(tmp_path / "r.2.jsonl")
        log2.close()
        assert read_runlog(log2.path)[0]["run"] == "r"  # header name stays stable
        log3 = RunLog("r", path=path, overwrite=True)
        assert log3.path == path
        log3.close()

    def test_v1_records_still_validate(self):
        v1 = [
            {"schema": 1, "event": "header", "run": "x", "name": "x", "config": {}},
            {"schema": 1, "event": "summary", "run": "x", "data": {}},
        ]
        validate_records(v1)  # no ts required at v1
        with pytest.raises(ValueError, match="schema >= 2"):
            validate_records([
                {"schema": 1, "event": "header", "run": "x", "name": "x", "config": {}},
                {"schema": 1, "event": "alert", "run": "x", "rule": "r", "severity": "warn", "detail": {}},
            ])
        with pytest.raises(ValueError, match="ts"):
            validate_records([
                {"schema": 2, "event": "header", "run": "x", "name": "x", "config": {}},
            ])


class TestObsExplore:
    @pytest.fixture()
    def explorer(self):
        return _load_module("scripts/obs_explore.py", "obs_explore")

    def _write_log(self, path, run, jain_last=0.8, alert=False):
        with RunLog(run, config={"K": 8}, path=path) as log:
            log.metrics(
                "fairness",
                window_reduce({"jain": np.array([0.5, jain_last])}, window=1),
                better={"jain": "higher"},
            )
            if alert:
                log.alert("starvation", "warn", {"jain": jain_last}, "low jain")
            log.summary(rounds_per_s=10.0)
        return path

    def test_summarize_and_fairness(self, tmp_path, capsys, explorer):
        self._write_log(str(tmp_path / "a.jsonl"), "a", alert=True)
        assert explorer.main(["summarize", str(tmp_path)]) == 0
        text = capsys.readouterr().out
        assert "== a" in text and "ALERT [warn] starvation" in text and "fairness.jain" in text
        assert explorer.main(["fairness", str(tmp_path / "a.jsonl"), "--csv"]) == 0
        csv = capsys.readouterr().out.strip().splitlines()
        assert csv[0] == "run,stream,metric,window,p50"
        assert csv[1].startswith("a,fairness,jain,0,")

    def test_diff_pairs_by_header_name(self, tmp_path, capsys, explorer):
        a_dir, b_dir = tmp_path / "A", tmp_path / "B"
        a_dir.mkdir(), b_dir.mkdir()
        self._write_log(str(a_dir / "x.jsonl"), "run1", jain_last=0.8)
        # same header name, different filename: still paired
        self._write_log(str(b_dir / "y.jsonl"), "run1", jain_last=0.2, alert=True)
        rc = explorer.main(["diff", str(a_dir), str(b_dir), "--strict"])
        text = capsys.readouterr().out
        assert rc == 1  # jain dropped 75% under direction "higher" -> gated regression
        assert "REGRESSED" in text and "NEW ALERT" in text
        # tolerant run: reported but exit 0 without --strict
        assert explorer.main(["diff", str(a_dir), str(b_dir)]) == 0

    def test_output_file(self, tmp_path, capsys, explorer):
        self._write_log(str(tmp_path / "a.jsonl"), "a")
        out = str(tmp_path / "rep" / "report.txt")
        assert explorer.main(["summarize", str(tmp_path / "a.jsonl"), "-o", out]) == 0
        capsys.readouterr()
        assert "== a" in open(out).read()


class TestFairnessExactMetrics:
    """``core.fairness.gini`` / ``top_share`` — the dense oracles the sketch
    stream approximates."""

    def test_gini_edge_cases(self):
        assert float(gini_exact(jnp.full(10, 3.0))) == pytest.approx(0.0, abs=1e-6)
        # one client holds everything: G -> (K-1)/K
        one = jnp.zeros(10).at[3].set(5.0)
        assert float(gini_exact(one)) == pytest.approx(0.9, abs=1e-6)

    def test_top_share_edge_cases(self):
        assert float(top_share_exact(jnp.full(10, 2.0), 0.1)) == pytest.approx(0.1, rel=1e-5)
        one = jnp.zeros(10).at[3].set(5.0)
        assert float(top_share_exact(one, 0.1)) == pytest.approx(1.0, rel=1e-5)


class TestTapRegistry:
    def test_round_taps_schema(self):
        # the default "round" group is exactly the in-scan gauges — the
        # fairness group (host-derived from sketches) must not leak into it
        assert set(ROUND_TAPS.gauge_names()) == {"selected", "on_time", "stale", "sigma", "capped_frac"}
        assert ROUND_TAPS.directions()["selected"] == "equal"
        assert ROUND_TAPS.directions()["on_time"] == "higher"
        assert set(ROUND_TAPS.gauge_names(group=None)) == {
            "selected", "on_time", "stale", "sigma", "capped_frac",
            "jain", "gini", "top_decile_share", "region_cep_skew",
            "queue_depth", "batch_jobs", "shed", "restarts", "recovery_s",
        }
        assert set(ROUND_TAPS.gauge_names(group="fairness")) == set(FAIRNESS_SERIES)
        assert set(ROUND_TAPS.gauge_names(group="serve")) == {
            "queue_depth", "batch_jobs", "shed", "restarts", "recovery_s",
        }
        assert ROUND_TAPS.directions("serve")["shed"] == "lower"
        assert ROUND_TAPS.directions("serve")["restarts"] == "lower"
        assert ROUND_TAPS.directions("serve")["recovery_s"] == "lower"
        fair_dirs = ROUND_TAPS.directions("fairness")
        assert fair_dirs["jain"] == "higher"
        assert fair_dirs["gini"] == "lower"
        assert fair_dirs["top_decile_share"] == "lower"
        assert fair_dirs["region_cep_skew"] == "none"

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            TapSpec("x", "nope")
        with pytest.raises(ValueError):
            TapSpec("x", "gauge", better="sideways")

    def test_accumulate_sources(self):
        reg = TapRegistry(
            TapSpec("a", "gauge"),
            TapSpec("b", "gauge"),
            TapSpec("ticks", "counter"),
            TapSpec("total", "counter", source=("a", "b")),
        )
        c = reg.init_counters()
        row = {"a": jnp.float32(2.0), "b": jnp.float32(3.0)}
        c = reg.accumulate(c, row)
        c = reg.accumulate(c, row)
        assert float(c["ticks"]) == 2.0
        assert float(c["total"]) == 10.0


class TestWindowReduce:
    def test_hand_checked(self):
        # [1..7] window 3: two full windows, one element dropped;
        # p99 interpolates linearly inside each 3-sample window
        out = window_reduce({"v": np.arange(1.0, 8.0)}, window=3)
        assert out["n_windows"] == 2 and out["dropped"] == 1
        aggs = out["aggs"]["v"]
        np.testing.assert_allclose(aggs["sum"], [6.0, 15.0])
        np.testing.assert_allclose(aggs["mean"], [2.0, 5.0])
        np.testing.assert_allclose(aggs["p50"], [2.0, 5.0])
        np.testing.assert_allclose(aggs["p99"], [2.98, 5.98])

    def test_tiny_three_client_horizon(self):
        # a K=3, k=1 horizon: the selected gauge is exactly 1 every round,
        # so every windowed aggregate of it is hand-computable
        out = scan_selection_sim("random", K=3, k=1, T=8, frac=0.0, seed=0, taps=True)
        red = window_reduce(out["taps"]["series"], window=4)
        assert red["n_windows"] == 2 and red["dropped"] == 0
        np.testing.assert_allclose(red["aggs"]["selected"]["sum"], [4.0, 4.0])
        np.testing.assert_allclose(red["aggs"]["selected"]["p50"], [1.0, 1.0])
        np.testing.assert_allclose(red["aggs"]["selected"]["mean"], [1.0, 1.0])
        assert out["taps"]["counters"]["cum_selected"] == 8.0

    def test_mismatched_lengths_raise(self):
        with pytest.raises(ValueError):
            window_reduce({"a": np.arange(6.0), "b": np.arange(5.0)}, window=3)


class TestRunLogRoundTrip:
    def test_full_round_trip(self, tmp_path):
        path = str(tmp_path / "run.jsonl")
        hist = LatencyHistogram()
        hist.observe(0.002)
        with RunLog("unit", config={"K": 4}, path=path) as log:
            log.metrics("s1", window_reduce({"v": np.arange(8.0)}, window=4), better={"v": "higher"})
            log.grid_row({"selector": "e3cs", "cep": 1.0})
            log.histogram("lat", hist.to_record())
            log.summary(done=True)
        records = read_runlog(path)
        validate_records(records)
        assert records[0]["event"] == "header"
        assert records[0]["schema"] == SCHEMA_VERSION
        assert records[0]["config"] == {"K": 4}
        events = [r["event"] for r in records]
        assert events == ["header", "metrics", "grid_row", "histogram", "summary"]
        streams = {r["stream"]: r for r in iter_metrics(records)}
        assert "s1" in streams and streams["s1"]["windows"]["n_windows"] == 2
        assert streams["s1"]["better"] == {"v": "higher"}

    def test_jsonable_coercion(self, tmp_path):
        path = str(tmp_path / "np.jsonl")
        with RunLog("unit", path=path) as log:
            log.summary(a=np.float32(1.5), b=jnp.int32(2), c=float("nan"), d=np.arange(3))
        rec = read_runlog(path)[-1]["data"]
        assert rec["a"] == 1.5 and rec["b"] == 2 and rec["c"] is None and rec["d"] == [0, 1, 2]

    def test_validate_rejects_bad(self):
        with pytest.raises(ValueError):
            validate_records([])
        with pytest.raises(ValueError):  # missing required payload key
            validate_records([{"schema": SCHEMA_VERSION, "event": "metrics", "run": "x"}])
        with pytest.raises(ValueError):  # wrong schema version
            validate_records([{"schema": 99, "event": "header", "run": "x", "name": "x", "config": {}}])
        with pytest.raises(ValueError):  # first record must be the header
            validate_records([
                {"schema": SCHEMA_VERSION, "event": "summary", "run": "x", "data": {}},
            ])


class TestReporter:
    def test_bench_json_with_metrics_block(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_RESULTS", str(tmp_path))
        rep = Reporter("unit", config={"smoke": True})
        rep.metrics_stream("s", {"v": np.arange(10.0)}, window=5, better={"v": "higher"})
        path = rep.save({"rounds_per_s": 42.0})
        assert path == str(tmp_path / "bench" / "BENCH_unit.json")
        blob = json.load(open(path))
        assert blob["rounds_per_s"] == 42.0
        assert blob["metrics"]["s"]["n_windows"] == 2
        assert blob["metrics"]["s"]["better"] == {"v": "higher"}
        records = read_runlog(str(tmp_path / "runlogs" / "unit.jsonl"))
        validate_records(records)
        assert records[-1]["event"] == "summary"


class TestPaths:
    def test_env_layout(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_RESULTS", raising=False)
        monkeypatch.delenv("REPRO_BENCH_OUT", raising=False)
        assert obs_paths.results_root() == "results"
        monkeypatch.setenv("REPRO_BENCH_OUT", str(tmp_path / "r" / "bench"))
        assert obs_paths.results_root() == str(tmp_path / "r")
        assert obs_paths.bench_dir() == str(tmp_path / "r" / "bench")
        monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "override"))
        assert obs_paths.results_root() == str(tmp_path / "override")
        assert obs_paths.artifact_path("x.json") == str(tmp_path / "override" / "x.json")
        assert obs_paths.bench_path("n").endswith(os.path.join("bench", "BENCH_n.json"))
        assert obs_paths.runlog_path("n").endswith(os.path.join("runlogs", "n.jsonl"))


class TestLatencyHistogram:
    def test_quantiles_bracket_samples(self):
        h = LatencyHistogram(lo=1e-4, hi=1.0, n_buckets=32)
        samples = [0.001, 0.002, 0.004, 0.008, 0.016]
        for s in samples:
            h.observe(s)
        s = h.summary()
        assert s["count"] == 5
        assert s["min_s"] == 0.001 and s["max_s"] == 0.016
        assert s["min_s"] <= s["p50_s"] <= s["max_s"]
        assert s["p50_s"] <= s["p99_s"] <= s["max_s"]
        assert s["mean_s"] == pytest.approx(np.mean(samples), rel=1e-6)
        rec = h.to_record()
        assert len(rec["counts"]) == 32 and sum(rec["counts"]) == 5

    def test_out_of_range_clamped(self):
        h = LatencyHistogram(lo=1e-3, hi=1e-2, n_buckets=8)
        h.observe(1e-6)
        h.observe(5.0)
        assert h.quantile(0.0) >= 1e-6
        assert math.isfinite(h.quantile(0.99))

    def test_span_timer(self):
        t = SpanTimer()
        with t.span("work"):
            pass
        with t.span("work", annotate=True):
            pass
        assert t.get("work").summary()["count"] == 2
        assert "work" in t.summary()


class TestStage:
    def test_host_and_traced(self):
        with stage("unit.host"):
            x = jnp.ones(4)

        @jax.jit
        def f(v):
            with stage("unit.traced"):
                return v * 2

        np.testing.assert_array_equal(np.asarray(f(x)), np.full(4, 2.0))


class TestCheckBench:
    def _compare(self, cb, new, base, tol=0.3, metrics_only=False):
        if metrics_only:
            checked_m, regs_m, notes_m = cb.compare_metrics(new, base, tol)
            return checked_m, regs_m, [], notes_m
        cs, rs, imps, ns = cb.compare_scalars(new, base, tol)
        cm, rm, nm = cb.compare_metrics(new, base, tol)
        return cs + cm, rs + rm, imps, ns + nm

    def test_scalar_regression_and_improvement(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"a": {"rounds_per_s": 5.0}, "b": {"ticks_per_s": 20.0}},
            {"a": {"rounds_per_s": 10.0}, "b": {"ticks_per_s": 10.0}},
        )
        assert checked == 2
        assert [r[0] for r in regs] == ["a.rounds_per_s"]
        assert [i[0] for i in imps] == ["b.ticks_per_s"]

    def test_zero_and_nonfinite_baselines_noted(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"a": {"rounds_per_s": 5.0}, "b": {"rounds_per_s": 5.0}},
            {"a": {"rounds_per_s": 0.0}, "b": {"rounds_per_s": float("nan")}},
        )
        assert checked == 0 and not regs
        assert any("<= 0" in n for n in notes)
        assert any("non-finite" in n for n in notes)

    def test_one_sided_keys_noted_not_failed(self, check_bench):
        checked, regs, imps, notes = self._compare(
            check_bench,
            {"new_only": {"rounds_per_s": 5.0}},
            {"old_only": {"rounds_per_s": 5.0}},
        )
        assert checked == 0 and not regs
        assert any("no baseline" in n for n in notes)
        assert any("baseline only" in n for n in notes)

    def _metrics_doc(self, p50, window=5, direction="higher"):
        return {"metrics": {"s": {
            "window": window, "n_windows": len(p50), "dropped": 0,
            "better": {"v": direction},
            "aggs": {"v": {"p50": list(p50), "p99": list(p50), "mean": list(p50), "sum": list(p50)}},
        }}}

    def test_metrics_direction_gates(self, check_bench):
        base = self._metrics_doc([10.0, 10.0])
        ok = self._metrics_doc([9.0, 11.0])
        bad = self._metrics_doc([10.0, 6.0])
        assert not self._compare(check_bench, ok, base, metrics_only=True)[1]
        regs = self._compare(check_bench, bad, base, metrics_only=True)[1]
        assert [r[0] for r in regs] == ["metrics.s.v.p50[1]"]
        # "lower" flips the inequality
        base_l = self._metrics_doc([10.0], direction="lower")
        assert not self._compare(check_bench, self._metrics_doc([12.0], direction="lower"),
                                 base_l, metrics_only=True)[1]
        assert self._compare(check_bench, self._metrics_doc([14.0], direction="lower"),
                             base_l, metrics_only=True)[1]
        # "equal" gates any drift; "none" never gates
        base_e = self._metrics_doc([10.0], direction="equal")
        assert self._compare(check_bench, self._metrics_doc([10.0001], direction="equal"),
                             base_e, metrics_only=True)[1]
        base_n = self._metrics_doc([10.0], direction="none")
        assert not self._compare(check_bench, self._metrics_doc([0.0], direction="none"),
                                 base_n, metrics_only=True)[1]

    def test_window_mismatch_skipped(self, check_bench):
        base = self._metrics_doc([10.0, 10.0])
        new = self._metrics_doc([10.0, 10.0, 10.0])
        checked, regs, _, notes = self._compare(check_bench, new, base, metrics_only=True)
        assert checked == 0 and not regs
        assert any("windows" in n for n in notes)
        new_w = self._metrics_doc([10.0, 10.0], window=7)
        _, regs, _, notes = self._compare(check_bench, new_w, base, metrics_only=True)
        assert not regs and any("window" in n for n in notes)

    def test_metrics_block_not_gated_as_leaves(self, check_bench):
        doc = self._metrics_doc([10.0])
        assert dict(check_bench.numeric_leaves(doc)) == {}


class TestTimeFn:
    def test_both_modes(self):
        common = _load_module("benchmarks/common.py", "bench_common")
        us_block = common.time_fn(lambda: jnp.ones(8) * 2, iters=2, warmup=1)
        us_pipe = common.time_fn(lambda: jnp.ones(8) * 2, iters=2, warmup=1, blocking=False)
        assert us_block > 0 and us_pipe > 0
