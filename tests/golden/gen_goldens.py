#!/usr/bin/env python
"""Capture the pre-RoundProgram engine outputs as golden pins.

Run ONCE against the engine as it stood before the PR-5 refactor (commit
5112c98) to freeze the bit-exact behaviour of every round-body flavour the
repo had at that point:

* sync, D=1: all five schemes (generated volatility), dense / packed /
  streamed replay, and the ``allocator="bisect"`` reference;
* sync, D=8: the sharded engine (e3cs + random, generated and packed);
* async S=2, D=1: four schemes (generated ``CompletionLag``) and the 2-bit
  ``ReplayLag`` packed-lag replay (trace itself stored too, so the new
  packed-lag *override* path can be pinned against the identical rows).

``tests/test_round_program.py`` replays the same configurations through the
unified ``RoundProgram`` and asserts bit-identity against this file.  The
npz is committed; regenerate only if the *intended* semantics change, and
say so in the PR.

Usage:  PYTHONPATH=src python tests/golden/gen_goldens.py
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

K, k, T, SEED, FRAC = 128, 16, 50, 3, 0.5
SYNC_SCHEMES = ("e3cs", "random", "fedcs", "ucb", "pow_d")
ASYNC_SCHEMES = ("e3cs", "random", "ucb", "fedcs")
OUT = os.path.join(os.path.dirname(__file__), "round_program_goldens.npz")


def dense_xs():
    return np.random.default_rng(11).binomial(1, 0.6, (T, K)).astype(np.float32)


def lag_model(rho):
    from repro.core.volatility import CompletionLag, make_volatility

    return CompletionLag(
        make_volatility("bernoulli", rho), p_late=0.7, lag_decay=0.5, max_lag=2
    )


def main():
    import jax.numpy as jnp

    from repro.core.volatility import make_volatility, paper_success_rates
    from repro.engine.scan_sim import async_selection_sim, scan_selection_sim
    from repro.engine.sharded import sharded_selection_sim
    from repro.launch.mesh import make_host_mesh
    from repro.scenarios.replay import (
        pack_trace,
        record_lag_trace,
        replay_packed_stream,
        save_packed_trace,
        ReplayLag,
    )

    rho = paper_success_rates(K)
    g = {}

    # --- sync, D=1 --------------------------------------------------------
    for scheme in SYNC_SCHEMES:
        out = scan_selection_sim(scheme, K=K, k=k, T=T, frac=FRAC, seed=SEED)
        g[f"sync_d1_{scheme}_masks"] = pack_trace(out["masks"])
        g[f"sync_d1_{scheme}_counts"] = out["counts"]
    out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, allocator="bisect")
    g["sync_d1_e3cs_bisect_masks"] = pack_trace(out["masks"])

    xs = dense_xs()
    out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, xs_override=xs)
    g["sync_d1_dense_masks"] = pack_trace(out["masks"])
    packed = pack_trace(xs)
    out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed)
    g["sync_d1_packed_masks"] = pack_trace(out["masks"])

    with tempfile.TemporaryDirectory() as d:
        path = save_packed_trace(os.path.join(d, "trace"), packed, K)
        out = replay_packed_stream("e3cs", path, k, chunk=16, frac=FRAC, seed=SEED)
    g["sync_d1_streamed_successes"] = out["successes"]
    g["sync_d1_streamed_counts"] = out["counts"]

    # --- sync, D=8 (sharded) ---------------------------------------------
    mesh8 = make_host_mesh(8)
    for scheme in ("e3cs", "random"):
        out = sharded_selection_sim(scheme, mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED)
        g[f"sync_d8_{scheme}_masks"] = pack_trace(out["masks"])
        g[f"sync_d8_{scheme}_counts"] = out["counts"]
    out = sharded_selection_sim("e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed)
    g["sync_d8_packed_masks"] = pack_trace(out["masks"])

    # --- async S=2, D=1 ---------------------------------------------------
    for scheme in ASYNC_SCHEMES:
        out = async_selection_sim(
            scheme, K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
            lag_model=lag_model(rho), rho=rho,
        )
        g[f"async_d1_{scheme}_masks"] = pack_trace(out["masks"])
        g[f"async_d1_{scheme}_lags"] = out["lags"].astype(np.int8)
        g[f"async_d1_{scheme}_counts"] = out["counts"]
        g[f"async_d1_{scheme}_cep"] = np.float32(out["cep"])
        g[f"async_d1_{scheme}_on_time"] = out["on_time"]
        g[f"async_d1_{scheme}_stale"] = out["stale"]

    lag_packed = record_lag_trace(lag_model(rho), T, seed=SEED)
    g["lag_trace_packed"] = lag_packed
    out = async_selection_sim(
        "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
        lag_model=ReplayLag(jnp.asarray(lag_packed), K), rho=rho,
    )
    g["async_d1_replay_masks"] = pack_trace(out["masks"])
    g["async_d1_replay_counts"] = out["counts"]
    g["async_d1_replay_cep"] = np.float32(out["cep"])

    np.savez_compressed(OUT, **g)
    print(f"wrote {OUT}: {len(g)} arrays, {os.path.getsize(OUT)} bytes")


if __name__ == "__main__":
    main()
