"""Dry-run smoke in a SUBPROCESS (so the fake-device XLA flag never pollutes
this test process — smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
import tempfile


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(arch, shape, mesh_dims="4x2", timeout=560):
    with tempfile.TemporaryDirectory() as out:
        env = dict(
            os.environ,
            PYTHONPATH=SRC,
            REPRO_DRYRUN_DEVICES="8",
            REPRO_DRYRUN_MESH=mesh_dims,
        )
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape, "--mesh",
             "multi" if mesh_dims.count("x") == 2 else "single", "--out", out],
            env=env,
            capture_output=True,
            text=True,
            timeout=timeout,
        )
        files = [f for f in os.listdir(out) if f.endswith(".json")]
        assert files, r.stdout + r.stderr
        with open(os.path.join(out, files[0])) as f:
            return json.load(f)


def test_dryrun_dense_train_single():
    rec = _run("stablelm-1.6b", "train_4k", "4x2")
    assert rec["status"] == "ok", rec.get("error")
    assert rec["roofline"]["compute_s"] > 0
    assert rec["collectives_raw_scanbody"]["total"] > 0  # selection+agg collectives present


def test_dryrun_dense_train_multipod():
    rec = _run("stablelm-1.6b", "train_4k", "2x2x2")
    assert rec["status"] == "ok", rec.get("error")
    assert rec["mesh_shape"] == [2, 2, 2]


def test_dryrun_ssm_decode():
    rec = _run("mamba2-130m", "long_500k", "4x2")
    assert rec["status"] == "ok", rec.get("error")
    # O(1) state decode: per-device HBM must be tiny even at 500k context
    assert rec["per_device_hbm_gb"] < 4.0


def test_dryrun_whisper_skip_long():
    rec = _run("whisper-base", "long_500k", "4x2")
    assert rec["status"] == "skipped"
