"""Property + unit tests for the paper's core: ProbAlloc, samplers, E3CS,
quota schedules, regret bound (Theorem 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.selection import e3cs_init, e3cs_round, make_quota_schedule, oracle_cep, prob_alloc, prob_alloc_reference, regret, sample_selection, theorem1_bound, theorem1_eta
from repro.core.selection.sampling import inclusion_probability_mc
from repro.core.volatility import BernoulliVolatility, paper_success_rates


@st.composite
def alloc_case(draw):
    K = draw(st.integers(3, 50))
    k = draw(st.integers(1, K))
    sigma_frac = draw(st.floats(0.0, 0.999))
    weights = draw(
        st.lists(st.floats(1e-4, 1e4, allow_nan=False, allow_infinity=False), min_size=K, max_size=K)
    )
    return K, k, sigma_frac * k / K, np.asarray(weights, np.float32)


class TestProbAlloc:
    @settings(max_examples=150, deadline=None)
    @given(alloc_case())
    def test_invariants_and_matches_reference(self, case):
        K, k, sigma, w = case
        p, capped = prob_alloc(jnp.asarray(w), k, sigma)
        p = np.asarray(p)
        # cardinality: sum p == k (Eq. 12 constraint)
        assert abs(p.sum() - k) < 1e-3 * k + 1e-3
        # fairness floor and ceiling: sigma <= p <= 1
        assert p.min() >= sigma - 1e-5
        assert p.max() <= 1.0 + 1e-5
        pr, capped_r = prob_alloc_reference(w, k, sigma)
        np.testing.assert_allclose(p, pr, rtol=3e-3, atol=1e-4)
        assert (np.asarray(capped) == capped_r).all()

    def test_monotone_in_weights(self):
        w = jnp.asarray([1.0, 2.0, 3.0, 4.0, 100.0])
        p, _ = prob_alloc(w, 2, 0.05)
        assert bool(jnp.all(jnp.diff(p) >= -1e-7))

    def test_uniform_weights_give_uniform_probs(self):
        p, capped = prob_alloc(jnp.ones(10), 3, 0.1)
        np.testing.assert_allclose(np.asarray(p), 0.3, atol=1e-6)
        assert not bool(capped.any())

    def test_capping_triggers_on_dominant_weight(self):
        w = jnp.asarray([1e6, 1.0, 1.0, 1.0, 1.0, 1.0])
        p, capped = prob_alloc(w, 3, 0.0)
        assert float(p[0]) == pytest.approx(1.0, abs=1e-5)
        assert bool(capped[0]) and not bool(capped[1:].any())


class TestSampling:
    def test_plackett_luce_returns_k_distinct(self):
        p, _ = prob_alloc(jnp.asarray(np.random.default_rng(0).gamma(1, 1, 30).astype(np.float32)), 8, 0.1 * 8 / 30)
        idx = sample_selection(jax.random.PRNGKey(0), p, 8, "plackett_luce")
        assert len(set(np.asarray(idx).tolist())) == 8

    def test_systematic_inclusion_probabilities_exact(self):
        rng = np.random.default_rng(1)
        p, _ = prob_alloc(jnp.asarray(rng.gamma(0.5, 2, 16).astype(np.float32)), 5, 0.2 * 5 / 16)
        inc = inclusion_probability_mc(jax.random.PRNGKey(1), p, 5, 3000, "systematic")
        # Madow sampling: E[1{i in A}] == p_i (3-sigma MC tolerance)
        tol = 3 * np.sqrt(np.asarray(p) * (1 - np.asarray(p)) / 3000) + 1e-3
        assert (np.abs(np.asarray(inc) - np.asarray(p)) <= tol).all()

    def test_systematic_beats_plackett_luce_on_inclusion_error(self):
        rng = np.random.default_rng(2)
        p, _ = prob_alloc(jnp.asarray(rng.gamma(0.3, 5, 20).astype(np.float32)), 6, 0.0)
        err = {}
        for m in ("plackett_luce", "systematic"):
            inc = inclusion_probability_mc(jax.random.PRNGKey(2), p, 6, 2000, m)
            err[m] = float(jnp.abs(inc - p).max())
        assert err["systematic"] < err["plackett_luce"]


class TestE3CS:
    def test_learns_stable_clients(self):
        K, k, T = 40, 8, 300
        rho = jnp.asarray(paper_success_rates(K))
        vol = BernoulliVolatility(rho)

        def step(carry, key):
            stt, vs = carry
            k1, k2 = jax.random.split(key)
            x, vs = vol.sample(k1, vs)
            stt, idx, mask, p = e3cs_round(stt, k2, x, k, jnp.float32(0.0), 0.5)
            return (stt, vs), mask

        (_, _), masks = jax.lax.scan(step, (e3cs_init(K), vol.init_state()), jax.random.split(jax.random.PRNGKey(0), T))
        per_class = np.asarray(masks.sum(0)).reshape(4, -1).sum(1)
        assert per_class[3] > 3 * per_class[0]  # rho=.9 class dominates rho=.1

    def test_fairness_quota_floor_respected_in_expectation(self):
        K, k = 20, 5
        sigma = 0.8 * k / K
        state = e3cs_init(K)
        # skew weights heavily, then check allocation still >= sigma
        state = state._replace(logw=jnp.linspace(0, 10, K))
        from repro.core.selection import e3cs_probs

        p, _ = e3cs_probs(state, k, jnp.float32(sigma))
        assert float(p.min()) >= sigma - 1e-6

    def test_regret_below_theorem1_bound(self):
        # adversarial-ish sequence: class success flips mid-horizon
        K, k, T = 16, 4, 400
        rng = np.random.default_rng(0)
        rho1 = np.concatenate([np.full(8, 0.9), np.full(8, 0.1)])
        rho2 = np.concatenate([np.full(8, 0.1), np.full(8, 0.9)])
        xs = np.stack([rng.binomial(1, rho1 if t < T // 2 else rho2) for t in range(T)]).astype(np.float32)
        sigma = 0.2 * k / K
        eta = theorem1_eta(K, k, np.full(T, sigma))
        state = e3cs_init(K)
        ps = []
        key = jax.random.PRNGKey(3)
        for t in range(T):
            key, sub = jax.random.split(key)
            state, idx, mask, p = e3cs_round(state, sub, jnp.asarray(xs[t]), k, jnp.float32(sigma), eta)
            ps.append(np.asarray(p))
        R = regret(np.stack(ps), xs, k, np.full(T, sigma), mode="static")
        bound = theorem1_bound(K, k, np.full(T, sigma), eta)
        assert R <= bound, (R, bound)

    def test_quota_schedules_bounded(self):
        for name in ("const", "inc", "linear", "cosine"):
            q = make_quota_schedule(name, 20, 100, 400, frac=0.7)
            vals = [float(q(jnp.asarray(t))) for t in [0, 100, 399]]
            assert all(0 <= v <= 20 / 100 + 1e-6 for v in vals), (name, vals)

    def test_e3cs_inc_schedule_switches_at_T4(self):
        q = make_quota_schedule("inc", 20, 100, 400)
        assert float(q(jnp.asarray(99))) == 0.0
        assert float(q(jnp.asarray(100))) == pytest.approx(0.2)


class TestOracle:
    def test_per_round_oracle_upper_bounds_static(self):
        rng = np.random.default_rng(5)
        xs = rng.binomial(1, 0.5, (50, 12)).astype(np.float32)
        assert oracle_cep(xs, 4, np.zeros(50), "per_round") >= oracle_cep(xs, 4, np.zeros(50), "static") - 1e-9

    def test_full_fairness_oracle_equals_uniform(self):
        xs = np.ones((10, 8), np.float32)
        sigma = np.full(10, 4 / 8)
        # sigma = k/K: everyone gets k/K, CEP* = T*k
        assert oracle_cep(xs, 4, sigma, "static") == pytest.approx(40.0)
