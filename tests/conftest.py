import os

# The K-sharded engine tests (tests/test_sharded.py) need a multi-device
# host; XLA only honours this before jax initialises its backend, so it must
# be set here, ahead of any jax import.  No-op when the operator already
# exported XLA_FLAGS (the tests then skip if fewer than 8 devices exist).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest

try:  # optional dev dependency (see requirements-dev.txt)
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    # Fallback shim: `from hypothesis import given, settings, strategies as st`
    # keeps importing, but every @given test is skipped with a clear reason.
    # Non-property tests in the same modules still run.
    import sys
    import types

    HAVE_HYPOTHESIS = False
    _skip = pytest.mark.skip(reason="hypothesis not installed (pip install -r requirements-dev.txt)")

    class _Strategy:
        """Inert stand-in for hypothesis strategy objects."""

        def __init__(self, *a, **k):
            pass

        def __call__(self, *a, **k):  # @st.composite-decorated fns get called
            return self

        def __getattr__(self, name):  # .map/.filter/.flatmap chains
            return _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            return _skip(fn)

        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    for _name in (
        "integers", "floats", "lists", "booleans", "sampled_from", "tuples",
        "just", "one_of", "composite", "data",
    ):
        setattr(_st, _name, _Strategy())
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *_a, **_k: True
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False, help="run slow tests")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
