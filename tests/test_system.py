"""End-to-end behaviour tests for the paper's system: the qualitative claims
of the paper reproduced at simulation scale (selection-only, fast).

These are the paper's §VI-B1 numerical results as assertions:
  * CEP ordering: FedCS > E3CS-0 > E3CS-0.5 > E3CS-0.8 > Random  (Fig. 4)
  * fairness ordering (Jain index) is the reverse                (Fig. 3)
  * E3CS-inc switches from greedy to fair at T/4                 (Fig. 4 top)
  * pow-d favours lossy (failure-prone) clients                  (Fig. 3 analysis)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.fairness import jain_index
from repro.core.selection import make_quota_schedule
from repro.core.volatility import BernoulliVolatility, paper_success_rates
from repro.fl.round import init_server_state, make_select_fn
from repro.core.selection import e3cs_update, selection_mask

K, k, T = 100, 20, 600


def run_selection_sim(scheme, quota="const", frac=0.0, T=T, seed=0):
    """Selection-only simulation (no model training) — mirrors Fig. 3/4."""
    fl = FLConfig(K=K, k=k, rounds=T, scheme=scheme, quota=quota, quota_frac=frac)
    rho = jnp.asarray(paper_success_rates(K))
    vol = BernoulliVolatility(rho)
    quota_fn = make_quota_schedule(quota, k, K, T, frac)
    select = jax.jit(make_select_fn(fl, quota_fn, rho))
    state = init_server_state({}, K, vol.init_state())
    key = jax.random.PRNGKey(seed)
    masks, xs = [], []
    for t in range(T):
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        x, vs = vol.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        e3cs = state.e3cs
        if scheme == "e3cs":
            e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        # pow-d loss proxy: failure-prone clients have higher loss (paper's analysis)
        loss_cache = jnp.where(mask > 0, 1.0 - x, state.loss_cache)
        state = state._replace(
            e3cs=e3cs, vol_state=vs, t=state.t + 1, sel_counts=state.sel_counts + mask, loss_cache=loss_cache
        )
        masks.append(np.asarray(mask))
        xs.append(np.asarray(x))
    masks, xs = np.stack(masks), np.stack(xs)
    return dict(
        cep=float((masks * xs).sum()),
        jain=float(jain_index(jnp.asarray(masks.sum(0)))),
        counts=masks.sum(0),
        succ_ratio=float((masks * xs).sum() / masks.sum()),
    )


@pytest.fixture(scope="module")
def sims():
    return {
        "fedcs": run_selection_sim("fedcs"),
        "e3cs-0": run_selection_sim("e3cs", frac=0.0),
        "e3cs-0.5": run_selection_sim("e3cs", frac=0.5),
        "e3cs-0.8": run_selection_sim("e3cs", frac=0.8),
        "random": run_selection_sim("random"),
        "pow_d": run_selection_sim("pow_d"),
    }


def test_cep_ordering_matches_fig4(sims):
    assert sims["fedcs"]["cep"] >= sims["e3cs-0"]["cep"] > sims["e3cs-0.5"]["cep"]
    assert sims["e3cs-0.5"]["cep"] > sims["e3cs-0.8"]["cep"] > sims["random"]["cep"] * 0.99


def test_fairness_ordering_matches_fig3(sims):
    assert sims["random"]["jain"] > sims["e3cs-0.8"]["jain"] > sims["e3cs-0.5"]["jain"]
    assert sims["e3cs-0.5"]["jain"] > sims["e3cs-0"]["jain"] > sims["fedcs"]["jain"]


def test_e3cs0_learns_most_reliable_class(sims):
    counts = sims["e3cs-0"]["counts"].reshape(4, -1).sum(1)
    assert counts[3] > 0.7 * sims["e3cs-0"]["counts"].sum()


def test_fedcs_dedicates_to_20_of_25_class1(sims):
    counts = sims["fedcs"]["counts"]
    assert (counts[75:] > 0).sum() >= 20 and counts[:75].sum() == 0


def test_powd_prefers_failure_prone_clients(sims):
    counts = sims["pow_d"]["counts"].reshape(4, -1).sum(1)
    assert counts[0] > counts[3]  # rho=0.1 class selected more than rho=0.9


def test_e3cs_inc_success_ratio_drops_after_T4():
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="inc")
    rho = jnp.asarray(paper_success_rates(K))
    vol = BernoulliVolatility(rho)
    quota_fn = make_quota_schedule("inc", k, K, T, 0)
    select = jax.jit(make_select_fn(fl, quota_fn, rho))
    state = init_server_state({}, K, vol.init_state())
    key = jax.random.PRNGKey(0)
    succ = []
    for t in range(T):
        key, k1, k2 = jax.random.split(key, 3)
        idx, p, capped, sigma = select(state, k1)
        x, vs = vol.sample(k2, state.vol_state)
        mask = selection_mask(idx, K)
        e3cs = e3cs_update(state.e3cs, p, capped, mask, x, k, sigma, fl.eta)
        state = state._replace(e3cs=e3cs, vol_state=vs, t=state.t + 1)
        succ.append(float((mask * x).sum() / k))
    early = np.mean(succ[T // 8 : T // 4])  # after learning, before the switch
    late = np.mean(succ[T // 2 :])  # uniform selection -> mean(rho) = 0.475
    assert early > 0.8 and late < 0.62


def test_selection_respects_cardinality(sims):
    for name, s in sims.items():
        assert s["counts"].sum() == T * k, name
