"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gumbel_topk import gumbel_topk_kernel_call

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


FLASH_CASES = [
    # (B, S, H, KV, hd, window, dtype)
    (2, 64, 4, 2, 32, 0, jnp.float32),
    (1, 128, 8, 1, 64, 0, jnp.float32),  # MQA
    (2, 96, 4, 4, 32, 0, jnp.float32),  # MHA, non-pow2 seq
    (1, 256, 4, 2, 64, 64, jnp.float32),  # sliding window
    (2, 64, 4, 2, 32, 0, jnp.bfloat16),
    (1, 100, 2, 2, 128, 33, jnp.float32),  # ragged seq + window
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c) for c in FLASH_CASES])
def test_flash_attention_matches_ref(case):
    B, S, H, KV, hd, win, dt = case
    q = _randn((B, S, H, hd), dt)
    k = _randn((B, S, KV, hd), dt)
    v = _randn((B, S, KV, hd), dt)
    out = ops.flash_attention(q, k, v, causal=True, window=win, block_q=32, block_k=32)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=win)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol, rtol=tol)


SSD_CASES = [
    # (b, S, H, P, G, N, chunk, dtype)
    (2, 64, 4, 16, 2, 32, 16, jnp.float32),
    (1, 80, 2, 32, 1, 16, 32, jnp.float32),  # ragged S vs chunk
    (2, 128, 8, 16, 8, 8, 64, jnp.float32),  # groups == heads
    (1, 32, 4, 64, 2, 128, 32, jnp.float32),  # wide state
]


@pytest.mark.parametrize("case", SSD_CASES, ids=[str(c) for c in SSD_CASES])
def test_ssd_scan_matches_sequential_ref(case):
    b, S, H, P, G, N, chunk, dt = case
    x = _randn((b, S, H, P), dt)
    dtv = jnp.asarray(RNG.uniform(0.01, 0.4, (b, S, H)), dt)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (H,)), dt)
    B = _randn((b, S, G, N), dt)
    C = _randn((b, S, G, N), dt)
    y, st = ops.ssd_scan(x, dtv, A, B, C, chunk=chunk)
    y_ref, st_ref = ref.ssd_scan_ref(x, dtv, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=5e-4, rtol=5e-4)


def test_ssd_kernel_matches_model_chunked_path():
    from repro.models.ssm import ssd_chunked

    b, S, H, P, G, N = 1, 96, 4, 16, 2, 24
    x = _randn((b, S, H, P), jnp.float32)
    dtv = jnp.asarray(RNG.uniform(0.01, 0.4, (b, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.3, 2.0, (H,)), jnp.float32)
    B = _randn((b, S, G, N), jnp.float32)
    C = _randn((b, S, G, N), jnp.float32)
    y1, st1 = ops.ssd_scan(x, dtv, A, B, C, chunk=32)
    y2, st2 = ssd_chunked(x, dtv, A, B, C, 32, return_final=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("K,k,tile", [(100, 10, 32), (1000, 20, 256), (513, 7, 128)])
def test_gumbel_topk_matches_lax_topk(K, k, tile):
    scores = jnp.asarray(RNG.normal(size=(K,)), jnp.float32)
    vals, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=True)
    idx_ref = ref.gumbel_topk_ref(scores, k)
    assert sorted(np.asarray(idx).tolist()) == sorted(np.asarray(idx_ref).tolist())


# Non-divisible tile sizes on purpose: every K here leaves a ragged final tile
# (K=7 pads 7 -> 8; 100 % 48 != 0; 10000 % 4096 != 0).
GUMBEL_CASES = [(7, 3, 8192), (7, 7, 8192), (100, 20, 48), (10000, 64, 4096), (10000, 200, 8192)]


@pytest.mark.parametrize("K,k,tile", GUMBEL_CASES, ids=[f"K{K}-k{k}-t{t}" for K, k, t in GUMBEL_CASES])
def test_gumbel_topk_perturbed_scores_agree_with_lax(K, k, tile):
    """Agreement with jax.lax.top_k on actual Gumbel-perturbed allocations."""
    p = jnp.asarray(RNG.gamma(1.0, 1.0, K).astype(np.float32))
    p = p / p.sum() * k
    g = jax.random.gumbel(jax.random.PRNGKey(K + k), p.shape, jnp.float32)
    scores = jnp.log(jnp.maximum(p, 1e-20)) + g
    vals, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=True)
    _, idx_ref = jax.lax.top_k(scores, k)
    idx = np.asarray(idx)
    assert sorted(idx.tolist()) == sorted(np.asarray(idx_ref).tolist())
    # duplicate-free guarantee and in-range indices
    assert len(set(idx.tolist())) == k
    assert (idx >= 0).all() and (idx < K).all()
    # values returned descending and consistent with the indices
    v = np.asarray(vals)
    assert (np.diff(v) <= 1e-6).all()
    np.testing.assert_allclose(v, np.asarray(scores)[idx], atol=1e-6)


@pytest.mark.parametrize("K,k,tile", [(7, 3, 8), (100, 20, 48), (10000, 64, 4096)])
def test_fused_gumbel_topk_matches_unfused(K, k, tile):
    """The fused perturb+topk kernel must agree with the jnp composition."""
    from repro.kernels.e3cs_tiles import fused_gumbel_topk_kernel_call

    p = jnp.asarray(RNG.gamma(1.0, 1.0, K).astype(np.float32))
    p = p / p.sum() * k
    u = jax.random.uniform(jax.random.PRNGKey(1), p.shape, jnp.float32)
    _, idx = fused_gumbel_topk_kernel_call(p, u, k, tile=tile, interpret=True)
    g = -jnp.log(-jnp.log(jnp.clip(u, 1e-20, 1.0 - 1e-7)))
    _, idx_ref = jax.lax.top_k(jnp.log(jnp.maximum(p, 1e-20)) + g, k)
    idx = np.asarray(idx)
    assert sorted(idx.tolist()) == sorted(np.asarray(idx_ref).tolist())
    assert len(set(idx.tolist())) == k


@pytest.mark.parametrize("K,k,tile", [(100, 20, 48), (5000, 100, 1024)])
def test_e3cs_update_kernel_matches_reference(K, k, tile):
    from repro.core.selection import E3CSState, e3cs_update, prob_alloc
    from repro.kernels.e3cs_tiles import e3cs_update_kernel_call

    logw = jnp.asarray(RNG.normal(0, 1, K).astype(np.float32))
    sigma = jnp.float32(0.3 * k / K)
    eta = 0.5
    w = jnp.exp(logw - jnp.max(logw))
    p, capped = prob_alloc(w, k, sigma)
    mask = jnp.zeros(K).at[jax.lax.top_k(p, k)[1]].set(1.0)
    x = jnp.asarray((RNG.random(K) < 0.6).astype(np.float32))
    expect = e3cs_update(E3CSState(logw=logw, t=jnp.zeros((), jnp.int32)), p, capped, mask, x, k, sigma, eta)
    scale = (k - K * sigma) * eta / K
    out, tmax = e3cs_update_kernel_call(logw, p, mask, x, capped.astype(jnp.float32), scale, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out - jnp.max(tmax)), np.asarray(expect.logw), atol=1e-6)


def test_gumbel_topk_sampler_distribution():
    # inclusion frequency should favour high-probability arms
    p = jnp.asarray([0.05] * 16 + [0.8] * 4, jnp.float32)
    p = p / p.sum() * 4
    hits = np.zeros(20)
    for i in range(300):
        idx = ops.gumbel_topk_sample(jax.random.PRNGKey(i), p, 4, tile=32)
        hits[np.asarray(idx)] += 1
    assert hits[16:].mean() > 4 * hits[:16].mean()
