"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gumbel_topk import gumbel_topk_kernel_call

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("K,k,tile", [(100, 10, 32), (1000, 20, 256), (513, 7, 128)])
def test_gumbel_topk_matches_lax_topk(K, k, tile):
    scores = jnp.asarray(RNG.normal(size=(K,)), jnp.float32)
    vals, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=True)
    idx_ref = ref.gumbel_topk_ref(scores, k)
    assert sorted(np.asarray(idx).tolist()) == sorted(np.asarray(idx_ref).tolist())


# Non-divisible tile sizes on purpose: every K here leaves a ragged final tile
# (K=7 pads 7 -> 8; 100 % 48 != 0; 10000 % 4096 != 0).
GUMBEL_CASES = [(7, 3, 8192), (7, 7, 8192), (100, 20, 48), (10000, 64, 4096), (10000, 200, 8192)]


@pytest.mark.parametrize("K,k,tile", GUMBEL_CASES, ids=[f"K{K}-k{k}-t{t}" for K, k, t in GUMBEL_CASES])
def test_gumbel_topk_perturbed_scores_agree_with_lax(K, k, tile):
    """Agreement with jax.lax.top_k on actual Gumbel-perturbed allocations."""
    p = jnp.asarray(RNG.gamma(1.0, 1.0, K).astype(np.float32))
    p = p / p.sum() * k
    g = jax.random.gumbel(jax.random.PRNGKey(K + k), p.shape, jnp.float32)
    scores = jnp.log(jnp.maximum(p, 1e-20)) + g
    vals, idx = gumbel_topk_kernel_call(scores, k, tile=tile, interpret=True)
    _, idx_ref = jax.lax.top_k(scores, k)
    idx = np.asarray(idx)
    assert sorted(idx.tolist()) == sorted(np.asarray(idx_ref).tolist())
    # duplicate-free guarantee and in-range indices
    assert len(set(idx.tolist())) == k
    assert (idx >= 0).all() and (idx < K).all()
    # values returned descending and consistent with the indices
    v = np.asarray(vals)
    assert (np.diff(v) <= 1e-6).all()
    np.testing.assert_allclose(v, np.asarray(scores)[idx], atol=1e-6)


@pytest.mark.parametrize("K,k,tile", [(7, 3, 8), (100, 20, 48), (10000, 64, 4096)])
def test_fused_gumbel_topk_matches_unfused(K, k, tile):
    """The fused perturb+topk kernel must agree with the jnp composition."""
    from repro.kernels.e3cs_tiles import fused_gumbel_topk_kernel_call

    p = jnp.asarray(RNG.gamma(1.0, 1.0, K).astype(np.float32))
    p = p / p.sum() * k
    u = jax.random.uniform(jax.random.PRNGKey(1), p.shape, jnp.float32)
    _, idx = fused_gumbel_topk_kernel_call(p, u, k, tile=tile, interpret=True)
    g = -jnp.log(-jnp.log(jnp.clip(u, 1e-20, 1.0 - 1e-7)))
    _, idx_ref = jax.lax.top_k(jnp.log(jnp.maximum(p, 1e-20)) + g, k)
    idx = np.asarray(idx)
    assert sorted(idx.tolist()) == sorted(np.asarray(idx_ref).tolist())
    assert len(set(idx.tolist())) == k


@pytest.mark.parametrize("K,k,tile", [(100, 20, 48), (5000, 100, 1024)])
def test_e3cs_update_kernel_matches_reference(K, k, tile):
    from repro.core.selection import E3CSState, e3cs_update, prob_alloc
    from repro.kernels.e3cs_tiles import e3cs_update_kernel_call

    logw = jnp.asarray(RNG.normal(0, 1, K).astype(np.float32))
    sigma = jnp.float32(0.3 * k / K)
    eta = 0.5
    w = jnp.exp(logw - jnp.max(logw))
    p, capped = prob_alloc(w, k, sigma)
    mask = jnp.zeros(K).at[jax.lax.top_k(p, k)[1]].set(1.0)
    x = jnp.asarray((RNG.random(K) < 0.6).astype(np.float32))
    expect = e3cs_update(E3CSState(logw=logw, t=jnp.zeros((), jnp.int32)), p, capped, mask, x, k, sigma, eta)
    scale = (k - K * sigma) * eta / K
    out, tmax = e3cs_update_kernel_call(logw, p, mask, x, capped.astype(jnp.float32), scale, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out - jnp.max(tmax)), np.asarray(expect.logw), atol=1e-6)


def test_gumbel_topk_sampler_distribution():
    # inclusion frequency should favour high-probability arms
    p = jnp.asarray([0.05] * 16 + [0.8] * 4, jnp.float32)
    p = p / p.sum() * 4
    hits = np.zeros(20)
    for i in range(300):
        idx = ops.gumbel_topk_sample(jax.random.PRNGKey(i), p, 4, tile=32)
        hits[np.asarray(idx)] += 1
    assert hits[16:].mean() > 4 * hits[:16].mean()


# ---------------------------------------------------------------------------
# Dispatch routing: REPRO_INTERPRET is read per call, autotune tiles per size
# ---------------------------------------------------------------------------


def test_repro_interpret_flip_takes_effect_mid_process(monkeypatch):
    """Flipping REPRO_INTERPRET between calls must change the route without a
    process restart (the old wrappers read the env at trace time and froze
    it into the jit cache)."""
    from repro.kernels import ops as ops_mod

    calls = []
    real = ops_mod.gumbel_topk_kernel_call

    def spy(scores, k, tile=8192, interpret=False):
        calls.append(interpret)
        return real(scores, k, tile=tile, interpret=interpret)

    monkeypatch.setattr(ops_mod, "gumbel_topk_kernel_call", spy)
    # unique K so each route change compiles fresh (jit caches per static combo)
    p = jnp.asarray(RNG.gamma(1.0, 1.0, 263).astype(np.float32))
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    idx_ref = ops.gumbel_topk_sample(jax.random.PRNGKey(0), p, 5, tile=64)
    assert calls == []  # forced-ref mode: the kernel is never invoked
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    idx_kern = ops.gumbel_topk_sample(jax.random.PRNGKey(0), p, 5, tile=64)
    assert calls and calls[-1] is True  # flipped: kernel path, interpret on CPU
    assert sorted(np.asarray(idx_kern).tolist()) == sorted(np.asarray(idx_ref).tolist())
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    n = len(calls)
    ops.gumbel_topk_sample(jax.random.PRNGKey(1), p, 5, tile=64)
    assert len(calls) == n  # flipped back: ref again


def test_repro_interpret_rejects_garbage(monkeypatch):
    from repro.kernels.dispatch import interpret_mode

    monkeypatch.setenv("REPRO_INTERPRET", "maybe")
    with pytest.raises(ValueError):
        interpret_mode()


def test_dispatch_consults_autotune_cache(monkeypatch, tmp_path):
    """tile=None must resolve through the on-disk autotune cache."""
    import json

    from repro.kernels import autotune
    from repro.kernels import ops as ops_mod

    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    key = autotune.cache_key("gumbel_topk", 263)
    (tmp_path / "autotune.json").write_text(json.dumps({key: {"tile": 48}}))

    seen = []
    real = ops_mod.gumbel_topk_kernel_call

    def spy(scores, k, tile=8192, interpret=False):
        seen.append(tile)
        return real(scores, k, tile=tile, interpret=interpret)

    monkeypatch.setattr(ops_mod, "gumbel_topk_kernel_call", spy)
    p = jnp.asarray(RNG.gamma(1.0, 1.0, 263).astype(np.float32))
    ops.gumbel_topk_sample(jax.random.PRNGKey(0), p, 5)  # tile=None -> cache
    assert seen == [48]
    # a size outside the cached bucket falls back to the defaults, recorded cold
    autotune.reset_cold()
    bigp = jnp.asarray(RNG.gamma(1.0, 1.0, 3001).astype(np.float32))
    ops.gumbel_topk_sample(jax.random.PRNGKey(0), bigp, 5)
    assert seen[-1] == autotune.DEFAULTS["gumbel_topk"]["tile"]
    assert autotune.cache_key("gumbel_topk", 3001) in autotune.cold_keys()
