"""Docs-as-tests: the tagged snippets in README.md and docs/serving.md run.

Any fenced ``python`` block immediately preceded by ``<!-- test: name -->``
is extracted and executed in a fresh namespace — so the README quickstart
and the serving client example cannot silently rot.  Snippets are expected
to be self-contained, CPU-cheap, and to ``assert`` their own success.

To exempt a block from execution, simply don't tag it.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "docs/serving.md"]

_SNIPPET = re.compile(
    r"<!--\s*test:\s*(?P<name>[\w-]+)\s*-->\s*\n```python\n(?P<code>.*?)```",
    re.DOTALL,
)


def _collect():
    found = []
    for rel in DOC_FILES:
        text = (ROOT / rel).read_text()
        for m in _SNIPPET.finditer(text):
            found.append(pytest.param(rel, m["name"], m["code"], id=f"{rel}::{m['name']}"))
    return found


SNIPPETS = _collect()


def test_docs_have_tagged_snippets():
    """Both top-level docs carry at least one executable snippet — removing
    the tags (and thereby the coverage) is itself a failure."""
    files = {rel for rel, _, _ in (p.values for p in SNIPPETS)}
    assert set(DOC_FILES) <= files, f"no tagged snippets found in {set(DOC_FILES) - files}"


@pytest.mark.parametrize("rel,name,code", SNIPPETS)
def test_doc_snippet_runs(rel, name, code):
    exec(compile(code, f"{rel}:{name}", "exec"), {"__name__": f"doctest_{name}"})
