"""Optimizers, schedules, data partitioners, pipeline, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, restore, save
from repro.data import (
    ClientStore,
    make_image_dataset,
    partition_dirichlet,
    partition_iid,
    partition_primary_label,
)
from repro.optim import adamw, cosine_decay, sgd, warmup_cosine


class TestOptim:
    def test_sgd_momentum_matches_closed_form(self):
        opt = sgd(0.1, 0.9)
        p = {"w": jnp.asarray([1.0])}
        st_ = opt.init(p)
        g = {"w": jnp.asarray([1.0])}
        p, st_ = opt.update(p, g, st_, 0)  # m=1, p=1-0.1
        np.testing.assert_allclose(np.asarray(p["w"]), [0.9])
        p, st_ = opt.update(p, g, st_, 1)  # m=1.9, p=0.9-0.19
        np.testing.assert_allclose(np.asarray(p["w"]), [0.71], rtol=1e-6)

    def test_adamw_decreases_quadratic(self):
        opt = adamw(0.1, weight_decay=0.0)
        p = {"w": jnp.asarray([5.0])}
        s = opt.init(p)
        for i in range(50):
            g = {"w": 2 * p["w"]}
            p, s = opt.update(p, g, s, i)
        assert abs(float(p["w"][0])) < 1.0

    def test_schedules(self):
        cd = cosine_decay(1.0, 100)
        assert float(cd(0)) == pytest.approx(1.0)
        assert float(cd(100)) == pytest.approx(0.1, abs=1e-6)
        wc = warmup_cosine(1.0, 10, 110)
        assert float(wc(0)) == pytest.approx(0.0)
        assert float(wc(10)) == pytest.approx(1.0)


class TestData:
    def test_primary_label_partition_is_skewed(self):
        data = make_image_dataset(10, (8, 8, 1), 4000, 100, seed=0)
        idxs = partition_primary_label(data["y"], K=20, per_client=100, primary_frac=0.8, seed=0)
        for c in idxs:
            labels, counts = np.unique(data["y"][c], return_counts=True)
            assert counts.max() >= 0.7 * 100  # dominant primary label

    def test_iid_partition_is_even(self):
        data = make_image_dataset(10, (8, 8, 1), 4000, 100, seed=0)
        idxs = partition_iid(data["y"], K=20, per_client=200, seed=0)
        for c in idxs:
            _, counts = np.unique(data["y"][c], return_counts=True)
            assert counts.max() < 0.35 * 200

    def test_dirichlet_partition_alpha_controls_skew(self):
        data = make_image_dataset(10, (8, 8, 1), 4000, 100, seed=0)
        skewed = partition_dirichlet(data["y"], 10, 200, alpha=0.05, seed=0)
        even = partition_dirichlet(data["y"], 10, 200, alpha=100.0, seed=0)

        def top_frac(idxs):
            return np.mean([np.unique(data["y"][c], return_counts=True)[1].max() / len(c) for c in idxs])

        assert top_frac(skewed) > top_frac(even) + 0.2

    def test_image_dataset_is_learnable_structure(self):
        # class prototypes must be separable: nearest-prototype acc >> chance
        d = make_image_dataset(10, (8, 8, 1), 2000, 500, seed=0, noise=0.5)
        protos = np.stack([d["x"][d["y"] == c].mean(0) for c in range(10)])
        pred = np.argmin(((d["x_test"][:, None] - protos[None]) ** 2).sum((2, 3, 4)), 1)
        assert (pred == d["y_test"]).mean() > 0.5

    def test_round_batches_static_shapes(self):
        data = make_image_dataset(5, (8, 8, 1), 1000, 100, seed=0)
        idxs = partition_iid(data["y"], 10, 50, seed=0)
        store = ClientStore(data, idxs)
        epochs = np.asarray([1, 2] * 5)
        xb, yb, mask = store.round_batches([0, 3, 5], epochs, batch_size=10, n_steps=10)
        assert xb.shape == (3, 10, 10, 8, 8, 1) and mask.shape == (3, 10)
        assert mask.sum(1).max() <= 10


class TestCheckpoint:
    def test_roundtrip_mixed_dtypes(self):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
            "c": {"d": jnp.asarray([1, 2, 3], jnp.int32)},
        }
        with tempfile.TemporaryDirectory() as d:
            path = save(os.path.join(d, "ckpt_7.ckpt"), tree, step=7)
            back = restore(path, tree)
            for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
                np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
            assert latest_checkpoint(d) == path
