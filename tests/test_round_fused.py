"""Fused round path: the ``fused=True`` engine must reproduce the staged
goldens bit-for-bit, under both the default dispatch (jnp references on CPU)
and ``REPRO_INTERPRET=1`` (Pallas kernels in interpret mode); the kernels
themselves are pinned against the ``ref.py`` oracles at ragged sizes and
across tile choices.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.volatility import CompletionLag, make_volatility, paper_success_rates
from repro.engine import scan_sim
from repro.engine.round_program import RoundProgram
from repro.engine.scan_sim import async_selection_sim, scan_selection_sim
from repro.engine.sharded import masked_prob_alloc_scalars, sharded_selection_sim
from repro.kernels import ref
from repro.kernels.round_fused import fused_alloc_select, fused_perturb_select, fused_round_tail
from repro.scenarios.replay import pack_trace

K, k, T, SEED, FRAC = 128, 16, 50, 3, 0.5
GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden", "round_program_goldens.npz"))


@pytest.fixture(scope="module")
def mesh8():
    from repro.launch.mesh import make_host_mesh

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8 (set in conftest)")
    return make_host_mesh(8)


@pytest.fixture(scope="module")
def mesh1():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(1)


def _rho():
    return paper_success_rates(K)


def _lag_model():
    return CompletionLag(
        make_volatility("bernoulli", _rho()), p_late=0.7, lag_decay=0.5, max_lag=2
    )


def _dense_xs():
    return np.random.default_rng(11).binomial(1, 0.6, (T, K)).astype(np.float32)


class TestFusedSyncGoldens:
    """fused=True, D=1: identical masks to the staged pre-refactor goldens."""

    def test_sort_allocator(self):
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, fused=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d1_e3cs_counts"])

    def test_bisect_allocator(self):
        out = scan_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, allocator="bisect", fused=True
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_e3cs_bisect_masks"])

    def test_dense_replay(self):
        out = scan_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, xs_override=_dense_xs(), fused=True
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_dense_masks"])

    def test_packed_replay(self):
        packed = pack_trace(_dense_xs())
        out = scan_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed, fused=True
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_packed_masks"])

    @pytest.mark.parametrize("allocator,key", [("sort", "sync_d1_e3cs_masks"),
                                               ("bisect", "sync_d1_e3cs_bisect_masks")])
    def test_interpret_kernels_reproduce_goldens(self, monkeypatch, allocator, key):
        # REPRO_INTERPRET=1 swaps the jnp references for the Pallas kernels in
        # interpret mode INSIDE the scanned round — the goldens must survive.
        monkeypatch.setenv("REPRO_INTERPRET", "1")
        scan_sim._compiled_runner.cache_clear()  # route is frozen at trace time
        try:
            out = scan_selection_sim(
                "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, allocator=allocator, fused=True
            )
        finally:
            scan_sim._compiled_runner.cache_clear()  # don't leak interpret traces
        assert np.array_equal(pack_trace(out["masks"]), GOLD[key])

    def test_interpret_kernels_packed_replay(self, monkeypatch):
        monkeypatch.setenv("REPRO_INTERPRET", "1")
        packed = pack_trace(_dense_xs())  # override path builds a fresh trace per call
        out = scan_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed, fused=True
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_packed_masks"])


class TestFusedAsyncGoldens:
    """fused=True, S=2, D=1: the async staleness machinery runs inside the
    tail kernel (lag decode, credit-ring shift, late feedback)."""

    def _kw(self):
        return dict(K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5, rho=_rho())

    def test_generated(self):
        out = async_selection_sim("e3cs", lag_model=_lag_model(), fused=True, **self._kw())
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_e3cs_masks"])
        assert np.array_equal(out["lags"].astype(np.int8), GOLD["async_d1_e3cs_lags"])
        assert np.array_equal(out["counts"], GOLD["async_d1_e3cs_counts"])
        assert np.float32(out["cep"]) == GOLD["async_d1_e3cs_cep"]
        assert np.array_equal(out["on_time"], GOLD["async_d1_e3cs_on_time"])
        assert np.array_equal(out["stale"], GOLD["async_d1_e3cs_stale"])

    def test_packed_lags_override(self):
        lp = GOLD["lag_trace_packed"]
        out = async_selection_sim(
            "e3cs", lag_model=_lag_model(), packed_lag_override=lp, fused=True, **self._kw()
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_replay_masks"])
        assert np.float32(out["cep"]) == GOLD["async_d1_replay_cep"]

    def test_late_credit_matches_staged(self):
        # no golden exists for late_credit; pin fused == staged directly
        outs = [
            async_selection_sim("e3cs", lag_model=_lag_model(), feedback="late_credit",
                                fused=f, **self._kw())
            for f in (True, False)
        ]
        assert np.array_equal(outs[0]["masks"], outs[1]["masks"])
        np.testing.assert_array_equal(outs[0]["final_logw"], outs[1]["final_logw"])
        assert outs[0]["cep"] == outs[1]["cep"]

    def test_interpret_kernels_packed_lags(self, monkeypatch):
        monkeypatch.setenv("REPRO_INTERPRET", "1")
        lp = GOLD["lag_trace_packed"]
        out = async_selection_sim(
            "e3cs", lag_model=_lag_model(), packed_lag_override=lp, fused=True, **self._kw()
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_replay_masks"])
        assert np.float32(out["cep"]) == GOLD["async_d1_replay_cep"]


class TestFusedSharded:
    """fused=True under the K-sharded engine: the select kernel emits local
    top-k candidates that merge across shards exactly like the staged path."""

    def test_sync_d8_goldens(self, mesh8):
        out = sharded_selection_sim("e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, fused=True)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d8_e3cs_masks"])
        assert np.array_equal(out["counts"], GOLD["sync_d8_e3cs_counts"])

    def test_packed_d8_goldens(self, mesh8):
        packed = pack_trace(_dense_xs())
        out = sharded_selection_sim(
            "e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed, fused=True
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d8_packed_masks"])

    def _async_run(self, mesh, fused, feedback="deadline"):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")
        pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
                          staleness=2, alpha=0.5, feedback=feedback, mesh=mesh, fused=fused)
        run, s0 = pm.build_runner(outputs="full")
        st, masks, lags, *_ = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(GOLD["lag_trace_packed"]))
        return np.asarray(masks)[:, :K], np.asarray(lags)[:, :K], float(st.cep), np.asarray(st.e3cs.logw)

    @pytest.mark.parametrize("feedback", ["deadline", "late_credit"])
    def test_async_d8_matches_staged(self, mesh8, feedback):
        mf, lf, cf, wf = self._async_run(mesh8, True, feedback)
        ms, ls, cs, ws = self._async_run(mesh8, False, feedback)
        assert np.array_equal(mf, ms)
        assert np.array_equal(lf, ls)
        assert cf == cs
        np.testing.assert_array_equal(wf[: K], ws[: K])

    def test_mesh1_matches_local(self, mesh1):
        mf, lf, cf, wf = self._async_run(mesh1, True)
        ml, ll, cl, wl = self._async_run(None, True)
        assert np.array_equal(mf, ml)
        assert np.array_equal(lf, ll)
        assert cf == cl
        np.testing.assert_array_equal(wf[:K], wl)


# ---------------------------------------------------------------------------
# Kernel-level: interpret-mode Pallas vs ref.py oracles, ragged K, tiles
# ---------------------------------------------------------------------------

RAGGED_K = 130  # 130 % 64 != 0: exercises the padded final tile


def _select_inputs(n=RAGGED_K, kk=16, with_active=False):
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.gamma(1.0, 1.0, n).astype(np.float32))
    g = jax.random.gumbel(jax.random.PRNGKey(17), (n,), jnp.float32)
    active = jnp.asarray((rng.random(n) < 0.85).astype(np.float32)) if with_active else None
    if active is not None:
        w = w * active
    sigma = jnp.float32(0.3 * kk / n)
    scalars = masked_prob_alloc_scalars(w, kk, sigma, active=active)
    return w, g, kk, sigma, scalars, active


@pytest.mark.parametrize("with_active", [False, True])
def test_alloc_select_kernel_matches_ref_ragged(with_active):
    w, g, kk, sigma, scalars, active = _select_inputs(with_active=with_active)
    pr, cr, vr, ir = ref.fused_alloc_select_ref(w, g, kk, sigma=sigma, scalars=scalars, active=active)
    pk, ck, vk, ik = fused_alloc_select(
        w, g, kk, sigma=sigma, scalars=scalars, active=active, tile=64, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(ck).astype(bool), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))


def test_perturb_select_kernel_matches_ref_ragged():
    w, g, kk, sigma, scalars, _ = _select_inputs()
    p, *_ = ref.fused_alloc_select_ref(w, g, kk, sigma=sigma, scalars=scalars)
    vr, ir = ref.fused_perturb_select_ref(p, g, kk)
    vk, ik = fused_perturb_select(p, g, kk, tile=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))


def test_select_kernel_tile_invariant():
    w, g, kk, sigma, scalars, _ = _select_inputs()
    outs = [
        fused_alloc_select(w, g, kk, sigma=sigma, scalars=scalars, tile=t, interpret=True)
        for t in (64, 8192)
    ]
    for a, b in zip(outs[0], outs[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _tail_inputs(n=RAGGED_K, kind="bits", S=2, with_active=False, late_fb=False):
    rng = np.random.default_rng(9)
    p = rng.gamma(1.0, 1.0, n).astype(np.float32)
    p = np.clip(p / p.sum() * 16, 0.01, 0.97)
    mask = (rng.random(n) < 0.2).astype(np.float32)
    capped = jnp.asarray(rng.random(n) < 0.1)
    logw = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    loss_cache = jnp.asarray(rng.random(n).astype(np.float32))
    if kind == "bits":
        obs = jnp.asarray(rng.integers(0, 256, (n + 7) // 8, dtype=np.uint8))
    elif kind == "crumbs":
        obs = jnp.asarray(rng.integers(0, 256, (n + 3) // 4, dtype=np.uint8))
    elif kind == "x":
        obs = jnp.asarray((rng.random(n) < 0.6).astype(np.float32))
    else:  # dense lags
        obs = jnp.asarray(rng.integers(0, 3, n, dtype=np.int32))
    credit = jnp.asarray(rng.random((S, n)).astype(np.float32)) if S else None
    fb = jnp.asarray(rng.normal(0, 0.1, (S, n)).astype(np.float32)) if late_fb else None
    active = jnp.asarray((rng.random(n) < 0.9).astype(np.float32)) if with_active else None
    kw = dict(kind=kind, residual=jnp.float32(16.0 - n * 0.02), eta=0.5, K_glob=n,
              decay=tuple(0.5 ** (s + 1) for s in range(S)), active=active)
    args = (obs, mask, jnp.asarray(p), capped, logw, loss_cache, credit, fb)
    return args, kw


TAIL_CASES = [
    ("bits", 0, False, False),
    ("x", 0, False, True),
    ("crumbs", 2, False, False),
    ("crumbs", 2, True, True),
    ("lag", 2, True, False),
]


@pytest.mark.parametrize("kind,S,late_fb,with_active", TAIL_CASES,
                         ids=[f"{c[0]}-S{c[1]}{'-fb' if c[2] else ''}{'-act' if c[3] else ''}"
                              for c in TAIL_CASES])
def test_round_tail_kernel_matches_ref_ragged(kind, S, late_fb, with_active):
    sync = kind in ("bits", "x")
    args, kw = _tail_inputs(kind=kind, S=0 if sync else S, with_active=with_active, late_fb=late_fb)
    want = ref.round_tail_ref(*args, **kw)
    got = fused_round_tail(*args, **kw, tile=64, interpret=True)
    assert set(got) == set(want)
    for key in want:
        np.testing.assert_array_equal(
            np.asarray(got[key]), np.asarray(want[key]), err_msg=f"tail product {key!r}"
        )


def test_round_tail_tile_invariant():
    args, kw = _tail_inputs(kind="crumbs", S=2, late_fb=True)
    a = fused_round_tail(*args, **kw, tile=64, interpret=True)
    b = fused_round_tail(*args, **kw, tile=8192, interpret=True)
    for key in a:
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]), err_msg=key)


class TestFusedConfigValidation:
    def test_rejects_non_e3cs_scheme(self):
        fl = FLConfig(K=32, k=4, rounds=5, scheme="random")
        vol = make_volatility("bernoulli", paper_success_rates(32))
        with pytest.raises(ValueError, match="fused"):
            RoundProgram(fl=fl, vol=vol, rho=None, fused=True)

    def test_rejects_non_gumbel_sampler(self):
        fl = FLConfig(K=32, k=4, rounds=5, scheme="e3cs", sampler="systematic")
        vol = make_volatility("bernoulli", paper_success_rates(32))
        with pytest.raises(ValueError, match="plackett_luce"):
            RoundProgram(fl=fl, vol=vol, rho=None, fused=True)

    def test_from_config_threads_fused(self):
        pm = RoundProgram.from_config(FLConfig(K=32, k=4, rounds=5, scheme="e3cs"), fused=True)
        assert pm.fused
