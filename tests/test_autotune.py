"""Autotune harness: cache round-trip, corrupt-cache degradation, sweep
determinism with an injected timer, and cross-process pickup semantics."""
import json

import pytest

from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _isolated_cache(monkeypatch, tmp_path):
    # every test gets its own cache dir and a cleared memo/cold-set
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path))
    autotune._cache_memo = (None, None, None)
    autotune.reset_cold()
    yield tmp_path
    autotune._cache_memo = (None, None, None)
    autotune.reset_cold()


def test_bucketing_and_key_shape():
    assert autotune._bucket(1) == 1024
    assert autotune._bucket(1024) == 1024
    assert autotune._bucket(1025) == 2048
    assert autotune._bucket(1_000_000) == 2**20
    key = autotune.cache_key("gumbel_topk", 1_000_000, backend="cpu")
    assert key == "gumbel_topk|K1048576|float32|cpu"


def test_cache_round_trip(tmp_path):
    cache = {
        "gumbel_topk|K1048576|float32|cpu": {"tile": 16384},
        "bisect_tiles|K1048576|float32|cpu": {"tile": 4096, "block": 2},
    }
    path = autotune.save_cache(cache, str(tmp_path / "autotune.json"))
    assert autotune.load_cache(path) == cache
    # sorted keys + trailing newline: byte-stable output for the checked-in baseline
    text = (tmp_path / "autotune.json").read_text()
    assert text.endswith("\n")
    assert list(json.loads(text)) == sorted(cache)


@pytest.mark.parametrize("garbage", ["{not json", '["a", "list"]', '{"key": 7}'])
def test_corrupt_cache_degrades_to_defaults(tmp_path, garbage):
    (tmp_path / "autotune.json").write_text(garbage)
    with pytest.warns(UserWarning, match="corrupt autotune cache"):
        assert autotune.load_cache() == {}
    # best_config never crashes on a corrupt cache: defaults, recorded cold
    with pytest.warns(UserWarning):
        cfg = autotune.best_config("gumbel_topk", 4096)
    assert cfg == autotune.DEFAULTS["gumbel_topk"]
    assert autotune.cache_key("gumbel_topk", 4096) in autotune.cold_keys()


def test_best_config_merges_hit_over_defaults(tmp_path):
    key = autotune.cache_key("bisect_tiles", 4096)
    autotune.save_cache({key: {"tile": 2048}})  # partial entry: no "block"
    cfg = autotune.best_config("bisect_tiles", 4096)
    assert cfg["tile"] == 2048
    assert cfg["block"] == autotune.DEFAULTS["bisect_tiles"]["block"]  # default survives
    assert autotune.cold_keys() == []


def test_external_write_picked_up_by_mtime_memo(tmp_path):
    # a lookup before any cache exists: defaults + cold
    assert autotune.best_config("gumbel_topk", 4096) == autotune.DEFAULTS["gumbel_topk"]
    assert autotune.cold_keys()
    # another process writes the cache (same effect: file appears / mtime moves)
    autotune.save_cache({autotune.cache_key("gumbel_topk", 4096): {"tile": 32768}})
    autotune.reset_cold()
    assert autotune.best_config("gumbel_topk", 4096)["tile"] == 32768
    assert autotune.cold_keys() == []


def test_sweep_deterministic_with_injected_timer():
    # timer keyed on the candidate: argmin must win
    def timer(fn, iters, warmup, blocking):
        timer.calls += 1
        return timer.plan[timer.calls - 1]

    timer.calls = 0
    timer.plan = [50.0, 10.0, 30.0]
    best, table = autotune.sweep(
        "gumbel_topk", 4096, candidates={"tile": [2048, 4096, 8192]}, timer=timer
    )
    assert best == {"tile": 4096}
    assert table == {'{"tile": 2048}': 50.0, '{"tile": 4096}': 10.0, '{"tile": 8192}': 30.0}


def test_sweep_tie_breaks_to_earlier_candidate():
    best, _ = autotune.sweep(
        "gumbel_topk", 4096,
        candidates={"tile": [2048, 4096, 8192]},
        timer=lambda fn, iters, warmup, blocking: 42.0,
    )
    assert best == {"tile": 2048}  # strict <: constant timings keep the first


def test_autotune_merges_and_persists(tmp_path):
    # pre-existing entry for another kernel must survive the merge
    keep_key = autotune.cache_key("e3cs_tiles", 4096)
    autotune.save_cache({keep_key: {"tile": 16384}})
    out = autotune.autotune(
        ["gumbel_topk"], [4096], timer=lambda fn, iters, warmup, blocking: 1.0
    )
    cache = autotune.load_cache(out["path"])
    assert keep_key in cache
    assert autotune.cache_key("gumbel_topk", 4096) in cache
    # the fresh write is immediately visible through best_config (memo reset)
    assert autotune.best_config("gumbel_topk", 4096)["tile"] == cache[
        autotune.cache_key("gumbel_topk", 4096)
    ]["tile"]


def test_sweep_smoke_real_timer(monkeypatch):
    # a real (non-injected) sweep at K=1e4 on the reference route: exercises
    # the ops-level benchmark builders end to end
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    for kernel in sorted(autotune.CANDIDATES):
        cands = {ax: vals[:2] for ax, vals in autotune.CANDIDATES[kernel].items()}
        best, table = autotune.sweep(kernel, 10_000, candidates=cands, iters=1, warmup=1)
        assert best[next(iter(cands))] in cands[next(iter(cands))]
        n = 1
        for vals in cands.values():
            n *= len(vals)
        assert len(table) == n
        assert all(us > 0 for us in table.values())
