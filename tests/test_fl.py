"""FL substrate tests: aggregation identities (property-based), masked local
update, volatility models, and a small end-to-end learning run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import FLConfig, get_config
from repro.core.volatility import BernoulliVolatility, MarkovVolatility, paper_success_rates
from repro.data import ClientStore, make_image_dataset, partition_primary_label
from repro.fl import FLServer, aggregate, make_local_update
from repro.models import build_model
from repro.optim import sgd


class TestAggregation:
    def _params(self, seed=0):
        rng = np.random.default_rng(seed)
        return {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32), "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}

    def test_all_failed_keeps_global(self):
        g = self._params()
        cohort = jax.tree.map(lambda a: jnp.stack([a + 1, a + 2]), g)
        out = aggregate(g, cohort, jnp.zeros(2), jnp.ones(2), jnp.float32(10.0), 10, "fedavg")
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_paper_substitution_semantics(self):
        # theta' = theta + sum_i w_i mask_i (theta_i - theta): one success of K
        g = self._params()
        delta = jax.tree.map(jnp.ones_like, g)
        cohort = jax.tree.map(lambda a, d: jnp.stack([a + d, a - 5 * d]), g, delta)
        out = aggregate(g, cohort, jnp.asarray([1.0, 0.0]), jnp.ones(2), jnp.float32(4.0), 4, "mean")
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b) + 1.0 / 4.0, rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 6), st.integers(0, 63))
    def test_unbiased_estimator_is_unbiased(self, k, succ_bits):
        # E_p[ sum w_i/p_i mask_i delta ] == sum w_i delta under full success
        g = {"w": jnp.zeros((2,))}
        delta = {"w": jnp.ones((2,))}
        cohort = jax.tree.map(lambda a, d: jnp.stack([a + d] * k), g, delta)
        p = jnp.full((k,), 0.5)
        out = aggregate(g, cohort, jnp.ones(k), jnp.ones(k), jnp.float32(2 * k), 2 * k, "unbiased", sel_probs=p)
        # w_i = (1/2k)/0.5 = 1/k each, k of them -> +1 total
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-5)

    def test_epoch_weighted_upweights_fewer_epochs(self):
        g = {"w": jnp.zeros(())}
        cohort = {"w": jnp.asarray([1.0, 1.0])}
        out_eq = aggregate(g, cohort, jnp.ones(2), jnp.ones(2), jnp.float32(2), 2, "epoch_weighted", epochs=jnp.asarray([1.0, 1.0]))
        out_sk = aggregate(g, cohort, jnp.ones(2), jnp.ones(2), jnp.float32(2), 2, "epoch_weighted", epochs=jnp.asarray([1.0, 4.0]))
        assert float(out_eq["w"]) == pytest.approx(1.0, rel=1e-5)  # total weight preserved
        assert float(out_sk["w"]) == pytest.approx(1.0, rel=1e-5)


class TestLocalUpdate:
    def _setup(self):
        cfg = get_config("emnist-cnn")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 8, 28, 28, 1)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 26, (4, 8)), jnp.int32)
        return model, params, {"x": x, "y": y}

    def test_masked_steps_are_noops(self):
        model, params, batches = self._setup()
        local = make_local_update(model, sgd(0.05, 0.9))
        half_mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
        p_half, _ = local(params, batches, half_mask, jax.random.PRNGKey(1))
        b2 = jax.tree.map(lambda a: a[:2], batches)
        p_two, _ = local(params, b2, jnp.ones((2,)), jax.random.PRNGKey(1))
        for a, b in zip(jax.tree.leaves(p_half), jax.tree.leaves(p_two)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_fedprox_stays_closer_to_global(self):
        model, params, batches = self._setup()
        mask = jnp.ones((4,))
        p_avg, _ = make_local_update(model, sgd(0.05, 0.9), "fedavg")(params, batches, mask, jax.random.PRNGKey(1))
        p_prox, _ = make_local_update(model, sgd(0.05, 0.9), "fedprox", prox_coef=5.0)(
            params, batches, mask, jax.random.PRNGKey(1)
        )

        def dist(a):
            return float(
                sum(jnp.sum((x - y) ** 2) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(params)))
            )

        assert dist(p_prox) < dist(p_avg)


class TestVolatility:
    def test_bernoulli_marginal_rates(self):
        rho = jnp.asarray(paper_success_rates(40))
        vol = BernoulliVolatility(rho)
        xs = []
        vs = vol.init_state()
        for i in range(400):
            x, vs = vol.sample(jax.random.PRNGKey(i), vs)
            xs.append(np.asarray(x))
        emp = np.stack(xs).mean(0).reshape(4, -1).mean(1)
        np.testing.assert_allclose(emp, [0.1, 0.3, 0.6, 0.9], atol=0.07)

    def test_deadline_marginals_calibrated_to_rho(self):
        # regression: base_time calibration used to be dead code (base == 1.0),
        # so the deadline mechanism dragged marginals well below rho.
        from repro.fl.server import build_volatility

        fl = FLConfig(K=40, volatility="deadline")
        vol, rho = build_volatility(fl, 40)

        def one(key):
            x, _ = vol.sample(key, vol.init_state())
            return x

        xs = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(0), 3000))
        emp = np.asarray(xs).mean(0).reshape(4, -1).mean(1)
        np.testing.assert_allclose(emp, [0.1, 0.3, 0.6, 0.9], atol=0.05)

    def test_markov_stationary_matches_rho_but_correlated(self):
        rho = jnp.full((20,), 0.5)
        vol = MarkovVolatility(rho, stickiness=0.9)
        vs = vol.init_state()
        xs = []
        for i in range(600):
            x, vs = vol.sample(jax.random.PRNGKey(i), vs)
            xs.append(np.asarray(x))
        xs = np.stack(xs)
        assert abs(xs.mean() - 0.5) < 0.06
        # lag-1 autocorrelation strongly positive
        a, b = xs[:-1].ravel(), xs[1:].ravel()
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.5


@pytest.mark.slow
def test_end_to_end_fl_learns():
    cfg = get_config("emnist-cnn")
    fl = FLConfig(K=40, k=8, rounds=16, scheme="e3cs", quota="const", quota_frac=0.5,
                  samples_per_client=60, batch_size=20, local_epochs=(1,))
    data = make_image_dataset(26, (28, 28, 1), 4000, 1500, seed=0)
    idxs = partition_primary_label(data["y"], fl.K, fl.samples_per_client, seed=0)
    store = ClientStore(data, idxs)
    model = build_model(cfg)

    def eval_fn(params):
        x, y = store.eval_batch(800)
        logits = model.forward(params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        from repro.models import cross_entropy

        return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean()), float(cross_entropy(logits, jnp.asarray(y)))

    srv = FLServer(model, fl, store, eval_fn)
    state = srv.init_state(jax.random.PRNGKey(0))
    state, hist = srv.run(state, eval_every=16)
    assert hist["acc"][-1] > 0.15  # >> 1/26 chance
    assert float(state.cep) > 0
