"""Fleet-scale engine tests: scan-sim equivalence with the legacy loop,
sort-free sharded ProbAlloc vs the paper's literal case-enumeration oracle,
and multi-job batching vs independent single-job engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selection import prob_alloc_reference
from repro.core.sim import selection_sim, selection_sim_loop
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs
from repro.engine.sharded import prob_alloc_sharded


class TestScanSim:
    SCHEMES = [
        ("e3cs", dict(frac=0.5)),
        ("e3cs", dict(frac=0.0, volatility="markov")),
        ("e3cs", dict(quota="inc")),
        ("random", {}),
        ("ucb", {}),
        ("fedcs", {}),
        ("pow_d", {}),
    ]

    @pytest.mark.parametrize("scheme,kw", SCHEMES, ids=[f"{s}-{i}" for i, (s, _) in enumerate(SCHEMES)])
    def test_matches_legacy_loop_bitwise(self, scheme, kw):
        a = selection_sim(scheme, K=100, k=20, T=200, backend="scan", **kw)
        b = selection_sim_loop(scheme, K=100, k=20, T=200, **kw)
        # discrete outputs must be bit-identical (same PRNG discipline)
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["xs"], b["xs"])
        assert np.array_equal(a["counts"], b["counts"])
        np.testing.assert_allclose(a["sigmas"], b["sigmas"], atol=0)
        # allocations may differ by XLA fusion roundoff only (~1 ulp)
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)

    def test_xs_override_matches_legacy_loop(self):
        rng = np.random.default_rng(0)
        xs = rng.binomial(1, 0.5, (150, 100)).astype(np.float32)
        a = selection_sim("e3cs", K=100, k=20, T=150, frac=0.25, xs_override=xs, backend="scan")
        b = selection_sim_loop("e3cs", K=100, k=20, T=150, frac=0.25, xs_override=xs)
        assert np.array_equal(a["masks"], b["masks"])
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)

    def test_mask_cardinality_every_round(self):
        out = selection_sim("e3cs", K=50, k=10, T=100, frac=0.5, backend="scan")
        np.testing.assert_array_equal(out["masks"].sum(1), np.full(100, 10.0))


class TestShardedProbAlloc:
    @pytest.mark.parametrize("K", [7, 57, 1000, 100_000])
    @pytest.mark.parametrize("sigma_frac", [0.0, 0.5, 0.9])
    def test_matches_reference_oracle(self, K, sigma_frac):
        rng = np.random.default_rng(K + int(sigma_frac * 10))
        k = max(1, K // 5)
        sigma = sigma_frac * k / K
        w = rng.gamma(0.3, 1.0, K).astype(np.float32)  # heavy tail forces capping
        p, capped = prob_alloc_sharded(jnp.asarray(w), k, sigma)
        pr, cr = prob_alloc_reference(w, k, sigma)
        np.testing.assert_allclose(np.asarray(p), pr, atol=1e-5)
        assert (np.asarray(capped) == cr).all()
        assert abs(float(np.asarray(p).sum()) - k) < 1e-3 * k + 1e-3

    def test_degenerate_cases(self):
        # dominant weight saturates at 1
        p, capped = prob_alloc_sharded(jnp.asarray([1e6, 1.0, 1.0, 1.0, 1.0, 1.0], jnp.float32), 3, 0.0)
        assert float(p[0]) == pytest.approx(1.0, abs=1e-5)
        assert bool(capped[0]) and not bool(capped[1:].any())
        # uniform weights, no overflow
        p, capped = prob_alloc_sharded(jnp.ones(10), 3, 0.1)
        np.testing.assert_allclose(np.asarray(p), 0.3, atol=1e-6)
        assert not bool(capped.any())
        # k == K with ties: everyone saturates (plateau of the alpha search)
        p, capped = prob_alloc_sharded(jnp.full((8,), 2.0), 8, 0.5)
        np.testing.assert_allclose(np.asarray(p), 1.0, atol=1e-5)

    def test_no_global_sort_in_compiled_program(self):
        # the whole point: the alpha-search lowers to reductions, not a sort
        w = jnp.asarray(np.random.default_rng(0).gamma(0.3, 1.0, 4096).astype(np.float32))
        hlo = jax.jit(lambda w: prob_alloc_sharded(w, 512, 0.05)).lower(w).compile().as_text()
        assert "sort(" not in hlo, "sharded ProbAlloc must not materialise a global sort"


class TestMultiJob:
    def _setup(self):
        Ks, ks = [37, 64, 100], [5, 9, 20]
        cfg, k_max = pack_jobs(Ks, ks, [0.0, 0.5, 0.8], [0.5, 0.5, 0.3])
        return Ks, ks, cfg, k_max

    def test_batched_matches_independent_single_jobs(self):
        Ks, ks, cfg, k_max = self._setup()
        job_step, batched = make_multi_job(k_max)
        state = multi_job_init(cfg)
        J, K_max = cfg.active.shape
        rng = np.random.default_rng(0)
        base_keys = jax.random.split(jax.random.PRNGKey(42), J)
        single = [(state.logw[j], state.t[j]) for j in range(J)]
        for t in range(15):
            keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
            xs = jnp.asarray((rng.random((J, K_max)) < 0.6).astype(np.float32))
            state, out = batched(cfg, state, keys, xs)
            for j in range(J):
                row = jax.tree.map(lambda a: a[j], cfg)
                lw, tt, o = job_step(row, single[j][0], single[j][1], keys[j], xs[j])
                single[j] = (lw, tt)
                # the acceptance criterion: selections are identical
                assert np.array_equal(np.asarray(o["idx"]), np.asarray(out["idx"][j])), (t, j)
                assert np.array_equal(np.asarray(o["mask"]), np.asarray(out["mask"][j])), (t, j)
                np.testing.assert_allclose(np.asarray(lw), np.asarray(state.logw[j]), atol=1e-5)
                np.testing.assert_allclose(np.asarray(o["p"]), np.asarray(out["p"][j]), atol=1e-6)

    def test_padding_invariants(self):
        Ks, ks, cfg, k_max = self._setup()
        _, batched = make_multi_job(k_max)
        state = multi_job_init(cfg)
        J, K_max = cfg.active.shape
        keys = jax.random.split(jax.random.PRNGKey(7), J)
        xs = jnp.ones((J, K_max), jnp.float32)
        state, out = batched(cfg, state, keys, xs)
        idx, p, mask = np.asarray(out["idx"]), np.asarray(out["p"]), np.asarray(out["mask"])
        for j in range(J):
            # exactly k_j real selections, padded with -1
            assert (idx[j] >= 0).sum() == ks[j]
            assert (idx[j][idx[j] >= 0] < Ks[j]).all()
            sel = idx[j][idx[j] >= 0]
            assert len(set(sel.tolist())) == ks[j]  # duplicate-free
            # allocation: sum p = k_j on live slots, zero off them
            assert p[j, Ks[j]:].sum() == 0.0
            assert abs(p[j].sum() - ks[j]) < 1e-3
            assert mask[j].sum() == ks[j]
            # fairness floor respected on live slots
            assert p[j, : Ks[j]].min() >= float(cfg.sigma[j]) - 1e-6
            # dead slots stay pinned in the carried state
            assert np.asarray(state.logw)[j, Ks[j]:].sum() == 0.0

    def test_fleet_learns_stable_clients(self):
        # with 4 paper volatility classes, E3CS mass should concentrate on the
        # rho=0.9 class in every job of the batch
        from repro.core.volatility import paper_success_rates

        Ks, ks = [40, 80], [8, 16]
        cfg, k_max = pack_jobs(Ks, ks, [0.0, 0.0], [0.5, 0.5])
        _, batched = make_multi_job(k_max)
        state = multi_job_init(cfg)
        J, K_max = cfg.active.shape
        rng = np.random.default_rng(3)
        rhos = np.stack([np.pad(paper_success_rates(Kj), (0, K_max - Kj)) for Kj in Ks])
        counts = np.zeros((J, K_max))
        base_keys = jax.random.split(jax.random.PRNGKey(0), J)
        for t in range(300):
            keys = jax.vmap(lambda kk: jax.random.fold_in(kk, t))(base_keys)
            xs = jnp.asarray((rng.random((J, K_max)) < rhos).astype(np.float32))
            state, out = batched(cfg, state, keys, xs)
            counts += np.asarray(out["mask"])
        for j in range(J):
            per_class = counts[j, : Ks[j]].reshape(4, -1).sum(1)
            assert per_class[3] > 2 * per_class[0], per_class
