"""Async/staleness subsystem tests: S=0 bit-identity with the synchronous
engine, hand-computed decay-weight aggregation, lag-model semantics, the
staleness-aware FL server, and the compiled serving loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.volatility import (
    DEAD_LAG,
    BinaryLag,
    CompletionLag,
    OnTimeBits,
    make_volatility,
    paper_success_rates,
)
from repro.engine.scan_sim import async_selection_sim, build_scan_runner, scan_selection_sim
from repro.fl.aggregation import aggregate, aggregate_async, staleness_weights


class _FixedLag:
    """Deterministic lag schedule for hand-computable tests: row t of ``lags``
    (T, K) is returned verbatim; state is the round index."""

    def __init__(self, lags):
        self.lags = jnp.asarray(lags, jnp.int32)

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def sample(self, rng, state):
        return jax.lax.dynamic_index_in_dim(self.lags, state, keepdims=False), state + 1


class TestLagModels:
    def test_binary_lag_consumes_base_randomness_exactly(self):
        rho = jnp.asarray(paper_success_rates(40))
        base = make_volatility("markov", rho, stickiness=0.8)
        lagm = BinaryLag(make_volatility("markov", rho, stickiness=0.8))
        key = jax.random.PRNGKey(0)
        xs, vs = base.init_state(), lagm.init_state()
        for i in range(20):
            k = jax.random.fold_in(key, i)
            x, xs = base.sample(k, xs)
            lag, vs = lagm.sample(k, vs)
            np.testing.assert_array_equal(np.asarray(x) > 0, np.asarray(lag) == 0)
            assert set(np.unique(np.asarray(lag))) <= {0, DEAD_LAG}

    def test_completion_lag_on_time_set_is_base_success_set(self):
        # lag==0 exactly when the base draw succeeds; late/dead only split the rest
        rho = jnp.full((200,), 0.5)
        lagm = CompletionLag(make_volatility("bernoulli", rho), p_late=0.6, lag_decay=0.5, max_lag=3)
        lag, _ = lagm.sample(jax.random.PRNGKey(1), lagm.init_state())
        lag = np.asarray(lag)
        assert ((lag == 0) | (lag == DEAD_LAG) | ((lag >= 1) & (lag <= 3))).all()
        assert (lag == 0).any() and (lag >= 1).any() and (lag == DEAD_LAG).any()

    def test_completion_lag_marginals(self):
        # P(lag==0) ~= rho; P(late | miss) ~= p_late; lag truncated at max_lag
        rho = jnp.full((500,), 0.4)
        lagm = CompletionLag(make_volatility("bernoulli", rho), p_late=0.7, lag_decay=0.5, max_lag=4)
        lags = []
        vs = lagm.init_state()
        for i in range(200):
            lag, vs = lagm.sample(jax.random.PRNGKey(i), vs)
            lags.append(np.asarray(lag))
        lags = np.stack(lags)
        assert abs((lags == 0).mean() - 0.4) < 0.03
        miss = lags != 0
        assert abs((lags[miss] != DEAD_LAG).mean() - 0.7) < 0.03
        assert lags.max() <= 4

    def test_completion_lag_composes_with_scenario_generators(self):
        from repro.scenarios import make_scenario

        vol, rho = make_scenario("diurnal", 60, 100, seed=0)
        lagm = CompletionLag(vol, p_late=0.5, lag_decay=0.5, max_lag=2)
        vs = lagm.init_state()
        for i in range(5):
            lag, vs = lagm.sample(jax.random.PRNGKey(i), vs)
            assert lag.shape == (60,) and lag.dtype == jnp.int32
        # diurnal state (round index) advanced through the wrapper
        assert int(vs) == 5

    def test_on_time_bits_inverse_adapter(self):
        rho = jnp.asarray(paper_success_rates(40))
        lagm = CompletionLag(make_volatility("bernoulli", rho), p_late=0.7, max_lag=3)
        view = OnTimeBits(lagm)
        k = jax.random.PRNGKey(3)
        lag, _ = lagm.sample(k, lagm.init_state())
        x, _ = view.sample(k, view.init_state())
        np.testing.assert_array_equal(np.asarray(x), (np.asarray(lag) == 0).astype(np.float32))


class TestAsyncScanBitIdentity:
    """The S=0 guarantee: async buffer disabled == legacy sync engine, same
    PRNG keys (and with a BinaryLag, *any* S is bit-identical)."""

    SCHEMES = [("e3cs", dict(frac=0.5)), ("random", {}), ("ucb", {}), ("fedcs", {})]

    @pytest.mark.parametrize("scheme,kw", SCHEMES, ids=[s for s, _ in SCHEMES])
    @pytest.mark.parametrize("S", [0, 3])
    def test_binary_lag_any_S_matches_sync_engine(self, scheme, kw, S):
        K, k, T = 80, 16, 150
        rho = paper_success_rates(K)
        a = async_selection_sim(
            scheme, K=K, k=k, T=T, seed=7, staleness=S,
            lag_model=BinaryLag(make_volatility("bernoulli", rho)), rho=rho, **kw,
        )
        b = scan_selection_sim(
            scheme, K=K, k=k, T=T, seed=7, vol=make_volatility("bernoulli", rho), rho=rho, **kw,
        )
        assert np.array_equal(a["masks"], b["masks"])
        assert np.array_equal(a["counts"], b["counts"])
        np.testing.assert_allclose(a["ps"], b["ps"], atol=1e-6)
        # a binary lag never schedules late work: zero stale credit at any S
        assert a["stale"].sum() == 0.0
        # on-time successes == the sync success count
        np.testing.assert_allclose(a["on_time"], (b["masks"] * b["xs"]).sum(1), atol=0)

    def test_s0_matches_sync_under_on_time_view(self):
        # with a *real* lag model at S=0, async == sync driven by the
        # on-time-bits view of the same model (same rng consumption)
        K, k, T = 60, 12, 120
        rho = paper_success_rates(K)

        def lagm():
            return CompletionLag(make_volatility("markov", rho, stickiness=0.9), p_late=0.7, max_lag=3)

        a = async_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, seed=5, staleness=0, lag_model=lagm(), rho=rho)
        b = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, seed=5, vol=OnTimeBits(lagm()), rho=rho)
        assert np.array_equal(a["masks"], b["masks"])
        assert a["stale"].sum() == 0.0

    def test_lean_matches_full(self):
        K, k, T = 60, 12, 100
        rho = paper_success_rates(K)

        def run(outputs):
            return async_selection_sim(
                "e3cs", K=K, k=k, T=T, frac=0.5, seed=2, staleness=2, alpha=0.5,
                lag_model=CompletionLag(make_volatility("bernoulli", rho), max_lag=2),
                rho=rho, outputs=outputs,
            )

        full, lean = run("full"), run("lean")
        np.testing.assert_allclose(full["on_time"], lean["on_time"], atol=0)
        np.testing.assert_allclose(full["stale"], lean["stale"], atol=0)
        assert full["cep"] == lean["cep"]
        np.testing.assert_array_equal(full["sel_counts"], lean["sel_counts"])


class TestStalenessCredit:
    def test_hand_computed_credit_schedule(self):
        # 3 clients, k=3 (everyone selected), fixed lags:
        #   t=0: lags (0, 1, 2) -> on_time 1; credit 0.5 at t=1, 0.25 at t=2
        #   t=1: lags (0, 0, dead) -> on_time 2; arriving 0.5
        #   t=2: all dead -> arriving 0.25
        #   t=3: all dead -> nothing in flight
        lags = [[0, 1, 2], [0, 0, DEAD_LAG], [DEAD_LAG] * 3, [DEAD_LAG] * 3]
        fl = FLConfig(K=3, k=3, rounds=4, scheme="random")
        run, state0 = build_scan_runner(
            fl, _FixedLag(lags), paper_success_rates(3), staleness=2, alpha=0.5
        )
        state, masks, out_lags, ps, sigmas, arrived = run(
            state0, jax.random.PRNGKey(0), jnp.zeros((4, 0), jnp.float32)
        )
        np.testing.assert_array_equal(np.asarray(masks), np.ones((4, 3)))
        np.testing.assert_allclose(np.asarray(arrived).sum(1), [0.0, 0.5, 0.25, 0.0], atol=1e-7)
        assert float(state.succ_hist) == 3.0  # on-time: 1 + 2
        assert float(state.cep) == pytest.approx(3.75)  # + 0.5 + 0.25

    def test_lag_beyond_buffer_is_dropped(self):
        # S=1 buffer: a lag-2 completion never lands
        lags = [[2, 2, 2]] + [[DEAD_LAG] * 3] * 3
        fl = FLConfig(K=3, k=3, rounds=4, scheme="random")
        run, state0 = build_scan_runner(
            fl, _FixedLag(lags), paper_success_rates(3), staleness=1, alpha=0.5
        )
        state, *_, arrived = run(state0, jax.random.PRNGKey(0), jnp.zeros((4, 0), jnp.float32))
        assert float(jnp.sum(arrived)) == 0.0
        assert float(state.cep) == 0.0

    def test_staleness_weights(self):
        lag = jnp.asarray([0, 1, 2, 3, DEAD_LAG], jnp.int32)
        w = np.asarray(staleness_weights(lag, 0.5, 2))
        np.testing.assert_allclose(w, [1.0, 0.5, 0.25, 0.0, 0.0])


class TestAggregateAsync:
    def _g(self):
        return {"w": jnp.zeros(())}

    def test_hand_computed_three_client_two_lag(self):
        # theta=0; client deltas (1, 2, 3); lags (0, 1, 2); alpha=0.5; equal
        # fedavg weights 1/3:  now = 1/3*1;  t+1 = 1/3*0.5*2;  t+2 = 1/3*0.25*3
        cohort = {"w": jnp.asarray([1.0, 2.0, 3.0])}
        lag = jnp.asarray([0, 1, 2], jnp.int32)
        new, late = aggregate_async(
            self._g(), cohort, lag, jnp.ones(3), jnp.float32(3.0), 3, "fedavg", alpha=0.5, staleness=2
        )
        assert float(new["w"]) == pytest.approx(1.0 / 3.0)
        np.testing.assert_allclose(np.asarray(late["w"]), [1.0 / 3.0, 0.25], rtol=1e-6)

    def test_staleness_zero_equals_sync_aggregate(self):
        rng = np.random.default_rng(0)
        cohort = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
        g = {"w": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
        succ = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        lag = jnp.where(succ > 0, 0, DEAD_LAG).astype(jnp.int32)
        sizes = jnp.asarray([1.0, 2.0, 3.0, 4.0])
        a = aggregate(g, cohort, succ, sizes, jnp.float32(10.0), 10, "fedavg")
        b, late = aggregate_async(g, cohort, lag, sizes, jnp.float32(10.0), 10, "fedavg", staleness=0)
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), atol=0)
        assert late["w"].shape == (0, 3)

    def test_dead_and_overflow_lags_contribute_nothing(self):
        cohort = {"w": jnp.asarray([5.0, 7.0])}
        lag = jnp.asarray([DEAD_LAG, 3], jnp.int32)  # dead; beyond S=2
        new, late = aggregate_async(
            self._g(), cohort, lag, jnp.ones(2), jnp.float32(2.0), 2, "fedavg", alpha=0.5, staleness=2
        )
        assert float(new["w"]) == 0.0
        np.testing.assert_allclose(np.asarray(late["w"]), [0.0, 0.0])


class TestServerVolatilitySpecs:
    """build_volatility accepts builtin strings (regression), scenario names,
    and model objects."""

    def test_builtin_string_path_regression(self):
        from repro.fl.server import build_volatility
        from repro.core.volatility import DeadlineVolatility, MarkovVolatility

        fl = FLConfig(K=40, volatility="markov")
        vol, rho = build_volatility(fl, 40)
        assert isinstance(vol, MarkovVolatility)
        np.testing.assert_allclose(np.asarray(rho), paper_success_rates(40))
        vol2, _ = build_volatility(FLConfig(K=40, volatility="deadline"), 40)
        assert isinstance(vol2, DeadlineVolatility)

    def test_scenario_name(self):
        from repro.fl.server import build_volatility
        from repro.scenarios import DiurnalVolatility

        vol, rho = build_volatility(FLConfig(K=40, rounds=200, volatility="diurnal"), 40)
        assert isinstance(vol, DiurnalVolatility)
        assert rho.shape == (40,)

    def test_model_object(self):
        from repro.fl.server import build_volatility

        rho = jnp.asarray(paper_success_rates(40))
        obj = make_volatility("markov", rho, stickiness=0.9)
        vol, rho_out = build_volatility(FLConfig(K=40), 40, volatility=obj)
        assert vol is obj
        np.testing.assert_allclose(np.asarray(rho_out), np.asarray(rho))

    def test_unknown_name_raises(self):
        from repro.fl.server import build_volatility

        with pytest.raises(ValueError, match="unknown volatility"):
            build_volatility(FLConfig(K=40, volatility="not_a_thing"), 40)


def test_async_fl_server_trains_and_applies_stale_updates():
    # ~7s: cheap enough to keep in the default (CI) run — this is the only
    # end-to-end coverage of the server-side pending-delta scheduling

    from repro.data import ClientStore, make_image_dataset, partition_primary_label
    from repro.fl import FLServer
    from repro.models import build_model
    from repro.configs import get_config

    cfg = get_config("emnist-cnn")
    fl = FLConfig(K=20, k=4, rounds=8, scheme="e3cs", quota="const", quota_frac=0.5,
                  samples_per_client=40, batch_size=20, local_epochs=(1,),
                  staleness_rounds=2, staleness_alpha=0.5, late_prob=0.9)
    data = make_image_dataset(26, (28, 28, 1), 1200, 400, seed=0)
    idxs = partition_primary_label(data["y"], fl.K, fl.samples_per_client, seed=0)
    store = ClientStore(data, idxs)
    srv = FLServer(build_model(cfg), fl, store)
    state = srv.init_state(jax.random.PRNGKey(0))
    state, hist = srv.run(state, eval_every=100)
    assert all(bool(jnp.isfinite(leaf).all()) for leaf in jax.tree.leaves(state.params))
    assert hist["n_late"] > 0  # stale updates actually happened and were applied


def test_compiled_service_loop_smoke():
    from repro.launch.select_serve import run_service_compiled

    rep = run_service_compiled(J=3, K_max=128, rounds=8, seed=0, staleness=2, reps=1)
    assert rep["ticks"] == 24
    assert rep["on_time_total"] > 0
    assert rep["stale_credit_total"] > 0
    sync = run_service_compiled(J=3, K_max=128, rounds=8, seed=0, staleness=0, reps=1)
    assert sync["stale_credit_total"] == 0.0
    assert sync["mode"] == "compiled_sync"
