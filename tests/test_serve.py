"""Serving front end tests: protocol, batcher, elastic restart, transport.

What is pinned here, and why it matters:

* **wire framing** — frames round-trip; truncation/oversize fail loudly.
* **batching invariance** — a job ticked alone produces bit-identical
  cohorts to the same job ticked coalesced with co-tenants (the per-job
  PRNG contract the whole batcher rests on).
* **elastic restart** — a server checkpointed mid-horizon and restored
  into a fresh process continues bit-identically to an uninterrupted run,
  for both backends, sync and async (S=2).  This is the acceptance bar of
  ROADMAP item 2: the loopback test drives 2 jobs >= 50 rounds through the
  compiled sharded-async engine across a kill/restore.
* **failure modes** — full slot bucket sheds with ``capacity``; full
  admission queue sheds with ``shed``; expired requests fail with
  ``timeout``; draining servers answer what they accepted.
"""
import socket
import threading

import numpy as np
import pytest

import jax

from repro.serve import (
    CapacityError,
    JobSpec,
    SelectionServer,
    ServeClient,
    ServeError,
    ShardedEngine,
    SlotEngine,
    latest_server_checkpoint,
    load_server,
    save_server,
)
from repro.serve import protocol

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
)


def _lags(rng, K, S=2):
    """A volatile round: most on time, some late (1..S), some never."""
    l = rng.integers(0, S + 2, K).astype(np.int32)
    return np.where(l > S, protocol.DEAD_LAG, l)


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


def test_protocol_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "tick", "job": 3, "xb": protocol.encode_bits(np.ones(17))}
        protocol.send_message(a, msg)
        assert protocol.recv_message(b) == msg
        a.close()
        with pytest.raises(protocol.ConnectionClosed):
            protocol.recv_message(b)
    finally:
        b.close()


def test_protocol_mid_frame_eof_is_error():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x00\x00\x00\x10{\"tru")  # announce 16 bytes, send 6
        a.close()
        with pytest.raises(protocol.ProtocolError):
            protocol.recv_message(b)
    finally:
        b.close()


def test_protocol_feedback_encodings():
    bits = np.asarray([1, 0, 1, 1, 0, 0, 1, 0, 1])
    out = protocol.decode_bits(protocol.encode_bits(bits), 9)
    np.testing.assert_array_equal(out, bits.astype(np.float32))
    lags = np.asarray([0, 1, 2, protocol.DEAD_LAG, 0])
    out = protocol.decode_lags(protocol.encode_lags(lags), 5)
    np.testing.assert_array_equal(out, lags)
    # sync bits normalise to {0, DEAD_LAG} lag codes
    req = {"xb": protocol.encode_bits(bits)}
    lag = protocol.feedback_lags(req, 9, staleness=0)
    np.testing.assert_array_equal(lag == 0, bits.astype(bool))
    assert set(np.unique(lag)) <= {0, protocol.DEAD_LAG}


# ---------------------------------------------------------------------------
# SlotEngine: batching invariance, bucket ladder, restart
# ---------------------------------------------------------------------------


def test_slot_engine_alone_vs_batched_bit_identical():
    """Co-tenancy must not perturb a job: same spec, same feedback, same
    cohorts whether the job ticks alone or batched with others."""
    rng = np.random.default_rng(0)
    spec = JobSpec(K=48, k=6, seed=13)
    feed = [_lags(rng, 48) for _ in range(8)]

    alone = SlotEngine(K_max=64, k_cap=8, staleness=2, buckets=(4,))
    ua = alone.admit(spec)
    solo = [alone.tick([(ua, f)])[ua]["cohort"] for f in feed]

    packed = SlotEngine(K_max=64, k_cap=8, staleness=2, buckets=(4,))
    u0 = packed.admit(JobSpec(K=64, k=8, seed=1))
    ub = packed.admit(spec)
    u2 = packed.admit(JobSpec(K=32, k=4, seed=2))
    both = []
    for f in feed:
        r = packed.tick([(u0, _lags(rng, 64)), (ub, f), (u2, _lags(rng, 32))])
        both.append(r[ub]["cohort"])
    assert solo == both


def test_slot_engine_bucket_ladder_and_capacity():
    eng = SlotEngine(K_max=16, k_cap=4, buckets=(2, 4))
    uids = [eng.admit(JobSpec(K=16, k=2, seed=i)) for i in range(2)]
    assert eng.n_slots == 2
    uids.append(eng.admit(JobSpec(K=16, k=2, seed=9)))  # grows 2 -> 4
    assert eng.n_slots == 4
    for i in range(3, 4):
        uids.append(eng.admit(JobSpec(K=16, k=2, seed=i)))
    with pytest.raises(CapacityError):
        eng.admit(JobSpec(K=16, k=2, seed=99))  # ladder exhausted
    # retire frees a slot for the next admit, ladder unchanged
    eng.retire(uids[1])
    eng.admit(JobSpec(K=16, k=2, seed=100))
    assert eng.n_slots == 4


def test_slot_engine_growth_preserves_streams():
    """Bucket growth is invisible to live jobs: their selection streams
    continue as if the batch had never been resized."""
    rng = np.random.default_rng(1)
    spec = JobSpec(K=24, k=3, seed=21)
    feed = [_lags(rng, 24, S=0) for _ in range(6)]

    ref = SlotEngine(K_max=32, k_cap=4, buckets=(2, 4))
    ur = ref.admit(spec)
    want = [ref.tick([(ur, f)])[ur]["cohort"] for f in feed]

    grow = SlotEngine(K_max=32, k_cap=4, buckets=(2, 4))
    ug = grow.admit(spec)
    got = [grow.tick([(ug, f)])[ug]["cohort"] for f in feed[:3]]
    grow.admit(JobSpec(K=32, k=4, seed=1))
    grow.admit(JobSpec(K=32, k=4, seed=2))  # triggers 2 -> 4 growth
    got += [grow.tick([(ug, f)])[ug]["cohort"] for f in feed[3:]]
    assert want == got


@pytest.mark.parametrize("staleness", [0, 2])
def test_slot_engine_restart_bit_identical(tmp_path, staleness):
    """Checkpoint mid-horizon, restore, continue: cohorts match an
    uninterrupted run exactly (sync and async S=2)."""
    rng = np.random.default_rng(2)
    specs = [JobSpec(K=40, k=5, seed=3), JobSpec(K=24, k=4, seed=4)]
    feed = [[_lags(rng, s.K, S=staleness) for _ in range(12)] for s in specs]

    def fresh():
        eng = SlotEngine(K_max=64, k_cap=8, staleness=staleness, buckets=(4,))
        return eng, [eng.admit(s) for s in specs]

    ref, uref = fresh()
    want = [ref.tick([(u, fr[t]) for u, fr in zip(uref, feed)]) for t in range(12)]

    eng, uids = fresh()
    for t in range(6):
        eng.tick([(u, fr[t]) for u, fr in zip(uids, feed)])
    stem = save_server(str(tmp_path), eng, step=6)
    assert latest_server_checkpoint(str(tmp_path)) == stem
    eng2, step = load_server(stem)
    assert step == 6
    for t in range(6, 12):
        got = eng2.tick([(u, fr[t]) for u, fr in zip(uids, feed)])
        for u in uids:
            assert got[u]["cohort"] == want[t][u]["cohort"]
            assert got[u]["round"] == want[t][u]["round"]


# ---------------------------------------------------------------------------
# transport: batcher, shed, timeout, drain
# ---------------------------------------------------------------------------


def _sync_server(**kw):
    return SelectionServer(SlotEngine(K_max=32, k_cap=4, buckets=(4,)), **kw)


def test_transport_roundtrip_and_errors():
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            assert c.hello()["engine"] == "slots"
            job = c.admit(K=32, k=4, seed=1)
            out = c.tick(job, bits=np.ones(32))
            assert out["round"] == 0 and len(out["cohort"]) == 4
            with pytest.raises(ServeError) as e:
                c.tick(999, bits=np.ones(32))
            assert e.value.code == "unknown_job"
            with pytest.raises(ServeError) as e:
                c.call(op="tick", job=job)  # no feedback field
            assert e.value.code == "bad_request"
            with pytest.raises(ServeError) as e:
                c.call(op="nonsense")
            assert e.value.code == "bad_request"
            c.retire(job)
            with pytest.raises(ServeError) as e:
                c.tick(job, bits=np.ones(32))
            assert e.value.code == "unknown_job"


def test_transport_concurrent_clients_batch():
    """Two clients hammering concurrently: every response is consistent and
    per-job rounds stay strictly sequential no matter how dispatches
    coalesce."""
    with _sync_server() as srv:
        rounds = {0: [], 1: []}

        def drive(i):
            with ServeClient.connect(srv.address) as c:
                job = c.admit(K=32, k=4, seed=i)
                for _ in range(20):
                    out = c.tick(job, bits=np.ones(32))
                    rounds[i].append(out["round"])
                    assert len(out["cohort"]) == 4

        threads = [threading.Thread(target=drive, args=(i,)) for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert rounds[0] == list(range(20)) and rounds[1] == list(range(20))
        assert srv.stats["ticks"] == 40


def test_transport_shed_on_full_queue():
    """A stalled engine + a bounded queue => overflow requests shed
    immediately instead of queueing into unbounded latency."""
    srv = _sync_server(max_queue=2)
    gate = threading.Event()
    real_tick = srv.engine.tick

    def slow_tick(items):
        gate.wait(10.0)
        return real_tick(items)

    srv.engine.tick = slow_tick
    with srv:
        with ServeClient.connect(srv.address) as admitc:
            job = admitc.admit(K=32, k=4, seed=1)
            results = []

            def one():
                with ServeClient.connect(srv.address) as c:
                    try:
                        c.tick(job, bits=np.ones(32))
                        results.append("ok")
                    except ServeError as e:
                        results.append(e.code)

            # first request occupies the engine thread; the next floods the
            # 2-deep queue
            threads = [threading.Thread(target=one) for _ in range(6)]
            for t in threads:
                t.start()
            import time

            time.sleep(0.5)
            gate.set()
            for t in threads:
                t.join()
        assert "shed" in results, results
        assert srv.stats["shed"] >= 1


def test_transport_timeout_expired_requests():
    """Requests older than request_timeout when dequeued fail with
    ``timeout`` and never reach the engine."""
    srv = _sync_server(request_timeout=0.0)
    with srv:
        with ServeClient.connect(srv.address) as c:
            job = c.call(op="admit", spec={"K": 32, "k": 4})["job"]
            with pytest.raises(ServeError) as e:
                c.tick(job, bits=np.ones(32))
            assert e.value.code == "timeout"
        assert srv.stats["timeouts"] == 1
        assert srv.stats["ticks"] == 0


def test_transport_drain_and_final_checkpoint(tmp_path):
    """Graceful close answers accepted work and writes a final checkpoint;
    the checkpoint restores to the drained state."""
    srv = _sync_server(ckpt_dir=str(tmp_path))
    with srv:
        with ServeClient.connect(srv.address) as c:
            job = c.admit(K=32, k=4, seed=5)
            for _ in range(3):
                c.tick(job, bits=np.ones(32))
    stem = latest_server_checkpoint(str(tmp_path))
    assert stem is not None
    eng, step = load_server(stem)
    assert step == 3 and int(np.asarray(eng.state.t)[eng.jobs[job]["slot"]]) == 3


def test_transport_draining_rejects_new_requests():
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            c.admit(K=32, k=4)
            assert c.shutdown()["ok"]
            with pytest.raises((ServeError, protocol.ProtocolError, OSError)):
                c.call(op="hello")


# ---------------------------------------------------------------------------
# acceptance: loopback client, 2 jobs, sharded-async engine, kill + restore
# ---------------------------------------------------------------------------


@needs8
def test_acceptance_sharded_async_kill_restore(tmp_path):
    """ROADMAP item 2's acceptance bar, end to end over the wire:

    admit 2 jobs into a D=8 sharded-async (S=2) server, drive >= 50 rounds
    through the compiled engine, checkpoint + kill mid-horizon, restore a
    fresh server from disk, finish the horizon — and every post-restore
    selection is bit-identical to an uninterrupted reference run.
    """
    ROUNDS, SPLIT = 52, 26
    rng = np.random.default_rng(7)
    specs = [
        dict(K=64, k=8, rounds=ROUNDS, seed=17),
        dict(K=48, k=4, rounds=ROUNDS, seed=23),
    ]
    feed = [[_lags(rng, s["K"]) for _ in range(ROUNDS)] for s in specs]

    # uninterrupted reference, same backend, straight through the engine
    ref = ShardedEngine(D=8, staleness=2)
    ruid = [ref.admit(JobSpec(**s)) for s in specs]
    want = [ref.tick([(u, f[t]) for u, f in zip(ruid, feed)]) for t in range(ROUNDS)]

    ckpt_dir = str(tmp_path / "ckpt")
    srv = SelectionServer(ShardedEngine(D=8, staleness=2), ckpt_dir=ckpt_dir)
    got = {0: [], 1: []}
    with srv:
        c = ServeClient.connect(srv.address)
        jobs = [c.admit(**s) for s in specs]
        for t in range(SPLIT):
            for i, j in enumerate(jobs):
                out = c.tick(j, lags=feed[i][t])
                got[i].append((out["round"], out["cohort"]))
        c.checkpoint()
        c.close()
        srv.kill()  # crash: no drain, no extra checkpoint

    stem = latest_server_checkpoint(ckpt_dir)
    assert stem is not None
    engine, step = load_server(stem)
    assert step == 2 * SPLIT
    with SelectionServer(engine, ckpt_dir=ckpt_dir) as srv2:
        c = ServeClient.connect(srv2.address)
        for t in range(SPLIT, ROUNDS):
            for i, j in enumerate(jobs):
                out = c.tick(j, lags=feed[i][t])
                got[i].append((out["round"], out["cohort"]))
        c.close()

    for i, u in enumerate(ruid):
        assert [r for r, _ in got[i]] == list(range(ROUNDS))
        for t in range(ROUNDS):
            assert got[i][t][1] == want[t][u]["cohort"], f"job {i} diverged at round {t}"
