"""Serving front end tests: protocol, batcher, elastic restart, transport.

What is pinned here, and why it matters:

* **wire framing** — frames round-trip; truncation/oversize fail loudly,
  and fuzzed garbage (random bytes, truncated frames, oversized prefixes,
  mid-frame disconnects) never leaves a dead handler behind.
* **batching invariance** — a job ticked alone produces bit-identical
  cohorts to the same job ticked coalesced with co-tenants (the per-job
  PRNG contract the whole batcher rests on).
* **elastic restart** — a server checkpointed mid-horizon and restored
  into a fresh process continues bit-identically to an uninterrupted run,
  for both backends, sync and async (S=2).  This is the acceptance bar of
  ROADMAP item 2: the loopback test drives 2 jobs >= 50 rounds through the
  compiled sharded-async engine across a kill/restore.
* **failure modes** — full slot bucket sheds with ``capacity``; full
  admission queue sheds with ``shed``; expired requests fail with
  ``timeout``; draining servers answer what they accepted; a hung engine
  thread at close is surfaced, not silently leaked.
* **fault tolerance** — crash-safe checkpoints (sha256 walk-back past
  corrupt stems, retention), idempotent round-tagged ticks (replay answers
  from cache, desync carries the expected round), client retries with
  seeded backoff, the non-finite-update guard, and the supervised restart
  loop — capped by the seeded chaos run: engine crash + corrupted
  checkpoint + dropped connections on a sharded-async horizon, with every
  cohort bit-identical to a fault-free run.
"""
import json
import socket
import struct
import threading
import time

import numpy as np
import pytest

import jax

from repro.serve import (
    CapacityError,
    FaultPlan,
    JobSpec,
    SelectionServer,
    ServeClient,
    ServeError,
    ShardedEngine,
    SlotEngine,
    latest_server_checkpoint,
    load_server,
    save_server,
    validate_stem,
)
from repro.serve import protocol

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
)


def _lags(rng, K, S=2):
    """A volatile round: most on time, some late (1..S), some never."""
    l = rng.integers(0, S + 2, K).astype(np.int32)
    return np.where(l > S, protocol.DEAD_LAG, l)


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


def test_protocol_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "tick", "job": 3, "xb": protocol.encode_bits(np.ones(17))}
        protocol.send_message(a, msg)
        assert protocol.recv_message(b) == msg
        a.close()
        with pytest.raises(protocol.ConnectionClosed):
            protocol.recv_message(b)
    finally:
        b.close()


def test_protocol_mid_frame_eof_is_error():
    a, b = socket.socketpair()
    try:
        a.sendall(b"\x00\x00\x00\x10{\"tru")  # announce 16 bytes, send 6
        a.close()
        with pytest.raises(protocol.ProtocolError):
            protocol.recv_message(b)
    finally:
        b.close()


def test_protocol_feedback_encodings():
    bits = np.asarray([1, 0, 1, 1, 0, 0, 1, 0, 1])
    out = protocol.decode_bits(protocol.encode_bits(bits), 9)
    np.testing.assert_array_equal(out, bits.astype(np.float32))
    lags = np.asarray([0, 1, 2, protocol.DEAD_LAG, 0])
    out = protocol.decode_lags(protocol.encode_lags(lags), 5)
    np.testing.assert_array_equal(out, lags)
    # sync bits normalise to {0, DEAD_LAG} lag codes
    req = {"xb": protocol.encode_bits(bits)}
    lag = protocol.feedback_lags(req, 9, staleness=0)
    np.testing.assert_array_equal(lag == 0, bits.astype(bool))
    assert set(np.unique(lag)) <= {0, protocol.DEAD_LAG}


# ---------------------------------------------------------------------------
# SlotEngine: batching invariance, bucket ladder, restart
# ---------------------------------------------------------------------------


def test_slot_engine_alone_vs_batched_bit_identical():
    """Co-tenancy must not perturb a job: same spec, same feedback, same
    cohorts whether the job ticks alone or batched with others."""
    rng = np.random.default_rng(0)
    spec = JobSpec(K=48, k=6, seed=13)
    feed = [_lags(rng, 48) for _ in range(8)]

    alone = SlotEngine(K_max=64, k_cap=8, staleness=2, buckets=(4,))
    ua = alone.admit(spec)
    solo = [alone.tick([(ua, f)])[ua]["cohort"] for f in feed]

    packed = SlotEngine(K_max=64, k_cap=8, staleness=2, buckets=(4,))
    u0 = packed.admit(JobSpec(K=64, k=8, seed=1))
    ub = packed.admit(spec)
    u2 = packed.admit(JobSpec(K=32, k=4, seed=2))
    both = []
    for f in feed:
        r = packed.tick([(u0, _lags(rng, 64)), (ub, f), (u2, _lags(rng, 32))])
        both.append(r[ub]["cohort"])
    assert solo == both


def test_slot_engine_bucket_ladder_and_capacity():
    eng = SlotEngine(K_max=16, k_cap=4, buckets=(2, 4))
    uids = [eng.admit(JobSpec(K=16, k=2, seed=i)) for i in range(2)]
    assert eng.n_slots == 2
    uids.append(eng.admit(JobSpec(K=16, k=2, seed=9)))  # grows 2 -> 4
    assert eng.n_slots == 4
    for i in range(3, 4):
        uids.append(eng.admit(JobSpec(K=16, k=2, seed=i)))
    with pytest.raises(CapacityError):
        eng.admit(JobSpec(K=16, k=2, seed=99))  # ladder exhausted
    # retire frees a slot for the next admit, ladder unchanged
    eng.retire(uids[1])
    eng.admit(JobSpec(K=16, k=2, seed=100))
    assert eng.n_slots == 4


def test_slot_engine_growth_preserves_streams():
    """Bucket growth is invisible to live jobs: their selection streams
    continue as if the batch had never been resized."""
    rng = np.random.default_rng(1)
    spec = JobSpec(K=24, k=3, seed=21)
    feed = [_lags(rng, 24, S=0) for _ in range(6)]

    ref = SlotEngine(K_max=32, k_cap=4, buckets=(2, 4))
    ur = ref.admit(spec)
    want = [ref.tick([(ur, f)])[ur]["cohort"] for f in feed]

    grow = SlotEngine(K_max=32, k_cap=4, buckets=(2, 4))
    ug = grow.admit(spec)
    got = [grow.tick([(ug, f)])[ug]["cohort"] for f in feed[:3]]
    grow.admit(JobSpec(K=32, k=4, seed=1))
    grow.admit(JobSpec(K=32, k=4, seed=2))  # triggers 2 -> 4 growth
    got += [grow.tick([(ug, f)])[ug]["cohort"] for f in feed[3:]]
    assert want == got


@pytest.mark.parametrize("staleness", [0, 2])
def test_slot_engine_restart_bit_identical(tmp_path, staleness):
    """Checkpoint mid-horizon, restore, continue: cohorts match an
    uninterrupted run exactly (sync and async S=2)."""
    rng = np.random.default_rng(2)
    specs = [JobSpec(K=40, k=5, seed=3), JobSpec(K=24, k=4, seed=4)]
    feed = [[_lags(rng, s.K, S=staleness) for _ in range(12)] for s in specs]

    def fresh():
        eng = SlotEngine(K_max=64, k_cap=8, staleness=staleness, buckets=(4,))
        return eng, [eng.admit(s) for s in specs]

    ref, uref = fresh()
    want = [ref.tick([(u, fr[t]) for u, fr in zip(uref, feed)]) for t in range(12)]

    eng, uids = fresh()
    for t in range(6):
        eng.tick([(u, fr[t]) for u, fr in zip(uids, feed)])
    stem = save_server(str(tmp_path), eng, step=6)
    assert latest_server_checkpoint(str(tmp_path)) == stem
    eng2, step = load_server(stem)
    assert step == 6
    for t in range(6, 12):
        got = eng2.tick([(u, fr[t]) for u, fr in zip(uids, feed)])
        for u in uids:
            assert got[u]["cohort"] == want[t][u]["cohort"]
            assert got[u]["round"] == want[t][u]["round"]


# ---------------------------------------------------------------------------
# transport: batcher, shed, timeout, drain
# ---------------------------------------------------------------------------


def _sync_server(**kw):
    return SelectionServer(SlotEngine(K_max=32, k_cap=4, buckets=(4,)), **kw)


def test_transport_roundtrip_and_errors():
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            assert c.hello()["engine"] == "slots"
            job = c.admit(K=32, k=4, seed=1)
            out = c.tick(job, bits=np.ones(32))
            assert out["round"] == 0 and len(out["cohort"]) == 4
            with pytest.raises(ServeError) as e:
                c.tick(999, bits=np.ones(32))
            assert e.value.code == "unknown_job"
            with pytest.raises(ServeError) as e:
                c.call(op="tick", job=job)  # no feedback field
            assert e.value.code == "bad_request"
            with pytest.raises(ServeError) as e:
                c.call(op="nonsense")
            assert e.value.code == "bad_request"
            c.retire(job)
            with pytest.raises(ServeError) as e:
                c.tick(job, bits=np.ones(32))
            assert e.value.code == "unknown_job"


def test_transport_concurrent_clients_batch():
    """Two clients hammering concurrently: every response is consistent and
    per-job rounds stay strictly sequential no matter how dispatches
    coalesce."""
    with _sync_server() as srv:
        rounds = {0: [], 1: []}

        def drive(i):
            with ServeClient.connect(srv.address) as c:
                job = c.admit(K=32, k=4, seed=i)
                for _ in range(20):
                    out = c.tick(job, bits=np.ones(32))
                    rounds[i].append(out["round"])
                    assert len(out["cohort"]) == 4

        threads = [threading.Thread(target=drive, args=(i,)) for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert rounds[0] == list(range(20)) and rounds[1] == list(range(20))
        assert srv.stats["ticks"] == 40


def test_transport_shed_on_full_queue():
    """A stalled engine + a bounded queue => overflow requests shed
    immediately instead of queueing into unbounded latency."""
    srv = _sync_server(max_queue=2)
    gate = threading.Event()
    real_tick = srv.engine.tick

    def slow_tick(items):
        gate.wait(10.0)
        return real_tick(items)

    srv.engine.tick = slow_tick
    with srv:
        with ServeClient.connect(srv.address) as admitc:
            job = admitc.admit(K=32, k=4, seed=1)
            results = []

            def one():
                with ServeClient.connect(srv.address) as c:
                    try:
                        c.tick(job, bits=np.ones(32))
                        results.append("ok")
                    except ServeError as e:
                        results.append(e.code)

            # first request occupies the engine thread; the next floods the
            # 2-deep queue
            threads = [threading.Thread(target=one) for _ in range(6)]
            for t in threads:
                t.start()
            import time

            time.sleep(0.5)
            gate.set()
            for t in threads:
                t.join()
        assert "shed" in results, results
        assert srv.stats["shed"] >= 1


def test_transport_timeout_expired_requests():
    """Requests older than request_timeout when dequeued fail with
    ``timeout`` and never reach the engine."""
    srv = _sync_server(request_timeout=0.0)
    with srv:
        with ServeClient.connect(srv.address) as c:
            job = c.call(op="admit", spec={"K": 32, "k": 4})["job"]
            with pytest.raises(ServeError) as e:
                c.tick(job, bits=np.ones(32))
            assert e.value.code == "timeout"
        assert srv.stats["timeouts"] == 1
        assert srv.stats["ticks"] == 0


def test_transport_drain_and_final_checkpoint(tmp_path):
    """Graceful close answers accepted work and writes a final checkpoint;
    the checkpoint restores to the drained state."""
    srv = _sync_server(ckpt_dir=str(tmp_path))
    with srv:
        with ServeClient.connect(srv.address) as c:
            job = c.admit(K=32, k=4, seed=5)
            for _ in range(3):
                c.tick(job, bits=np.ones(32))
    stem = latest_server_checkpoint(str(tmp_path))
    assert stem is not None
    eng, step = load_server(stem)
    assert step == 3 and int(np.asarray(eng.state.t)[eng.jobs[job]["slot"]]) == 3


def test_transport_draining_rejects_new_requests():
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            c.admit(K=32, k=4)
            assert c.shutdown()["ok"]
            with pytest.raises((ServeError, protocol.ProtocolError, OSError)):
                c.call(op="hello")


# ---------------------------------------------------------------------------
# protocol fuzz: garbage on the wire never leaves a dead handler behind
# ---------------------------------------------------------------------------


def test_fuzz_random_bytes_never_kill_the_server():
    """Seeded random byte blasts: each connection dies alone (error response
    or clean close); the server keeps answering well-formed clients."""
    rng = np.random.default_rng(11)
    with _sync_server() as srv:
        for _ in range(12):
            s = socket.create_connection(srv.address, timeout=5.0)
            try:
                n = int(rng.integers(1, 256))
                s.sendall(rng.integers(0, 256, n, dtype=np.uint8).tobytes())
            finally:
                s.close()
        with ServeClient.connect(srv.address) as c:
            assert c.hello()["ok"]


def test_fuzz_oversized_length_prefix():
    """A frame announcing more than MAX_MESSAGE_BYTES: error response, then
    hang up — the stream cannot be resynced."""
    with _sync_server() as srv:
        s = socket.create_connection(srv.address, timeout=5.0)
        try:
            s.sendall(struct.pack("!I", protocol.MAX_MESSAGE_BYTES + 1))
            resp = protocol.recv_message(s)
            assert resp["ok"] is False and resp["error"] == "bad_request"
            with pytest.raises((protocol.ProtocolError, OSError)):
                protocol.recv_message(s)
        finally:
            s.close()
        with ServeClient.connect(srv.address) as c:
            assert c.hello()["ok"]


def test_fuzz_truncated_frame_and_midframe_disconnect():
    """A valid header with a partial payload, then disconnect: the handler
    exits; concurrent well-formed connections are unaffected."""
    with _sync_server() as srv:
        body = json.dumps({"op": "hello"}).encode()
        for cut in (0, len(body) // 2):
            s = socket.create_connection(srv.address, timeout=5.0)
            s.sendall(struct.pack("!I", len(body)) + body[:cut])
            s.close()
        body = json.dumps({"op": "hello"}).encode()  # not-JSON payloads too
        s = socket.create_connection(srv.address, timeout=5.0)
        try:
            junk = b"\xff" * len(body)
            s.sendall(struct.pack("!I", len(junk)) + junk)
            resp = protocol.recv_message(s)
            assert resp["ok"] is False and resp["error"] == "bad_request"
        finally:
            s.close()
        with ServeClient.connect(srv.address) as c:
            assert c.hello()["ok"]


# ---------------------------------------------------------------------------
# crash-safe checkpoints: sha walk-back, retention
# ---------------------------------------------------------------------------


def test_checkpoint_walkback_and_retention(tmp_path):
    """Corrupt stems (truncation or a bit flip) fail validation and the
    restore walk-back skips them; retention prunes to the newest N stems."""
    rng = np.random.default_rng(5)
    eng = SlotEngine(K_max=32, k_cap=4, buckets=(4,))
    uid = eng.admit(JobSpec(K=32, k=4, seed=3))
    stems = []
    for step in (1, 2, 3):
        eng.tick([(uid, _lags(rng, 32, S=0))])
        stems.append(save_server(str(tmp_path), eng, step=step))
    assert all(validate_stem(s) for s in stems)
    assert latest_server_checkpoint(str(tmp_path)) == stems[2]

    # truncate the newest payload: sha mismatch, walk back one stem
    with open(stems[2] + ".ckpt", "r+b") as f:
        f.seek(0, 2)
        f.truncate(f.tell() // 2)
    assert not validate_stem(stems[2])
    assert latest_server_checkpoint(str(tmp_path)) == stems[1]

    # flip one byte in the next stem via the chaos hook: walk back again
    plan = FaultPlan(corrupt_checkpoints=(0,), corrupt_mode="bitflip")
    plan.on_checkpoint(stems[1])
    assert plan.fired()["corrupt"] == 1
    assert not validate_stem(stems[1])
    assert latest_server_checkpoint(str(tmp_path)) == stems[0]
    restored, step = load_server(stems[0])
    assert step == 1 and restored.job_round(uid) == 1

    # retention: keep=2 prunes everything but the newest 2 stems
    eng.tick([(uid, _lags(rng, 32, S=0))])
    s4 = save_server(str(tmp_path), eng, step=4, keep=2)
    import os

    left = sorted(f for f in os.listdir(str(tmp_path)) if f.endswith(".json"))
    assert len(left) == 2 and left[-1] == os.path.basename(s4) + ".json"


# ---------------------------------------------------------------------------
# idempotent ticks, client retries, numerics guard, hung engine
# ---------------------------------------------------------------------------


def test_idempotent_tick_replay_and_desync():
    """A replayed round answers from the cache (feedback NOT re-applied); a
    round that disagrees with the engine's cursor fails with the expected
    round attached."""
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            job = c.admit(K=32, k=4, seed=2)
            xb = protocol.encode_bits(np.ones(32))
            out0 = c.call(op="tick", job=job, round=0, xb=xb)
            # replay round 0 with DIFFERENT feedback: the cached response
            # comes back and the engine state is untouched
            again = c.call(op="tick", job=job, round=0,
                           xb=protocol.encode_bits(np.zeros(32)))
            assert again == out0
            assert srv.stats["replayed"] == 1
            with pytest.raises(ServeError) as e:
                c.call(op="tick", job=job, round=5, xb=xb)
            assert e.value.code == "round_desync"
            assert e.value.response["expected"] == 1
            assert c.call(op="tick", job=job, round=1, xb=xb)["round"] == 1


def test_client_retries_through_dropped_responses():
    """Fault-injected connection drops lose responses after execution; the
    retrying client reconnects, resends the same round, and the idempotency
    cache answers — the feedback stream lands exactly once."""
    plan = FaultPlan(drop_responses=(3, 5))
    srv = _sync_server(faults=plan)
    with srv:
        with ServeClient.connect(srv.address, retries=4, seed=0) as c:
            job = c.admit(K=32, k=4, seed=1)  # response 0; ticks follow
            got = [c.tick(job, bits=np.ones(32))["cohort"] for _ in range(8)]
    ref = SlotEngine(K_max=32, k_cap=4, buckets=(4,))
    u = ref.admit(JobSpec(K=32, k=4, seed=1))
    want = [ref.tick([(u, np.zeros(32, np.int32))])[u]["cohort"] for _ in range(8)]
    assert got == want
    assert plan.fired()["drop"] == 2
    assert srv.stats["replayed"] == 2 and srv.stats["ticks"] == 8


def test_numerics_guard_refuses_update():
    """A non-finite selector update is refused inside the compiled step
    (donation makes host-side rollback impossible): the request fails with
    ``numerics``, the round cursor does not advance, an alert is raised."""
    with _sync_server() as srv:
        with ServeClient.connect(srv.address) as c:
            job = c.admit(K=32, k=4, seed=1)
            c.tick(job, bits=np.ones(32))
            slot = srv.engine.jobs[job]["slot"]
            srv.engine.state = srv.engine.state._replace(
                logw=srv.engine.state.logw.at[slot, 0].set(np.nan)
            )
            with pytest.raises(ServeError) as e:
                c.tick(job, bits=np.ones(32))
            assert e.value.code == "numerics"
            stats = c.stats()["stats"]
            assert stats["numerics"] == 1
        assert srv.engine.job_round(job) == 1  # cursor did not advance
    assert any(a.rule == "numerics" for a in srv.alerts)


def test_close_surfaces_hung_engine():
    """A join that outlives stop_timeout is reported (``hung_engine`` stat),
    not silently leaked."""
    srv = _sync_server(stop_timeout=0.3)
    gate = threading.Event()
    real_tick = srv.engine.tick

    def stuck(items):
        gate.wait(30.0)
        return real_tick(items)

    srv.engine.tick = stuck
    srv.start()
    c = ServeClient.connect(srv.address)
    job = c.admit(K=32, k=4, seed=1)

    def one():
        try:
            c.tick(job, bits=np.ones(32))
        except (ServeError, protocol.ProtocolError, OSError):
            pass

    t = threading.Thread(target=one)
    t.start()
    time.sleep(0.3)  # let the engine thread block inside the tick
    srv.close(checkpoint=False)
    assert srv.stats["hung_engine"] == 1
    gate.set()
    t.join(timeout=10.0)
    c.close()


# ---------------------------------------------------------------------------
# supervised recovery
# ---------------------------------------------------------------------------


def _drive_with_replay(c, job, feed, *, rounds):
    """Round-cursor driver that survives retries, cache replay, and
    recovery rollback: on ``round_desync`` it rewinds to the server's
    expected round and replays the (deterministic) feedback from there."""
    got = {}
    t = 0
    while t < rounds:
        try:
            out = c.tick(job, lags=feed[t], round=t)
        except ServeError as e:
            if e.code == "round_desync":
                t = int(e.response["expected"])
                continue
            raise
        got[out["round"]] = out["cohort"]
        t = out["round"] + 1
    return [got[i] for i in range(rounds)]


def test_supervisor_restart_from_checkpoint(tmp_path):
    """A fault-injected engine crash: the supervisor restores the newest
    valid checkpoint, clients rewind on ``round_desync`` and replay — the
    full cohort stream is bit-identical to a fault-free run."""
    ROUNDS = 12
    plan = FaultPlan(crash_steps=(7,))
    rng = np.random.default_rng(3)
    feed = [_lags(rng, 32, S=0) for _ in range(ROUNDS)]

    ref = SlotEngine(K_max=32, k_cap=4, buckets=(4,))
    u = ref.admit(JobSpec(K=32, k=4, seed=9))
    want = [ref.tick([(u, f)])[u]["cohort"] for f in feed]

    srv = SelectionServer(
        SlotEngine(K_max=32, k_cap=4, buckets=(4,)),
        ckpt_dir=str(tmp_path), ckpt_every=3, faults=plan, restart_backoff=0.01,
    )
    with srv:
        with ServeClient.connect(srv.address, retries=6, seed=1) as c:
            job = c.admit(K=32, k=4, seed=9)
            got = _drive_with_replay(c, job, feed, rounds=ROUNDS)
            stats = c.stats()["stats"]
    assert got == want
    assert plan.fired()["crash"] == 1
    assert stats["restarts"] == 1
    assert stats["degraded"] == 0  # cleared by the first clean dispatch
    assert len(srv.recoveries) == 1
    assert any(a.rule == "engine_restart" for a in srv.alerts)
    assert srv.serve_series()["restarts"].sum() == 1


def test_restart_budget_exhaustion_answers_engine_down(tmp_path):
    """Past max_restarts the server stops restarting and answers
    ``engine_down`` instead of looping forever."""
    plan = FaultPlan(crash_steps=(0, 1, 2, 3))
    srv = SelectionServer(
        SlotEngine(K_max=32, k_cap=4, buckets=(4,)),
        ckpt_dir=str(tmp_path), faults=plan, max_restarts=2, restart_backoff=0.0,
    )
    with srv:
        with ServeClient.connect(srv.address, retries=8, seed=2) as c:
            job = c.admit(K=32, k=4, seed=1)
            with pytest.raises(ServeError) as e:
                _drive_with_replay(c, job, [_lags(np.random.default_rng(0), 32, S=0)], rounds=1)
            assert e.value.code in ("retry", "engine_down")
            with pytest.raises(ServeError) as e:
                c.call(op="tick", job=job, round=0,
                       xb=protocol.encode_bits(np.ones(32)))
            assert e.value.code == "engine_down"
    assert srv.stats["restarts"] == 3  # 2 allowed + the one that broke the budget


# ---------------------------------------------------------------------------
# acceptance: loopback client, 2 jobs, sharded-async engine, kill + restore
# ---------------------------------------------------------------------------


@needs8
def test_acceptance_sharded_async_kill_restore(tmp_path):
    """ROADMAP item 2's acceptance bar, end to end over the wire:

    admit 2 jobs into a D=8 sharded-async (S=2) server, drive >= 50 rounds
    through the compiled engine, checkpoint + kill mid-horizon, restore a
    fresh server from disk, finish the horizon — and every post-restore
    selection is bit-identical to an uninterrupted reference run.
    """
    ROUNDS, SPLIT = 52, 26
    rng = np.random.default_rng(7)
    specs = [
        dict(K=64, k=8, rounds=ROUNDS, seed=17),
        dict(K=48, k=4, rounds=ROUNDS, seed=23),
    ]
    feed = [[_lags(rng, s["K"]) for _ in range(ROUNDS)] for s in specs]

    # uninterrupted reference, same backend, straight through the engine
    ref = ShardedEngine(D=8, staleness=2)
    ruid = [ref.admit(JobSpec(**s)) for s in specs]
    want = [ref.tick([(u, f[t]) for u, f in zip(ruid, feed)]) for t in range(ROUNDS)]

    ckpt_dir = str(tmp_path / "ckpt")
    srv = SelectionServer(ShardedEngine(D=8, staleness=2), ckpt_dir=ckpt_dir)
    got = {0: [], 1: []}
    with srv:
        c = ServeClient.connect(srv.address)
        jobs = [c.admit(**s) for s in specs]
        for t in range(SPLIT):
            for i, j in enumerate(jobs):
                out = c.tick(j, lags=feed[i][t])
                got[i].append((out["round"], out["cohort"]))
        c.checkpoint()
        c.close()
        srv.kill()  # crash: no drain, no extra checkpoint

    stem = latest_server_checkpoint(ckpt_dir)
    assert stem is not None
    engine, step = load_server(stem)
    assert step == 2 * SPLIT
    with SelectionServer(engine, ckpt_dir=ckpt_dir) as srv2:
        c = ServeClient.connect(srv2.address)
        for t in range(SPLIT, ROUNDS):
            for i, j in enumerate(jobs):
                out = c.tick(j, lags=feed[i][t])
                got[i].append((out["round"], out["cohort"]))
        c.close()

    for i, u in enumerate(ruid):
        assert [r for r, _ in got[i]] == list(range(ROUNDS))
        for t in range(ROUNDS):
            assert got[i][t][1] == want[t][u]["cohort"], f"job {i} diverged at round {t}"


@needs8
def test_acceptance_chaos_bit_identical(tmp_path):
    """ISSUE 9's acceptance bar: a seeded chaos schedule — ≥1 engine crash,
    ≥1 corrupted checkpoint stem, ≥2 dropped connections, a slow dispatch —
    against a 2-tenant sharded-async (D=8, S=2) horizon.  The horizon
    completes, recovery restores from the newest *valid* stem (the corrupt
    one is walked past), retrying clients rewind and replay on
    ``round_desync`` — and every selection is cohort-for-cohort
    bit-identical to a fault-free run.
    """
    ROUNDS = 30
    rng = np.random.default_rng(29)
    specs = [dict(K=64, k=8, rounds=ROUNDS, seed=31),
             dict(K=48, k=4, rounds=ROUNDS, seed=37)]
    feed = [[_lags(rng, s["K"]) for _ in range(ROUNDS)] for s in specs]

    # fault-free reference, straight through the engine
    ref = ShardedEngine(D=8, staleness=2)
    ruid = [ref.admit(JobSpec(**s)) for s in specs]
    want = [ref.tick([(u, f[t]) for u, f in zip(ruid, feed)]) for t in range(ROUNDS)]

    # sequential driver => 1 tick per dispatch: checkpoints land at rounds
    # 6/12/18/24 (write indices 0..3); corrupting index 3 kills the NEWEST
    # stem before the crash at dispatch 25, so recovery MUST walk back to
    # step 18 (not just reload the latest file)
    plan = FaultPlan(
        crash_steps=(25,), corrupt_checkpoints=(3,), drop_responses=(12, 31),
        slow_steps={5: 0.02},
    )
    ckpt_dir = str(tmp_path / "ckpt")
    srv = SelectionServer(
        ShardedEngine(D=8, staleness=2),
        ckpt_dir=ckpt_dir, ckpt_every=6, faults=plan, restart_backoff=0.01,
    )
    with srv:
        with ServeClient.connect(srv.address, retries=6, seed=5) as c:
            jobs = [c.admit(**s) for s in specs]
            cursors = {i: 0 for i in range(len(jobs))}
            got = {i: {} for i in range(len(jobs))}
            while any(t < ROUNDS for t in cursors.values()):
                for i, j in enumerate(jobs):
                    t = cursors[i]
                    if t >= ROUNDS:
                        continue
                    try:
                        out = c.tick(j, lags=feed[i][t], round=t)
                    except ServeError as e:
                        if e.code == "round_desync":
                            cursors[i] = int(e.response["expected"])
                            continue
                        raise
                    got[i][out["round"]] = out["cohort"]
                    cursors[i] = out["round"] + 1
            stats = c.stats()["stats"]

    # the schedule really ran
    fired = plan.fired()
    assert fired["crash"] == 1 and fired["corrupt"] == 1
    assert fired["drop"] == 2 and fired["slow"] == 1
    assert stats["restarts"] == 1 and stats["replayed"] >= 1
    # recovery walked back PAST the corrupt step-24 stem (the newest at
    # crash time) to step 18 — recorded in the restart alert; the corrupt
    # file itself is later overwritten by a valid post-replay checkpoint
    restart = [a for a in srv.alerts if a.rule == "engine_restart"]
    assert len(restart) == 1
    assert restart[0].detail["restored_step"] == 18
    assert restart[0].detail["checkpoint"].endswith("ckpt_00000018")
    assert srv.serve_series()["restarts"].sum() == 1
    assert srv.serve_series()["recovery_s"].sum() > 0

    # and the horizon is cohort-for-cohort bit-identical to the clean run
    for i, u in enumerate(ruid):
        assert sorted(got[i]) == list(range(ROUNDS))
        for t in range(ROUNDS):
            assert got[i][t] == want[t][u]["cohort"], f"job {i} diverged at round {t}"
