"""RoundProgram tests: the bit-identity matrix against pre-refactor goldens,
the new compositions (sharded async, packed-lag replay, late-credit
feedback), and the single knob-resolution path (`from_config`).

The goldens in ``tests/golden/round_program_goldens.npz`` were captured from
the engines as they stood before the PR-5 unification (see
``tests/golden/gen_goldens.py``); every cell here replays the identical
configuration through the unified ``RoundProgram`` and must reproduce them
bit-for-bit.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig
from repro.core.volatility import DEAD_LAG, BinaryLag, CompletionLag, make_volatility, paper_success_rates
from repro.engine.round_program import RoundProgram
from repro.engine.scan_sim import async_selection_sim, scan_selection_sim
from repro.engine.sharded import sharded_selection_sim
from repro.scenarios.replay import (
    ReplayLag,
    pack_trace,
    record_lag_trace,
    replay_packed_stream,
    save_packed_trace,
    unpack_lags,
)

K, k, T, SEED, FRAC = 128, 16, 50, 3, 0.5
GOLD = np.load(os.path.join(os.path.dirname(__file__), "golden", "round_program_goldens.npz"))


@pytest.fixture(scope="module")
def mesh8():
    from repro.launch.mesh import make_host_mesh

    if len(jax.devices()) < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8 (set in conftest)")
    return make_host_mesh(8)


@pytest.fixture(scope="module")
def mesh1():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(1)


def _rho():
    return paper_success_rates(K)


def _lag_model():
    return CompletionLag(
        make_volatility("bernoulli", _rho()), p_late=0.7, lag_decay=0.5, max_lag=2
    )


def _dense_xs():
    return np.random.default_rng(11).binomial(1, 0.6, (T, K)).astype(np.float32)


class TestSyncBitIdentityMatrix:
    """(S=None, D=1) == the pre-refactor scan engine; (S=None, D=8) == the
    pre-refactor sharded engine — for every scheme and observe source."""

    @pytest.mark.parametrize("scheme", ["e3cs", "random", "fedcs", "ucb", "pow_d"])
    def test_generated_d1(self, scheme):
        out = scan_selection_sim(scheme, K=K, k=k, T=T, frac=FRAC, seed=SEED)
        assert np.array_equal(pack_trace(out["masks"]), GOLD[f"sync_d1_{scheme}_masks"])
        assert np.array_equal(out["counts"], GOLD[f"sync_d1_{scheme}_counts"])

    def test_bisect_allocator_d1(self):
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, allocator="bisect")
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_e3cs_bisect_masks"])

    def test_dense_replay_d1(self):
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, xs_override=_dense_xs())
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_dense_masks"])

    def test_packed_replay_d1(self):
        packed = pack_trace(_dense_xs())
        out = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d1_packed_masks"])

    def test_streamed_replay_d1(self, tmp_path):
        path = save_packed_trace(str(tmp_path / "trace"), pack_trace(_dense_xs()), K)
        out = replay_packed_stream("e3cs", path, k, chunk=16, frac=FRAC, seed=SEED)
        assert np.array_equal(out["successes"], GOLD["sync_d1_streamed_successes"])
        assert np.array_equal(out["counts"], GOLD["sync_d1_streamed_counts"])

    @pytest.mark.parametrize("scheme", ["e3cs", "random"])
    def test_generated_d8(self, mesh8, scheme):
        out = sharded_selection_sim(scheme, mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED)
        assert np.array_equal(pack_trace(out["masks"]), GOLD[f"sync_d8_{scheme}_masks"])
        assert np.array_equal(out["counts"], GOLD[f"sync_d8_{scheme}_counts"])

    def test_packed_replay_d8(self, mesh8):
        packed = pack_trace(_dense_xs())
        out = sharded_selection_sim("e3cs", mesh8, K=K, k=k, T=T, frac=FRAC, seed=SEED, packed_override=packed)
        assert np.array_equal(pack_trace(out["masks"]), GOLD["sync_d8_packed_masks"])


class TestAsyncBitIdentityMatrix:
    """(S=2, D=1) == the pre-refactor async engine, generated and replayed."""

    @pytest.mark.parametrize("scheme", ["e3cs", "random", "ucb", "fedcs"])
    def test_generated_d1(self, scheme):
        out = async_selection_sim(
            scheme, K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5,
            lag_model=_lag_model(), rho=_rho(),
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD[f"async_d1_{scheme}_masks"])
        assert np.array_equal(out["lags"].astype(np.int8), GOLD[f"async_d1_{scheme}_lags"])
        assert np.array_equal(out["counts"], GOLD[f"async_d1_{scheme}_counts"])
        assert np.float32(out["cep"]) == GOLD[f"async_d1_{scheme}_cep"]
        assert np.array_equal(out["on_time"], GOLD[f"async_d1_{scheme}_on_time"])
        assert np.array_equal(out["stale"], GOLD[f"async_d1_{scheme}_stale"])

    def _replay_kw(self):
        return dict(K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2, alpha=0.5, rho=_rho())

    def test_replay_lag_model_d1(self):
        lp = GOLD["lag_trace_packed"]
        out = async_selection_sim("e3cs", lag_model=ReplayLag(jnp.asarray(lp), K), **self._replay_kw())
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_replay_masks"])
        assert np.float32(out["cep"]) == GOLD["async_d1_replay_cep"]

    def test_packed_lags_override_d1(self):
        # the new packed-lag *override* replays the identical rows bit-identically
        lp = GOLD["lag_trace_packed"]
        out = async_selection_sim(
            "e3cs", lag_model=_lag_model(), packed_lag_override=lp, **self._replay_kw()
        )
        assert np.array_equal(pack_trace(out["masks"]), GOLD["async_d1_replay_masks"])
        assert np.float32(out["cep"]) == GOLD["async_d1_replay_cep"]

    def test_dense_lag_replay_d1(self):
        # dense int32 lag rows streamed through the scan xs == the crumb path
        lp = GOLD["lag_trace_packed"]
        lags = unpack_lags(lp, K)
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC)
        program = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), override="dense", staleness=2, alpha=0.5)
        run, s0 = program.build_runner(outputs="full")
        _, masks, *_ = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lags, jnp.int32))
        assert np.array_equal(pack_trace(np.asarray(masks)), GOLD["async_d1_replay_masks"])

    def test_streamed_lag_replay_d1(self, tmp_path):
        lp = GOLD["lag_trace_packed"]
        path = save_packed_trace(str(tmp_path / "lags"), lp, K, kind="lags")
        out = replay_packed_stream("e3cs", path, k, chunk=16, frac=FRAC, seed=SEED)
        assert np.float32(out["cep"]) == GOLD["async_d1_replay_cep"]
        assert np.array_equal(out["counts"], GOLD["async_d1_replay_counts"])


class TestShardedAsync:
    """The previously-impossible composition: staleness ring sharded
    (S, K/D), 2-bit lag replay rows sharded along K."""

    def test_mesh1_bit_identical_to_unsharded(self, mesh1):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")
        pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh1)
        run, s0 = pm.build_runner(outputs="full")
        st, masks, lags, ps, sigmas, arrived = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        pl = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5)
        runl, s0l = pl.build_runner(outputs="full")
        stl, masksl, lagsl, psl, sigmasl, arrivedl = runl(
            s0l, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32)
        )
        assert np.array_equal(np.asarray(masks), np.asarray(masksl))
        assert np.array_equal(np.asarray(lags), np.asarray(lagsl))
        assert np.array_equal(np.asarray(arrived), np.asarray(arrivedl))
        assert float(st.cep) == float(stl.cep)
        np.testing.assert_array_equal(np.asarray(st.e3cs.logw), np.asarray(stl.e3cs.logw))

    def test_d8_generated_invariants(self, mesh8):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")
        pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh8)
        run, s0 = pm.build_runner(outputs="full")
        st, masks, lags, ps, sigmas, arrived = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))
        masks = np.asarray(masks)[:, :K]
        lags = np.asarray(lags)[:, :K]
        arrived = np.asarray(arrived)[:, :K]
        # exact cohort size every round, counts conserved
        np.testing.assert_array_equal(masks.sum(1), np.full(T, float(k)))
        np.testing.assert_array_equal(np.asarray(st.sel_counts)[:K], masks.sum(0))
        # the staleness-aware CEP decomposes into on-time + decayed late credit
        on_time = (masks * (lags == 0)).sum()
        stale = arrived.sum()
        assert stale > 0.0
        assert float(st.cep) == pytest.approx(on_time + stale, rel=1e-5)
        # every arriving credit is alpha**lag of a scheduled selection
        sched = sum(
            (masks[:-s] * (lags[:-s] == s) * 0.5**s).sum() for s in (1, 2) if T > s
        )
        assert arrived.sum() <= sched + 1e-4

    def test_d8_lean_matches_full(self, mesh8):
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")

        def go(outputs):
            pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5, mesh=mesh8)
            run, s0 = pm.build_runner(outputs=outputs)
            return run(s0, jax.random.PRNGKey(SEED), jnp.zeros((T, 0), jnp.float32))

        st_f, masks, lags, ps, sigmas, arrived = go("full")
        st_l, on_time, stale, sigmas_l = go("lean")
        np.testing.assert_array_equal(np.asarray(st_f.sel_counts), np.asarray(st_l.sel_counts))
        assert float(st_f.cep) == float(st_l.cep)
        masks, lags = np.asarray(masks), np.asarray(lags)
        np.testing.assert_allclose((masks * (lags == 0)).sum(1), np.asarray(on_time), atol=1e-4)
        np.testing.assert_allclose(np.asarray(arrived).sum(1), np.asarray(stale), atol=1e-4)

    def test_d8_lag_replay_random_matches_d1_bitwise(self, mesh8):
        # packed-lag replay draws no volatility randomness and the `random`
        # selector draws replicated, so D=8 must equal D=1 bit-for-bit
        lp = GOLD["lag_trace_packed"]
        fl = FLConfig(K=K, k=k, rounds=T, scheme="random", quota_frac=FRAC)
        outs = []
        for mesh in (None, mesh8):
            pm = RoundProgram(
                fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
                staleness=2, alpha=0.5, mesh=mesh,
            )
            run, s0 = pm.build_runner(outputs="full")
            st, masks, lags, *_ = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lp))
            outs.append((np.asarray(masks)[:, :K], np.asarray(lags)[:, :K], float(st.cep)))
        assert np.array_equal(outs[0][0], outs[1][0])
        assert np.array_equal(outs[0][1], outs[1][1])
        assert outs[0][2] == outs[1][2]

    def test_d8_chunked_equals_one_shot(self, mesh8):
        # carry_key threads the PRNG key and the sharded rings across chunks
        lp = GOLD["lag_trace_packed"]
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=FRAC, allocator="bisect")
        pm = RoundProgram(
            fl=fl, vol=_lag_model(), rho=_rho(), override="packed_lags",
            staleness=2, alpha=0.5, mesh=mesh8,
        )
        run, s0 = pm.build_runner(outputs="lean")
        st_ref, on_ref, stale_ref, _ = run(s0, jax.random.PRNGKey(SEED), jnp.asarray(lp))
        chunk = 25
        runc, s0c = pm.build_runner(outputs="lean", carry_key=True, scan_length=chunk)
        state, key, rings = s0c, jax.random.PRNGKey(SEED), pm.init_rings()  # (S, K_pad) via the mesh
        ons, stales = [], []
        for lo in range(0, T, chunk):
            state, key, rings, on, stale, _ = runc(state, key, rings, jnp.asarray(lp[lo : lo + chunk]))
            ons.append(np.asarray(on))
            stales.append(np.asarray(stale))
        assert np.array_equal(np.concatenate(ons), np.asarray(on_ref))
        assert np.array_equal(np.concatenate(stales), np.asarray(stale_ref))
        np.testing.assert_array_equal(np.asarray(state.sel_counts), np.asarray(st_ref.sel_counts))


class _FixedLag:
    """Deterministic lag schedule: row t of ``lags`` is returned verbatim."""

    def __init__(self, lags):
        self.lags = jnp.asarray(lags, jnp.int32)

    def init_state(self):
        return jnp.zeros((), jnp.int32)

    def sample(self, rng, state):
        return jax.lax.dynamic_index_in_dim(self.lags, state, keepdims=False), state + 1


class TestLateCreditFeedback:
    def test_s0_and_binary_lag_equal_deadline(self):
        # no late arrivals ever -> the feedback ring stays empty -> identical
        rho = _rho()
        base = lambda: BinaryLag(make_volatility("bernoulli", rho))  # noqa: E731
        a = async_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2,
            lag_model=base(), rho=rho, feedback="late_credit",
        )
        b = async_selection_sim(
            "e3cs", K=K, k=k, T=T, frac=FRAC, seed=SEED, staleness=2,
            lag_model=base(), rho=rho, feedback="deadline",
        )
        assert np.array_equal(a["masks"], b["masks"])
        np.testing.assert_array_equal(a["final_logw"], b["final_logw"])

    def test_hand_computed_feedback_step(self):
        # K=4, k=2, sigma=0, uniform weights: p = 0.5 each, no capping.
        # Round 0: the two selected clients complete 1 round late; everyone
        # observed x=0, so deadline feedback never moves logw.  Late-credit
        # applies step = min(residual*eta*credit/p/K, 1) = (2*0.5*(0.5/0.5))/4
        # = 0.25 to the selected pair at round 1, then re-centers: final logw
        # is 0 on the selected pair and -0.25 elsewhere — exactly.
        lags = [[1, 1, 1, 1], [DEAD_LAG] * 4]
        fl = FLConfig(K=4, k=2, rounds=2, scheme="e3cs", quota_frac=0.0)
        pm = RoundProgram(fl=fl, vol=_FixedLag(lags), rho=paper_success_rates(4),
                          staleness=2, alpha=0.5, feedback="late_credit")
        run, s0 = pm.build_runner(outputs="full")
        st, masks, *_ = run(s0, jax.random.PRNGKey(0), jnp.zeros((2, 0), jnp.float32))
        sel = np.asarray(masks)[0]  # round-0 cohort
        expect = np.where(sel > 0, 0.0, -0.25).astype(np.float32)
        np.testing.assert_array_equal(np.asarray(st.e3cs.logw), expect)
        # deadline feedback leaves the weights untouched
        pm_d = RoundProgram(fl=fl, vol=_FixedLag(lags), rho=paper_success_rates(4),
                            staleness=2, alpha=0.5)
        run_d, s0_d = pm_d.build_runner(outputs="full")
        st_d, *_ = run_d(s0_d, jax.random.PRNGKey(0), jnp.zeros((2, 0), jnp.float32))
        np.testing.assert_array_equal(np.asarray(st_d.e3cs.logw), np.zeros(4, np.float32))

    def test_late_credit_moves_estimator_and_fairness(self):
        rho = _rho()
        kw = dict(K=K, k=k, T=200, frac=FRAC, seed=SEED, staleness=2, alpha=0.5, rho=rho)
        a = async_selection_sim("e3cs", lag_model=_lag_model(), feedback="deadline", **kw)
        b = async_selection_sim("e3cs", lag_model=_lag_model(), feedback="late_credit", **kw)
        assert np.abs(a["final_logw"] - b["final_logw"]).max() > 0.01

    def test_sharded_late_credit_runs(self, mesh8):
        fl = FLConfig(K=K, k=k, rounds=30, scheme="e3cs", quota_frac=FRAC, allocator="bisect")
        pm = RoundProgram(fl=fl, vol=_lag_model(), rho=_rho(), staleness=2, alpha=0.5,
                          feedback="late_credit", mesh=mesh8)
        run, s0 = pm.build_runner(outputs="lean")
        st, on_time, stale, _ = run(s0, jax.random.PRNGKey(SEED), jnp.zeros((30, 0), jnp.float32))
        assert float(np.asarray(st.sel_counts).sum()) == 30.0 * k
        assert float(stale.sum()) > 0

    def test_harness_late_credit_columns(self):
        from repro.scenarios.harness import evaluate_cell, format_grid

        row = evaluate_cell("e3cs", "paper_iid", K=40, k=8, T=60, staleness=2, feedback="late_credit")
        for col in ("lc_cep", "lc_eff", "lc_jain", "lc_drift", "async_jain"):
            assert col in row, col
        table = format_grid([row])
        assert "lc_cep" in table and "lc_drift" in table


class TestFromConfigResolution:
    """The knob-drift regression: every entry point resolves through ONE
    constructor, and the constructor resolves the knobs the documented way."""

    def test_async_knobs(self):
        fl = FLConfig(K=32, k=4, rounds=10, scheme="e3cs", staleness_rounds=3,
                      staleness_alpha=0.25, late_prob=0.9, lag_decay=0.3)
        pm = RoundProgram.from_config(fl)
        assert pm.staleness == 3 and pm.alpha == 0.25
        lm = pm.lag_model
        assert isinstance(lm, CompletionLag)
        assert lm.p_late == 0.9 and lm.lag_decay == 0.3 and lm.max_lag == 3
        assert pm.base_vol is lm.base

    def test_sync_knobs(self):
        pm = RoundProgram.from_config(FLConfig(K=32, k=4, rounds=10, volatility="markov"))
        assert pm.staleness is None and pm.lag_model is None
        assert type(pm.vol).__name__ == "MarkovVolatility"

    def test_mesh_forces_bisect_allocator(self, mesh1):
        pm = RoundProgram.from_config(FLConfig(K=32, k=4, rounds=10, allocator="sort"), mesh=mesh1)
        assert pm.fl.allocator == "bisect"

    def test_fl_server_routes_through_from_config(self):
        from repro.configs import get_config
        from repro.data import ClientStore, make_image_dataset, partition_primary_label
        from repro.fl import FLServer
        from repro.models import build_model

        cfg = get_config("emnist-cnn")
        fl = FLConfig(K=10, k=2, rounds=2, scheme="e3cs", samples_per_client=20,
                      batch_size=10, local_epochs=(1,), staleness_rounds=2, staleness_alpha=0.5)
        data = make_image_dataset(26, (28, 28, 1), 240, 60, seed=0)
        idxs = partition_primary_label(data["y"], fl.K, fl.samples_per_client, seed=0)
        srv = FLServer(build_model(cfg), fl, ClientStore(data, idxs))
        assert isinstance(srv.program, RoundProgram)
        assert srv.lag_model is srv.program.lag_model
        assert srv.staleness == 2 and srv.vol is srv.program.base_vol
        ref = RoundProgram.from_config(fl)
        assert type(srv.program.lag_model) is type(ref.lag_model)
        assert srv.program.lag_model.max_lag == ref.lag_model.max_lag == 2
        np.testing.assert_array_equal(np.asarray(srv.rho), np.asarray(ref.rho))

    def test_select_serve_sharded_async_smoke(self, mesh8):
        from repro.launch.select_serve import run_service_sharded

        rep = run_service_sharded(K=1024, rounds=8, D=8, k=16, seed=0, reps=1, staleness=2)
        assert rep["mode"] == "compiled_sharded_async"
        assert rep["staleness"] == 2
        assert rep["on_time_total"] > 0
        assert rep["stale_credit_total"] > 0

    def test_invalid_modes_raise(self):
        fl = FLConfig(K=8, k=2, rounds=4)
        vol = make_volatility("bernoulli", paper_success_rates(8))
        with pytest.raises(ValueError, match="packed_lags"):
            RoundProgram(fl=fl, vol=vol, rho=None, override="packed_lags")
        with pytest.raises(ValueError, match="packed_lags"):
            RoundProgram(fl=fl, vol=vol, rho=None, override="packed", staleness=2)
        with pytest.raises(ValueError, match="feedback"):
            RoundProgram(fl=fl, vol=vol, rho=None, feedback="nope")

    def test_record_lag_trace_roundtrip_through_override(self):
        # record -> pack -> override replay == model replay (whole pipeline)
        rho = paper_success_rates(32)
        lm = CompletionLag(make_volatility("markov", rho, stickiness=0.9), p_late=0.6, max_lag=2)
        lp = record_lag_trace(lm, 30, seed=9)
        a = async_selection_sim("e3cs", K=32, k=6, T=30, frac=0.5, seed=9, staleness=2,
                                lag_model=ReplayLag(jnp.asarray(lp), 32), rho=rho)
        b = async_selection_sim("e3cs", K=32, k=6, T=30, frac=0.5, seed=9, staleness=2,
                                lag_model=lm, packed_lag_override=lp, rho=rho)
        assert np.array_equal(a["masks"], b["masks"])
        assert a["cep"] == b["cep"]
