"""Fig. 7 / Appendix B — varying selection cardinality k in {10, 20, 30}."""
from __future__ import annotations

import time


from repro.core.sim import selection_sim

from .common import QUICK, emit, save_json


def run():
    T = 400 if QUICK else 2500
    out = {}
    for k in (10, 20, 30):
        for name, kw in [("E3CS-inc", dict(scheme="e3cs", quota="inc")), ("Random", dict(scheme="random"))]:
            t0 = time.perf_counter()
            sim = selection_sim(T=T, k=k, **kw)
            us = (time.perf_counter() - t0) / T * 1e6
            cep = float((sim["masks"] * sim["xs"]).sum())
            out[f"{name}_k{k}"] = {"cep": cep, "cep_per_slot": cep / (T * k)}
            emit(f"fig7/{name}_k{k}", us, f"cep={cep:.0f};per_slot={cep/(T*k):.3f}")
    save_json("fig7_cardinality", {"rounds": T, "results": out})
    return out


if __name__ == "__main__":
    run()
