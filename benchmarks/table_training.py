"""Tables II/III — real FL training: rounds-to-accuracy thresholds + final
accuracy per selection scheme, on the synthetic EMNIST-like and CIFAR-like
tasks (iid + non-iid, FedAvg and FedProx).

Quick mode (default on this CPU box) runs a reduced protocol: fewer rounds,
smaller shards, epochs {1,2}; the *qualitative* orderings the paper claims
are asserted in tests/test_system.py, while this benchmark records the
quantitative curves for EXPERIMENTS.md.  Full paper scale is `QUICK=0`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import FLConfig, get_config
from repro.data import ClientStore, make_image_dataset, partition_iid, partition_primary_label
from repro.fl import FLServer
from repro.models import build_model, cross_entropy

from .common import QUICK, emit, save_json

SCHEMES = [
    ("E3CS-0", dict(scheme="e3cs", quota="const", quota_frac=0.0)),
    ("E3CS-0.5", dict(scheme="e3cs", quota="const", quota_frac=0.5)),
    ("E3CS-inc", dict(scheme="e3cs", quota="inc")),
    ("FedCS", dict(scheme="fedcs")),
    ("Random", dict(scheme="random")),
    ("pow-d", dict(scheme="pow_d")),
]

TASKS = {
    "emnist": dict(classes=26, img=(28, 28, 1), cfg="emnist-cnn", thresholds=(0.3, 0.45, 0.6)),
    "cifar": dict(classes=10, img=(32, 32, 3), cfg="cifar-cnn", thresholds=(0.35, 0.45, 0.55)),
}


def _rounds_to(history, thr):
    for r, a in zip(history["round"], history["acc"]):
        if a >= thr:
            return r
    return None  # NaN in the paper's notation


def run_task(task: str, non_iid: bool, rounds: int, local_update: str = "fedavg", schemes=None):
    t = TASKS[task]
    cfg = get_config(t["cfg"])
    fl_base = dict(
        K=100, k=20, rounds=rounds, samples_per_client=60 if QUICK else 500,
        batch_size=20 if QUICK else 40, local_epochs=(1, 2) if QUICK else (1, 2, 3, 4),
        non_iid=non_iid, local_update=local_update, seed=0,
    )
    data = make_image_dataset(t["classes"], t["img"], 100 * fl_base["samples_per_client"] // 2, 3000, seed=0)
    part = partition_primary_label if non_iid else partition_iid
    idxs = part(data["y"], 100, fl_base["samples_per_client"], seed=0)
    store = ClientStore(data, idxs)
    model = build_model(cfg)

    def eval_fn(params):
        x, y = store.eval_batch(1500)
        logits = model.forward(params, {"x": jnp.asarray(x), "y": jnp.asarray(y)})
        return float((jnp.argmax(logits, -1) == jnp.asarray(y)).mean()), float(
            cross_entropy(logits, jnp.asarray(y))
        )

    out = {}
    for name, kw in schemes or SCHEMES:
        fl = FLConfig(**fl_base, **kw)
        srv = FLServer(model, fl, store, eval_fn)
        state = srv.init_state(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        state, hist = srv.run(state, eval_every=max(2, rounds // 20))
        wall = time.perf_counter() - t0
        row = {
            "final_acc": hist["acc"][-1],
            "cep": float(state.cep),
            "acc_curve": list(zip(hist["round"], [round(a, 4) for a in hist["acc"]])),
            "rounds_to": {str(th): _rounds_to(hist, th) for th in t["thresholds"]},
            "wall_s": round(wall, 1),
        }
        out[name] = row
        emit(
            f"table_{task}_{'noniid' if non_iid else 'iid'}_{local_update}/{name}",
            wall / rounds * 1e6,
            f"final={row['final_acc']:.3f};cep={row['cep']:.0f};r2a={row['rounds_to']}",
        )
    return out


def run():
    import json
    import os

    from .common import RESULTS

    cached = os.path.join(RESULTS, "BENCH_table_training.json")
    if not os.path.exists(cached) and os.path.exists(os.path.join(RESULTS, "table_training.json")):
        cached = os.path.join(RESULTS, "table_training.json")  # pre-rename cache (~2h to regenerate)
    if QUICK and os.path.exists(cached) and os.environ.get("REPRO_BENCH_FORCE") != "1":
        # real-training tables take ~2h on this 1-core box; the harness run
        # re-emits the cached result (delete the json / set FORCE to re-run)
        with open(cached) as f:
            results = json.load(f)
        for task, groups in results.items():
            for group, rows in groups.items():
                for name, row in rows.items():
                    emit(f"table_{task}_{group}/{name} (cached)", 0.0,
                         f"final={row['final_acc']:.3f};cep={row['cep']:.0f};r2a={row['rounds_to']}")
        return results
    rounds = 60 if QUICK else 400
    results = {}
    for task in ("emnist", "cifar"):
        results[task] = {
            "noniid_fedavg": run_task(task, True, rounds),
        }
        save_json("table_training", results)
        if not QUICK:
            results[task]["iid_fedavg"] = run_task(task, False, rounds)
            results[task]["noniid_fedprox"] = run_task(task, True, rounds, "fedprox")
            save_json("table_training", results)
    save_json("table_training", results)
    return results


if __name__ == "__main__":
    run()
