"""Engine-scale benchmark: scan simulator vs legacy loop, sharded (sort-free)
ProbAlloc vs the sorted baseline across K, and multi-job batching across J.

Rows (name,us_per_call,derived):
  engine/scan_sim            — compiled whole-horizon sim at K=100
  engine/loop_sim            — legacy per-round Python loop (baseline)
  engine/prob_alloc/K=...    — bisection allocator; derived carries the sorted
                               baseline time and (K <= 1e5) the max |p - ref|
                               error vs the paper's literal case enumeration
  engine/multi_job/J=...     — one batched dispatch vs J single dispatches

CLI:  python benchmarks/engine_scale.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, save_json, time_fn
except ImportError:  # running as a script: python benchmarks/engine_scale.py
    from common import emit, save_json, time_fn

from repro.core.selection import prob_alloc, prob_alloc_reference
from repro.core.sim import selection_sim, selection_sim_loop
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs
from repro.engine.sharded import prob_alloc_sharded


def bench_sim(T: int, out: dict):
    t0 = time.perf_counter()
    selection_sim("e3cs", K=100, k=20, T=T, frac=0.5, backend="scan")  # compile + run
    scan_total = time.perf_counter() - t0
    t0 = time.perf_counter()
    selection_sim("e3cs", K=100, k=20, T=T, frac=0.5, backend="scan")  # steady state
    scan_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    selection_sim_loop("e3cs", K=100, k=20, T=T, frac=0.5)
    loop_s = time.perf_counter() - t0
    speedup = loop_s / scan_s
    out["sim"] = {
        "T": T, "scan_s": scan_s, "scan_with_compile_s": scan_total, "loop_s": loop_s,
        "speedup": speedup, "scan_rounds_per_s": T / scan_s,
    }
    emit("engine/scan_sim", scan_s / T * 1e6, f"T={T};speedup_vs_loop={speedup:.1f}x")
    emit("engine/loop_sim", loop_s / T * 1e6, f"T={T}")
    return speedup


def bench_prob_alloc(K_list, out: dict):
    rng = np.random.default_rng(0)
    rows = {}
    for K in K_list:
        k = max(1, K // 50)
        sigma = 0.5 * k / K
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))  # heavy tail => capping
        sorted_jit = jax.jit(prob_alloc, static_argnums=(1,))  # fair compiled baseline
        us_shard = time_fn(lambda: jax.block_until_ready(prob_alloc_sharded(w, k, sigma)[0]))
        us_sorted = time_fn(lambda: jax.block_until_ready(sorted_jit(w, k, sigma)[0]))
        derived = f"sorted_us={us_sorted:.1f}"
        err = None
        if K <= 100_000:  # the python reference enumerates K cases; skip at 1e6
            p, capped = prob_alloc_sharded(w, k, sigma)
            pr, cr = prob_alloc_reference(np.asarray(w), k, sigma)
            err = float(np.abs(np.asarray(p) - pr).max())
            derived += f";max_err_vs_ref={err:.2e};capped_match={bool((np.asarray(capped) == cr).all())}"
        rows[K] = {"k": k, "sharded_us": us_shard, "sorted_us": us_sorted, "max_err_vs_ref": err}
        emit(f"engine/prob_alloc/K={K}", us_shard, derived)
    out["prob_alloc"] = rows


def bench_multi_job(J_list, K: int, out: dict):
    rng = np.random.default_rng(1)
    rows = {}
    for J in J_list:
        Ks = [K] * J
        ks = [max(4, K // 50)] * J
        cfg, k_max = pack_jobs(Ks, ks, [0.5] * J, [0.5] * J)
        job_step, batched = make_multi_job(k_max)
        state = multi_job_init(cfg)
        keys = jax.random.split(jax.random.PRNGKey(0), J)
        xs = jnp.asarray((rng.random((J, K)) < 0.6).astype(np.float32))
        us_batched = time_fn(lambda: jax.block_until_ready(batched(cfg, state, keys, xs)[0].logw))
        single = jax.jit(job_step)
        row0 = jax.tree.map(lambda a: a[0], cfg)
        us_single = time_fn(lambda: jax.block_until_ready(single(row0, state.logw[0], state.t[0], keys[0], xs[0])[0]))
        amortized = us_batched / J
        rows[J] = {"batched_us": us_batched, "single_us": us_single, "amortized_us_per_job": amortized}
        emit(f"engine/multi_job/J={J}", us_batched, f"K={K};single_us={us_single:.1f};per_job={amortized:.1f}")
    out["multi_job"] = rows


def run(smoke: bool = False):
    out = {}
    T = 300 if smoke else 2500
    K_list = [1_000, 10_000] if smoke else [1_000, 10_000, 100_000, 1_000_000]
    J_list = [1, 8] if smoke else [1, 8, 64]
    speedup = bench_sim(T, out)
    bench_prob_alloc(K_list, out)
    bench_multi_job(J_list, 1_000 if smoke else 10_000, out)
    save_json("engine_scale", out)
    if speedup < 5.0:
        print(f"engine_scale,0,WARN:scan_speedup_{speedup:.1f}x_below_5x", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced CPU/CI protocol")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    sys.path.insert(0, ".")
    main()
